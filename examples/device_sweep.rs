//! Device-design sweep: how much does task reordering buy as the device
//! changes? Sweeps (a) the number of DMA engines, (b) the duplex
//! contention factor sigma, and (c) CKE tail overlap, reporting the
//! heuristic's improvement over the mean and worst orderings on the
//! temporal model. This is the ablation behind the paper's observation
//! that overlap opportunities (hence reordering wins) depend on the
//! engine topology.
//!
//! Run with: `cargo run --release --example device_sweep`

use oclcc::config::profile_by_name;
use oclcc::model::simulator::makespan_of_order;
use oclcc::model::EngineState;
use oclcc::sched::bruteforce::OrderStats;
use oclcc::sched::heuristic::batch_reorder;
use oclcc::task::real::real_benchmark;
use oclcc::util::rng::Pcg64;
use oclcc::util::stats;
use oclcc::util::table::{f, Table};

fn main() -> anyhow::Result<()> {
    let base = profile_by_name("amd_r9")?;
    let mut table = Table::new(&[
        "variant", "DMA", "sigma", "heuristic x (gm)", "max x (gm)",
    ]);

    let variants = vec![
        ("1 DMA engine", 1u8, 1.0),
        ("2 DMA, sigma 1.0 (ideal duplex)", 2, 1.0),
        ("2 DMA, sigma 1.18 (measured R9)", 2, 1.18),
        ("2 DMA, sigma 1.5 (congested)", 2, 1.5),
        ("2 DMA, sigma 2.0 (serial-ish)", 2, 2.0),
    ];
    for (name, dma, sigma) in variants {
        let mut p = base.clone();
        p.name = format!("sweep-{dma}-{sigma}");
        p.dma_engines = dma;
        p.duplex_slowdown = sigma;
        let mut heus = Vec::new();
        let mut maxes = Vec::new();
        for trial in 0..8 {
            let mut rng = Pcg64::seeded(100 + trial);
            let g = real_benchmark("BK50", "amd_r9", &p, 5, &mut rng, 1.0)?;
            let st = OrderStats::exhaustive(&g.tasks, &p, 120, &mut rng);
            let order = batch_reorder(&g.tasks, &p, EngineState::default());
            let h = makespan_of_order(&g.tasks, &order, &p);
            heus.push(st.worst / h);
            maxes.push(st.worst / st.best);
        }
        table.row(vec![
            name.to_string(),
            dma.to_string(),
            f(sigma, 2),
            f(stats::geomean(&heus), 3),
            f(stats::geomean(&maxes), 3),
        ]);
    }
    println!("Reordering win vs device topology (BK50 real mix, T=5):");
    table.print();
    println!(
        "Expected shape: 2 DMA engines with good duplex (low sigma) give the\n\
         largest reordering headroom; a single engine (Phi-like) compresses\n\
         the spread between best and worst orders."
    );
    Ok(())
}
