//! END-TO-END DRIVER — proves all layers compose on a real workload:
//!
//!   L1/L2 (build time): nine Pallas/JAX kernels AOT-lowered to HLO text
//!   runtime:            artifacts compiled + executed on the PJRT CPU client
//!   device:             virtual accelerator paces transfers, executes real kernels
//!   coordinator:        multi-worker proxy with the Batch Reordering heuristic
//!
//! Workload: a Poisson trace of mixed real tasks (MM / BS / FWT / FLW /
//! CONV / VA / MT / DCT at several data sizes) submitted by T workers.
//! Kernel durations are *measured* (Eq. 1 profiling pass), transfers sized
//! from the artifact manifest. The headline metric is the paper's: tasks
//! throughput and makespan, NoReorder vs Heuristic.
//!
//! Requires artifacts: `make artifacts` first.
//! Run with: `cargo run --release --example e2e_trace`

use std::sync::Arc;

use oclcc::config::profile_by_name;
use oclcc::coordinator::{DriverBuilder, LaneOptions, Policy};
use oclcc::device::{Device, VirtualDevice};
use oclcc::runtime::manifest::default_artifact_dir;
use oclcc::runtime::{PjrtExecutor, PjrtService};
use oclcc::task::{KernelSpec, TaskSpec};
use oclcc::util::rng::Pcg64;
use oclcc::util::stats;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let t_workers: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let n_tasks: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(6);

    // ---- 1. Runtime + profiling pass (Eq. 1 measurements) --------------
    let artifact_dir = default_artifact_dir();
    let service = PjrtService::start(artifact_dir.clone())?;
    println!(
        "PJRT platform: {} | artifacts: {}",
        service.platform()?,
        artifact_dir.display()
    );
    let manifest = oclcc::runtime::Manifest::load(&artifact_dir)?;
    let mut variant_secs = std::collections::BTreeMap::new();
    println!("profiling {} artifact variants (3 reps each)...", manifest.variants.len());
    for name in manifest.variants.keys() {
        service.warmup(name)?;
        let mut samples = Vec::new();
        for _ in 0..3 {
            samples.push(service.execute(name)?.exec_secs);
        }
        variant_secs.insert(name.clone(), stats::median(&samples));
    }

    // ---- 2. Build the trace: T workers x N tasks, random variants ------
    // Keep variants whose measured kernel time is inside the paper's task
    // envelope (Table 5 tops out at ~15 ms): the largest-buffer variants
    // pay PJRT literal-copy overhead that makes any group compute-bound
    // and ordering moot.
    let profile = profile_by_name("cpu_live")?;
    let mut rng = Pcg64::seeded(0xE2E);
    let names: Vec<&String> = manifest
        .variants
        .keys()
        .filter(|v| variant_secs[v.as_str()] <= 10e-3)
        .collect();
    println!(
        "catalog: {} of {} variants within the 10 ms kernel envelope",
        names.len(),
        manifest.variants.len()
    );
    let mk_task = |rng: &mut Pcg64| -> TaskSpec {
        let v = names[rng.below(names.len() as u64) as usize];
        let meta = manifest.get(v).unwrap();
        TaskSpec::simple(
            v,
            meta.htd_bytes,
            KernelSpec::Artifact { variant: v.clone(), est_secs: variant_secs[v.as_str()] },
            meta.dth_bytes,
        )
    };
    let batches: Vec<Vec<TaskSpec>> = (0..t_workers)
        .map(|_| (0..n_tasks).map(|_| mk_task(&mut rng)).collect())
        .collect();
    let total = t_workers * n_tasks;
    println!(
        "trace: {t_workers} workers x {n_tasks} tasks = {total} offloads, mixed variants"
    );

    // ---- 3. Run the full stack under both policies ---------------------
    // Median over several interleaved trials: PJRT-CPU kernel times share
    // this host's core(s) with the pacing threads, so single runs are
    // noisy — exactly like timing on a busy real machine.
    let trials: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(5);
    let device: Arc<dyn Device> = Arc::new(VirtualDevice::new(
        profile.clone(),
        Arc::new(PjrtExecutor::new(service.clone())),
    ));
    let mut walls = [Vec::new(), Vec::new()];
    let mut last_metrics = Vec::new();
    for trial in 0..trials {
        last_metrics.clear();
        for (i, policy) in [Policy::NoReorder, Policy::Heuristic].iter().enumerate() {
            // Same stack, one entrypoint: the Driver façade builds the
            // lane coordinator and returns the unified report.
            let driver = DriverBuilder::lanes(LaneOptions {
                policy: *policy,
                ..LaneOptions::default()
            })
            .device(device.clone())
            .build()?;
            let m = driver.run(batches.clone()).metrics;
            walls[i].push(m.total_secs);
            if trial == trials - 1 {
                println!(
                    "\n{policy:?} (trial {trial}):\n  wall {:.1} ms | throughput {:.1} tasks/s\n  mean latency {:.2} ms | p95 {:.2} ms\n  {} task groups | sched overhead {:.3} ms ({:.3}% of device time)",
                    m.total_secs * 1e3,
                    m.tasks_per_sec,
                    m.mean_latency() * 1e3,
                    stats::percentile(&m.latencies, 95.0) * 1e3,
                    m.n_groups,
                    m.sched_overhead_secs * 1e3,
                    100.0 * m.sched_overhead_secs
                        / m.group_makespans.iter().sum::<f64>().max(1e-12),
                );
            }
            last_metrics.push(m);
        }
    }
    let no = stats::median(&walls[0]);
    let heu = stats::median(&walls[1]);
    println!(
        "\n=> medians over {trials} trials: NoReorder {:.1} ms, Heuristic {:.1} ms",
        no * 1e3,
        heu * 1e3
    );
    println!(
        "=> heuristic end-to-end speedup {:.3}x, throughput {:.1} -> {:.1} tasks/s \
         (record in EXPERIMENTS.md)",
        no / heu,
        total as f64 / no,
        total as f64 / heu
    );
    service.shutdown();
    Ok(())
}
