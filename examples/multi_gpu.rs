//! Multi-accelerator scheduling (the paper's future-work section):
//! place a task group across heterogeneous devices with the temporal
//! model, reorder per device with the Batch Reordering heuristic, and
//! compare against round-robin placement.
//!
//! Run with: `cargo run --release --example multi_gpu`

use oclcc::config::profile_by_name;
use oclcc::sched::multidevice::{round_robin, schedule_multi};
use oclcc::task::real::real_benchmark;
use oclcc::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let profiles = vec![
        profile_by_name("amd_r9")?,
        profile_by_name("k20c")?,
        profile_by_name("xeon_phi")?,
    ];
    let catalog_dev = profile_by_name("amd_r9")?;
    let mut rng = Pcg64::seeded(2026);
    let g = real_benchmark("BK50", "amd_r9", &catalog_dev, 12, &mut rng, 1.0)?;
    println!(
        "12 mixed real tasks across {:?}",
        profiles.iter().map(|p| p.name.as_str()).collect::<Vec<_>>()
    );

    let rr = round_robin(&g.tasks, &profiles);
    let smart = schedule_multi(&g.tasks, &profiles);
    for (name, s) in [("round-robin", &rr), ("model-driven", &smart)] {
        println!("\n{name}: makespan {:.3} ms", s.makespan() * 1e3);
        for (dev, (order, m)) in
            s.orders.iter().zip(&s.device_makespans).enumerate()
        {
            println!(
                "  {:<9} {:.3} ms  {:?}",
                profiles[dev].name,
                m * 1e3,
                order
                    .iter()
                    .map(|&i| g.tasks[i].name.as_str())
                    .collect::<Vec<_>>()
            );
        }
    }
    println!(
        "\nmodel-driven placement + per-device reordering: {:.3}x vs round-robin",
        rr.makespan() / smart.makespan()
    );
    Ok(())
}
