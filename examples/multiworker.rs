//! Multi-worker serving scenario (paper §6.2, Fig. 8): T worker threads
//! each offload a batch of N dependent real tasks through the shared
//! buffer; the host proxy forms task groups and reorders them. Compares
//! NoReorder vs Heuristic policies end to end and reports tasks/s.
//!
//! Run with: `cargo run --release --example multiworker -- [T] [N]`

use std::sync::Arc;

use oclcc::config::profile_by_name;
use oclcc::coordinator::{Coordinator, Policy};
use oclcc::device::{SpinExecutor, VirtualDevice};
use oclcc::task::real::real_benchmark;
use oclcc::task::TaskSpec;
use oclcc::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let t: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(3);
    let device_name = "k20c";
    let profile = profile_by_name(device_name)?;
    let device = Arc::new(VirtualDevice::new(profile.clone(), Arc::new(SpinExecutor)));

    // Each worker draws its batch from the BK50 real-task mix (Table 5
    // ranges, random sizes) — scale 0.5 halves wall-clock.
    let mut rng = Pcg64::seeded(42);
    let all = real_benchmark("BK50", device_name, &profile, t * n, &mut rng, 0.5)?;
    let batches: Vec<Vec<TaskSpec>> = (0..t)
        .map(|w| (0..n).map(|r| all.tasks[w * n + r].clone()).collect())
        .collect();
    println!(
        "{t} workers x {n} dependent tasks on {device_name} (BK50 real mix)"
    );
    for (w, b) in batches.iter().enumerate() {
        println!(
            "  worker {w}: {:?}",
            b.iter().map(|t| t.name.as_str()).collect::<Vec<_>>()
        );
    }

    let mut base = 0.0;
    for policy in [Policy::NoReorder, Policy::Heuristic] {
        let coord = Coordinator::new(device.clone(), policy);
        let m = coord.run(batches.clone());
        println!(
            "\n{policy:?}:\n  wall {:.1} ms | {:.1} tasks/s | mean latency {:.2} ms\n  {} groups, device busy {:.1} ms, sched overhead {:.3} ms",
            m.total_secs * 1e3,
            m.tasks_per_sec,
            m.mean_latency() * 1e3,
            m.n_groups,
            m.group_makespans.iter().sum::<f64>() * 1e3,
            m.sched_overhead_secs * 1e3,
        );
        if policy == Policy::NoReorder {
            base = m.total_secs;
        } else {
            println!("  speedup vs NoReorder: {:.3}x", base / m.total_secs);
        }
    }
    Ok(())
}
