//! Quickstart: build a mixed task group, predict its execution with the
//! temporal model, find a near-optimal order with the Batch Reordering
//! heuristic, and verify the win on the virtual device.
//!
//! Run with: `cargo run --release --example quickstart`

use std::sync::Arc;

use oclcc::config::profile_by_name;
use oclcc::coordinator::{DriverBuilder, LaneOptions, Policy};
use oclcc::device::{Device, SpinExecutor, VirtualDevice};
use oclcc::model::timeline::Timeline;
use oclcc::model::{simulate, EngineState, SimOptions};
use oclcc::sched::bruteforce::OrderStats;
use oclcc::sched::heuristic::batch_reorder;
use oclcc::task::synthetic::synthetic_benchmark;
use oclcc::task::TaskSpec;
use oclcc::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    // 1. Pick a device profile (paper Table 1) and a benchmark (Table 3).
    let profile = profile_by_name("amd_r9")?;
    let group = synthetic_benchmark("BK25", &profile, 1.0)?;
    println!(
        "BK25 on {}: tasks {:?}",
        profile.name,
        group.tasks.iter().map(|t| t.name.as_str()).collect::<Vec<_>>()
    );

    // 2. Predict the submission order the workers happened to use...
    let fifo = simulate(
        &group.tasks,
        &profile,
        EngineState::default(),
        SimOptions { record_timeline: true },
    );
    println!("\nFIFO order (predicted):");
    print!("{}", Timeline(&fifo.timeline).gantt(64));

    // 3. ...then let the heuristic pick a near-optimal order.
    let order = batch_reorder(&group.tasks, &profile, EngineState::default());
    let reordered: Vec<TaskSpec> =
        order.iter().map(|&i| group.tasks[i].clone()).collect();
    let heur = simulate(
        &reordered,
        &profile,
        EngineState::default(),
        SimOptions { record_timeline: true },
    );
    println!(
        "\nHeuristic order {:?} (predicted):",
        order.iter().map(|&i| group.tasks[i].name.as_str()).collect::<Vec<_>>()
    );
    print!("{}", Timeline(&heur.timeline).gantt(64));

    // 4. Compare against the full permutation distribution (4! = 24).
    let mut rng = Pcg64::seeded(1);
    let st = OrderStats::exhaustive(&group.tasks, &profile, 24, &mut rng);
    println!(
        "\npermutations: best {:.3} ms | mean {:.3} | worst {:.3}",
        st.best * 1e3,
        st.mean * 1e3,
        st.worst * 1e3
    );
    println!(
        "heuristic:    {:.3} ms -> {:.3}x vs worst ({}% of best improvement)",
        heur.makespan * 1e3,
        st.worst / heur.makespan,
        (((st.worst - heur.makespan) / (st.worst - st.best)) * 100.0) as i32
    );

    // 5. Verify on the virtual device (real threads, paced transfers),
    //    going through the unified Driver façade — the same entrypoint
    //    the coordinators, the trace service and the CLI share.
    let device: Arc<dyn Device> =
        Arc::new(VirtualDevice::new(profile.clone(), Arc::new(SpinExecutor)));
    let driver = DriverBuilder::lanes(LaneOptions {
        policy: Policy::Heuristic,
        ..LaneOptions::default()
    })
    .device(device)
    .build()?;
    let report = driver.run(vec![group.tasks.clone()]);
    let measured: f64 = report.metrics.group_makespans.iter().sum();
    println!(
        "measured on virtual device ({} backend): {:.3} ms (prediction error {:.2}%)",
        report.backend,
        measured * 1e3,
        (measured - heur.makespan).abs() / measured * 100.0
    );
    Ok(())
}
