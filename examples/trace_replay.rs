//! Deterministic trace replay: parse the checked-in sample NDJSON trace,
//! replay it twice through the virtual-clock engine, and verify the two
//! runs are bit-identical — the contract `oclcc replay` is built on.
//!
//! Then replay the same trace under admission pressure (tiny per-tenant
//! cap, shed-lowest overflow) to show per-decision telemetry: every
//! accept / shed / group / done event is one JSON line.
//!
//! Run with: `cargo run --release --example trace_replay`

use oclcc::config::profile_by_name;
use oclcc::coordinator::{AdmissionOptions, DrainPolicyKind, Overflow};
use oclcc::trace::{parse_trace, replay, ReplayOptions};

const SAMPLE: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/traces/sample.ndjson");

fn main() -> anyhow::Result<()> {
    let text = std::fs::read_to_string(SAMPLE)?;
    let trace = parse_trace(&text)?;
    let n_tasks = trace
        .iter()
        .filter(|e| matches!(e, oclcc::trace::TraceIn::Task(_)))
        .count();
    println!("parsed {} events ({n_tasks} tasks) from {SAMPLE}", trace.len());

    // 1. Replay twice with identical options: bit-for-bit reproducible.
    let opts = ReplayOptions::single(profile_by_name("amd_r9")?);
    let a = replay(&trace, &opts)?;
    let b = replay(&trace, &opts)?;
    assert_eq!(a, b, "replay must be bit-identical for identical inputs");
    println!(
        "\nreplay on amd_r9: {} tasks in {} groups, makespan {:.3} ms",
        a.n_tasks,
        a.n_groups,
        a.makespan_s * 1e3
    );
    println!("completion order: {:?}", a.completion_order);
    for line in &a.events {
        println!("  {line}");
    }

    // 2. Same trace under admission pressure: per-tenant queue cap of 1,
    //    overflow evicts the lowest class. Shed decisions are events too.
    let strained = ReplayOptions {
        drain: DrainPolicyKind::StrictPriority,
        admission: Some(AdmissionOptions {
            per_tenant_cap: 1,
            overflow: Overflow::ShedLowest,
            ..AdmissionOptions::default()
        }),
        ..ReplayOptions::single(profile_by_name("amd_r9")?)
    };
    let s = replay(&trace, &strained)?;
    println!(
        "\nwith per_tenant_cap=1 + shed_lowest: {} ran, {} shed",
        s.n_tasks, s.n_shed
    );
    for line in s.events.iter().filter(|l| l.contains("\"shed\"")) {
        println!("  {line}");
    }
    Ok(())
}
