"""AOT pipeline: lower every L2 variant to HLO *text* + a JSON manifest.

Interchange is HLO text, NOT a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version the
published `xla` 0.1.6 crate links) rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py and DESIGN.md.

Usage (from python/):  python -m compile.aot --out ../artifacts
`make artifacts` is a no-op when artifacts are newer than their inputs.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(variant) -> str:
    lowered = jax.jit(variant.fn).lower(*variant.abstract_inputs())
    return to_hlo_text(lowered)


def manifest_entry(variant, hlo_file: str) -> dict:
    out_shapes = [
        list(o.shape)
        for o in jax.eval_shape(variant.fn, *variant.abstract_inputs())
    ]
    return {
        "name": variant.name,
        "kernel": variant.kernel,
        "file": hlo_file,
        "dominance": variant.dominance,
        "inputs": [{"shape": list(s), "dtype": "f32"} for s in variant.in_shapes],
        "outputs": [{"shape": s, "dtype": "f32"} for s in out_shapes],
        "htd_bytes": variant.htd_bytes,
        "dth_bytes": sum(4 * int(jax_numel(s)) for s in out_shapes),
    }


def jax_numel(shape):
    n = 1
    for d in shape:
        n *= int(d)
    return n


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--only", default=None,
        help="comma-separated variant names (default: all)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    names = list(model.VARIANTS) if args.only is None else args.only.split(",")
    manifest = {}
    for name in names:
        variant = model.VARIANTS[name]
        hlo_file = f"{name}.hlo.txt"
        text = lower_variant(variant)
        path = os.path.join(args.out, hlo_file)
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = manifest_entry(variant, hlo_file)
        print(f"  aot: {name:>10} -> {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"  aot: wrote manifest with {len(manifest)} variants")


if __name__ == "__main__":
    main()
