"""L1 — Pallas kernels for the eight real-benchmark tasks (paper Table 4)
plus the synthetic kernel (paper Listing 1).

Every kernel is written with `pl.pallas_call(..., interpret=True)`: the CPU
PJRT plugin cannot execute Mosaic custom-calls, so interpret mode is the
correctness/lowering path (see DESIGN.md §Hardware-Adaptation). Pure-jnp
oracles live in `ref.py`; pytest compares them element-wise.
"""

from .matmul import matmul
from .black_scholes import black_scholes
from .fwt import fwt
from .floyd_warshall import floyd_warshall
from .conv_sep import conv_sep
from .vecadd import vecadd
from .transpose import transpose
from .dct import dct8x8
from .synthetic import synthetic

__all__ = [
    "matmul",
    "black_scholes",
    "fwt",
    "floyd_warshall",
    "conv_sep",
    "vecadd",
    "transpose",
    "dct8x8",
    "synthetic",
]
