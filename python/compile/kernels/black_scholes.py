"""BS — Black-Scholes European option pricing (paper Table 4, dominant-kernel).

Element-wise kernel: each grid step prices a 1-D chunk of options held in
VMEM (3 input vectors + 2 output vectors per chunk; 8K-option chunks are
~160 KB of VMEM). The transcendental-heavy body maps onto the VPU; there is
no MXU work, matching the paper's classification of BS as compute-dominant
through sheer arithmetic intensity, not matmul shape.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_RISKFREE = 0.02
_VOLATILITY = 0.30
_INV_SQRT2 = 0.7071067811865476


def _erf(x):
    # Abramowitz & Stegun 7.1.26 rational approximation (|err| <= 1.5e-7).
    # Written out in basic ops: the xla_extension 0.5.1 HLO text parser the
    # Rust runtime links predates the dedicated `erf` opcode, so the kernel
    # must lower to add/mul/exp only.
    a = (0.254829592, -0.284496736, 1.421413741, -1.453152027, 1.061405429)
    p = 0.3275911
    sign = jnp.sign(x)
    ax = jnp.abs(x)
    t = 1.0 / (1.0 + p * ax)
    poly = t * (a[0] + t * (a[1] + t * (a[2] + t * (a[3] + t * a[4]))))
    return sign * (1.0 - poly * jnp.exp(-ax * ax))


def _cnd(d):
    # Standard normal CDF via the polynomial erf above.
    return 0.5 * (1.0 + _erf(d * _INV_SQRT2))


def _bs_kernel(price_ref, strike_ref, years_ref, call_ref, put_ref):
    s = price_ref[...]
    x = strike_ref[...]
    t = years_ref[...]
    sqrt_t = jnp.sqrt(t)
    d1 = (jnp.log(s / x) + (_RISKFREE + 0.5 * _VOLATILITY**2) * t) / (
        _VOLATILITY * sqrt_t
    )
    d2 = d1 - _VOLATILITY * sqrt_t
    expr = jnp.exp(-_RISKFREE * t)
    call = s * _cnd(d1) - x * expr * _cnd(d2)
    put = x * expr * _cnd(-d2) - s * _cnd(-d1)
    call_ref[...] = call
    put_ref[...] = put


@functools.partial(jax.jit, static_argnames=("chunk",))
def black_scholes(price, strike, years, *, chunk: int = 8192):
    """Price calls and puts for f32[N] option batches.

    Returns (call: f32[N], put: f32[N]). N must be divisible by ``chunk``
    (or smaller than it).
    """
    (n,) = price.shape
    chunk = min(chunk, n)
    assert n % chunk == 0, (n, chunk)
    grid = (n // chunk,)
    spec = pl.BlockSpec((chunk,), lambda i: (i,))
    return pl.pallas_call(
        _bs_kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), price.dtype),
            jax.ShapeDtypeStruct((n,), price.dtype),
        ],
        interpret=True,
    )(price, strike, years)
