"""CONV — separable 2-D convolution (paper Table 4, dominant-kernel).

Row pass then column pass with a static tap count, as in the OpenCL SDK
SeparableConvolution sample. Each grid step convolves one (bm + 2R, W + 2R)
halo row-band: the padded image stays in (interpreter-)VMEM and the band is
dynamically sliced per step, because overlapping halo reads cannot be
expressed with plain Blocked BlockSpecs. The taps are unrolled at trace time
so the body is a chain of shifted multiply-adds the VPU vectorizes cleanly.
VMEM per band: (bm + 2R) * (W + 2R) * 4 B — bm=64, R<=8, W<=1024 -> <=330 KB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv1d_valid(x, taps, axis):
    """'valid' 1-D correlation along ``axis`` with statically unrolled taps."""
    r = len(taps)
    n = x.shape[axis] - r + 1
    acc = None
    for i, t in enumerate(taps):
        sl = jax.lax.slice_in_dim(x, i, i + n, axis=axis)
        acc = sl * t if acc is None else acc + sl * t
    return acc


def _conv_kernel(x_ref, o_ref, *, taps, bm):
    i = pl.program_id(0)
    r = len(taps) // 2
    w2 = x_ref.shape[1]
    band = jax.lax.dynamic_slice(x_ref[...], (i * bm, 0), (bm + 2 * r, w2))
    y = _conv1d_valid(band, taps, axis=1)  # row pass
    o_ref[...] = _conv1d_valid(y, taps, axis=0)  # column pass


@functools.partial(jax.jit, static_argnames=("taps", "bm"))
def conv_sep(img, *, taps=(0.05, 0.1, 0.2, 0.3, 0.2, 0.1, 0.05), bm: int = 64):
    """Separable 2-D convolution of f32[H, W] with a symmetric tap vector.

    Uses zero ('same') padding; H must be divisible by ``bm``.
    """
    taps = tuple(float(t) for t in taps)
    r = len(taps) // 2
    h, w = img.shape
    bm = min(bm, h)
    assert h % bm == 0, (h, bm)
    padded = jnp.pad(img, ((r, r), (r, r)))
    return pl.pallas_call(
        functools.partial(_conv_kernel, taps=taps, bm=bm),
        grid=(h // bm,),
        in_specs=[
            pl.BlockSpec(padded.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), img.dtype),
        interpret=True,
    )(padded)
