"""DCT — 8x8 blocked discrete cosine transform (paper Table 4, DT/DK).

JPEG-style: the image is partitioned into 8x8 blocks and each block B is
replaced by D @ B @ D^T with the type-II DCT basis D. On TPU this is two
batched 8x8 matmuls per block — small MXU work per byte moved, which is why
the paper observes DCT flipping between dominant-transfer (R9/K20c) and
dominant-kernel (Xeon Phi). Each grid step transforms a (bm, W) row-band of
blocks in VMEM (bm=64, W<=1024 -> <=256 KB).
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dct_basis():
    d = [
        [
            math.sqrt((1.0 if k == 0 else 2.0) / 8.0)
            * math.cos((2 * n + 1) * k * math.pi / 16.0)
            for n in range(8)
        ]
        for k in range(8)
    ]
    return jnp.asarray(d, dtype=jnp.float32)


def _dct_kernel(x_ref, d_ref, o_ref):
    x = x_ref[...]
    bm, w = x.shape
    d = d_ref[...]
    # (bm//8, 8, w//8, 8) -> batched D @ B @ D^T over the two 8-axes.
    blocks = x.reshape(bm // 8, 8, w // 8, 8)
    y = jnp.einsum("ki,aibj,lj->akbl", d, blocks, d)
    o_ref[...] = y.reshape(bm, w)


@functools.partial(jax.jit, static_argnames=("bm",))
def dct8x8(img, *, bm: int = 64):
    """8x8 blocked type-II DCT of f32[H, W]; H, W divisible by 8, H % bm == 0."""
    h, w = img.shape
    bm = min(bm, h)
    assert h % 8 == 0 and w % 8 == 0 and h % bm == 0 and bm % 8 == 0
    return pl.pallas_call(
        _dct_kernel,
        grid=(h // bm,),
        in_specs=[
            pl.BlockSpec((bm, w), lambda i: (i, 0)),
            pl.BlockSpec((8, 8), lambda i: (0, 0)),  # basis, same every step
        ],
        out_specs=pl.BlockSpec((bm, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), img.dtype),
        interpret=True,
    )(img, _dct_basis())
