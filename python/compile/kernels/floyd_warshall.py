"""FLW — Floyd-Warshall all-pairs shortest paths (paper Table 4, dominant-kernel).

The OpenCL SDK version launches one NDRange kernel per pivot k; on TPU the
distance matrix (f32[n, n], n<=512 -> <=1 MB) stays resident in VMEM and a
`fori_loop` walks the pivots inside one kernel, so the n kernel launches and
their HBM round-trips collapse into a single invocation.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flw_kernel(d_ref, o_ref):
    d = d_ref[...]
    n = d.shape[0]

    def body(k, d):
        row = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=0)  # (1, n)
        col = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=1)  # (n, 1)
        return jnp.minimum(d, col + row)

    o_ref[...] = jax.lax.fori_loop(0, n, body, d)


@jax.jit
def floyd_warshall(dist):
    """All-pairs shortest paths over an f32[n, n] adjacency matrix.

    Missing edges should be encoded as a large finite value (not inf, to
    keep the arithmetic well-defined under +).
    """
    n, n2 = dist.shape
    assert n == n2, dist.shape
    return pl.pallas_call(
        _flw_kernel,
        out_shape=jax.ShapeDtypeStruct((n, n), dist.dtype),
        interpret=True,
    )(dist)
