"""FWT — Fast Walsh-Hadamard Transform (paper Table 4, DT/DK depending on device).

The OpenCL SDK version ping-pongs global buffers across log2(N) passes; on
TPU the natural mapping keeps the whole vector resident in VMEM (f32[2^k],
k<=20 fits in <=4 MB) and unrolls the butterfly stages at trace time, so a
single kernel invocation performs the full transform — the HBM<->VMEM
round-trips between passes disappear.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwt_stages(x):
    n = x.shape[-1]
    h = 1
    while h < n:
        x = x.reshape(-1, 2 * h)
        a = x[:, :h]
        b = x[:, h:]
        x = jnp.concatenate([a + b, a - b], axis=1)
        h *= 2
    return x.reshape(n)


def _fwt_kernel(x_ref, o_ref):
    o_ref[...] = _fwt_stages(x_ref[...])


@jax.jit
def fwt(x):
    """Walsh-Hadamard transform of f32[N], N a power of two."""
    (n,) = x.shape
    assert n & (n - 1) == 0, f"N={n} must be a power of two"
    return pl.pallas_call(
        _fwt_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(x)
