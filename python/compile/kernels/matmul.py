"""MM — block-tiled matrix multiplication (paper Table 4, dominant-kernel).

TPU adaptation of the OpenCL SDK MatrixMul NDRange kernel: instead of
work-group shared-memory tiles we tile for VMEM with `BlockSpec` and let the
MXU consume (bm, K) x (K, bn) panels. VMEM footprint per grid step is
bm*K + K*bn + bm*bn floats; with the default bm=bn=128 and K<=1024 that is
<=1.5 MB, comfortably inside the ~16 MB/core VMEM budget.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_kernel(x_ref, y_ref, o_ref):
    # One (bm, K) x (K, bn) panel product per grid step; the full K dimension
    # is resident so no cross-step accumulator is needed.
    o_ref[...] = jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul(x, y, *, bm: int = 128, bn: int = 128):
    """Compute ``x @ y`` with a VMEM-tiled Pallas kernel.

    Args:
      x: f32[M, K]; M must be divisible by ``bm``.
      y: f32[K, N]; N must be divisible by ``bn``.
    Returns:
      f32[M, N]
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = min(bm, m)
    bn = min(bn, n)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)
