"""Pure-jnp oracles for every L1 kernel — the CORE correctness signal.

Each function mirrors the public signature of its Pallas counterpart but is
written with stock jax.numpy only (no pallas), so pytest can compare the two
element-wise under `assert_allclose`.
"""

import math

import jax
import jax.numpy as jnp

_RISKFREE = 0.02
_VOLATILITY = 0.30


def matmul(x, y):
    return x @ y


def black_scholes(price, strike, years):
    sqrt_t = jnp.sqrt(years)
    d1 = (
        jnp.log(price / strike)
        + (_RISKFREE + 0.5 * _VOLATILITY**2) * years
    ) / (_VOLATILITY * sqrt_t)
    d2 = d1 - _VOLATILITY * sqrt_t
    cnd = lambda d: 0.5 * (1.0 + jax.lax.erf(d / jnp.sqrt(2.0)))
    expr = jnp.exp(-_RISKFREE * years)
    call = price * cnd(d1) - strike * expr * cnd(d2)
    put = strike * expr * cnd(-d2) - price * cnd(-d1)
    return call, put


def fwt(x):
    """O(N^2) Walsh-Hadamard via the explicit Hadamard matrix (natural order)."""
    n = x.shape[0]
    k = int(math.log2(n))
    h = jnp.asarray([[1.0]], dtype=x.dtype)
    for _ in range(k):
        h = jnp.block([[h, h], [h, -h]])
    return h @ x


def floyd_warshall(dist):
    n = dist.shape[0]
    d = dist
    for k in range(n):
        d = jnp.minimum(d, d[:, k : k + 1] + d[k : k + 1, :])
    return d


def conv_sep(img, taps=(0.05, 0.1, 0.2, 0.3, 0.2, 0.1, 0.05)):
    taps = jnp.asarray(taps, dtype=img.dtype)
    r = taps.shape[0] // 2
    padded = jnp.pad(img, ((r, r), (r, r)))
    # Row pass ('same' with zero padding): (h + 2r, w + 2r) -> (h + 2r, w).
    rows = sum(
        padded[:, i : i + img.shape[1]] * taps[i] for i in range(taps.shape[0])
    )
    # rows still carries the row halo; column pass consumes it.
    cols = sum(
        rows[i : i + img.shape[0], :] * taps[i] for i in range(taps.shape[0])
    )
    return cols


def vecadd(a, b):
    return a + b


def transpose(x):
    return x.T


def _dct_basis(dtype=jnp.float32):
    d = [
        [
            math.sqrt((1.0 if k == 0 else 2.0) / 8.0)
            * math.cos((2 * n + 1) * k * math.pi / 16.0)
            for n in range(8)
        ]
        for k in range(8)
    ]
    return jnp.asarray(d, dtype=dtype)


def dct8x8(img):
    h, w = img.shape
    d = _dct_basis(img.dtype)
    blocks = img.reshape(h // 8, 8, w // 8, 8)
    return jnp.einsum("ki,aibj,lj->akbl", d, blocks, d).reshape(h, w)


def synthetic(x, num_iterations=64, factor=1.0000001):
    # The oracle may use the closed form; only the Pallas kernel must burn
    # the iterations for real.
    return x * jnp.float32(factor) ** num_iterations
