"""Synthetic kernel (paper Listing 1): ``input[idx] *= factor`` repeated
``num_iterations`` times.

The paper uses this to dial kernel duration independently of transfer size:
the array size fixes HtD/DtH time, ``num_iterations`` fixes K time. The loop
must actually execute (a closed form ``x * factor**iters`` would be constant
time), so it is a `fori_loop` carried in VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _syn_kernel(x_ref, o_ref, *, num_iterations, factor):
    def body(_, v):
        return v * factor

    o_ref[...] = jax.lax.fori_loop(0, num_iterations, body, x_ref[...])


@functools.partial(jax.jit, static_argnames=("num_iterations", "factor", "chunk"))
def synthetic(x, *, num_iterations: int = 64, factor: float = 1.0000001,
              chunk: int = 65536):
    """Iteratively scale f32[N] in place ``num_iterations`` times."""
    (n,) = x.shape
    chunk = min(chunk, n)
    assert n % chunk == 0, (n, chunk)
    spec = pl.BlockSpec((chunk,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(
            _syn_kernel, num_iterations=num_iterations, factor=factor
        ),
        grid=(n // chunk,),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(x)
