"""MT — tiled matrix transposition (paper Table 4, dominant-transfer).

The OpenCL SDK version stages 16x16 tiles through shared memory to coalesce
both the load and the store; the TPU analogue stages (bm, bn) tiles through
VMEM with swapped output indexing, expressed entirely in the BlockSpec
index maps. VMEM per step: 2 * bm * bn * 4 B (128x128 tiles -> 128 KB).
"""

import functools

import jax
from jax.experimental import pallas as pl


def _mt_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def transpose(x, *, bm: int = 128, bn: int = 128):
    """Transpose f32[M, N] -> f32[N, M]; M % bm == 0, N % bn == 0."""
    m, n = x.shape
    bm = min(bm, m)
    bn = min(bn, n)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    return pl.pallas_call(
        _mt_kernel,
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        interpret=True,
    )(x)
