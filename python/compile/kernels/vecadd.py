"""VA — vector addition (paper Table 4, dominant-transfer).

The canonical bandwidth-bound task: two HtD streams in, one DtH stream out,
one add per element. Chunked so each grid step streams 3 * chunk * 4 B
through VMEM; compute is negligible, which is exactly why the paper
classifies VA as dominant-transfer on every device.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _va_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk",))
def vecadd(a, b, *, chunk: int = 65536):
    """Element-wise f32[N] + f32[N]; N divisible by ``chunk`` (or < chunk)."""
    (n,) = a.shape
    chunk = min(chunk, n)
    assert n % chunk == 0, (n, chunk)
    spec = pl.BlockSpec((chunk,), lambda i: (i,))
    return pl.pallas_call(
        _va_kernel,
        grid=(n // chunk,),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=True,
    )(a, b)
