"""L2 — task-level JAX compute graphs (paper Table 4 + Listing 1).

Each *task* in the paper is a HtD -> K -> DtH chain; this module defines the
K stage of every task as a jitted JAX function over explicit array inputs,
calling the L1 Pallas kernels. `VARIANTS` enumerates the (kernel x data-size)
grid the paper uses ("each task has been executed using several data sizes",
Table 5); `aot.py` lowers every variant to an HLO-text artifact the Rust
runtime executes via PJRT.

All dtypes are f32 so the Rust side needs a single literal builder.
"""

import dataclasses
import functools
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class Variant:
    """One AOT-compiled (kernel, size) point.

    Attributes:
      name: artifact stem, e.g. ``mm_256``.
      kernel: kernel family name (matches `kernels.__all__`).
      fn: the K-stage function; positional f32 array args only.
      ref_fn: pure-jnp oracle with the same signature.
      in_shapes: input shapes (all f32).
      n_outputs: number of outputs (lowered with return_tuple=True).
      dominance: 'DK' or 'DT' per paper Table 4 (device-independent label;
        DCT/FWT flip per device — we tag their *majority* class and the Rust
        task catalog re-derives dominance from measured times anyway).
    """

    name: str
    kernel: str
    fn: Callable
    ref_fn: Callable
    in_shapes: Tuple[Tuple[int, ...], ...]
    n_outputs: int
    dominance: str

    @property
    def htd_bytes(self) -> int:
        return sum(4 * _numel(s) for s in self.in_shapes)

    def example_inputs(self, seed: int = 0) -> Sequence[jax.Array]:
        """Deterministic, numerically safe inputs (positive, O(1) magnitude)."""
        keys = jax.random.split(jax.random.PRNGKey(seed), len(self.in_shapes))
        return [
            jax.random.uniform(k, s, jnp.float32, 0.5, 1.5)
            for k, s in zip(keys, self.in_shapes)
        ]

    def abstract_inputs(self):
        return [jax.ShapeDtypeStruct(s, jnp.float32) for s in self.in_shapes]


def _numel(shape):
    n = 1
    for d in shape:
        n *= d
    return n


def _tuple_fn(fn):
    """Wrap so every variant returns a tuple (uniform Rust-side unpacking)."""

    @functools.wraps(fn)
    def wrapped(*args):
        out = fn(*args)
        return out if isinstance(out, tuple) else (out,)

    return wrapped


def _bs(price, strike, years):
    return kernels.black_scholes(price, strike, years)


def _syn(iters):
    def fn(x):
        return kernels.synthetic(x, num_iterations=iters)

    def rf(x):
        return ref.synthetic(x, num_iterations=iters)

    return fn, rf


def _variants():
    v = []

    def add(name, kernel, fn, ref_fn, in_shapes, n_outputs, dom):
        v.append(
            Variant(
                name=name,
                kernel=kernel,
                fn=_tuple_fn(fn),
                ref_fn=_tuple_fn(ref_fn),
                in_shapes=tuple(tuple(s) for s in in_shapes),
                n_outputs=n_outputs,
                dominance=dom,
            )
        )

    # MM — dominant kernel.
    for n in (256, 384, 512):
        add(f"mm_{n}", "matmul", kernels.matmul, ref.matmul,
            [(n, n), (n, n)], 1, "DK")
    # BS — dominant kernel (arithmetic intensity).
    for n, tag in ((1 << 16, "64k"), (1 << 18, "256k")):
        add(f"bs_{tag}", "black_scholes", _bs, ref.black_scholes,
            [(n,), (n,), (n,)], 2, "DK")
    # FWT — DT/DK per device.
    for n, tag in ((1 << 14, "16k"), (1 << 16, "64k")):
        add(f"fwt_{tag}", "fwt", kernels.fwt, ref.fwt, [(n,)], 1, "DT")
    # FLW — dominant kernel (O(n^3) on O(n^2) bytes).
    for n in (128, 192):
        add(f"flw_{n}", "floyd_warshall", kernels.floyd_warshall,
            ref.floyd_warshall, [(n, n)], 1, "DK")
    # CONV — dominant kernel.
    for n in (512, 1024):
        add(f"conv_{n}", "conv_sep", kernels.conv_sep, ref.conv_sep,
            [(n, n)], 1, "DK")
    # VA — dominant transfer.
    for n, tag in ((1 << 18, "256k"), (1 << 20, "1m")):
        add(f"va_{tag}", "vecadd", kernels.vecadd, ref.vecadd,
            [(n,), (n,)], 1, "DT")
    # MT — dominant transfer.
    for n in (512, 1024):
        add(f"mt_{n}", "transpose", kernels.transpose, ref.transpose,
            [(n, n)], 1, "DT")
    # DCT — DT/DK per device.
    for n in (256, 512):
        add(f"dct_{n}", "dct8x8", kernels.dct8x8, ref.dct8x8, [(n, n)], 1, "DT")
    # Synthetic (Listing 1): array size fixes transfers, iters fixes K time.
    for iters in (16, 128, 1024):
        fn, rf = _syn(iters)
        add(f"syn_i{iters}", "synthetic", fn, rf, [(1 << 16,)], 1,
            "DT" if iters <= 16 else "DK")
    return {x.name: x for x in v}


VARIANTS = _variants()


def small_variants():
    """Cheap-to-execute subset used by interpret-mode pytest sweeps."""
    names = ["mm_256", "bs_64k", "fwt_16k", "flw_128", "conv_512",
             "va_256k", "mt_512", "dct_256", "syn_i16"]
    return {k: VARIANTS[k] for k in names}
