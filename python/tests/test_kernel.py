"""Pallas kernel vs pure-jnp oracle — the CORE correctness signal.

Every L1 kernel is compared element-wise against `kernels.ref` on the small
variant grid, plus targeted shape/edge cases per kernel.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import kernels, model
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def _rand(key, shape, lo=0.5, hi=1.5):
    return jax.random.uniform(key, shape, jnp.float32, lo, hi)


@pytest.mark.parametrize("name", sorted(model.small_variants()))
def test_variant_matches_ref(name):
    v = model.VARIANTS[name]
    inputs = v.example_inputs(seed=42)
    got = v.fn(*inputs)
    want = v.ref_fn(*inputs)
    assert len(got) == len(want) == v.n_outputs
    # f32: butterfly vs dense-matmul orderings differ by O(log n) roundings.
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=6e-3, atol=1e-2)


@pytest.mark.parametrize("m,k,n,bm,bn", [
    (128, 128, 128, 128, 128),   # single block
    (256, 128, 256, 128, 128),   # 2x2 grid
    (256, 64, 128, 64, 32),      # non-square blocks
    (64, 256, 64, 64, 64),       # deep K
])
def test_matmul_shapes(m, k, n, bm, bn):
    kx, ky = jax.random.split(jax.random.PRNGKey(m * n))
    x = _rand(kx, (m, k), -1.0, 1.0)
    y = _rand(ky, (k, n), -1.0, 1.0)
    got = kernels.matmul(x, y, bm=bm, bn=bn)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ y),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n", [2, 8, 64, 1024, 4096])
def test_fwt_sizes(n):
    x = _rand(jax.random.PRNGKey(n), (n,), -1.0, 1.0)
    got = kernels.fwt(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.fwt(x)),
                               rtol=1e-3, atol=1e-3)


def test_fwt_involution():
    # H H x = n x for the unnormalized transform.
    n = 256
    x = _rand(jax.random.PRNGKey(0), (n,), -1.0, 1.0)
    twice = kernels.fwt(kernels.fwt(x))
    np.testing.assert_allclose(np.asarray(twice), np.asarray(x) * n,
                               rtol=1e-3, atol=1e-2)


def test_floyd_warshall_triangle_inequality():
    n = 32
    key = jax.random.PRNGKey(7)
    d0 = jax.random.uniform(key, (n, n), jnp.float32, 1.0, 10.0)
    d0 = d0.at[jnp.arange(n), jnp.arange(n)].set(0.0)
    d = np.asarray(kernels.floyd_warshall(d0))
    # Closure: d[i,j] <= d[i,k] + d[k,j] for all k.
    for k in range(n):
        assert (d <= d[:, k:k+1] + d[k:k+1, :] + 1e-4).all()
    np.testing.assert_allclose(d, np.asarray(ref.floyd_warshall(d0)),
                               rtol=1e-5, atol=1e-5)


def test_transpose_roundtrip():
    x = _rand(jax.random.PRNGKey(1), (256, 128), -1.0, 1.0)
    tt = kernels.transpose(kernels.transpose(x, bm=128, bn=128), bm=128, bn=128)
    np.testing.assert_array_equal(np.asarray(tt), np.asarray(x))


def test_dct_energy_preservation():
    # Orthonormal basis: Frobenius norm is preserved.
    x = _rand(jax.random.PRNGKey(3), (64, 64), -1.0, 1.0)
    y = kernels.dct8x8(x)
    np.testing.assert_allclose(float(jnp.linalg.norm(y)),
                               float(jnp.linalg.norm(x)), rtol=1e-4)


def test_dct_constant_block_is_dc_only():
    x = jnp.ones((8, 8), jnp.float32)
    y = np.asarray(kernels.dct8x8(x))
    assert abs(y[0, 0] - 8.0) < 1e-4  # DC = 8 * mean for orthonormal type-II
    mask = np.ones_like(y, bool)
    mask[0, 0] = False
    assert np.abs(y[mask]).max() < 1e-4


def test_synthetic_iterations_applied():
    x = jnp.full((1024,), 2.0, jnp.float32)
    got = np.asarray(kernels.synthetic(x, num_iterations=10, factor=1.01,
                                       chunk=256))
    np.testing.assert_allclose(got, 2.0 * 1.01**10, rtol=1e-5)


def test_black_scholes_put_call_parity():
    n = 4096
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(9), 3)
    s = _rand(k1, (n,), 20.0, 100.0)
    x = _rand(k2, (n,), 20.0, 100.0)
    t = _rand(k3, (n,), 0.2, 5.0)
    call, put = kernels.black_scholes(s, x, t, chunk=1024)
    # C - P = S - X e^{-rT}
    lhs = np.asarray(call - put)
    rhs = np.asarray(s - x * jnp.exp(-0.02 * t))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-2)


def test_conv_sep_impulse_response():
    taps = (0.25, 0.5, 0.25)
    img = jnp.zeros((64, 64), jnp.float32).at[32, 32].set(1.0)
    out = np.asarray(kernels.conv_sep(img, taps=taps, bm=32))
    want = np.outer([0.25, 0.5, 0.25], [0.25, 0.5, 0.25])
    np.testing.assert_allclose(out[31:34, 31:34], want, atol=1e-6)
    assert abs(out.sum() - 1.0) < 1e-5


def test_vecadd_chunk_edge():
    # N smaller than the chunk exercises the clamping path.
    a = _rand(jax.random.PRNGKey(4), (100,), -1.0, 1.0)
    b = _rand(jax.random.PRNGKey(5), (100,), -1.0, 1.0)
    np.testing.assert_allclose(np.asarray(kernels.vecadd(a, b)),
                               np.asarray(a + b), rtol=1e-6)
