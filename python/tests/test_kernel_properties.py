"""Hypothesis property sweeps over kernel shapes/values vs the jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

_SETTINGS = dict(max_examples=25, deadline=None)


def _arr(seed, shape, lo=-2.0, hi=2.0):
    return jax.random.uniform(jax.random.PRNGKey(seed), shape, jnp.float32,
                              lo, hi)


@settings(**_SETTINGS)
@given(n=st.sampled_from([64, 128, 256, 512]),
       chunk=st.sampled_from([32, 64, 128]),
       seed=st.integers(0, 2**16))
def test_vecadd_any_chunking(n, chunk, seed):
    a = _arr(seed, (n,))
    b = _arr(seed + 1, (n,))
    got = kernels.vecadd(a, b, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a + b), rtol=1e-6)


@settings(**_SETTINGS)
@given(logn=st.integers(1, 10), seed=st.integers(0, 2**16))
def test_fwt_linearity_and_ref(logn, seed):
    n = 1 << logn
    x = _arr(seed, (n,))
    y = _arr(seed + 1, (n,))
    fx = np.asarray(kernels.fwt(x))
    fy = np.asarray(kernels.fwt(y))
    fxy = np.asarray(kernels.fwt(x + y))
    np.testing.assert_allclose(fxy, fx + fy, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(fx, np.asarray(ref.fwt(x)), rtol=1e-3,
                               atol=1e-3)


@settings(**_SETTINGS)
@given(m=st.sampled_from([32, 64, 128]), n=st.sampled_from([32, 64, 128]),
       seed=st.integers(0, 2**16))
def test_transpose_any_shape(m, n, seed):
    x = _arr(seed, (m, n))
    got = kernels.transpose(x, bm=32, bn=32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x).T)


@settings(**_SETTINGS)
@given(m=st.sampled_from([32, 64]), k=st.sampled_from([32, 64, 96]),
       n=st.sampled_from([32, 64]), seed=st.integers(0, 2**16))
def test_matmul_any_shape(m, k, n, seed):
    x = _arr(seed, (m, k))
    y = _arr(seed + 1, (k, n))
    got = kernels.matmul(x, y, bm=32, bn=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ y),
                               rtol=1e-3, atol=1e-3)


@settings(**_SETTINGS)
@given(iters=st.integers(0, 64), seed=st.integers(0, 2**16))
def test_synthetic_matches_closed_form(iters, seed):
    x = _arr(seed, (512,), 0.5, 1.5)
    got = kernels.synthetic(x, num_iterations=iters, factor=1.001, chunk=512)
    want = ref.synthetic(x, num_iterations=iters, factor=1.001)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4)


@settings(**_SETTINGS)
@given(n=st.sampled_from([8, 16, 24, 32]), seed=st.integers(0, 2**16))
def test_floyd_warshall_idempotent(n, seed):
    d0 = _arr(seed, (n, n), 1.0, 10.0)
    d0 = d0.at[jnp.arange(n), jnp.arange(n)].set(0.0)
    once = kernels.floyd_warshall(d0)
    twice = kernels.floyd_warshall(once)
    np.testing.assert_allclose(np.asarray(twice), np.asarray(once),
                               rtol=1e-5, atol=1e-5)
