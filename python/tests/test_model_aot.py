"""L2 model registry + AOT lowering sanity (shapes, manifest, HLO text)."""

import json

import jax
import pytest

from compile import aot, model


def test_variant_registry_complete():
    # Every kernel family from paper Table 4 + the synthetic kernel.
    families = {v.kernel for v in model.VARIANTS.values()}
    assert families == {
        "matmul", "black_scholes", "fwt", "floyd_warshall", "conv_sep",
        "vecadd", "transpose", "dct8x8", "synthetic",
    }
    # Multiple sizes per family (paper: "several data sizes").
    for fam in families:
        assert sum(v.kernel == fam for v in model.VARIANTS.values()) >= 2, fam


def test_variant_shapes_consistent():
    for v in model.VARIANTS.values():
        outs = jax.eval_shape(v.fn, *v.abstract_inputs())
        assert len(outs) == v.n_outputs, v.name
        assert v.htd_bytes == sum(
            4 * aot.jax_numel(s) for s in v.in_shapes), v.name


def test_dominance_labels():
    assert model.VARIANTS["mm_256"].dominance == "DK"
    assert model.VARIANTS["va_1m"].dominance == "DT"
    assert model.VARIANTS["syn_i16"].dominance == "DT"
    assert model.VARIANTS["syn_i1024"].dominance == "DK"


@pytest.mark.parametrize("name", ["mm_256", "va_256k", "syn_i16"])
def test_lowering_produces_hlo_text(name):
    v = model.VARIANTS[name]
    text = aot.lower_variant(v)
    assert text.startswith("HloModule"), text[:80]
    # return_tuple=True: root must be a tuple for uniform Rust unpacking.
    assert "tuple(" in text or ") tuple" in text, text[:400]


def test_manifest_entry_roundtrips(tmp_path):
    v = model.VARIANTS["bs_64k"]
    entry = aot.manifest_entry(v, "bs_64k.hlo.txt")
    s = json.dumps(entry)
    back = json.loads(s)
    assert back["name"] == "bs_64k"
    assert back["htd_bytes"] == 3 * 4 * (1 << 16)
    assert back["dth_bytes"] == 2 * 4 * (1 << 16)
    assert len(back["inputs"]) == 3 and len(back["outputs"]) == 2
