//! `cargo bench --bench coordinator_throughput` — tasks-throughput of the
//! sharded coordinator: a sweep over workers × lanes × group size on the
//! amd_r9 virtual device (time-compressed tasks so each cell runs in
//! milliseconds while ratios stay intact).
//!
//! Each cell runs the full live pipeline — worker threads, per-lane
//! buffers with batched drains, heuristic reorder on a persistent arena,
//! virtual-device execution, completion events — and records:
//!
//! * `tasks_per_sec` — the paper's tasks-throughput metric, now for the
//!   coordinator itself;
//! * `p50_latency_s` / `p99_latency_s` — per-task submission→completion
//!   wall latency;
//! * `sched_overhead_share` — fraction of wall-clock the proxies spent
//!   inside the reordering heuristic (the Table-6 overhead envelope,
//!   extended to the multi-lane runtime);
//! * model-vs-device drift per cell (predicted vs measured busy seconds).
//!
//! Emits `BENCH_coordinator_throughput.json` (one row per cell × rep
//! aggregate) — the coordinator-throughput trajectory future PRs regress
//! against, alongside `BENCH_sched_overhead.json` from PR 1. The headline
//! comparison printed at the end: multi-lane vs single-lane tasks/sec at
//! 8 workers.

use std::sync::Arc;
use std::time::Duration;

use oclcc::config::profile_by_name;
use oclcc::coordinator::lanes::{LaneCoordinator, LaneOptions};
use oclcc::coordinator::runner::Policy;
use oclcc::device::executor::SpinExecutor;
use oclcc::task::real::real_benchmark;
use oclcc::task::TaskSpec;
use oclcc::util::bench::{bench_mode, fast_mode_from_env};
use oclcc::util::json::Json;
use oclcc::util::stats;

const OUT_PATH: &str = "BENCH_coordinator_throughput.json";

/// Time compression for the virtual device: Table-5 magnitudes are
/// 0.1-10 ms per command; 0.05 keeps every cell in the low milliseconds.
const SCALE: f64 = 0.05;

/// Per-worker dependent batch length (rounds of task groups per run).
const BATCH: usize = 3;

fn workloads(workers: usize, scale: f64) -> Vec<Vec<TaskSpec>> {
    let p = profile_by_name("amd_r9").unwrap();
    let mut rng = oclcc::util::rng::Pcg64::seeded(0xC00D + workers as u64);
    // One BK50 pool, tasks dealt round-robin so every worker's batch is a
    // representative DK/DT mix.
    let g = real_benchmark("BK50", "amd_r9", &p, 8, &mut rng, scale).unwrap();
    (0..workers)
        .map(|w| (0..BATCH).map(|i| g.tasks[(w + i) % g.len()].clone()).collect())
        .collect()
}

struct Cell {
    workers: usize,
    lanes: usize,
    group_cap: usize,
    tasks_per_sec: f64,
    p50: f64,
    p99: f64,
    sched_share: f64,
    drift: f64,
    n_groups: usize,
    n_cands_pruned: f64,
    n_rollouts_early_exit: f64,
    n_twin_collapsed: f64,
}

fn run_cell(workers: usize, lanes: usize, group_cap: usize, reps: usize) -> Cell {
    let profile = profile_by_name("amd_r9").unwrap();
    let mut tput = Vec::with_capacity(reps);
    let mut p50 = Vec::with_capacity(reps);
    let mut p99 = Vec::with_capacity(reps);
    let mut share = Vec::with_capacity(reps);
    let mut drift = Vec::with_capacity(reps);
    let mut groups = Vec::with_capacity(reps);
    let mut pruned = Vec::with_capacity(reps);
    let mut early = Vec::with_capacity(reps);
    let mut twins = Vec::with_capacity(reps);
    for _ in 0..reps {
        let coord = LaneCoordinator::homogeneous(
            profile.clone(),
            Arc::new(SpinExecutor),
            LaneOptions {
                lanes,
                policy: Policy::Heuristic,
                settle: Duration::from_micros(200),
                group_cap,
                scoring_threads: 1,
                online: None,
                recalibrate: None,
                recovery: None,
                admission: None,
            },
        );
        let m = coord.run(workloads(workers, SCALE));
        assert_eq!(m.n_tasks, workers * BATCH, "lost tasks in cell");
        tput.push(m.tasks_per_sec);
        p50.push(m.p50_latency());
        p99.push(m.p99_latency());
        share.push(m.sched_overhead_share());
        let (busy, pred): (f64, f64) = m
            .per_lane
            .iter()
            .fold((0.0, 0.0), |(b, p), l| (b + l.busy_secs, p + l.predicted_secs));
        drift.push(if pred > 0.0 { busy / pred } else { 1.0 });
        groups.push(m.n_groups as f64);
        let (mut np, mut ne, mut nt) = (0u64, 0u64, 0u64);
        for l in &m.per_lane {
            np += l.n_cands_pruned;
            ne += l.n_rollouts_early_exit;
            nt += l.n_twin_collapsed;
        }
        pruned.push(np as f64);
        early.push(ne as f64);
        twins.push(nt as f64);
    }
    Cell {
        workers,
        lanes,
        group_cap,
        tasks_per_sec: stats::median(&tput),
        p50: stats::median(&p50),
        p99: stats::median(&p99),
        sched_share: stats::median(&share),
        drift: stats::median(&drift),
        // Median across reps like every other cell metric — group
        // formation depends on settle-window timing, so a single rep's
        // count is scheduling noise.
        n_groups: stats::median(&groups).round() as usize,
        n_cands_pruned: stats::median(&pruned),
        n_rollouts_early_exit: stats::median(&early),
        n_twin_collapsed: stats::median(&twins),
    }
}

fn main() {
    let fast = fast_mode_from_env();
    let reps = if fast { 2 } else { 5 };

    let mut rows: Vec<Json> = Vec::new();
    let mut cells: Vec<Cell> = Vec::new();
    println!("== coordinator throughput: workers x lanes x group size ==");
    println!(
        "{:>7} {:>5} {:>5} {:>12} {:>10} {:>10} {:>9} {:>7}",
        "workers", "lanes", "T", "tasks/sec", "p50 lat", "p99 lat", "sched%", "drift"
    );
    for &workers in &[2usize, 4, 8] {
        for &lanes in &[1usize, 2, 4] {
            if lanes > workers {
                continue;
            }
            // T = group size cap: a full lane round, and a split round.
            let full = workers.div_ceil(lanes);
            let caps = if full > 2 { vec![full, 2] } else { vec![full] };
            for cap in caps {
                let c = run_cell(workers, lanes, cap, reps);
                println!(
                    "{:>7} {:>5} {:>5} {:>12.1} {:>9.3}ms {:>9.3}ms {:>8.2}% {:>7.3}",
                    c.workers,
                    c.lanes,
                    c.group_cap,
                    c.tasks_per_sec,
                    c.p50 * 1e3,
                    c.p99 * 1e3,
                    c.sched_share * 100.0,
                    c.drift,
                );
                rows.push(Json::obj(vec![
                    ("workers", Json::num(c.workers as f64)),
                    ("lanes", Json::num(c.lanes as f64)),
                    ("t_group_cap", Json::num(c.group_cap as f64)),
                    ("reps", Json::num(reps as f64)),
                    ("tasks_per_sec", Json::num(c.tasks_per_sec)),
                    ("p50_latency_s", Json::num(c.p50)),
                    ("p99_latency_s", Json::num(c.p99)),
                    ("sched_overhead_share", Json::num(c.sched_share)),
                    ("measured_vs_predicted", Json::num(c.drift)),
                    ("n_groups", Json::num(c.n_groups as f64)),
                    ("n_cands_pruned", Json::num(c.n_cands_pruned)),
                    ("n_rollouts_early_exit", Json::num(c.n_rollouts_early_exit)),
                    ("n_twin_collapsed", Json::num(c.n_twin_collapsed)),
                ]));
                cells.push(c);
            }
        }
    }

    // Headline: the lane scaling the sharded coordinator buys at 8 workers.
    let best_at = |workers: usize, lanes: usize| -> Option<f64> {
        cells
            .iter()
            .filter(|c| c.workers == workers && c.lanes == lanes)
            .map(|c| c.tasks_per_sec)
            .reduce(f64::max)
    };
    if let (Some(single), Some(multi)) = (
        best_at(8, 1),
        [2usize, 4].iter().filter_map(|&l| best_at(8, l)).reduce(f64::max),
    ) {
        println!(
            "\n8 workers: multi-lane {multi:.1} tasks/s vs single-lane \
             {single:.1} tasks/s ({:.2}x)",
            multi / single.max(1e-12)
        );
    }

    // Self-describing header: the effective OCLCC_BENCH_FAST mode, so a
    // trajectory file records whether it holds smoke or full numbers.
    let doc = Json::obj(vec![
        ("bench_mode", Json::str(bench_mode())),
        ("rows", Json::arr(rows)),
    ]);
    match std::fs::write(OUT_PATH, doc.to_string()) {
        Ok(()) => println!("[saved {OUT_PATH}, mode={}]", bench_mode()),
        Err(e) => eprintln!("failed to write {OUT_PATH}: {e}"),
    }
}
