//! `cargo bench` — end-to-end coordinator throughput on the virtual
//! device (spin backend, compressed time scale): NoReorder vs Heuristic.

use std::sync::Arc;

use oclcc::config::profile_by_name;
use oclcc::coordinator::{Coordinator, Policy};
use oclcc::device::{SpinExecutor, VirtualDevice};
use oclcc::task::real::real_benchmark;
use oclcc::task::TaskSpec;
use oclcc::util::bench::Bencher;
use oclcc::util::rng::Pcg64;

fn main() {
    let profile = profile_by_name("amd_r9").unwrap();
    let device = Arc::new(VirtualDevice::new(
        profile.clone(),
        Arc::new(SpinExecutor),
    ));
    let mut rng = Pcg64::seeded(0xE2E);
    let g = real_benchmark("BK50", "amd_r9", &profile, 8, &mut rng, 0.2).unwrap();
    let batches: Vec<Vec<TaskSpec>> = (0..4)
        .map(|w| (0..2).map(|r| g.tasks[w * 2 + r].clone()).collect())
        .collect();
    let mut b = Bencher::new(3.0, 30);
    for (name, policy) in
        [("noreorder", Policy::NoReorder), ("heuristic", Policy::Heuristic)]
    {
        let device = device.clone();
        let batches = batches.clone();
        let r = b.bench(&format!("coordinator 4x2 {name}"), move || {
            Coordinator::new(device.clone(), policy).run(batches.clone())
        });
        println!("  -> {:.1} tasks/s", 8.0 / r.median);
    }
    println!("== e2e coordinator bench (time-scale 0.2) ==");
    print!("{}", b.report());
}
