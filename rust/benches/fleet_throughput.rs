//! `cargo bench --bench fleet_throughput` — heterogeneous fleet
//! scheduling vs the static multi-device scheduler and the round-robin
//! baseline, plus the live fleet-coordinator cells (quarantine-rescue
//! stealing, calibrated vs static placement models).
//!
//! Three row families in `BENCH_fleet.json`, all keyed `(cell, impl)`
//! with `tasks_per_sec` as the gated metric:
//!
//! * **Static scheduling cells** (`hom2`, `het3` — two R9s, and the
//!   paper's R9 + Xeon Phi + K20c trio): model-time throughput
//!   `n_tasks / predicted group makespan` for `impl` = `fleet`
//!   (bound-gated `schedule_fleet`), `static_multi` (`schedule_multi`,
//!   which routes through the same fleet core — the row pins the
//!   wrapper's bit-equality in the trajectory) and `round_robin`. The
//!   bench asserts fleet ≤ static_multi and, on the heterogeneous cell,
//!   fleet strictly beats round_robin, with non-zero placement-prune
//!   counters. Scheduling *wall* time is reported per row
//!   (`sched_wall_s`), pruned vs unpruned, so the bound-gating win is
//!   visible alongside the model-time quality.
//! * **`steal_rescue`** — the live [`FleetCoordinator`] on one
//!   persistently-failing chaos device plus one healthy device:
//!   quarantine trips, backlog shed, health-aware rescue stealing. The
//!   bench asserts every task completes and the steal counter is
//!   non-zero.
//! * **`place_het3`** — the live fleet on the het3 trio with the
//!   placement batch cap and scoring stripes swept (`impl` = `batch1`
//!   per-arrival greedy, `batched` joint drain on one stripe,
//!   `batched_par` joint drain over three stripes). A model-clock
//!   preamble asserts, deterministically, that `place_batch(1, ..)` is
//!   bit-identical to the exact per-arrival scan it replaced and that
//!   the joint batch objective never lands behind per-arrival greedy.
//! * **`retry_liveness`** — one transiently-faulting chaos device under
//!   a 10ms `RetryBackoff` next to a healthy device: groups park on the
//!   retry deadline wheel while the proxy keeps placing. The bench
//!   asserts retries fired yet measured placement p99 stays below one
//!   backoff — planning never absorbed a backoff sleep.
//! * **`miscal_het3`** — the live fleet on three devices whose planning
//!   models believe links run 2x faster than reality (`impl` =
//!   `static_model` vs `calibrated`): the calibrated side adopts
//!   per-device corrections and must show reduced pooled model drift.
//!
//! Runtime rows carry measured ingress-to-placement latency
//! (`placement_p50_us` / `placement_p99_us`, gated on the live cells)
//! and the joint-round count `n_place_rounds` alongside
//! `tasks_per_sec`. Wall-clock rows inherit the usual noise caveats of
//! the coordinator benches; the static cells are model-time and
//! bit-stable.

use std::sync::Arc;
use std::time::{Duration, Instant};

use oclcc::config::{profile_by_name, DeviceProfile};
use oclcc::coordinator::{FleetCoordOptions, FleetCoordinator, FleetMetrics};
use oclcc::device::{ChaosDevice, ChaosOptions, Device, SimDevice};
use oclcc::model::simulator::SimCursor;
use oclcc::model::{CalibrateOptions, EngineState, TaskTable};
use oclcc::sched::fleet::{schedule_fleet, BatchPlacer, FleetOptions};
use oclcc::sched::multidevice::{round_robin, schedule_multi, MultiSchedule};
use oclcc::task::real::real_benchmark;
use oclcc::task::TaskSpec;
use oclcc::util::bench::{bench_mode, fast_mode_from_env};
use oclcc::util::json::Json;
use oclcc::util::rng::Pcg64;
use oclcc::util::stats;

const OUT_PATH: &str = "BENCH_fleet.json";

/// Time compression for the live cells (ratios intact, cells in low
/// milliseconds).
const SCALE: f64 = 0.05;

fn hom2() -> Vec<DeviceProfile> {
    vec![
        profile_by_name("amd_r9").unwrap(),
        profile_by_name("amd_r9").unwrap(),
    ]
}

fn het3() -> Vec<DeviceProfile> {
    vec![
        profile_by_name("amd_r9").unwrap(),
        profile_by_name("xeon_phi").unwrap(),
        profile_by_name("k20c").unwrap(),
    ]
}

/// The jittered BK50 catalog the static cells schedule: enough tasks
/// that placement quality (not just ordering) decides the makespan.
fn static_tasks(n: usize) -> Vec<TaskSpec> {
    let p = profile_by_name("amd_r9").unwrap();
    let mut rng = Pcg64::seeded(0xf1ee7);
    real_benchmark("BK50", "amd_r9", &p, n, &mut rng, 1.0).unwrap().tasks
}

struct StaticCell {
    makespan: f64,
    tasks_per_sec: f64,
    /// Median wall seconds to compute the schedule.
    sched_wall: f64,
}

fn time_schedule(
    reps: usize,
    run: &dyn Fn() -> MultiSchedule,
    n: usize,
) -> StaticCell {
    let mut walls = Vec::with_capacity(reps);
    let mut makespan = 0.0;
    for _ in 0..reps {
        let t0 = Instant::now();
        let s = run();
        walls.push(t0.elapsed().as_secs_f64());
        makespan = s.makespan();
    }
    StaticCell {
        makespan,
        tasks_per_sec: n as f64 / makespan,
        sched_wall: stats::median(&walls),
    }
}

fn push_static_row(
    rows: &mut Vec<Json>,
    cell: &str,
    impl_name: &str,
    n: usize,
    r: &StaticCell,
) {
    rows.push(Json::obj(vec![
        ("cell", Json::str(cell)),
        ("impl", Json::str(impl_name)),
        ("n_tasks", Json::num(n as f64)),
        ("makespan_s", Json::num(r.makespan)),
        ("tasks_per_sec", Json::num(r.tasks_per_sec)),
        ("sched_wall_s", Json::num(r.sched_wall)),
    ]));
}

fn push_runtime_row(rows: &mut Vec<Json>, cell: &str, impl_name: &str, m: &FleetMetrics) {
    let drift = {
        let (mut busy, mut pred) = (0.0f64, 0.0f64);
        for l in &m.per_device {
            busy += l.busy_secs;
            pred += l.predicted_secs;
        }
        if pred > 0.0 { (busy / pred - 1.0).abs() } else { 0.0 }
    };
    rows.push(Json::obj(vec![
        ("cell", Json::str(cell)),
        ("impl", Json::str(impl_name)),
        ("n_tasks", Json::num(m.n_tasks as f64)),
        ("total_secs", Json::num(m.total_secs)),
        ("tasks_per_sec", Json::num(m.tasks_per_sec)),
        ("n_placements", Json::num(m.n_placements as f64)),
        ("n_stolen", Json::num(m.n_stolen() as f64)),
        ("n_steal_considered", Json::num(m.n_steal_considered as f64)),
        ("n_steal_rejected", Json::num(m.n_steal_rejected as f64)),
        ("placement_pruned", Json::num(m.placement_prune.n_cands_pruned as f64)),
        (
            "placement_early_exit",
            Json::num(m.placement_prune.n_rollouts_early_exit as f64),
        ),
        ("model_drift", Json::num(drift)),
        (
            "n_recalibrations",
            Json::num(m.per_device.iter().map(|l| l.n_recalibrations).sum::<usize>()
                as f64),
        ),
        ("sched_overhead_share", Json::num(m.sched_overhead_share())),
        // Measured ingress-to-placement decision latency (FleetMetrics::
        // placement_latencies) and how many joint rounds produced it.
        ("placement_p50_us", Json::num(m.placement_p50_s() * 1e6)),
        ("placement_p99_us", Json::num(m.placement_p99_s() * 1e6)),
        ("n_place_rounds", Json::num(m.n_place_rounds as f64)),
    ]));
}

/// Median-throughput run of a live fleet cell; `check` vets every rep.
fn run_fleet_cell(
    reps: usize,
    build: &dyn Fn() -> FleetCoordinator,
    mk: &dyn Fn() -> Vec<Vec<TaskSpec>>,
    check: &dyn Fn(&FleetMetrics),
) -> FleetMetrics {
    let mut runs: Vec<FleetMetrics> = (0..reps)
        .map(|_| {
            let m = build().run(mk());
            check(&m);
            m
        })
        .collect();
    runs.sort_by(|a, b| a.tasks_per_sec.total_cmp(&b.tasks_per_sec));
    runs.swap_remove(runs.len() / 2)
}

fn workloads(workers: usize, batch: usize) -> Vec<Vec<TaskSpec>> {
    let p = profile_by_name("amd_r9").unwrap();
    let g = oclcc::task::synthetic::synthetic_benchmark("BK50", &p, SCALE).unwrap();
    (0..workers)
        .map(|w| (0..batch).map(|i| g.tasks[(w + i) % g.len()].clone()).collect())
        .collect()
}

/// Links modeled 2x too fast — the planted miscalibration.
fn miscal(p: &DeviceProfile) -> DeviceProfile {
    let mut m = p.clone();
    m.htd.bytes_per_sec *= 2.0;
    m.dth.bytes_per_sec *= 2.0;
    m
}

fn main() {
    let fast = fast_mode_from_env();
    let reps = if fast { 2 } else { 5 };
    let n = if fast { 24 } else { 48 };
    let mut rows: Vec<Json> = Vec::new();

    // ---- static scheduling cells -------------------------------------
    println!("== static fleet scheduling vs baselines (model time) ==");
    println!(
        "{:>5} {:>14} {:>12} {:>12} {:>9} {:>9}",
        "cell", "impl", "makespan", "tasks/s", "wall", "rr_ratio"
    );
    let tasks = static_tasks(n);
    for (cell, profs) in [("hom2", hom2()), ("het3", het3())] {
        let fleet = time_schedule(
            reps,
            &|| {
                let f = schedule_fleet(&tasks, &profs, &FleetOptions::default());
                MultiSchedule {
                    assignment: f.assignment,
                    orders: f.orders,
                    device_makespans: f.device_makespans,
                }
            },
            n,
        );
        // Unpruned wall time, for the bound-gating comparison (the
        // schedule itself is bit-identical — prop_fleet.rs).
        let unpruned = time_schedule(
            reps,
            &|| {
                let f = schedule_fleet(
                    &tasks,
                    &profs,
                    &FleetOptions { prune: false, ..FleetOptions::default() },
                );
                MultiSchedule {
                    assignment: f.assignment,
                    orders: f.orders,
                    device_makespans: f.device_makespans,
                }
            },
            n,
        );
        let multi = time_schedule(reps, &|| schedule_multi(&tasks, &profs), n);
        let rr = time_schedule(reps, &|| round_robin(&tasks, &profs), n);

        // Acceptance: fleet never behind the static wrapper, and the
        // placement actually pays off against round-robin on the
        // heterogeneous cell (equal-profile cells can tie).
        assert!(
            fleet.makespan <= multi.makespan,
            "{cell}: fleet ({}) worse than schedule_multi ({})",
            fleet.makespan,
            multi.makespan
        );
        assert!(
            fleet.makespan <= rr.makespan,
            "{cell}: fleet ({}) worse than round_robin ({})",
            fleet.makespan,
            rr.makespan
        );
        if cell == "het3" {
            assert!(
                fleet.makespan < rr.makespan,
                "het3: fleet ({}) does not strictly beat round_robin ({})",
                fleet.makespan,
                rr.makespan
            );
        }
        let s = schedule_fleet(&tasks, &profs, &FleetOptions::default());
        assert!(
            s.prune.total_saved() > 0,
            "{cell}: placement pruning never fired: {:?}",
            s.prune
        );

        for (name, r) in
            [("fleet", &fleet), ("static_multi", &multi), ("round_robin", &rr)]
        {
            println!(
                "{:>5} {:>14} {:>10.3}ms {:>12.1} {:>7.1}us {:>8.3}x",
                cell,
                name,
                r.makespan * 1e3,
                r.tasks_per_sec,
                r.sched_wall * 1e6,
                rr.makespan / r.makespan,
            );
            push_static_row(&mut rows, cell, name, n, r);
        }
        println!(
            "{:>5} {:>14} {:>10}   {:>12} {:>7.1}us (pruned {:.2}x faster, \
             pruned {} / early-exit {} / twins {})",
            cell,
            "fleet-unpruned",
            "-",
            "-",
            unpruned.sched_wall * 1e6,
            unpruned.sched_wall / fleet.sched_wall.max(1e-12),
            s.prune.n_cands_pruned,
            s.prune.n_rollouts_early_exit,
            s.prune.n_twin_collapsed,
        );
    }

    // ---- batched placement: model-clock exactness assertions ---------
    // Deterministic (pure model time, no wall clocks): (a) a stream of
    // one-task batches through `BatchPlacer::place_batch(1, ..)` makes
    // bit-identical decisions to the exact per-arrival scan the batched
    // path replaced, and (b) the joint batch objective is never worse
    // than the per-arrival greedy baseline on the het3 cell.
    {
        let profs = het3();
        let tables: Vec<TaskTable> =
            profs.iter().map(|p| TaskTable::compile(&tasks, p)).collect();
        let fresh = || -> Vec<SimCursor> {
            tables
                .iter()
                .map(|t| {
                    let mut c = SimCursor::detached();
                    c.reset_for_table(t, EngineState::default());
                    c
                })
                .collect()
        };
        let d = tables.len();
        let elapsed = vec![0.0f64; d];
        let available = vec![true; d];
        let mut placer = BatchPlacer::new(2);
        let mut probe = SimCursor::detached();
        let mut assignment = Vec::new();
        // (a) batch=1 identity along a sequentially-placed stream.
        let mut frontiers = fresh();
        for i in 0..n {
            let subs: Vec<TaskTable> = tables
                .iter()
                .map(|t| {
                    let mut s = TaskTable::new();
                    s.gather_into(t, &[i]);
                    s
                })
                .collect();
            let mut ref_dev = 0usize;
            let mut ref_rem = f64::INFINITY;
            for (dev, sub) in subs.iter().enumerate() {
                probe.resume_from(&frontiers[dev]);
                probe.push_task_compiled(sub, 0);
                let rem = probe.run_to_quiescence() - elapsed[dev];
                if rem.total_cmp(&ref_rem).is_lt() {
                    ref_rem = rem;
                    ref_dev = dev;
                }
            }
            let refs: Vec<&TaskTable> = subs.iter().collect();
            placer
                .place_batch(1, &refs, &frontiers, &elapsed, &available, true, &mut assignment)
                .unwrap();
            assert_eq!(
                assignment,
                vec![ref_dev],
                "task {i}: batch=1 diverged from the per-arrival scan"
            );
            frontiers[ref_dev].push_task_compiled(&subs[ref_dev], 0);
        }
        // (b) joint ≤ per-arrival greedy on the whole het3 batch.
        let frontiers = fresh();
        let refs: Vec<&TaskTable> = tables.iter().collect();
        let out = placer
            .place_batch(n, &refs, &frontiers, &elapsed, &available, true, &mut assignment)
            .unwrap();
        assert!(
            out.objective.total_cmp(&out.greedy_objective).is_le(),
            "het3: joint batch objective {} worse than per-arrival greedy {}",
            out.objective,
            out.greedy_objective
        );
        println!(
            "\nhet3 joint batch: objective {:.3}ms vs greedy {:.3}ms ({:.2}% better)",
            out.objective * 1e3,
            out.greedy_objective * 1e3,
            (1.0 - out.objective / out.greedy_objective.max(1e-12)) * 100.0,
        );
    }

    // ---- place_het3: live fleet, batched vs per-arrival placement ----
    println!("\n== live fleet: batched joint placement ==");
    {
        let workers = 6usize;
        let batch = 3usize;
        let build = |place_batch: usize, threads: usize| {
            let devices: Vec<Arc<dyn Device>> = het3()
                .into_iter()
                .map(|p| Arc::new(SimDevice::new(p)) as Arc<dyn Device>)
                .collect();
            FleetCoordinator::with_devices(
                devices,
                FleetCoordOptions {
                    place_batch,
                    placement_threads: threads,
                    ..FleetCoordOptions::default()
                },
            )
        };
        for (impl_name, place_batch, threads) in [
            ("batch1", 1usize, 1usize),
            ("batched", usize::MAX, 1),
            ("batched_par", usize::MAX, 3),
        ] {
            let m = run_fleet_cell(
                reps,
                &|| build(place_batch, threads),
                &|| workloads(workers, batch),
                &|m| {
                    assert_eq!(m.n_tasks, workers * batch, "{impl_name} lost tasks");
                    assert_eq!(
                        m.placement_latencies.len(),
                        m.n_placements,
                        "{impl_name}: every placement must be measured"
                    );
                    assert!(m.n_place_rounds > 0, "{impl_name}: no rounds");
                    if place_batch == 1 {
                        // A batch cap of one places exactly one per round.
                        assert_eq!(m.n_place_rounds, m.n_placements, "{impl_name}");
                    }
                },
            );
            println!(
                "{:>12}: {:>8.1} tasks/s, place p50 {:.1}us p99 {:.1}us, \
                 {} rounds / {} placements",
                impl_name,
                m.tasks_per_sec,
                m.placement_p50_s() * 1e6,
                m.placement_p99_s() * 1e6,
                m.n_place_rounds,
                m.n_placements,
            );
            push_runtime_row(&mut rows, "place_het3", impl_name, &m);
        }
    }

    // ---- retry_liveness: placement advances through a Retry backoff --
    println!("\n== live fleet: planning through retry backoffs ==");
    {
        use oclcc::coordinator::recovery::{RecoveryOptions, RetryBackoff};
        let workers = 6usize;
        let batch = 3usize;
        // Backoffs far longer than a placement decision: if a backoff
        // ever blocked the proxy, placement latency tails would absorb
        // whole 10ms parks.
        let backoff_base = Duration::from_millis(10);
        let build = || {
            let flaky: Arc<dyn Device> = Arc::new(ChaosDevice::new(
                Arc::new(SimDevice::new(profile_by_name("amd_r9").unwrap())),
                ChaosOptions {
                    seed: 0x3e72e,
                    p_error: 0.6,
                    transient: true,
                    ..ChaosOptions::default()
                },
            ));
            let steady: Arc<dyn Device> =
                Arc::new(SimDevice::new(profile_by_name("k20c").unwrap()));
            FleetCoordinator::with_devices(
                vec![flaky, steady],
                FleetCoordOptions {
                    recovery: Some(RecoveryOptions::retry(RetryBackoff {
                        base: backoff_base,
                        cap: Duration::from_millis(20),
                        ..RetryBackoff::default()
                    })),
                    ..FleetCoordOptions::default()
                },
            )
        };
        let m = run_fleet_cell(reps, &build, &|| workloads(workers, batch), &|m| {
            assert_eq!(m.n_tasks, workers * batch, "retry_liveness lost tasks");
            let retries: usize = m.per_device.iter().map(|l| l.n_retries).sum();
            assert!(retries > 0, "retry_liveness: chaos device never retried");
            // The liveness claim: groups sat out ≥10ms backoffs on the
            // deadline wheel, yet no placement decision waited anywhere
            // near one backoff — the proxy kept placing throughout.
            assert!(
                m.placement_p99_s() < backoff_base.as_secs_f64(),
                "retry_liveness: placement p99 {:.1}us absorbed a backoff park \
                 (backoff {:.1}us)",
                m.placement_p99_s() * 1e6,
                backoff_base.as_secs_f64() * 1e6,
            );
        });
        let retries: usize = m.per_device.iter().map(|l| l.n_retries).sum();
        println!(
            "retry_liveness: {:.1} tasks/s, {} retries, place p99 {:.1}us \
             (backoff {}ms)",
            m.tasks_per_sec,
            retries,
            m.placement_p99_s() * 1e6,
            backoff_base.as_millis(),
        );
        push_runtime_row(&mut rows, "retry_liveness", "fleet", &m);
    }

    // ---- steal_rescue: live fleet, one device dies -------------------
    println!("\n== live fleet: quarantine-rescue stealing ==");
    {
        use oclcc::coordinator::recovery::{
            BlacklistAfterN, QuarantineOptions, RecoveryOptions,
        };
        let workers = 4usize;
        let batch = 3usize;
        let build = || {
            let flaky: Arc<dyn Device> = Arc::new(ChaosDevice::new(
                Arc::new(SimDevice::new(profile_by_name("amd_r9").unwrap())),
                ChaosOptions {
                    seed: 0xdead,
                    p_error: 1.0,
                    transient: false,
                    ..ChaosOptions::default()
                },
            ));
            let steady: Arc<dyn Device> =
                Arc::new(SimDevice::new(profile_by_name("amd_r9").unwrap()));
            FleetCoordinator::with_devices(
                vec![flaky, steady],
                FleetCoordOptions {
                    recovery: Some(RecoveryOptions {
                        deadline: None,
                        quarantine: QuarantineOptions {
                            cooldown: Duration::from_secs(600),
                        },
                        ..RecoveryOptions::blacklist(BlacklistAfterN {
                            n_failures: 1,
                            ..BlacklistAfterN::default()
                        })
                    }),
                    ..FleetCoordOptions::default()
                },
            )
        };
        let m = run_fleet_cell(reps, &build, &|| workloads(workers, batch), &|m| {
            assert_eq!(m.n_tasks, workers * batch, "steal_rescue lost tasks");
            assert!(
                m.n_stolen() > 0,
                "steal_rescue: quarantined backlog never rescued"
            );
        });
        println!(
            "steal_rescue: {:.1} tasks/s, {} stolen, {} quarantine trips",
            m.tasks_per_sec,
            m.n_stolen(),
            m.per_device.iter().map(|l| l.n_quarantine_trips).sum::<usize>(),
        );
        push_runtime_row(&mut rows, "steal_rescue", "fleet", &m);
    }

    // ---- miscal_het3: calibrated vs static placement models ----------
    println!("\n== live fleet: calibrated vs static placement models ==");
    {
        let workers = 6usize;
        let batch = 3usize;
        let build = |recal: Option<CalibrateOptions>| {
            let devices: Vec<Arc<dyn Device>> = het3()
                .into_iter()
                .map(|p| Arc::new(SimDevice::new(p)) as Arc<dyn Device>)
                .collect();
            FleetCoordinator::with_devices(
                devices,
                FleetCoordOptions { recalibrate: recal, ..FleetCoordOptions::default() },
            )
            .with_plan_models(het3().iter().map(miscal).collect())
        };
        let stat = run_fleet_cell(
            reps,
            &|| build(None),
            &|| workloads(workers, batch),
            &|m| assert_eq!(m.n_tasks, workers * batch),
        );
        let cal = run_fleet_cell(
            reps,
            &|| build(Some(CalibrateOptions::default())),
            &|| workloads(workers, batch),
            &|m| assert_eq!(m.n_tasks, workers * batch),
        );
        println!(
            "static {:.1} tasks/s, calibrated {:.1} tasks/s ({} adoptions)",
            stat.tasks_per_sec,
            cal.tasks_per_sec,
            cal.per_device.iter().map(|l| l.n_recalibrations).sum::<usize>(),
        );
        push_runtime_row(&mut rows, "miscal_het3", "static_model", &stat);
        push_runtime_row(&mut rows, "miscal_het3", "calibrated", &cal);
    }

    let doc = Json::obj(vec![
        ("bench_mode", Json::str(bench_mode())),
        ("rows", Json::arr(rows)),
    ]);
    match std::fs::write(OUT_PATH, doc.to_string()) {
        Ok(()) => println!("\n[saved {OUT_PATH}, mode={}]", bench_mode()),
        Err(e) => eprintln!("failed to write {OUT_PATH}: {e}"),
    }
}
