//! `cargo bench --bench fleet_throughput` — heterogeneous fleet
//! scheduling vs the static multi-device scheduler and the round-robin
//! baseline, plus the live fleet-coordinator cells (quarantine-rescue
//! stealing, calibrated vs static placement models).
//!
//! Three row families in `BENCH_fleet.json`, all keyed `(cell, impl)`
//! with `tasks_per_sec` as the gated metric:
//!
//! * **Static scheduling cells** (`hom2`, `het3` — two R9s, and the
//!   paper's R9 + Xeon Phi + K20c trio): model-time throughput
//!   `n_tasks / predicted group makespan` for `impl` = `fleet`
//!   (bound-gated `schedule_fleet`), `static_multi` (`schedule_multi`,
//!   which routes through the same fleet core — the row pins the
//!   wrapper's bit-equality in the trajectory) and `round_robin`. The
//!   bench asserts fleet ≤ static_multi and, on the heterogeneous cell,
//!   fleet strictly beats round_robin, with non-zero placement-prune
//!   counters. Scheduling *wall* time is reported per row
//!   (`sched_wall_s`), pruned vs unpruned, so the bound-gating win is
//!   visible alongside the model-time quality.
//! * **`steal_rescue`** — the live [`FleetCoordinator`] on one
//!   persistently-failing chaos device plus one healthy device:
//!   quarantine trips, backlog shed, health-aware rescue stealing. The
//!   bench asserts every task completes and the steal counter is
//!   non-zero.
//! * **`miscal_het3`** — the live fleet on three devices whose planning
//!   models believe links run 2x faster than reality (`impl` =
//!   `static_model` vs `calibrated`): the calibrated side adopts
//!   per-device corrections and must show reduced pooled model drift.
//!
//! Wall-clock rows inherit the usual noise caveats of the coordinator
//! benches; the static cells are model-time and bit-stable.

use std::sync::Arc;
use std::time::{Duration, Instant};

use oclcc::config::{profile_by_name, DeviceProfile};
use oclcc::coordinator::{FleetCoordOptions, FleetCoordinator, FleetMetrics};
use oclcc::device::{ChaosDevice, ChaosOptions, Device, SimDevice};
use oclcc::model::CalibrateOptions;
use oclcc::sched::fleet::{schedule_fleet, FleetOptions};
use oclcc::sched::multidevice::{round_robin, schedule_multi, MultiSchedule};
use oclcc::task::real::real_benchmark;
use oclcc::task::TaskSpec;
use oclcc::util::bench::{bench_mode, fast_mode_from_env};
use oclcc::util::json::Json;
use oclcc::util::rng::Pcg64;
use oclcc::util::stats;

const OUT_PATH: &str = "BENCH_fleet.json";

/// Time compression for the live cells (ratios intact, cells in low
/// milliseconds).
const SCALE: f64 = 0.05;

fn hom2() -> Vec<DeviceProfile> {
    vec![
        profile_by_name("amd_r9").unwrap(),
        profile_by_name("amd_r9").unwrap(),
    ]
}

fn het3() -> Vec<DeviceProfile> {
    vec![
        profile_by_name("amd_r9").unwrap(),
        profile_by_name("xeon_phi").unwrap(),
        profile_by_name("k20c").unwrap(),
    ]
}

/// The jittered BK50 catalog the static cells schedule: enough tasks
/// that placement quality (not just ordering) decides the makespan.
fn static_tasks(n: usize) -> Vec<TaskSpec> {
    let p = profile_by_name("amd_r9").unwrap();
    let mut rng = Pcg64::seeded(0xf1ee7);
    real_benchmark("BK50", "amd_r9", &p, n, &mut rng, 1.0).unwrap().tasks
}

struct StaticCell {
    makespan: f64,
    tasks_per_sec: f64,
    /// Median wall seconds to compute the schedule.
    sched_wall: f64,
}

fn time_schedule(
    reps: usize,
    run: &dyn Fn() -> MultiSchedule,
    n: usize,
) -> StaticCell {
    let mut walls = Vec::with_capacity(reps);
    let mut makespan = 0.0;
    for _ in 0..reps {
        let t0 = Instant::now();
        let s = run();
        walls.push(t0.elapsed().as_secs_f64());
        makespan = s.makespan();
    }
    StaticCell {
        makespan,
        tasks_per_sec: n as f64 / makespan,
        sched_wall: stats::median(&walls),
    }
}

fn push_static_row(
    rows: &mut Vec<Json>,
    cell: &str,
    impl_name: &str,
    n: usize,
    r: &StaticCell,
) {
    rows.push(Json::obj(vec![
        ("cell", Json::str(cell)),
        ("impl", Json::str(impl_name)),
        ("n_tasks", Json::num(n as f64)),
        ("makespan_s", Json::num(r.makespan)),
        ("tasks_per_sec", Json::num(r.tasks_per_sec)),
        ("sched_wall_s", Json::num(r.sched_wall)),
    ]));
}

fn push_runtime_row(rows: &mut Vec<Json>, cell: &str, impl_name: &str, m: &FleetMetrics) {
    let drift = {
        let (mut busy, mut pred) = (0.0f64, 0.0f64);
        for l in &m.per_device {
            busy += l.busy_secs;
            pred += l.predicted_secs;
        }
        if pred > 0.0 { (busy / pred - 1.0).abs() } else { 0.0 }
    };
    rows.push(Json::obj(vec![
        ("cell", Json::str(cell)),
        ("impl", Json::str(impl_name)),
        ("n_tasks", Json::num(m.n_tasks as f64)),
        ("total_secs", Json::num(m.total_secs)),
        ("tasks_per_sec", Json::num(m.tasks_per_sec)),
        ("n_placements", Json::num(m.n_placements as f64)),
        ("n_stolen", Json::num(m.n_stolen() as f64)),
        ("n_steal_considered", Json::num(m.n_steal_considered as f64)),
        ("n_steal_rejected", Json::num(m.n_steal_rejected as f64)),
        ("placement_pruned", Json::num(m.placement_prune.n_cands_pruned as f64)),
        (
            "placement_early_exit",
            Json::num(m.placement_prune.n_rollouts_early_exit as f64),
        ),
        ("model_drift", Json::num(drift)),
        (
            "n_recalibrations",
            Json::num(m.per_device.iter().map(|l| l.n_recalibrations).sum::<usize>()
                as f64),
        ),
        ("sched_overhead_share", Json::num(m.sched_overhead_share())),
    ]));
}

/// Median-throughput run of a live fleet cell; `check` vets every rep.
fn run_fleet_cell(
    reps: usize,
    build: &dyn Fn() -> FleetCoordinator,
    mk: &dyn Fn() -> Vec<Vec<TaskSpec>>,
    check: &dyn Fn(&FleetMetrics),
) -> FleetMetrics {
    let mut runs: Vec<FleetMetrics> = (0..reps)
        .map(|_| {
            let m = build().run(mk());
            check(&m);
            m
        })
        .collect();
    runs.sort_by(|a, b| a.tasks_per_sec.total_cmp(&b.tasks_per_sec));
    runs.swap_remove(runs.len() / 2)
}

fn workloads(workers: usize, batch: usize) -> Vec<Vec<TaskSpec>> {
    let p = profile_by_name("amd_r9").unwrap();
    let g = oclcc::task::synthetic::synthetic_benchmark("BK50", &p, SCALE).unwrap();
    (0..workers)
        .map(|w| (0..batch).map(|i| g.tasks[(w + i) % g.len()].clone()).collect())
        .collect()
}

/// Links modeled 2x too fast — the planted miscalibration.
fn miscal(p: &DeviceProfile) -> DeviceProfile {
    let mut m = p.clone();
    m.htd.bytes_per_sec *= 2.0;
    m.dth.bytes_per_sec *= 2.0;
    m
}

fn main() {
    let fast = fast_mode_from_env();
    let reps = if fast { 2 } else { 5 };
    let n = if fast { 24 } else { 48 };
    let mut rows: Vec<Json> = Vec::new();

    // ---- static scheduling cells -------------------------------------
    println!("== static fleet scheduling vs baselines (model time) ==");
    println!(
        "{:>5} {:>14} {:>12} {:>12} {:>9} {:>9}",
        "cell", "impl", "makespan", "tasks/s", "wall", "rr_ratio"
    );
    let tasks = static_tasks(n);
    for (cell, profs) in [("hom2", hom2()), ("het3", het3())] {
        let fleet = time_schedule(
            reps,
            &|| {
                let f = schedule_fleet(&tasks, &profs, &FleetOptions::default());
                MultiSchedule {
                    assignment: f.assignment,
                    orders: f.orders,
                    device_makespans: f.device_makespans,
                }
            },
            n,
        );
        // Unpruned wall time, for the bound-gating comparison (the
        // schedule itself is bit-identical — prop_fleet.rs).
        let unpruned = time_schedule(
            reps,
            &|| {
                let f = schedule_fleet(
                    &tasks,
                    &profs,
                    &FleetOptions { prune: false, ..FleetOptions::default() },
                );
                MultiSchedule {
                    assignment: f.assignment,
                    orders: f.orders,
                    device_makespans: f.device_makespans,
                }
            },
            n,
        );
        let multi = time_schedule(reps, &|| schedule_multi(&tasks, &profs), n);
        let rr = time_schedule(reps, &|| round_robin(&tasks, &profs), n);

        // Acceptance: fleet never behind the static wrapper, and the
        // placement actually pays off against round-robin on the
        // heterogeneous cell (equal-profile cells can tie).
        assert!(
            fleet.makespan <= multi.makespan,
            "{cell}: fleet ({}) worse than schedule_multi ({})",
            fleet.makespan,
            multi.makespan
        );
        assert!(
            fleet.makespan <= rr.makespan,
            "{cell}: fleet ({}) worse than round_robin ({})",
            fleet.makespan,
            rr.makespan
        );
        if cell == "het3" {
            assert!(
                fleet.makespan < rr.makespan,
                "het3: fleet ({}) does not strictly beat round_robin ({})",
                fleet.makespan,
                rr.makespan
            );
        }
        let s = schedule_fleet(&tasks, &profs, &FleetOptions::default());
        assert!(
            s.prune.total_saved() > 0,
            "{cell}: placement pruning never fired: {:?}",
            s.prune
        );

        for (name, r) in
            [("fleet", &fleet), ("static_multi", &multi), ("round_robin", &rr)]
        {
            println!(
                "{:>5} {:>14} {:>10.3}ms {:>12.1} {:>7.1}us {:>8.3}x",
                cell,
                name,
                r.makespan * 1e3,
                r.tasks_per_sec,
                r.sched_wall * 1e6,
                rr.makespan / r.makespan,
            );
            push_static_row(&mut rows, cell, name, n, r);
        }
        println!(
            "{:>5} {:>14} {:>10}   {:>12} {:>7.1}us (pruned {:.2}x faster, \
             pruned {} / early-exit {} / twins {})",
            cell,
            "fleet-unpruned",
            "-",
            "-",
            unpruned.sched_wall * 1e6,
            unpruned.sched_wall / fleet.sched_wall.max(1e-12),
            s.prune.n_cands_pruned,
            s.prune.n_rollouts_early_exit,
            s.prune.n_twin_collapsed,
        );
    }

    // ---- steal_rescue: live fleet, one device dies -------------------
    println!("\n== live fleet: quarantine-rescue stealing ==");
    {
        use oclcc::coordinator::recovery::{
            BlacklistAfterN, QuarantineOptions, RecoveryOptions,
        };
        let workers = 4usize;
        let batch = 3usize;
        let build = || {
            let flaky: Arc<dyn Device> = Arc::new(ChaosDevice::new(
                Arc::new(SimDevice::new(profile_by_name("amd_r9").unwrap())),
                ChaosOptions {
                    seed: 0xdead,
                    p_error: 1.0,
                    transient: false,
                    ..ChaosOptions::default()
                },
            ));
            let steady: Arc<dyn Device> =
                Arc::new(SimDevice::new(profile_by_name("amd_r9").unwrap()));
            FleetCoordinator::with_devices(
                vec![flaky, steady],
                FleetCoordOptions {
                    recovery: Some(RecoveryOptions {
                        deadline: None,
                        quarantine: QuarantineOptions {
                            cooldown: Duration::from_secs(600),
                        },
                        ..RecoveryOptions::blacklist(BlacklistAfterN {
                            n_failures: 1,
                            ..BlacklistAfterN::default()
                        })
                    }),
                    ..FleetCoordOptions::default()
                },
            )
        };
        let m = run_fleet_cell(reps, &build, &|| workloads(workers, batch), &|m| {
            assert_eq!(m.n_tasks, workers * batch, "steal_rescue lost tasks");
            assert!(
                m.n_stolen() > 0,
                "steal_rescue: quarantined backlog never rescued"
            );
        });
        println!(
            "steal_rescue: {:.1} tasks/s, {} stolen, {} quarantine trips",
            m.tasks_per_sec,
            m.n_stolen(),
            m.per_device.iter().map(|l| l.n_quarantine_trips).sum::<usize>(),
        );
        push_runtime_row(&mut rows, "steal_rescue", "fleet", &m);
    }

    // ---- miscal_het3: calibrated vs static placement models ----------
    println!("\n== live fleet: calibrated vs static placement models ==");
    {
        let workers = 6usize;
        let batch = 3usize;
        let build = |recal: Option<CalibrateOptions>| {
            let devices: Vec<Arc<dyn Device>> = het3()
                .into_iter()
                .map(|p| Arc::new(SimDevice::new(p)) as Arc<dyn Device>)
                .collect();
            FleetCoordinator::with_devices(
                devices,
                FleetCoordOptions { recalibrate: recal, ..FleetCoordOptions::default() },
            )
            .with_plan_models(het3().iter().map(miscal).collect())
        };
        let stat = run_fleet_cell(
            reps,
            &|| build(None),
            &|| workloads(workers, batch),
            &|m| assert_eq!(m.n_tasks, workers * batch),
        );
        let cal = run_fleet_cell(
            reps,
            &|| build(Some(CalibrateOptions::default())),
            &|| workloads(workers, batch),
            &|m| assert_eq!(m.n_tasks, workers * batch),
        );
        println!(
            "static {:.1} tasks/s, calibrated {:.1} tasks/s ({} adoptions)",
            stat.tasks_per_sec,
            cal.tasks_per_sec,
            cal.per_device.iter().map(|l| l.n_recalibrations).sum::<usize>(),
        );
        push_runtime_row(&mut rows, "miscal_het3", "static_model", &stat);
        push_runtime_row(&mut rows, "miscal_het3", "calibrated", &cal);
    }

    let doc = Json::obj(vec![
        ("bench_mode", Json::str(bench_mode())),
        ("rows", Json::arr(rows)),
    ]);
    match std::fs::write(OUT_PATH, doc.to_string()) {
        Ok(()) => println!("\n[saved {OUT_PATH}, mode={}]", bench_mode()),
        Err(e) => eprintln!("failed to write {OUT_PATH}: {e}"),
    }
}
