//! `cargo bench --bench multitenant` — QoS under multi-tenant overload,
//! per (cell) on the admission-armed online lane pipeline.
//!
//! Cells:
//!
//! * `hi_solo` — the two Hi tenants alone (no contention): the baseline
//!   their overload p99 is bounded against;
//! * `overload_shed` — the same Hi tenants while a saturating pack of
//!   BestEffort workers crowds one shared tenant past its backlog cap
//!   under `ShedLowest` + strict-priority draining. In-bench asserts:
//!   the exactly-once ledger identity (`executed + shed == submitted`),
//!   Hi work is never shed, the overload actually sheds (> 0 receipts),
//!   and Hi p99 stays inside a bounded multiple of `hi_solo`;
//! * `overload_block` — the same saturating load under `Block`: nothing
//!   is shed, every task completes (backpressure trades throughput for
//!   completeness — the block-vs-shed comparison cell);
//! * `overload_reject` — the same load under `RejectNew`;
//! * `fairness8` — 8 identical tenants under weighted-fair draining:
//!   Jain fairness over per-tenant mean latency must be >= 0.9;
//! * `collapse` — byte-identical submissions from 4 tenants on the
//!   legacy batch path with `collapse_twins`: cross-tenant spec twins
//!   execute once per drained batch (`n_xtenant_collapsed > 0`).
//!
//! Emits `BENCH_multitenant.json`; CI's bench-smoke job gates
//! `tasks_per_sec` per cell (higher is better, 30%) and `hi_p99_us` on
//! the Hi-bearing cells (lower is better, 150% — wall-clock p99 tails
//! jitter; the gate exists to catch priority inversion, which costs
//! orders of magnitude, not fractions).

use std::sync::Arc;
use std::time::Duration;

use oclcc::config::profile_by_name;
use oclcc::coordinator::lanes::{
    LaneCoordinator, LaneMetrics, LaneOptions, TenantWorkload,
};
use oclcc::coordinator::runner::Policy;
use oclcc::coordinator::{
    AdmissionOptions, DrainPolicyKind, Overflow, Priority, TenantId,
};
use oclcc::device::executor::SpinExecutor;
use oclcc::device::vdev::VirtualDevice;
use oclcc::device::Device;
use oclcc::sched::online::OnlineOptions;
use oclcc::task::synthetic::synthetic_benchmark;
use oclcc::task::TaskSpec;
use oclcc::util::bench::{bench_mode, fast_mode_from_env};
use oclcc::util::json::Json;
use oclcc::util::stats;

const OUT_PATH: &str = "BENCH_multitenant.json";

/// Time compression (same rationale as the other coordinator benches).
const SCALE: f64 = 0.05;

const LANES: usize = 2;
/// Hi tenant ids (one worker each; nothing outranks them).
const HI_TENANTS: [u32; 2] = [100, 101];
/// The shared tenant the BestEffort pack crowds past its cap.
const BE_TENANT: u32 = 9;

fn devices() -> Vec<Arc<dyn Device>> {
    (0..LANES)
        .map(|_| {
            let p = profile_by_name("amd_r9").unwrap();
            Arc::new(VirtualDevice::new(p, Arc::new(SpinExecutor)))
                as Arc<dyn Device>
        })
        .collect()
}

fn tasks(n: usize, offset: usize) -> Vec<TaskSpec> {
    let p = profile_by_name("amd_r9").unwrap();
    let g = synthetic_benchmark("BK50", &p, SCALE).unwrap();
    (0..n).map(|i| g.tasks[(offset + i) % g.len()].clone()).collect()
}

fn hi_workloads(batch: usize) -> Vec<TenantWorkload> {
    HI_TENANTS
        .iter()
        .map(|&t| TenantWorkload {
            tenant: TenantId(t),
            class: Priority::Hi,
            deadline: None,
            tasks: tasks(batch, t as usize),
        })
        .collect()
}

fn be_workloads(workers: usize, batch: usize) -> Vec<TenantWorkload> {
    (0..workers)
        .map(|w| TenantWorkload {
            tenant: TenantId(BE_TENANT),
            class: Priority::BestEffort,
            deadline: None,
            tasks: tasks(batch, w),
        })
        .collect()
}

fn coordinator(admission: AdmissionOptions) -> LaneCoordinator {
    LaneCoordinator::with_devices(
        devices(),
        LaneOptions {
            lanes: LANES,
            policy: Policy::Heuristic,
            settle: Duration::from_micros(200),
            group_cap: 2,
            online: Some(OnlineOptions::default()),
            admission: Some(admission),
            ..LaneOptions::default()
        },
    )
}

fn overload_admission(overflow: Overflow) -> AdmissionOptions {
    AdmissionOptions {
        per_tenant_cap: 1,
        global_cap: 16,
        overflow,
        policy: DrainPolicyKind::StrictPriority,
        collapse_twins: false,
        ..AdmissionOptions::default()
    }
}

struct CellResult {
    tasks_per_sec: f64,
    /// p99 completion latency over the Hi tenants' tasks (None when the
    /// cell has no Hi tenant).
    hi_p99: Option<f64>,
    n_shed: usize,
    n_block_waits: usize,
    jain: f64,
    n_collapsed: u64,
    n_tasks: usize,
}

fn summarize(m: &LaneMetrics) -> CellResult {
    let rep = m.admission.as_ref().expect("every cell is admission-armed");
    let hi: Vec<f64> = m
        .latencies
        .iter()
        .zip(&m.latency_tenants)
        .filter(|&(_, &t)| HI_TENANTS.contains(&t))
        .map(|(&l, _)| l)
        .collect();
    CellResult {
        tasks_per_sec: m.tasks_per_sec,
        hi_p99: (!hi.is_empty()).then(|| stats::percentile(&hi, 99.0)),
        n_shed: rep.n_shed,
        n_block_waits: rep.n_block_waits,
        jain: rep.jain_fairness,
        n_collapsed: m.per_lane.iter().map(|l| l.n_xtenant_collapsed).sum(),
        n_tasks: m.n_tasks,
    }
}

/// Median-of-reps run of one cell; per-rep invariants checked by
/// `check` (ledger identities, QoS asserts).
fn run_cell(
    mk: impl Fn() -> (LaneCoordinator, Vec<TenantWorkload>),
    reps: usize,
    check: impl Fn(&LaneMetrics),
) -> CellResult {
    let mut tps = Vec::with_capacity(reps);
    let mut p99 = Vec::with_capacity(reps);
    let mut last = None;
    for _ in 0..reps {
        let (c, wl) = mk();
        let m = c.run_tenants(wl);
        check(&m);
        let r = summarize(&m);
        tps.push(r.tasks_per_sec);
        if let Some(v) = r.hi_p99 {
            p99.push(v);
        }
        last = Some(r);
    }
    let mut r = last.expect("reps >= 1");
    r.tasks_per_sec = stats::median(&tps);
    if !p99.is_empty() {
        r.hi_p99 = Some(stats::median(&p99));
    }
    r
}

fn main() {
    let fast = fast_mode_from_env();
    let reps = if fast { 2 } else { 5 };
    let be_workers = if fast { 4 } else { 8 };
    let batch = if fast { 3 } else { 4 };
    let hi_total = HI_TENANTS.len() * batch;

    println!("== multi-tenant admission under overload (per cell) ==");
    println!(
        "{:>15} {:>12} {:>10} {:>7} {:>7} {:>6} {:>9}",
        "cell", "goodput", "hi_p99", "shed", "blocked", "jain", "collapsed"
    );
    let mut rows: Vec<Json> = Vec::new();

    // hi_solo: the two Hi tenants alone — the p99 baseline.
    let solo = run_cell(
        || (coordinator(overload_admission(Overflow::ShedLowest)), hi_workloads(batch)),
        reps,
        |m| {
            assert_eq!(m.n_tasks, hi_total, "solo Hi run lost tasks");
            let rep = m.admission.as_ref().unwrap();
            assert_eq!(rep.n_shed, 0, "uncontended Hi tenants can never shed");
        },
    );
    emit(&mut rows, "hi_solo", &solo);
    let solo_p99 = solo.hi_p99.expect("hi_solo has Hi latencies");
    // Bounded threshold for the overload cells: a generous multiple of
    // the uncontended baseline with an absolute floor so scheduler
    // jitter on millisecond tails cannot trip it — priority inversion
    // (Hi queued behind a saturating BestEffort backlog) costs far more.
    let hi_bound = (10.0 * solo_p99).max(0.025);

    let overload =
        |overflow| move || -> (LaneCoordinator, Vec<TenantWorkload>) {
            let mut wl = hi_workloads(batch);
            wl.extend(be_workloads(be_workers, batch));
            (coordinator(overload_admission(overflow)), wl)
        };
    let total = hi_total + be_workers * batch;

    let hi_all_complete = |m: &LaneMetrics| {
        let rep = m.admission.as_ref().unwrap();
        for t in &rep.per_tenant {
            if HI_TENANTS.contains(&t.tenant) {
                assert_eq!(t.n_shed, 0, "Hi tenant {} was shed", t.tenant);
                assert_eq!(
                    t.n_completed, batch,
                    "Hi tenant {} lost work",
                    t.tenant
                );
            }
        }
    };

    // overload_shed: saturating BestEffort pack vs bounded Hi p99.
    let shed = run_cell(overload(Overflow::ShedLowest), reps, |m| {
        let rep = m.admission.as_ref().unwrap();
        assert_eq!(
            m.n_tasks + rep.n_shed,
            total,
            "ledger identity: executed + shed == submitted"
        );
        assert!(rep.n_shed > 0, "the overload cell must actually shed");
        hi_all_complete(m);
        let hi: Vec<f64> = m
            .latencies
            .iter()
            .zip(&m.latency_tenants)
            .filter(|&(_, &t)| HI_TENANTS.contains(&t))
            .map(|(&l, _)| l)
            .collect();
        let hi_p99 = stats::percentile(&hi, 99.0);
        assert!(
            hi_p99 <= hi_bound,
            "saturating BestEffort pushed Hi p99 to {:.2}ms \
             (bound {:.2}ms, solo {:.2}ms)",
            hi_p99 * 1e3,
            hi_bound * 1e3,
            solo_p99 * 1e3
        );
    });
    emit(&mut rows, "overload_shed", &shed);

    // overload_block: backpressure — nothing shed, everything completes.
    let block = run_cell(overload(Overflow::Block), reps, |m| {
        let rep = m.admission.as_ref().unwrap();
        assert_eq!(rep.n_shed, 0, "Block never sheds");
        assert_eq!(m.n_tasks, total, "blocked producers must all finish");
        hi_all_complete(m);
    });
    emit(&mut rows, "overload_block", &block);

    // overload_reject: immediate typed rejection.
    let reject = run_cell(overload(Overflow::RejectNew), reps, |m| {
        let rep = m.admission.as_ref().unwrap();
        assert_eq!(m.n_tasks + rep.n_shed, total, "ledger identity");
        hi_all_complete(m);
    });
    emit(&mut rows, "overload_reject", &reject);

    // fairness8: 8 identical tenants under weighted-fair draining.
    let fair = run_cell(
        || {
            let wl: Vec<TenantWorkload> = (0..8)
                .map(|t| TenantWorkload {
                    tenant: TenantId(t),
                    class: Priority::Normal,
                    deadline: None,
                    tasks: tasks(batch, t as usize),
                })
                .collect();
            let adm = AdmissionOptions {
                per_tenant_cap: 4,
                global_cap: 64,
                overflow: Overflow::Block,
                policy: DrainPolicyKind::WeightedFair,
                collapse_twins: false,
                ..AdmissionOptions::default()
            };
            (coordinator(adm), wl)
        },
        reps,
        |m| {
            assert_eq!(m.n_tasks, 8 * batch, "fairness cell lost tasks");
            let rep = m.admission.as_ref().unwrap();
            assert!(
                rep.jain_fairness >= 0.9,
                "Jain fairness {:.3} < 0.9 across 8 equal tenants",
                rep.jain_fairness
            );
        },
    );
    emit(&mut rows, "fairness8", &fair);

    // collapse: byte-identical submissions across tenants, legacy path.
    let collapse = run_cell(
        || {
            let spec = tasks(1, 0).remove(0);
            let wl: Vec<TenantWorkload> = (0..4)
                .map(|t| TenantWorkload {
                    tenant: TenantId(t),
                    class: Priority::Normal,
                    deadline: None,
                    tasks: vec![spec.clone(); 2],
                })
                .collect();
            let c = LaneCoordinator::with_devices(
                vec![devices().remove(0)],
                LaneOptions {
                    lanes: 1,
                    policy: Policy::NoReorder,
                    // A wide straggler window so all 4 tenants' identical
                    // submissions land in the same drained batch.
                    settle: Duration::from_millis(5),
                    admission: Some(AdmissionOptions {
                        per_tenant_cap: 4,
                        global_cap: 64,
                        overflow: Overflow::Block,
                        policy: DrainPolicyKind::Fifo,
                        collapse_twins: true,
                        ..AdmissionOptions::default()
                    }),
                    ..LaneOptions::default()
                },
            );
            (c, wl)
        },
        reps,
        |m| {
            assert_eq!(m.n_tasks, 8, "every collapsed twin still completes");
            let n: u64 =
                m.per_lane.iter().map(|l| l.n_xtenant_collapsed).sum();
            assert!(n > 0, "identical cross-tenant rows must collapse");
        },
    );
    emit(&mut rows, "collapse", &collapse);

    let doc = Json::obj(vec![
        ("bench_mode", Json::str(bench_mode())),
        ("rows", Json::arr(rows)),
    ]);
    match std::fs::write(OUT_PATH, doc.to_string()) {
        Ok(()) => println!("[saved {OUT_PATH}, mode={}]", bench_mode()),
        Err(e) => eprintln!("failed to write {OUT_PATH}: {e}"),
    }
}

fn emit(rows: &mut Vec<Json>, cell: &str, r: &CellResult) {
    let hi_p99_s = r.hi_p99.unwrap_or(f64::NAN);
    println!(
        "{:>15} {:>9.1}/s {:>8} {:>7} {:>7} {:>6.3} {:>9}",
        cell,
        r.tasks_per_sec,
        r.hi_p99
            .map_or_else(|| "-".to_string(), |v| format!("{:.2}ms", v * 1e3)),
        r.n_shed,
        r.n_block_waits,
        r.jain,
        r.n_collapsed,
    );
    let mut fields = vec![
        ("cell", Json::str(cell)),
        ("n_tasks", Json::num(r.n_tasks as f64)),
        ("tasks_per_sec", Json::num(r.tasks_per_sec)),
        ("n_shed", Json::num(r.n_shed as f64)),
        ("n_block_waits", Json::num(r.n_block_waits as f64)),
        ("jain_fairness", Json::num(r.jain)),
        ("n_xtenant_collapsed", Json::num(r.n_collapsed as f64)),
    ];
    if hi_p99_s.is_finite() {
        fields.push(("hi_p99_us", Json::num(hi_p99_s * 1e6)));
    }
    rows.push(Json::obj(fields));
}
