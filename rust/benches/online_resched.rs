//! `cargo bench --bench online_resched` — online mid-group rescheduling
//! vs the drain-then-plan baseline, per workers × lanes cell on the two
//! workload poles (dominant-transfer BK0 and dominant-kernel BK100), plus
//! a deliberately skewed cell that exercises lane work-stealing.
//!
//! Each cell runs the full live pipeline twice on identical workloads:
//! once with `LaneOptions::online` (device execution on a runner thread,
//! mid-group merge into the uncommitted suffix, drift-gated suffix
//! re-plans, cross-round `EngineState` carry, bounded work-stealing) and
//! once with the classic drain → plan → run rounds. Recorded per cell:
//!
//! * `makespan_s` online vs `baseline_makespan_s`, and their ratio — the
//!   headline "mid-group rescheduling beats drain-then-plan" number;
//! * `replan_p50_s` / `replan_p99_s` — re-plan latency distribution (the
//!   Table-6 overhead budget now applies to re-plans);
//! * `drift_gate_fire_rate` — fired / considered gate consultations;
//! * `steal_count` — submissions moved between lanes;
//! * `sched_overhead_share` for both runtimes;
//! * `model_drift` — pooled |measured/predicted − 1| of the lane models.
//!
//! A second sweep runs **calibrated vs static** cells on deliberately
//! miscalibrated planning models (link bandwidths 2x reality, via
//! `LaneCoordinator::with_plan_model`): the static model plans on the
//! wrong rates for the whole run, the calibrated one feeds measured
//! per-engine times back through `LaneOptions::recalibrate` and must show
//! reduced model drift. Rows carry shapes `miscal_static` /
//! `miscal_calibrated` plus the adopted correction factors.
//!
//! Emits `BENCH_online_resched.json` with a self-describing
//! `bench_mode` header; uploaded by CI's bench-smoke job next to the
//! existing BENCH_*.json trajectories.

use std::sync::Arc;
use std::time::Duration;

use oclcc::config::{profile_by_name, DeviceProfile};
use oclcc::coordinator::lanes::{LaneCoordinator, LaneMetrics, LaneOptions};
use oclcc::coordinator::runner::Policy;
use oclcc::device::executor::SpinExecutor;
use oclcc::model::CalibrateOptions;
use oclcc::sched::online::OnlineOptions;
use oclcc::task::synthetic::synthetic_benchmark;
use oclcc::task::TaskSpec;
use oclcc::util::bench::{bench_mode, fast_mode_from_env};
use oclcc::util::json::Json;
use oclcc::util::stats;

const OUT_PATH: &str = "BENCH_online_resched.json";

/// Time compression for the virtual device (same rationale as the
/// coordinator bench: ratios intact, cells in low milliseconds).
const SCALE: f64 = 0.05;

/// Per-worker dependent batch length.
const BATCH: usize = 3;

/// Balanced workload: every worker runs `BATCH` tasks dealt round-robin
/// from the labelled synthetic catalog (BK0 = all dominant-transfer,
/// BK100 = all dominant-kernel).
fn workloads(label: &str, workers: usize) -> Vec<Vec<TaskSpec>> {
    let p = profile_by_name("amd_r9").unwrap();
    let g = synthetic_benchmark(label, &p, SCALE).unwrap();
    (0..workers)
        .map(|w| (0..BATCH).map(|i| g.tasks[(w + i) % g.len()].clone()).collect())
        .collect()
}

/// Skewed workload: only even worker slots carry tasks, so with 2 lanes
/// every submission lands on lane 0 and lane 1 can only contribute by
/// stealing.
fn skewed_workloads(label: &str, loaded: usize) -> Vec<Vec<TaskSpec>> {
    let p = profile_by_name("amd_r9").unwrap();
    let g = synthetic_benchmark(label, &p, SCALE).unwrap();
    (0..loaded * 2)
        .map(|w| {
            if w % 2 == 0 {
                (0..BATCH).map(|i| g.tasks[(w + i) % g.len()].clone()).collect()
            } else {
                Vec::new()
            }
        })
        .collect()
}

fn coordinator(lanes: usize, group_cap: usize, online: Option<OnlineOptions>) -> LaneCoordinator {
    coordinator_calibrated(lanes, group_cap, online, None, None)
}

/// [`coordinator`] with an optional planning-model override (the
/// miscalibrated-model cells) and optional online recalibration.
fn coordinator_calibrated(
    lanes: usize,
    group_cap: usize,
    online: Option<OnlineOptions>,
    plan_model: Option<DeviceProfile>,
    recalibrate: Option<CalibrateOptions>,
) -> LaneCoordinator {
    let c = LaneCoordinator::homogeneous(
        profile_by_name("amd_r9").unwrap(),
        Arc::new(SpinExecutor),
        LaneOptions {
            lanes,
            policy: Policy::Heuristic,
            settle: Duration::from_micros(200),
            group_cap,
            scoring_threads: 1,
            online,
            recalibrate,
            recovery: None,
            admission: None,
        },
    );
    match plan_model {
        Some(m) => c.with_plan_model(m),
        None => c,
    }
}

/// amd_r9 with both link bandwidths doubled: a model that believes
/// transfers run 2x faster than the device actually paces them.
fn miscalibrated_model() -> DeviceProfile {
    let mut m = profile_by_name("amd_r9").unwrap();
    m.htd.bytes_per_sec *= 2.0;
    m.dth.bytes_per_sec *= 2.0;
    m
}

struct CellResult {
    makespan: f64,
    sched_share: f64,
    /// Pooled per-re-plan wall seconds (distribution for p50/p99).
    replans: Vec<f64>,
    /// Median re-plan count per rep (rep-count independent).
    replans_per_rep: f64,
    fire_rate: f64,
    /// Median steal count per rep (rep-count independent).
    steals_per_rep: f64,
    /// Median bound-gated pruning counters per rep (rep-count
    /// independent), summed across lanes.
    pruned_per_rep: f64,
    early_exit_per_rep: f64,
    twin_collapsed_per_rep: f64,
    /// Pooled model drift |measured/predicted - 1| across lanes.
    model_drift: f64,
    /// Median corrected-model adoptions per rep, summed across lanes.
    recalibrations_per_rep: f64,
    /// Mean adopted correction factors across lanes (1.0 = static).
    calib_htd: f64,
    calib_kernel: f64,
    calib_dth: f64,
    n_tasks: usize,
}

fn summarize(m: &LaneMetrics) -> CellResult {
    let mut replans: Vec<f64> = Vec::new();
    let (mut fired, mut considered, mut steals) = (0usize, 0usize, 0usize);
    let (mut pruned, mut early, mut twins) = (0u64, 0u64, 0u64);
    let (mut busy, mut pred) = (0.0f64, 0.0f64);
    let mut recals = 0usize;
    let (mut ch, mut ck, mut cd) = (0.0f64, 0.0f64, 0.0f64);
    for l in &m.per_lane {
        replans.extend(l.replan_secs.iter().copied());
        fired += l.n_replans;
        considered += l.n_replan_considered;
        steals += l.n_stolen;
        pruned += l.n_cands_pruned;
        early += l.n_rollouts_early_exit;
        twins += l.n_twin_collapsed;
        busy += l.busy_secs;
        pred += l.predicted_secs;
        recals += l.n_recalibrations;
        ch += l.calib_htd;
        ck += l.calib_kernel;
        cd += l.calib_dth;
    }
    let lanes = m.per_lane.len().max(1) as f64;
    CellResult {
        makespan: m.total_secs,
        sched_share: m.sched_overhead_share(),
        replans,
        replans_per_rep: fired as f64,
        fire_rate: if considered == 0 { 0.0 } else { fired as f64 / considered as f64 },
        steals_per_rep: steals as f64,
        pruned_per_rep: pruned as f64,
        early_exit_per_rep: early as f64,
        twin_collapsed_per_rep: twins as f64,
        model_drift: if pred > 0.0 { (busy / pred - 1.0).abs() } else { 0.0 },
        recalibrations_per_rep: recals as f64,
        calib_htd: ch / lanes,
        calib_kernel: ck / lanes,
        calib_dth: cd / lanes,
        n_tasks: m.n_tasks,
    }
}

/// Median-of-reps run of one (workload, lanes, mode) cell. Count metrics
/// (re-plans, steals, recalibrations) are per-rep medians so fast
/// (2-rep) and full (5-rep) trajectories stay comparable; only the
/// re-plan *latency* samples are pooled across reps, for a denser
/// p50/p99. `build` constructs a fresh coordinator per rep.
fn run_cell(
    build: &dyn Fn() -> LaneCoordinator,
    mk: &dyn Fn() -> Vec<Vec<TaskSpec>>,
    reps: usize,
    expect_tasks: usize,
) -> CellResult {
    let mut makespans = Vec::with_capacity(reps);
    let mut shares = Vec::with_capacity(reps);
    let mut fire_rates = Vec::with_capacity(reps);
    let mut replan_counts = Vec::with_capacity(reps);
    let mut steal_counts = Vec::with_capacity(reps);
    let mut pruned_counts = Vec::with_capacity(reps);
    let mut early_counts = Vec::with_capacity(reps);
    let mut twin_counts = Vec::with_capacity(reps);
    let mut drifts = Vec::with_capacity(reps);
    let mut recal_counts = Vec::with_capacity(reps);
    let mut calib_h = Vec::with_capacity(reps);
    let mut calib_k = Vec::with_capacity(reps);
    let mut calib_d = Vec::with_capacity(reps);
    let mut replans: Vec<f64> = Vec::new();
    for _ in 0..reps {
        let c = build();
        let m = c.run(mk());
        assert_eq!(m.n_tasks, expect_tasks, "lost tasks in cell");
        let r = summarize(&m);
        makespans.push(r.makespan);
        shares.push(r.sched_share);
        fire_rates.push(r.fire_rate);
        replan_counts.push(r.replans_per_rep);
        steal_counts.push(r.steals_per_rep);
        pruned_counts.push(r.pruned_per_rep);
        early_counts.push(r.early_exit_per_rep);
        twin_counts.push(r.twin_collapsed_per_rep);
        drifts.push(r.model_drift);
        recal_counts.push(r.recalibrations_per_rep);
        calib_h.push(r.calib_htd);
        calib_k.push(r.calib_kernel);
        calib_d.push(r.calib_dth);
        replans.extend(r.replans);
    }
    CellResult {
        makespan: stats::median(&makespans),
        sched_share: stats::median(&shares),
        replans,
        replans_per_rep: stats::median(&replan_counts),
        fire_rate: stats::median(&fire_rates),
        steals_per_rep: stats::median(&steal_counts),
        pruned_per_rep: stats::median(&pruned_counts),
        early_exit_per_rep: stats::median(&early_counts),
        twin_collapsed_per_rep: stats::median(&twin_counts),
        model_drift: stats::median(&drifts),
        recalibrations_per_rep: stats::median(&recal_counts),
        calib_htd: stats::median(&calib_h),
        calib_kernel: stats::median(&calib_k),
        calib_dth: stats::median(&calib_d),
        n_tasks: expect_tasks,
    }
}

fn main() {
    let fast = fast_mode_from_env();
    let reps = if fast { 2 } else { 5 };

    let mut rows: Vec<Json> = Vec::new();
    println!("== online mid-group rescheduling vs drain-then-plan ==");
    println!(
        "{:>7} {:>8} {:>5} {:>11} {:>11} {:>7} {:>9} {:>9} {:>6} {:>6}",
        "load", "workers", "lanes", "online", "baseline", "ratio", "replan50",
        "replan99", "fire%", "steals"
    );

    let mut cells: Vec<(String, f64)> = Vec::new();
    for label in ["BK0", "BK100"] {
        for &workers in &[4usize, 8] {
            for &lanes in &[1usize, 2] {
                if lanes > workers {
                    continue;
                }
                let expect = workers * BATCH;
                // Half-round groups: with full-round groups every worker's
                // next submission arrives only after the group drains
                // (dependent batches), so there would be nothing to merge
                // mid-group in either runtime. Splitting rounds keeps the
                // buffer hot while the device runs — the open-stream shape
                // the online pipeline (and the paper's motivating
                // scenario) is about. Both runtimes get the same cap.
                let cap = workers.div_ceil(lanes).div_ceil(2).max(2);
                let mk = move || workloads(label, workers);
                let online = run_cell(
                    &|| coordinator(lanes, cap, Some(OnlineOptions::default())),
                    &mk,
                    reps,
                    expect,
                );
                let base =
                    run_cell(&|| coordinator(lanes, cap, None), &mk, reps, expect);
                emit_cell(
                    &mut rows,
                    &mut cells,
                    label,
                    "balanced",
                    workers,
                    lanes,
                    &online,
                    &base,
                );
            }
        }
        // Skewed cell: 4 loaded workers, all on lane 0 of 2; group_cap 2
        // keeps the victim's buffer hot so stealing has something to move.
        let loaded = 4usize;
        let expect = loaded * BATCH;
        let mk = move || skewed_workloads(label, loaded);
        let online = run_cell(
            &|| coordinator(2, 2, Some(OnlineOptions::default())),
            &mk,
            reps,
            expect,
        );
        let base = run_cell(&|| coordinator(2, 2, None), &mk, reps, expect);
        emit_cell(
            &mut rows,
            &mut cells,
            label,
            "skewed",
            loaded,
            2,
            &online,
            &base,
        );
    }

    // ---- calibrated vs static model on miscalibrated profiles --------
    //
    // The planning model believes both links are 2x faster than the
    // device paces them. The static cells plan on the wrong rates
    // forever; the calibrated cells adopt measured-rate corrections and
    // must show reduced model drift (and the correction factors pulling
    // toward ~2x). BK0 is the transfer-dominant pole where the planted
    // error distorts ordering most; BK100 bounds the kernel-dominant
    // side.
    println!("\n== online recalibration vs static model (links modeled 2x too fast) ==");
    println!(
        "{:>7} {:>11} {:>11} {:>9} {:>9} {:>7} {:>7}",
        "load", "static", "calibrated", "driftS", "driftC", "recals", "htd_fx"
    );
    for label in ["BK0", "BK100"] {
        let workers = 4usize;
        let lanes = 1usize;
        let cap = 2usize;
        let expect = workers * BATCH;
        let mk = move || workloads(label, workers);
        let online = Some(OnlineOptions::default());
        let stat = run_cell(
            &|| coordinator_calibrated(lanes, cap, online, Some(miscalibrated_model()), None),
            &mk,
            reps,
            expect,
        );
        let cal = run_cell(
            &|| {
                coordinator_calibrated(
                    lanes,
                    cap,
                    online,
                    Some(miscalibrated_model()),
                    Some(CalibrateOptions::default()),
                )
            },
            &mk,
            reps,
            expect,
        );
        println!(
            "{:>7} {:>9.3}ms {:>9.3}ms {:>8.1}% {:>8.1}% {:>7.1} {:>6.2}x",
            label,
            stat.makespan * 1e3,
            cal.makespan * 1e3,
            stat.model_drift * 100.0,
            cal.model_drift * 100.0,
            cal.recalibrations_per_rep,
            cal.calib_htd,
        );
        // Both sides are first-class trajectory cells (distinct shapes
        // keep the (workload, shape, workers, lanes) diff key unique);
        // neither joins the online-vs-drain headline geomean.
        emit_miscal_cell(&mut rows, label, "miscal_static", workers, lanes, &stat);
        emit_miscal_cell(&mut rows, label, "miscal_calibrated", workers, lanes, &cal);
    }

    // Headline: geometric-mean speedup of online over drain-then-plan.
    let ratios: Vec<f64> = cells.iter().map(|(_, r)| *r).collect();
    let gm = stats::geomean(&ratios);
    println!(
        "\nonline vs drain-then-plan makespan, geometric mean over {} cells: \
         {gm:.3}x (>1 = online faster)",
        cells.len()
    );

    let doc = Json::obj(vec![
        ("bench_mode", Json::str(bench_mode())),
        ("geomean_speedup", Json::num(gm)),
        ("rows", Json::arr(rows)),
    ]);
    match std::fs::write(OUT_PATH, doc.to_string()) {
        Ok(()) => println!("[saved {OUT_PATH}, mode={}]", bench_mode()),
        Err(e) => eprintln!("failed to write {OUT_PATH}: {e}"),
    }
}

#[allow(clippy::too_many_arguments)]
fn emit_cell(
    rows: &mut Vec<Json>,
    cells: &mut Vec<(String, f64)>,
    label: &str,
    shape: &str,
    workers: usize,
    lanes: usize,
    online: &CellResult,
    base: &CellResult,
) {
    let ratio = base.makespan / online.makespan.max(1e-12);
    let p50 = stats::percentile(&online.replans, 50.0);
    let p99 = stats::percentile(&online.replans, 99.0);
    println!(
        "{:>7} {:>8} {:>5} {:>9.3}ms {:>9.3}ms {:>6.3}x {:>7.1}us {:>7.1}us {:>5.0}% {:>6.1}",
        format!("{label}/{shape}"),
        workers,
        lanes,
        online.makespan * 1e3,
        base.makespan * 1e3,
        ratio,
        p50 * 1e6,
        p99 * 1e6,
        online.fire_rate * 100.0,
        online.steals_per_rep,
    );
    rows.push(Json::obj(vec![
        ("workload", Json::str(label)),
        ("shape", Json::str(shape)),
        ("workers", Json::num(workers as f64)),
        ("lanes", Json::num(lanes as f64)),
        ("n_tasks", Json::num(online.n_tasks as f64)),
        ("makespan_s", Json::num(online.makespan)),
        ("baseline_makespan_s", Json::num(base.makespan)),
        ("speedup_vs_baseline", Json::num(ratio)),
        ("replan_count", Json::num(online.replans_per_rep)),
        ("replan_p50_s", Json::num(p50)),
        ("replan_p99_s", Json::num(p99)),
        ("drift_gate_fire_rate", Json::num(online.fire_rate)),
        ("steal_count", Json::num(online.steals_per_rep)),
        ("sched_overhead_share", Json::num(online.sched_share)),
        ("baseline_sched_overhead_share", Json::num(base.sched_share)),
        ("model_drift", Json::num(online.model_drift)),
        ("baseline_model_drift", Json::num(base.model_drift)),
        ("n_cands_pruned", Json::num(online.pruned_per_rep)),
        ("n_rollouts_early_exit", Json::num(online.early_exit_per_rep)),
        ("n_twin_collapsed", Json::num(online.twin_collapsed_per_rep)),
        ("baseline_n_cands_pruned", Json::num(base.pruned_per_rep)),
        ("baseline_n_rollouts_early_exit", Json::num(base.early_exit_per_rep)),
        ("baseline_n_twin_collapsed", Json::num(base.twin_collapsed_per_rep)),
    ]));
    cells.push((format!("{label}/{shape}/{workers}w{lanes}l"), ratio));
}

/// One calibrated-vs-static trajectory row (shapes `miscal_static` /
/// `miscal_calibrated`): the cell's own makespan, model drift and
/// calibration telemetry — no drain-then-plan baseline pairing.
fn emit_miscal_cell(
    rows: &mut Vec<Json>,
    label: &str,
    shape: &str,
    workers: usize,
    lanes: usize,
    cell: &CellResult,
) {
    rows.push(Json::obj(vec![
        ("workload", Json::str(label)),
        ("shape", Json::str(shape)),
        ("workers", Json::num(workers as f64)),
        ("lanes", Json::num(lanes as f64)),
        ("n_tasks", Json::num(cell.n_tasks as f64)),
        ("makespan_s", Json::num(cell.makespan)),
        ("model_drift", Json::num(cell.model_drift)),
        ("sched_overhead_share", Json::num(cell.sched_share)),
        ("drift_gate_fire_rate", Json::num(cell.fire_rate)),
        ("replan_count", Json::num(cell.replans_per_rep)),
        ("n_recalibrations", Json::num(cell.recalibrations_per_rep)),
        ("calib_htd", Json::num(cell.calib_htd)),
        ("calib_kernel", Json::num(cell.calib_kernel)),
        ("calib_dth", Json::num(cell.calib_dth)),
    ]));
}
