//! `cargo bench --bench recovery` — goodput under injected faults, per
//! (recovery policy × fault rate) cell on the online lane pipeline.
//!
//! Each lane's virtual device is wrapped in a deterministic
//! [`ChaosDevice`] (seeded per lane, so every run of a cell sees the
//! same fault schedule) and driven through `LaneOptions::recovery`:
//!
//! * `none / 0%` — no wrapper, no recovery: the pre-fault-tolerance
//!   pipeline, the transparency baseline;
//! * `retry / {0,10,30}%` — transient `Err` injections absorbed by
//!   [`RetryBackoff`] (the 0% cell must match the baseline — the cost of
//!   *arming* recovery);
//! * `blacklist / {0,10,30}%` — same faults under [`BlacklistAfterN`]
//!   (quarantine + sibling rescue instead of unbounded same-lane
//!   retries);
//! * `deadline / 15%` — artificial device hangs caught by the
//!   run-deadline watchdog (`predicted × slack + floor`), lane
//!   quarantined, backlog rescued by the healthy sibling.
//!
//! Recorded per cell: goodput (`tasks_per_sec` — every task completes
//! exactly once, so goodput is throughput), p99 task latency, and the
//! six `LaneStats` fault counters summed across lanes. Emits
//! `BENCH_recovery.json` with a self-describing `bench_mode` header;
//! CI's bench-smoke job diffs `tasks_per_sec` per (policy, fault_pct)
//! cell against the previous run (higher is better, 30% threshold).

use std::sync::Arc;
use std::time::Duration;

use oclcc::config::profile_by_name;
use oclcc::coordinator::lanes::{LaneCoordinator, LaneMetrics, LaneOptions};
use oclcc::coordinator::recovery::{
    BlacklistAfterN, DeadlineOptions, QuarantineOptions, RecoveryOptions,
    RetryBackoff,
};
use oclcc::coordinator::runner::Policy;
use oclcc::device::executor::SpinExecutor;
use oclcc::device::vdev::VirtualDevice;
use oclcc::device::{ChaosDevice, ChaosOptions, Device};
use oclcc::sched::online::OnlineOptions;
use oclcc::task::synthetic::synthetic_benchmark;
use oclcc::task::TaskSpec;
use oclcc::util::bench::{bench_mode, fast_mode_from_env};
use oclcc::util::json::Json;
use oclcc::util::stats;

const OUT_PATH: &str = "BENCH_recovery.json";

/// Time compression (same rationale as the other coordinator benches).
const SCALE: f64 = 0.05;

const WORKERS: usize = 4;
const LANES: usize = 2;
const BATCH: usize = 3;

fn workloads() -> Vec<Vec<TaskSpec>> {
    let p = profile_by_name("amd_r9").unwrap();
    let g = synthetic_benchmark("BK50", &p, SCALE).unwrap();
    (0..WORKERS)
        .map(|w| (0..BATCH).map(|i| g.tasks[(w + i) % g.len()].clone()).collect())
        .collect()
}

/// One lane device: a real paced virtual device, chaos-wrapped when any
/// fault probability is set. Seeded per lane so the whole fleet's fault
/// schedule is a deterministic function of the cell.
fn lane_device(lane: usize, chaos: Option<&ChaosOptions>) -> Arc<dyn Device> {
    let p = profile_by_name("amd_r9").unwrap();
    let vdev = Arc::new(VirtualDevice::new(p, Arc::new(SpinExecutor)));
    match chaos {
        None => vdev,
        Some(opts) => Arc::new(ChaosDevice::new(
            vdev,
            ChaosOptions { seed: opts.seed + lane as u64, ..opts.clone() },
        )),
    }
}

fn coordinator(
    chaos: Option<&ChaosOptions>,
    recovery: Option<RecoveryOptions>,
) -> LaneCoordinator {
    let devices =
        (0..LANES).map(|l| lane_device(l, chaos)).collect::<Vec<_>>();
    LaneCoordinator::with_devices(
        devices,
        LaneOptions {
            lanes: LANES,
            policy: Policy::Heuristic,
            settle: Duration::from_micros(200),
            group_cap: 2,
            scoring_threads: 1,
            online: Some(OnlineOptions::default()),
            recalibrate: None,
            recovery,
            admission: None,
        },
    )
}

struct CellResult {
    tasks_per_sec: f64,
    p99_latency: f64,
    n_faults: usize,
    n_retries: usize,
    n_timeouts: usize,
    n_requeued: usize,
    n_quarantine_trips: usize,
    n_halfopen_probes: usize,
    n_stolen: usize,
}

fn summarize(m: &LaneMetrics) -> CellResult {
    let mut r = CellResult {
        tasks_per_sec: m.tasks_per_sec,
        p99_latency: m.p99_latency(),
        n_faults: 0,
        n_retries: 0,
        n_timeouts: 0,
        n_requeued: 0,
        n_quarantine_trips: 0,
        n_halfopen_probes: 0,
        n_stolen: 0,
    };
    for l in &m.per_lane {
        r.n_faults += l.n_faults;
        r.n_retries += l.n_retries;
        r.n_timeouts += l.n_timeouts;
        r.n_requeued += l.n_requeued;
        r.n_quarantine_trips += l.n_quarantine_trips;
        r.n_halfopen_probes += l.n_halfopen_probes;
        r.n_stolen += l.n_stolen;
    }
    r
}

/// Median-of-reps run of one cell; every rep must complete every task
/// exactly once (`LaneMetrics` counts completion events).
fn run_cell(
    chaos: Option<&ChaosOptions>,
    recovery: Option<&RecoveryOptions>,
    reps: usize,
) -> CellResult {
    let expect = WORKERS * BATCH;
    let mut tps = Vec::with_capacity(reps);
    let mut p99 = Vec::with_capacity(reps);
    let mut last = None;
    for _ in 0..reps {
        let c = coordinator(chaos, recovery.cloned());
        let m = c.run(workloads());
        assert_eq!(m.n_tasks, expect, "lost or duplicated tasks in cell");
        assert_eq!(m.latencies.len(), expect, "latency per completed task");
        let r = summarize(&m);
        tps.push(r.tasks_per_sec);
        p99.push(r.p99_latency);
        last = Some(r);
    }
    let mut r = last.expect("reps >= 1");
    r.tasks_per_sec = stats::median(&tps);
    r.p99_latency = stats::median(&p99);
    r
}

fn chaos_error(fault_pct: u32) -> ChaosOptions {
    ChaosOptions {
        seed: 0xc0de,
        p_error: fault_pct as f64 / 100.0,
        transient: true,
        ..ChaosOptions::default()
    }
}

fn retry_policy() -> RecoveryOptions {
    RecoveryOptions::retry(RetryBackoff {
        base: Duration::from_micros(100),
        cap: Duration::from_millis(2),
        ..RetryBackoff::default()
    })
}

fn blacklist_policy() -> RecoveryOptions {
    RecoveryOptions {
        quarantine: QuarantineOptions { cooldown: Duration::from_millis(5) },
        ..RecoveryOptions::blacklist(BlacklistAfterN::default())
    }
}

fn main() {
    let fast = fast_mode_from_env();
    let reps = if fast { 2 } else { 5 };

    println!("== goodput under injected faults (policy x fault rate) ==");
    println!(
        "{:>10} {:>6} {:>12} {:>10} {:>7} {:>8} {:>9} {:>6}",
        "policy", "fault%", "goodput", "p99", "faults", "retries", "timeouts",
        "quar"
    );

    let mut rows: Vec<Json> = Vec::new();
    let mut zero_fault_tps: Vec<(String, f64)> = Vec::new();

    // Transparency baseline: no wrapper, no recovery.
    let base = run_cell(None, None, reps);
    let baseline_tps = base.tasks_per_sec;
    emit(&mut rows, "none", 0, &base);

    for (policy_name, policy) in
        [("retry", retry_policy()), ("blacklist", blacklist_policy())]
    {
        for fault_pct in [0u32, 10, 30] {
            let chaos = chaos_error(fault_pct);
            let cell = run_cell(Some(&chaos), Some(&policy), reps);
            if fault_pct == 0 {
                zero_fault_tps
                    .push((policy_name.to_string(), cell.tasks_per_sec));
            }
            emit(&mut rows, policy_name, fault_pct, &cell);
        }
    }

    // Hang cell: the watchdog (not the device) detects the fault.
    let hang = ChaosOptions {
        seed: 0xdead,
        p_hang: 0.15,
        hang: Duration::from_millis(30),
        transient: true,
        ..ChaosOptions::default()
    };
    let deadline = RecoveryOptions {
        deadline: Some(DeadlineOptions {
            slack: 4.0,
            floor: Duration::from_millis(10),
        }),
        quarantine: QuarantineOptions { cooldown: Duration::from_millis(5) },
        ..RecoveryOptions::blacklist(BlacklistAfterN::default())
    };
    let cell = run_cell(Some(&hang), Some(&deadline), reps);
    emit(&mut rows, "deadline", 15, &cell);

    // The cost of arming recovery: zero-fault cells vs the unwrapped
    // baseline (informational — the CI gate diffs across commits).
    for (name, tps) in &zero_fault_tps {
        println!(
            "\n{name}/0% vs baseline: {:.3}x (1.0 = wrapper + policy free)",
            tps / baseline_tps.max(1e-12)
        );
    }

    let doc = Json::obj(vec![
        ("bench_mode", Json::str(bench_mode())),
        ("rows", Json::arr(rows)),
    ]);
    match std::fs::write(OUT_PATH, doc.to_string()) {
        Ok(()) => println!("[saved {OUT_PATH}, mode={}]", bench_mode()),
        Err(e) => eprintln!("failed to write {OUT_PATH}: {e}"),
    }
}

fn emit(rows: &mut Vec<Json>, policy: &str, fault_pct: u32, r: &CellResult) {
    println!(
        "{:>10} {:>6} {:>9.1}/s {:>7.2}ms {:>7} {:>8} {:>9} {:>6}",
        policy,
        fault_pct,
        r.tasks_per_sec,
        r.p99_latency * 1e3,
        r.n_faults,
        r.n_retries,
        r.n_timeouts,
        r.n_quarantine_trips,
    );
    rows.push(Json::obj(vec![
        ("policy", Json::str(policy)),
        ("fault_pct", Json::num(fault_pct as f64)),
        ("workers", Json::num(WORKERS as f64)),
        ("lanes", Json::num(LANES as f64)),
        ("n_tasks", Json::num((WORKERS * BATCH) as f64)),
        ("tasks_per_sec", Json::num(r.tasks_per_sec)),
        ("p99_latency_s", Json::num(r.p99_latency)),
        ("n_faults", Json::num(r.n_faults as f64)),
        ("n_retries", Json::num(r.n_retries as f64)),
        ("n_timeouts", Json::num(r.n_timeouts as f64)),
        ("n_requeued", Json::num(r.n_requeued as f64)),
        ("n_quarantine_trips", Json::num(r.n_quarantine_trips as f64)),
        ("n_halfopen_probes", Json::num(r.n_halfopen_probes as f64)),
        ("n_stolen", Json::num(r.n_stolen as f64)),
    ]));
}
