//! `cargo bench` — throughput of the event-driven simulator (the
//! heuristic's inner loop; DESIGN.md §Perf targets >= 1e5 sims/s at T=8).

use oclcc::config::profile_by_name;
use oclcc::model::{simulate, EngineState, SimOptions};
use oclcc::task::real::real_benchmark;
use oclcc::util::bench::Bencher;
use oclcc::util::rng::Pcg64;

fn main() {
    let mut b = Bencher::new(1.0, 10_000);
    for dev in ["amd_r9", "xeon_phi"] {
        let profile = profile_by_name(dev).unwrap();
        for t in [4usize, 8, 16] {
            let mut rng = Pcg64::seeded(0x51A + t as u64);
            let g = real_benchmark("BK50", dev, &profile, t, &mut rng, 1.0)
                .unwrap();
            let r = b.bench(&format!("simulate {dev} T={t}"), || {
                simulate(
                    &g.tasks,
                    &profile,
                    EngineState::default(),
                    SimOptions::default(),
                )
            });
            println!(
                "  -> {:.0} simulations/s",
                1.0 / r.median.max(1e-12)
            );
        }
        // With timeline recording (reporting path, not the hot path).
        let mut rng = Pcg64::seeded(0x51B);
        let g = real_benchmark("BK50", dev, &profile, 8, &mut rng, 1.0).unwrap();
        b.bench(&format!("simulate {dev} T=8 +timeline"), || {
            simulate(
                &g.tasks,
                &profile,
                EngineState::default(),
                SimOptions { record_timeline: true },
            )
        });
    }
    println!("== simulator micro-bench ==");
    print!("{}", b.report());
}
