//! `cargo bench --bench simulator_perf` — throughput of the event-driven
//! simulator (the heuristic's inner loop; DESIGN.md §Perf targets >= 1e5
//! sims/s at T=8), for both the one-shot wrapper and the resumable
//! SimCursor snapshot/resume path the beam search actually runs.

use oclcc::config::profile_by_name;
use oclcc::model::{simulate, EngineState, SimCursor, SimOptions};
use oclcc::task::real::real_benchmark;
use oclcc::util::bench::Bencher;
use oclcc::util::rng::Pcg64;

fn main() {
    let mut b = Bencher::new(1.0, 10_000);
    for dev in ["amd_r9", "xeon_phi"] {
        let profile = profile_by_name(dev).unwrap();
        for t in [4usize, 8, 16] {
            let mut rng = Pcg64::seeded(0x51A + t as u64);
            let g = real_benchmark("BK50", dev, &profile, t, &mut rng, 1.0)
                .unwrap();
            let r = b.bench(&format!("simulate {dev} T={t}"), || {
                simulate(
                    &g.tasks,
                    &profile,
                    EngineState::default(),
                    SimOptions::default(),
                )
            });
            println!(
                "  -> {:.0} simulations/s",
                1.0 / r.median.max(1e-12)
            );

            // Resumable hot path: a reused cursor reset per iteration —
            // the same event work with zero allocations after warm-up.
            let mut cursor = SimCursor::new(&profile, EngineState::default());
            let r = b.bench(&format!("cursor reset+run {dev} T={t}"), || {
                cursor.reset(&profile, EngineState::default());
                for task in &g.tasks {
                    cursor.push_task(task);
                }
                cursor.run_to_quiescence()
            });
            println!(
                "  -> {:.0} cursor sims/s",
                1.0 / r.median.max(1e-12)
            );

            // Snapshot/resume scoring pattern: pay for the half-group
            // prefix once, then score each remaining task by resume+push.
            let half = t / 2;
            let mut prefix = SimCursor::new(&profile, EngineState::default());
            for task in &g.tasks[..half] {
                prefix.push_task(task);
            }
            let mut probe = SimCursor::new(&profile, EngineState::default());
            let r = b.bench(
                &format!("resume-score {dev} T={t} ({} cands)", t - half),
                || {
                    let mut acc = 0.0;
                    for task in &g.tasks[half..] {
                        probe.resume_from(&prefix);
                        probe.push_task(task);
                        acc += probe.run_to_quiescence();
                    }
                    acc
                },
            );
            println!(
                "  -> {:.0} candidate scores/s",
                (t - half) as f64 / r.median.max(1e-12)
            );
        }
        // With timeline recording (reporting path, not the hot path).
        let mut rng = Pcg64::seeded(0x51B);
        let g = real_benchmark("BK50", dev, &profile, 8, &mut rng, 1.0).unwrap();
        b.bench(&format!("simulate {dev} T=8 +timeline"), || {
            simulate(
                &g.tasks,
                &profile,
                EngineState::default(),
                SimOptions { record_timeline: true },
            )
        });
    }
    println!("== simulator micro-bench ==");
    print!("{}", b.report());
}
