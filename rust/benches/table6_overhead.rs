//! `cargo bench` — Table 6: CPU cost of the Batch Reordering heuristic
//! for T = 4/6/8, plus the width-1 (pure Algorithm-1) variant.

use oclcc::config::profile_by_name;
use oclcc::model::EngineState;
use oclcc::sched::heuristic::{batch_reorder, batch_reorder_beam};
use oclcc::task::real::real_benchmark;
use oclcc::util::bench::Bencher;
use oclcc::util::rng::Pcg64;

fn main() {
    let profile = profile_by_name("k20c").unwrap();
    let mut b = Bencher::new(1.0, 400);
    for t in [4usize, 6, 8] {
        let mut rng = Pcg64::seeded(0xBE6C + t as u64);
        let g = real_benchmark("BK50", "k20c", &profile, t, &mut rng, 1.0).unwrap();
        b.bench(&format!("batch_reorder T={t} (beam 3)"), || {
            batch_reorder(&g.tasks, &profile, EngineState::default())
        });
        b.bench(&format!("batch_reorder T={t} (beam 1)"), || {
            batch_reorder_beam(&g.tasks, &profile, EngineState::default(), 1)
        });
    }
    println!("== Table 6 counterpart: heuristic CPU time ==");
    print!("{}", b.report());
    println!("paper budget (K20c, Core 2 Quad): 0.06 / 0.10 / 0.22 ms for T=4/6/8");
}
