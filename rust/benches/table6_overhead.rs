//! `cargo bench --bench table6_overhead` — Table 6: CPU cost of the Batch
//! Reordering heuristic for T = 4/6/8 per device, measured for BOTH the
//! resumable-cursor implementation and the pre-refactor from-scratch
//! replay baseline, plus the width-1 (pure Algorithm-1) variant — and,
//! since the sharded-pipeline PR, parallel-vs-serial reorder cases at
//! T = 16/24 (multi-lane candidate scoring over a persistent pool), and,
//! since the bound-gated-search PR, pruned-vs-unpruned serial cases at
//! T = 16/24 on twin-rich catalog groups (identical orders asserted,
//! prune/early-exit/twin counters recorded).
//!
//! Emits `BENCH_sched_overhead.json` (array of rows with mean/p50/p99
//! seconds per (device, T, impl) and per-point speedups) so future PRs
//! have a perf trajectory to regress against. Acceptance targets:
//! >= 3x mean resumable-vs-fromscratch speedup at T=8 on amd_r9 (PR 1),
//! >= 2x mean parallel-vs-serial speedup at T >= 16 with >= 4 scoring
//! threads (this PR).

use oclcc::config::profile_by_name;
use oclcc::model::EngineState;
use oclcc::sched::heuristic::{
    batch_reorder_beam_into, batch_reorder_beam_replay, BeamScratch,
    DEFAULT_BEAM_WIDTH,
};
use oclcc::sched::parallel::{batch_reorder_beam_parallel_into, ParBeamScratch};
use oclcc::task::real::real_benchmark;
use oclcc::task::synthetic::synthetic_benchmark;
use oclcc::task::TaskSpec;
use oclcc::util::bench::{bench_mode, BenchResult, Bencher};
use oclcc::util::json::Json;
use oclcc::util::rng::Pcg64;

const OUT_PATH: &str = "BENCH_sched_overhead.json";

fn row(device: &str, t: usize, imp: &str, r: &BenchResult) -> Json {
    Json::obj(vec![
        ("device", Json::str(device)),
        ("t", Json::num(t as f64)),
        ("impl", Json::str(imp)),
        ("bench", r.to_json()),
    ])
}

fn main() {
    let mut b = Bencher::from_env(1.0, 400);
    let mut json_rows: Vec<Json> = Vec::new();
    let mut speedups: Vec<(String, usize, f64)> = Vec::new();

    for dev in ["amd_r9", "k20c", "xeon_phi"] {
        let profile = profile_by_name(dev).unwrap();
        for t in [4usize, 6, 8] {
            let mut rng = Pcg64::seeded(0xBE6C + t as u64);
            let g =
                real_benchmark("BK50", dev, &profile, t, &mut rng, 1.0).unwrap();

            // Resumable path through an explicit scratch (what the
            // coordinator hot loop does); warm-up iterations inside the
            // Bencher also warm the arena, so steady-state is measured.
            let mut scratch = BeamScratch::new();
            let mut order: Vec<usize> = Vec::new();
            let fast = b
                .bench(&format!("reorder {dev} T={t} resumable"), || {
                    batch_reorder_beam_into(
                        &g.tasks,
                        &profile,
                        EngineState::default(),
                        DEFAULT_BEAM_WIDTH,
                        &mut scratch,
                        &mut order,
                    );
                    order.len()
                })
                .clone();
            json_rows.push(row(dev, t, "resumable", &fast));

            // Pre-refactor baseline: from-scratch simulate per candidate.
            let slow = b
                .bench(&format!("reorder {dev} T={t} fromscratch"), || {
                    batch_reorder_beam_replay(
                        &g.tasks,
                        &profile,
                        EngineState::default(),
                        DEFAULT_BEAM_WIDTH,
                    )
                })
                .clone();
            json_rows.push(row(dev, t, "fromscratch", &slow));

            // Width-1 pure Algorithm-1 greedy, for the Table-6 comparison.
            let w1 = b
                .bench(&format!("reorder {dev} T={t} beam1"), || {
                    batch_reorder_beam_into(
                        &g.tasks,
                        &profile,
                        EngineState::default(),
                        1,
                        &mut scratch,
                        &mut order,
                    );
                    order.len()
                })
                .clone();
            json_rows.push(row(dev, t, "beam1", &w1));

            let speedup = slow.mean / fast.mean.max(1e-12);
            speedups.push((dev.to_string(), t, speedup));
            json_rows.push(Json::obj(vec![
                ("device", Json::str(dev)),
                ("t", Json::num(t as f64)),
                ("impl", Json::str("speedup_resumable_vs_fromscratch")),
                ("speedup_mean", Json::num(speedup)),
                ("speedup_p50", Json::num(slow.median / fast.median.max(1e-12))),
            ]));
        }
    }

    // ---- parallel candidate scoring at coordinator-scale group sizes:
    // the serial resumable search vs the multi-lane pool (4 and 8
    // stripes). Same machine, same groups; acceptance is >= 2x mean at
    // T >= 16 with >= 4 threads.
    let mut par_speedups: Vec<(String, usize, usize, f64)> = Vec::new();
    for dev in ["amd_r9", "k20c"] {
        let profile = profile_by_name(dev).unwrap();
        for t in [16usize, 24] {
            let mut rng = Pcg64::seeded(0x9A7 + t as u64);
            let g =
                real_benchmark("BK50", dev, &profile, t, &mut rng, 1.0).unwrap();

            let mut scratch = BeamScratch::new();
            let mut order: Vec<usize> = Vec::new();
            let serial = b
                .bench(&format!("reorder {dev} T={t} serial"), || {
                    batch_reorder_beam_into(
                        &g.tasks,
                        &profile,
                        EngineState::default(),
                        DEFAULT_BEAM_WIDTH,
                        &mut scratch,
                        &mut order,
                    );
                    order.len()
                })
                .clone();
            json_rows.push(row(dev, t, "serial", &serial));

            for threads in [4usize, 8] {
                let mut par = ParBeamScratch::new(threads);
                let mut par_order: Vec<usize> = Vec::new();
                let fast = b
                    .bench(&format!("reorder {dev} T={t} parallel{threads}"), || {
                        batch_reorder_beam_parallel_into(
                            &g.tasks,
                            &profile,
                            EngineState::default(),
                            DEFAULT_BEAM_WIDTH,
                            &mut par,
                            &mut par_order,
                        );
                        par_order.len()
                    })
                    .clone();
                assert_eq!(
                    par_order, order,
                    "parallel order diverged from serial ({dev} T={t})"
                );
                json_rows.push(row(dev, t, &format!("parallel{threads}"), &fast));
                let speedup = serial.mean / fast.mean.max(1e-12);
                par_speedups.push((dev.to_string(), t, threads, speedup));
                json_rows.push(Json::obj(vec![
                    ("device", Json::str(dev)),
                    ("t", Json::num(t as f64)),
                    (
                        "impl",
                        Json::str(&format!(
                            "speedup_parallel{threads}_vs_serial"
                        )),
                    ),
                    ("speedup_mean", Json::num(speedup)),
                    (
                        "speedup_p50",
                        Json::num(serial.median / fast.median.max(1e-12)),
                    ),
                ]));
            }
        }
    }

    // ---- bound-gated pruning at coordinator-scale group sizes: the
    // serial search with the pruning layer off vs on, over twin-rich
    // BK-catalog groups (the 4-spec BK50 catalog cycled to T, the shape
    // a lane drains when several workers submit identical kernels). The
    // orders are asserted identical — pruning is provably result-
    // invariant — and the efficacy counters are asserted > 0 so the
    // trajectory records a genuine reduction in simulated-event work.
    let mut prune_speedups: Vec<(String, usize, f64)> = Vec::new();
    for dev in ["amd_r9", "k20c"] {
        let profile = profile_by_name(dev).unwrap();
        for t in [16usize, 24] {
            let g = synthetic_benchmark("BK50", &profile, 1.0).unwrap();
            let tasks: Vec<TaskSpec> =
                (0..t).map(|i| g.tasks[i % g.len()].clone()).collect();

            let mut plain = BeamScratch::with_pruning(false);
            let mut order: Vec<usize> = Vec::new();
            let off = b
                .bench(&format!("reorder {dev} T={t} pruned_off"), || {
                    batch_reorder_beam_into(
                        &tasks,
                        &profile,
                        EngineState::default(),
                        DEFAULT_BEAM_WIDTH,
                        &mut plain,
                        &mut order,
                    );
                    order.len()
                })
                .clone();
            json_rows.push(row(dev, t, "pruned_off", &off));

            let mut pruned = BeamScratch::new();
            let mut pruned_order: Vec<usize> = Vec::new();
            let on = b
                .bench(&format!("reorder {dev} T={t} pruned_on"), || {
                    batch_reorder_beam_into(
                        &tasks,
                        &profile,
                        EngineState::default(),
                        DEFAULT_BEAM_WIDTH,
                        &mut pruned,
                        &mut pruned_order,
                    );
                    pruned_order.len()
                })
                .clone();
            json_rows.push(row(dev, t, "pruned_on", &on));
            assert_eq!(
                pruned_order, order,
                "pruned order diverged from unpruned ({dev} T={t})"
            );
            // Counters for the trajectory: one warm call's worth, not the
            // cumulative total over the Bencher's adaptive iteration
            // count (which would scale with machine speed / fast mode).
            pruned.reset_prune_counters();
            batch_reorder_beam_into(
                &tasks,
                &profile,
                EngineState::default(),
                DEFAULT_BEAM_WIDTH,
                &mut pruned,
                &mut pruned_order,
            );
            let c = pruned.prune_counters();
            assert!(
                c.n_cands_pruned + c.n_rollouts_early_exit > 0,
                "bound layer never fired on twin-rich {dev} T={t}: {c:?}"
            );
            assert!(
                c.n_twin_collapsed > 0,
                "twin collapse never fired on twin-rich {dev} T={t}: {c:?}"
            );

            let speedup = off.mean / on.mean.max(1e-12);
            prune_speedups.push((dev.to_string(), t, speedup));
            json_rows.push(Json::obj(vec![
                ("device", Json::str(dev)),
                ("t", Json::num(t as f64)),
                ("impl", Json::str("speedup_pruned_vs_unpruned")),
                ("speedup_mean", Json::num(speedup)),
                ("speedup_p50", Json::num(off.median / on.median.max(1e-12))),
                ("n_cands_pruned", Json::num(c.n_cands_pruned as f64)),
                (
                    "n_rollouts_early_exit",
                    Json::num(c.n_rollouts_early_exit as f64),
                ),
                ("n_twin_collapsed", Json::num(c.n_twin_collapsed as f64)),
            ]));
        }
    }

    println!("== Table 6 counterpart: heuristic CPU time ==");
    print!("{}", b.report());
    println!("paper budget (K20c, Core 2 Quad): 0.06 / 0.10 / 0.22 ms for T=4/6/8");
    println!("\nresumable vs from-scratch (mean):");
    for (dev, t, s) in &speedups {
        println!("  {dev} T={t}: {s:.2}x");
    }
    println!("\nparallel vs serial reorder (mean):");
    for (dev, t, threads, s) in &par_speedups {
        println!("  {dev} T={t} threads={threads}: {s:.2}x");
    }
    println!("\npruned vs unpruned serial reorder (mean, twin-rich groups):");
    for (dev, t, s) in &prune_speedups {
        println!("  {dev} T={t}: {s:.2}x");
    }

    // Self-describing header: the effective OCLCC_BENCH_FAST mode, so a
    // trajectory file records whether it holds smoke or full numbers.
    let doc = Json::obj(vec![
        ("bench_mode", Json::str(bench_mode())),
        ("rows", Json::arr(json_rows)),
    ]);
    match std::fs::write(OUT_PATH, doc.to_string()) {
        Ok(()) => println!("[saved {OUT_PATH}, mode={}]", bench_mode()),
        Err(e) => eprintln!("failed to write {OUT_PATH}: {e}"),
    }
}
