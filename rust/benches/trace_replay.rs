//! `cargo bench --bench trace_replay` — NDJSON trace pipeline throughput,
//! per (cell).
//!
//! Cells:
//!
//! * `ingest` — synthetic trace text streamed through the incremental
//!   [`TraceReader`] in 4 KiB chunks (the `serve --stdin` framing path):
//!   `lines_per_sec` of strict parse + schema decode;
//! * `replay_lane` — the deterministic virtual-clock replay engine on a
//!   single amd_r9 model: `tasks_per_sec` of admission + drain + beam
//!   ordering + temporal simulation. In-bench asserts: two replays of
//!   the same trace are bit-identical, and the exactly-once ledger
//!   (`executed + shed == submitted`) holds;
//! * `replay_fleet3` — the same engine placing each drained round over
//!   three device models via `schedule_fleet`.
//!
//! Emits `BENCH_trace.json`; CI's bench-smoke job gates `lines_per_sec`
//! and `tasks_per_sec` per cell (higher is better, 30%) via
//! `tools/bench_diff.py`.

use std::time::Instant;

use oclcc::config::profile_by_name;
use oclcc::trace::{parse_trace, replay, ReplayOptions, TraceIn, TraceReader};
use oclcc::util::bench::{bench_mode, fast_mode_from_env};
use oclcc::util::json::Json;
use oclcc::util::rng::Pcg64;
use oclcc::util::stats;

const OUT_PATH: &str = "BENCH_trace.json";

/// Synthetic trace text: `n_tasks` task lines with mixed tags, a flush
/// every 8 tasks (bounds each replay round), comments sprinkled in.
fn trace_text(n_tasks: usize, seed: u64) -> String {
    let mut rng = Pcg64::seeded(seed);
    let mut lines = Vec::with_capacity(n_tasks + n_tasks / 8 + 2);
    lines.push("# synthetic bench trace".to_string());
    for i in 0..n_tasks {
        let tenant = rng.below(4);
        lines.push(format!(
            "{{\"ev\":\"task\",\"name\":\"t{i}\",\"worker\":{tenant},\
             \"tenant\":{tenant},\"class\":\"{}\",\"htd\":[{},{}],\
             \"kernel_s\":0.00{},\"dth\":{}}}",
            ["hi", "normal", "besteffort"][rng.below(3) as usize],
            1024 * (1 + rng.below(256)),
            512 * (1 + rng.below(64)),
            1 + rng.below(9),
            1024 * (1 + rng.below(256)),
        ));
        if i % 8 == 7 {
            lines.push("{\"ev\":\"flush\"}".to_string());
        }
    }
    lines.push("{\"ev\":\"end\"}".to_string());
    lines.join("\n") + "\n"
}

/// One timed pass of the incremental reader over `text` in 4 KiB chunks;
/// returns (events decoded, elapsed seconds).
fn ingest_once(text: &str) -> (usize, f64) {
    let bytes = text.as_bytes();
    let t0 = Instant::now();
    let mut r = TraceReader::new();
    let mut n = 0usize;
    for chunk in bytes.chunks(4096) {
        r.feed(chunk);
        while r.next_event().expect("bench trace is valid").is_some() {
            n += 1;
        }
    }
    r.end();
    while r.next_event().expect("bench trace is valid").is_some() {
        n += 1;
    }
    (n, t0.elapsed().as_secs_f64())
}

fn replay_cell(trace: &[TraceIn], opts: &ReplayOptions, reps: usize) -> f64 {
    let submitted =
        trace.iter().filter(|e| matches!(e, TraceIn::Task(_))).count();
    let baseline = replay(trace, opts).expect("bench options are valid");
    assert_eq!(
        baseline.n_tasks + baseline.n_shed,
        submitted,
        "ledger identity: executed + shed == submitted"
    );
    let mut tps = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        let r = replay(trace, opts).expect("bench options are valid");
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(r, baseline, "replay must be bit-identical across runs");
        tps.push(r.n_tasks as f64 / dt.max(1e-9));
    }
    stats::median(&tps)
}

fn main() {
    let fast = fast_mode_from_env();
    let reps = if fast { 3 } else { 7 };
    let ingest_lines = if fast { 2_000 } else { 20_000 };
    let replay_tasks = if fast { 48 } else { 160 };

    println!("== NDJSON trace pipeline throughput (per cell) ==");
    let mut rows: Vec<Json> = Vec::new();

    // ingest: incremental strict parse + schema decode.
    let text = trace_text(ingest_lines, 0x1e57);
    let n_lines = text.lines().count();
    let expect_events = parse_trace(&text).unwrap().len();
    let mut lps = Vec::with_capacity(reps);
    for _ in 0..reps {
        let (n, dt) = ingest_once(&text);
        assert_eq!(n, expect_events, "chunked ingest must decode every event");
        lps.push(n_lines as f64 / dt.max(1e-9));
    }
    let lines_per_sec = stats::median(&lps);
    println!("{:>14} {:>12.0} lines/s ({n_lines} lines)", "ingest", lines_per_sec);
    rows.push(Json::obj(vec![
        ("cell", Json::str("ingest")),
        ("n_lines", Json::num(n_lines as f64)),
        ("lines_per_sec", Json::num(lines_per_sec)),
    ]));

    // replay_lane / replay_fleet3: the virtual-clock engine end to end.
    let trace = parse_trace(&trace_text(replay_tasks, 0x4e91a)).unwrap();
    let amd = profile_by_name("amd_r9").unwrap();
    let lane = ReplayOptions { group_cap: 8, ..ReplayOptions::single(amd.clone()) };
    let lane_tps = replay_cell(&trace, &lane, reps);
    println!("{:>14} {:>12.0} tasks/s ({replay_tasks} tasks)", "replay_lane", lane_tps);
    rows.push(Json::obj(vec![
        ("cell", Json::str("replay_lane")),
        ("n_tasks", Json::num(replay_tasks as f64)),
        ("tasks_per_sec", Json::num(lane_tps)),
    ]));

    let fleet = ReplayOptions {
        group_cap: 8,
        ..ReplayOptions::fleet(vec![
            amd,
            profile_by_name("k20c").unwrap(),
            profile_by_name("xeon_phi").unwrap(),
        ])
    };
    let fleet_tps = replay_cell(&trace, &fleet, reps);
    println!(
        "{:>14} {:>12.0} tasks/s ({replay_tasks} tasks, 3 devices)",
        "replay_fleet3", fleet_tps
    );
    rows.push(Json::obj(vec![
        ("cell", Json::str("replay_fleet3")),
        ("n_tasks", Json::num(replay_tasks as f64)),
        ("tasks_per_sec", Json::num(fleet_tps)),
    ]));

    let doc = Json::obj(vec![
        ("bench_mode", Json::str(bench_mode())),
        ("rows", Json::arr(rows)),
    ]);
    match std::fs::write(OUT_PATH, doc.to_string()) {
        Ok(()) => println!("[saved {OUT_PATH}, mode={}]", bench_mode()),
        Err(e) => eprintln!("failed to write {OUT_PATH}: {e}"),
    }
}
