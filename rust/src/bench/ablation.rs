//! Ablation — design choices DESIGN.md calls out:
//!
//! 1. Beam width (1 = paper's pure Algorithm-1 greedy vs wider beams).
//! 2. Baseline orderings (FIFO / random / SJF / longest-kernel-first /
//!    alternate-dominance) vs the model-guided heuristic.
//!
//! Reported as the fraction of the best ordering's improvement captured,
//! averaged over synthetic + real benchmarks on every device.

use crate::config::profile_by_name;
use crate::model::simulator::makespan_of_order;
use crate::model::EngineState;
use crate::sched::baselines;
use crate::sched::bruteforce::OrderStats;
use crate::sched::heuristic::batch_reorder_beam;
use crate::task::real::real_benchmark;
use crate::task::synthetic::{benchmark_labels, synthetic_benchmark};
use crate::task::TaskSpec;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::stats;
use crate::util::table::{f, Table};

pub fn run(args: &Args) -> anyhow::Result<()> {
    let trials = args.opt_usize("trials", 6);
    let t_tasks = args.opt_usize("t", 5);
    println!("== Ablation: ordering policies, capture of best improvement ==");

    let policies: Vec<&str> = vec![
        "fifo", "random", "sjf", "lkf", "alternate", "beam1", "beam2",
        "beam3(default)", "beam6",
    ];
    let mut capture: std::collections::BTreeMap<&str, Vec<f64>> =
        policies.iter().map(|&p| (p, Vec::new())).collect();

    for dev in ["amd_r9", "k20c", "xeon_phi"] {
        let profile = profile_by_name(dev)?;
        let mut groups: Vec<Vec<TaskSpec>> = Vec::new();
        for label in benchmark_labels() {
            groups.push(synthetic_benchmark(label, &profile, 1.0)?.tasks);
            for trial in 0..trials {
                let mut rng = Pcg64::new(0xAB1 + trial as u64, label.len() as u64);
                groups.push(
                    real_benchmark(label, dev, &profile, t_tasks, &mut rng, 1.0)?
                        .tasks,
                );
            }
        }
        for tasks in &groups {
            let mut rng = Pcg64::seeded(0xC0);
            let st = OrderStats::exhaustive(tasks, &profile, 720, &mut rng);
            let gain = (st.worst - st.best).max(1e-12);
            let mut eval = |name: &str, order: Vec<usize>| {
                let m = makespan_of_order(tasks, &order, &profile);
                capture
                    .get_mut(name)
                    .unwrap()
                    .push(((st.worst - m) / gain).clamp(0.0, 1.0));
            };
            eval("fifo", baselines::fifo(tasks));
            eval("random", baselines::random(tasks, &mut rng));
            eval("sjf", baselines::sjf(tasks, &profile));
            eval("lkf", baselines::longest_kernel_first(tasks, &profile));
            eval("alternate", baselines::alternate_dominance(tasks, &profile));
            for (name, w) in
                [("beam1", 1), ("beam2", 2), ("beam3(default)", 3), ("beam6", 6)]
            {
                eval(
                    name,
                    batch_reorder_beam(tasks, &profile, EngineState::default(), w),
                );
            }
        }
    }

    let mut table = Table::new(&["policy", "capture (mean)", "capture (p10)"]);
    let mut json_rows = Vec::new();
    for p in &policies {
        let xs = &capture[p];
        table.row(vec![
            p.to_string(),
            f(stats::mean(xs), 3),
            f(stats::percentile(xs, 10.0), 3),
        ]);
        json_rows.push(Json::obj(vec![
            ("policy", Json::str(p)),
            ("capture_mean", Json::num(stats::mean(xs))),
            ("capture_p10", Json::num(stats::percentile(xs, 10.0))),
        ]));
    }
    table.print();
    crate::bench::save_results("ablation", &Json::arr(json_rows))?;
    Ok(())
}
