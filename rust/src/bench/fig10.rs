//! Fig. 10 — speedups on the *real-task* benchmarks: T*N tasks drawn from
//! the Table-5 catalog with the benchmark's DK/DT mix, random data sizes.

use crate::bench::fig9::run_grid;
use crate::bench::speedup::paper_grid;
use crate::task::real::real_benchmark;
use crate::util::cli::Args;

pub fn run(args: &Args) -> anyhow::Result<()> {
    let quick = args.flag("quick");
    let scale = args.opt_f64("scale", 1.0);
    let seed = args.opt_u64("seed", 0xA10);
    let measured_reps = args.opt_usize("measured-reps", 0);
    let grid: Vec<(usize, usize, usize)> = if quick {
        vec![(4, 1, 24), (4, 2, 24), (6, 1, 120)]
    } else {
        paper_grid()
    };
    println!("== Fig 10: real-task benchmark speedups vs worst permutation ==");
    run_grid(
        &grid,
        scale,
        seed,
        measured_reps,
        "fig10",
        |label, profile, t, n, rng| {
            let g = real_benchmark(label, &profile.name, profile, t * n, rng, scale)?;
            // Column-split the T*N tasks into worker batches.
            Ok((0..t)
                .map(|w| (0..n).map(|r| g.tasks[w * n + r].clone()).collect())
                .collect())
        },
    )
}
