//! Fig. 11 — geometric mean of Maximum / Average / Heuristic speedups over
//! all real-task experiments per device, plus the "% of best improvement"
//! headline (paper: R9 1.23/1.24 = 96%, Phi 84%, K20c 87%).

use crate::bench::speedup::{paper_grid, speedup_experiment};
use crate::config::profile_by_name;
use crate::task::real::real_benchmark;
use crate::task::TaskSpec;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::stats;
use crate::util::table::{f, pct, Table};

pub fn run(args: &Args) -> anyhow::Result<()> {
    let quick = args.flag("quick");
    let scale = args.opt_f64("scale", 1.0);
    let seed = args.opt_u64("seed", 0xF11);
    let grid: Vec<(usize, usize, usize)> = if quick {
        vec![(4, 1, 24), (4, 2, 24), (6, 1, 120)]
    } else {
        paper_grid()
    };
    let labels = ["BK0", "BK25", "BK50", "BK75", "BK100"];
    println!("== Fig 11: geomean speedups over all real-task experiments ==");
    let mut table = Table::new(&[
        "device", "max x (gm)", "avg x (gm)", "heuristic x (gm)", "% of best",
    ]);
    let mut json_rows = Vec::new();
    for dev in ["amd_r9", "k20c", "xeon_phi"] {
        let profile = profile_by_name(dev)?;
        let mut maxes = Vec::new();
        let mut means = Vec::new();
        let mut heus = Vec::new();
        for label in labels {
            for &(t, n, cap) in &grid {
                let mut rng =
                    Pcg64::new(seed ^ (t * 10 + n) as u64, label.len() as u64);
                let g = real_benchmark(label, dev, &profile, t * n, &mut rng, scale)?;
                let batches: Vec<Vec<TaskSpec>> = (0..t)
                    .map(|w| (0..n).map(|r| g.tasks[w * n + r].clone()).collect())
                    .collect();
                let out =
                    speedup_experiment(&batches, &profile, cap, 0, &mut rng);
                maxes.push(out.max_speedup());
                means.push(out.mean_speedup());
                heus.push(out.heuristic_speedup());
            }
        }
        let gm_max = stats::geomean(&maxes);
        let gm_mean = stats::geomean(&means);
        let gm_heu = stats::geomean(&heus);
        let capture = (gm_heu - 1.0) / (gm_max - 1.0).max(1e-9);
        table.row(vec![
            dev.to_string(),
            f(gm_max, 3),
            f(gm_mean, 3),
            f(gm_heu, 3),
            pct(capture.min(1.0), 0),
        ]);
        json_rows.push(Json::obj(vec![
            ("device", Json::str(dev)),
            ("gm_max", Json::num(gm_max)),
            ("gm_mean", Json::num(gm_mean)),
            ("gm_heuristic", Json::num(gm_heu)),
            ("capture", Json::num(capture)),
        ]));
    }
    table.print();
    println!("paper: amd_r9 1.24/~/1.23 (96%), k20c 1.27 (87%), xeon_phi 1.16 (84%)");
    crate::bench::save_results("fig11", &Json::arr(json_rows))?;
    Ok(())
}
