//! Fig. 6 — relative error of bidirectional transfer-time prediction.
//!
//! Protocol (paper §4.2.1): one CQ runs a HtD transfer while another
//! launches a DtH transfer overlapping 0/25/50/75/100% of it, for several
//! transfer sizes. The measured pair-completion time is compared against
//! three predictors: non-overlapped, fully-overlapped and the paper's
//! partially-overlapped model. Expectation (paper): the partial model
//! stays under ~2% at every overlap degree; the strawmen blow up at one
//! end of the sweep each.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::profile_by_name;
use crate::device::bus::Bus;
use crate::model::transfer::{predict_pair, OverlapModel};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::stats;
use crate::util::table::{pct, Table};

pub fn run(args: &Args) -> anyhow::Result<()> {
    let device = args.opt_or("device", "amd_r9");
    let profile = Arc::new(profile_by_name(&device)?);
    // Paper sizes: 16-512 MB. The virtual bus replays the same bandwidth,
    // so we default to a compressed ladder unless --full is given.
    let sizes_mb: Vec<u64> = if args.flag("full") {
        vec![16, 32, 64, 128, 256, 512]
    } else {
        vec![8, 16, 32, 64]
    };
    let overlaps = [0.0, 0.25, 0.5, 0.75, 1.0];
    let reps = args.opt_usize("reps", 3);

    println!("== Fig 6: bidirectional transfer prediction error ({device}) ==");
    println!(
        "   sizes {sizes_mb:?} MB, overlap degrees {overlaps:?}, {reps} reps"
    );
    let models = [
        ("non-overlapped", OverlapModel::NonOverlapped),
        ("full-overlapped", OverlapModel::FullOverlap),
        ("partial (ours)", OverlapModel::PartialOverlap),
    ];
    let mut table = Table::new(&[
        "overlap",
        "err non-overlapped",
        "err full-overlapped",
        "err partial (ours)",
    ]);
    let mut json_rows = Vec::new();

    for &ov in &overlaps {
        let mut errs = [Vec::new(), Vec::new(), Vec::new()];
        for &mb in &sizes_mb {
            let bytes = mb * 1024 * 1024;
            let solo_h = profile.htd.transfer_secs(bytes);
            // DtH starts so that it overlaps `ov` of the HtD transfer.
            let dth_start = (1.0 - ov) * solo_h;
            let mut measured = Vec::new();
            for _ in 0..reps {
                measured.push(measure_pair(&profile, bytes, dth_start));
            }
            let meas = stats::median(&measured);
            for (i, (_, m)) in models.iter().enumerate() {
                let pred =
                    predict_pair(*m, &profile, bytes, bytes, dth_start)
                        .makespan();
                errs[i].push(stats::rel_err(pred, meas));
            }
        }
        table.row(vec![
            pct(ov, 0),
            pct(stats::mean(&errs[0]), 2),
            pct(stats::mean(&errs[1]), 2),
            pct(stats::mean(&errs[2]), 2),
        ]);
        json_rows.push(Json::obj(vec![
            ("overlap", Json::num(ov)),
            ("err_non_overlapped", Json::num(stats::mean(&errs[0]))),
            ("err_full_overlapped", Json::num(stats::mean(&errs[1]))),
            ("err_partial", Json::num(stats::mean(&errs[2]))),
        ]));
    }
    table.print();
    crate::bench::save_results("fig6", &Json::arr(json_rows))?;
    Ok(())
}

/// Measure one HtD/DtH pair on the live bus; returns pair makespan (s).
/// Both "command queues" (threads) are spawned first and released through
/// a barrier so thread-creation skew does not pollute the measurement.
fn measure_pair(
    profile: &Arc<crate::config::DeviceProfile>,
    bytes: u64,
    dth_start: f64,
) -> f64 {
    let bus = Bus::new(profile.clone());
    let barrier = Arc::new(std::sync::Barrier::new(3));

    let bus_h = bus.clone();
    let b_h = barrier.clone();
    let htd = std::thread::spawn(move || {
        b_h.wait();
        let _g = bus_h.begin_transfer(true);
        bus_h.pace(true, bytes);
    });
    let bus_d = bus.clone();
    let b_d = barrier.clone();
    let dth = std::thread::spawn(move || {
        b_d.wait();
        crate::util::timing::precise_wait(Duration::from_secs_f64(dth_start));
        let _g = bus_d.begin_transfer(false);
        bus_d.pace(false, bytes);
    });
    barrier.wait();
    let t0 = Instant::now();
    htd.join().unwrap();
    dth.join().unwrap();
    t0.elapsed().as_secs_f64()
}
