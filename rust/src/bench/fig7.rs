//! Fig. 7 — temporal-model validation: average prediction error over all
//! task permutations of each synthetic benchmark, per device.
//!
//! The paper reports geomean errors below 1% (R9/K20c) and 1.12% (Phi).
//! Here the measurement substrate is the virtual device; errors reflect
//! real thread asynchrony + pacing granularity.

use std::sync::Arc;

use crate::config::profile_by_name;
use crate::device::executor::SpinExecutor;
use crate::device::vdev::VirtualDevice;
use crate::model::{simulate, EngineState, SimOptions};
use crate::sched::bruteforce::permutation_sample;
use crate::task::synthetic::{benchmark_labels, synthetic_benchmark};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::stats;
use crate::util::table::{pct, Table};

pub fn run(args: &Args) -> anyhow::Result<()> {
    let scale = args.opt_f64("scale", 1.0);
    let cap = args.opt_usize("perms", if args.flag("full") { 24 } else { 8 });
    let devices = ["amd_r9", "k20c", "xeon_phi"];
    println!("== Fig 7: model prediction error, all permutations ==");
    println!("   time-unit scale {scale}, permutations per benchmark {cap}");

    let mut table = Table::new(&["device", "BK0", "BK25", "BK50", "BK75", "BK100", "geomean"]);
    let mut json_rows = Vec::new();
    for dev in devices {
        let profile = profile_by_name(dev)?;
        let device = VirtualDevice::new(profile.clone(), Arc::new(SpinExecutor));
        let mut cells = vec![dev.to_string()];
        let mut per_bench = Vec::new();
        for label in benchmark_labels() {
            let g = synthetic_benchmark(label, &profile, scale)?;
            let mut rng = Pcg64::seeded(0xF16 + label.len() as u64);
            let orders = permutation_sample(g.len(), cap, &mut rng);
            let mut errs = Vec::new();
            for order in &orders {
                let tasks = g.reordered(order).tasks;
                let pred = simulate(
                    &tasks,
                    &profile,
                    EngineState::default(),
                    SimOptions::default(),
                )
                .makespan;
                let meas = device.run_group(&tasks).makespan;
                errs.push(stats::rel_err(pred, meas));
            }
            let mean_err = stats::mean(&errs);
            per_bench.push(mean_err);
            cells.push(pct(mean_err, 2));
            json_rows.push(Json::obj(vec![
                ("device", Json::str(dev)),
                ("benchmark", Json::str(label)),
                ("mean_error", Json::num(mean_err)),
            ]));
        }
        let gm = stats::geomean(&per_bench);
        cells.push(pct(gm, 2));
        table.row(cells);
        println!("   {dev}: geomean error {}", pct(gm, 2));
    }
    table.print();
    crate::bench::save_results("fig7", &Json::arr(json_rows))?;
    Ok(())
}
