//! Fig. 9 — speedups on the *synthetic* benchmarks (BK0..BK100) for every
//! device and (T, N) point of the paper grid: maximum (best permutation),
//! mean, and heuristic speedup, all relative to the worst permutation.

use crate::bench::speedup::{paper_grid, speedup_experiment};
use crate::config::profile_by_name;
use crate::task::synthetic::{benchmark_labels, synthetic_benchmark};
use crate::task::TaskSpec;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::table::{f, Table};

pub fn run(args: &Args) -> anyhow::Result<()> {
    let quick = args.flag("quick");
    let scale = args.opt_f64("scale", 1.0);
    let seed = args.opt_u64("seed", 0x519);
    let measured_reps =
        args.opt_usize("measured-reps", 0); // 0 = model-evaluated (default)
    let grid: Vec<(usize, usize, usize)> = if quick {
        vec![(4, 1, 24), (4, 2, 24), (6, 1, 120)]
    } else {
        paper_grid()
    };
    println!("== Fig 9: synthetic-benchmark speedups vs worst permutation ==");
    run_grid(
        &grid,
        scale,
        seed,
        measured_reps,
        "fig9",
        |label, profile, t, n, rng| {
            let g = synthetic_benchmark(label, profile, scale)?;
            // T*N tasks randomly drawn from the benchmark's 4 tasks (§6.2).
            Ok((0..t)
                .map(|_| {
                    (0..n)
                        .map(|_| {
                            g.tasks[rng.below(4) as usize].clone()
                        })
                        .collect()
                })
                .collect())
        },
    )
}

/// Shared driver for Figs. 9 and 10 (synthetic vs real task sources).
pub fn run_grid(
    grid: &[(usize, usize, usize)],
    _scale: f64,
    seed: u64,
    measured_reps: usize,
    result_name: &str,
    mut make_batches: impl FnMut(
        &str,
        &crate::config::DeviceProfile,
        usize,
        usize,
        &mut Pcg64,
    ) -> anyhow::Result<Vec<Vec<TaskSpec>>>,
) -> anyhow::Result<()> {
    let devices = ["amd_r9", "k20c", "xeon_phi"];
    let mut json_rows = Vec::new();
    for dev in devices {
        let profile = profile_by_name(dev)?;
        let mut table = Table::new(&[
            "benchmark", "T", "N", "max x", "mean x", "heuristic x", "capture",
        ]);
        println!("-- {dev} --");
        for label in benchmark_labels() {
            for &(t, n, cap) in grid {
                let mut rng =
                    Pcg64::new(seed ^ (t * 100 + n) as u64, label.len() as u64);
                let batches = make_batches(label, &profile, t, n, &mut rng)?;
                let out = speedup_experiment(
                    &batches,
                    &profile,
                    cap,
                    measured_reps,
                    &mut rng,
                );
                table.row(vec![
                    label.to_string(),
                    t.to_string(),
                    n.to_string(),
                    f(out.max_speedup(), 3),
                    f(out.mean_speedup(), 3),
                    f(out.heuristic_speedup(), 3),
                    crate::util::table::pct(out.improvement_fraction(), 0),
                ]);
                json_rows.push(Json::obj(vec![
                    ("device", Json::str(dev)),
                    ("benchmark", Json::str(label)),
                    ("t", Json::num(t as f64)),
                    ("n", Json::num(n as f64)),
                    ("max_speedup", Json::num(out.max_speedup())),
                    ("mean_speedup", Json::num(out.mean_speedup())),
                    ("heuristic_speedup", Json::num(out.heuristic_speedup())),
                    ("capture", Json::num(out.improvement_fraction())),
                    (
                        "measured_heuristic",
                        out.measured_heuristic.map(Json::num).unwrap_or(Json::Null),
                    ),
                ]));
            }
        }
        table.print();
    }
    crate::bench::save_results(result_name, &Json::arr(json_rows))?;
    Ok(())
}
