//! Paper-figure/table regeneration harnesses (`oclcc bench <exp>`).
//!
//! Each submodule regenerates one experiment from the paper's evaluation:
//! the same workloads, the same sweep axes, the same reported rows/series
//! (absolute numbers differ — the substrate is the virtual device, not the
//! authors' testbed; shapes and ratios are the reproduction target).
//! Results print as ASCII tables and are archived as JSON under
//! `results/`.

pub mod ablation;
pub mod fig10;
pub mod fig11;
pub mod fig6;
pub mod fig7;
pub mod fig9;
pub mod speedup;
pub mod table5;
pub mod table6;

use std::path::Path;

use crate::util::json::Json;

/// Write a result JSON under `results/<name>.json`.
pub fn save_results(name: &str, json: &Json) -> anyhow::Result<()> {
    let dir = Path::new("results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, json.to_string())?;
    println!("  [saved {}]", path.display());
    Ok(())
}
