//! Shared harness for the speedup experiments (Figs. 9-11): the NoReorder
//! permutation distribution vs the heuristic ordering, for T workers
//! submitting N dependent tasks each.
//!
//! Tasks are organised as `batch[w][r]`: worker w's r-th task. Batch
//! dependencies serialize rounds, so the group scheduled at round r is
//! {batch[w][r] | w}. The NoReorder setup permutes within each round
//! ((T!)^N joint orderings — evaluated per-round and summed, which is
//! exact under round serialization); the Heuristic setup reorders each
//! round with Algorithm 1.
//!
//! `measured = false` evaluates orderings with the temporal model (valid
//! per Fig. 7's <2% error, and how the paper's own heuristic reasons);
//! `measured = true` replays the key orderings (worst/best/heuristic) on
//! the virtual device with repetitions, like the paper's 15-rep medians.

use std::sync::Arc;

use crate::config::DeviceProfile;
use crate::device::executor::SpinExecutor;
use crate::device::vdev::VirtualDevice;
use crate::model::{EngineState, SimOptions};
use crate::sched::bruteforce::{permutation_sample, OrderStats};
use crate::sched::heuristic::batch_reorder;
use crate::task::TaskSpec;
use crate::util::rng::Pcg64;
use crate::util::stats;

#[derive(Clone, Debug)]
pub struct SpeedupOutcome {
    /// NoReorder distribution (summed over rounds).
    pub worst: f64,
    pub best: f64,
    pub mean: f64,
    pub median: f64,
    /// Heuristic total.
    pub heuristic: f64,
    /// Device-measured totals for worst/best/heuristic orders (if any).
    pub measured_worst: Option<f64>,
    pub measured_best: Option<f64>,
    pub measured_heuristic: Option<f64>,
}

impl SpeedupOutcome {
    /// Speedups w.r.t. the worst ordering (the paper's normalization).
    pub fn max_speedup(&self) -> f64 {
        self.worst / self.best
    }

    pub fn mean_speedup(&self) -> f64 {
        self.worst / self.mean
    }

    pub fn median_speedup(&self) -> f64 {
        self.worst / self.median
    }

    pub fn heuristic_speedup(&self) -> f64 {
        self.worst / self.heuristic
    }

    /// Fraction of the best ordering's improvement the heuristic captured
    /// (the paper's 84-96% headline metric).
    pub fn improvement_fraction(&self) -> f64 {
        let best_gain = self.worst - self.best;
        if best_gain <= 0.0 {
            return 1.0;
        }
        ((self.worst - self.heuristic) / best_gain).min(1.0)
    }
}

/// Run one speedup experiment over `batches[w][r]`.
pub fn speedup_experiment(
    batches: &[Vec<TaskSpec>],
    profile: &DeviceProfile,
    perm_cap: usize,
    measured_reps: usize,
    rng: &mut Pcg64,
) -> SpeedupOutcome {
    let t = batches.len();
    let n = batches[0].len();
    assert!(batches.iter().all(|b| b.len() == n));

    let mut worst = 0.0;
    let mut best = 0.0;
    let mut mean = 0.0;
    let mut median = 0.0;
    let mut heuristic = 0.0;
    let mut worst_orders: Vec<Vec<usize>> = Vec::new();
    let mut best_orders: Vec<Vec<usize>> = Vec::new();
    let mut heur_orders: Vec<Vec<usize>> = Vec::new();

    for r in 0..n {
        let round: Vec<TaskSpec> =
            (0..t).map(|w| batches[w][r].clone()).collect();
        let orders = permutation_sample(t, perm_cap, rng);
        let st = OrderStats::evaluate(&round, &orders, profile);
        worst += st.worst;
        best += st.best;
        mean += st.mean;
        median += st.median;
        let h_order = batch_reorder(&round, profile, EngineState::default());
        heuristic += crate::model::simulator::simulate_order(
            &round,
            &h_order,
            profile,
            EngineState::default(),
            SimOptions::default(),
        )
        .makespan;
        worst_orders.push(st.worst_order);
        best_orders.push(st.best_order);
        heur_orders.push(h_order);
    }

    let (measured_worst, measured_best, measured_heuristic) =
        if measured_reps > 0 {
            let dev =
                VirtualDevice::new(profile.clone(), Arc::new(SpinExecutor));
            let measure = |orders: &[Vec<usize>]| -> f64 {
                let mut total = 0.0;
                for r in 0..n {
                    let round: Vec<TaskSpec> = orders[r]
                        .iter()
                        .map(|&i| batches[i][r].clone())
                        .collect();
                    let mut runs = Vec::new();
                    for _ in 0..measured_reps {
                        runs.push(dev.run_group(&round).makespan);
                    }
                    total += stats::median(&runs);
                }
                total
            };
            (
                Some(measure(&worst_orders)),
                Some(measure(&best_orders)),
                Some(measure(&heur_orders)),
            )
        } else {
            (None, None, None)
        };

    SpeedupOutcome {
        worst,
        best,
        mean,
        median,
        heuristic,
        measured_worst,
        measured_best,
        measured_heuristic,
    }
}

/// The paper's (T, N) grid: all permutations at T=4; subsets where the
/// space explodes, exactly as §6.2 describes.
pub fn paper_grid() -> Vec<(usize, usize, usize)> {
    // (T, N, perm_cap)
    vec![
        (4, 1, 24),
        (4, 2, 24),
        (4, 4, 24),
        (6, 1, 720),
        (6, 2, 36), // 5% of 720 per round
        (8, 1, 400),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;
    use crate::task::synthetic::synthetic_benchmark;

    fn batches(t: usize, n: usize) -> Vec<Vec<TaskSpec>> {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
        (0..t)
            .map(|w| (0..n).map(|r| g.tasks[(w + r) % 4].clone()).collect())
            .collect()
    }

    #[test]
    fn outcome_orderings_consistent() {
        let p = profile_by_name("amd_r9").unwrap();
        let mut rng = Pcg64::seeded(5);
        let out = speedup_experiment(&batches(4, 2), &p, 24, 0, &mut rng);
        assert!(out.best <= out.median && out.median <= out.worst);
        assert!(out.heuristic <= out.mean + 1e-9, "paper claim");
        assert!(out.max_speedup() >= out.heuristic_speedup() - 0.05);
        assert!(out.improvement_fraction() >= 0.5);
    }

    #[test]
    fn measured_mode_returns_values() {
        let _t = crate::util::timing::timing_test_lock();
        let p = profile_by_name("amd_r9").unwrap();
        let mut rng = Pcg64::seeded(6);
        let small: Vec<Vec<TaskSpec>> = {
            let g = synthetic_benchmark("BK25", &p, 0.1).unwrap();
            (0..3).map(|w| vec![g.tasks[w].clone()]).collect()
        };
        let out = speedup_experiment(&small, &p, 6, 1, &mut rng);
        let mw = out.measured_worst.unwrap();
        let mh = out.measured_heuristic.unwrap();
        assert!(mw > 0.0 && mh > 0.0);
        // Measured heuristic should not be wildly slower than worst.
        assert!(mh <= mw * 1.25, "mh {mh} mw {mw}");
    }
}
