//! Tables 1-5 — catalog dumps plus, when AOT artifacts are present, a
//! live-measured "Table 5" for the `cpu_live` device: per-kernel-family
//! command time ranges measured over the size variants on the PJRT
//! runtime with paced transfers.

use crate::config::{builtin_profiles, profile_by_name};
use crate::runtime::manifest::default_artifact_dir;
use crate::runtime::service::PjrtService;
use crate::task::real::{table5, FAMILIES};
use crate::task::synthetic::TABLE2;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::table::{f, Table};

pub fn run(args: &Args) -> anyhow::Result<()> {
    // Table 1.
    println!("== Table 1: device profiles ==");
    let mut t1 = Table::new(&[
        "device", "DMA engines", "HtD GB/s", "DtH GB/s", "sigma", "launch (us)",
    ]);
    for p in builtin_profiles() {
        t1.row(vec![
            p.name.clone(),
            p.dma_engines.to_string(),
            f(p.htd.bytes_per_sec / 1e9, 2),
            f(p.dth.bytes_per_sec / 1e9, 2),
            f(p.duplex_slowdown, 2),
            f(p.kernel_launch_overhead * 1e6, 0),
        ]);
    }
    t1.print();

    // Table 2.
    println!("\n== Table 2: synthetic tasks (fractions of the 10 ms unit) ==");
    let mut t2 = Table::new(&["task", "HtD", "K", "DtH", "class"]);
    for (i, (h, k, d)) in TABLE2.iter().enumerate() {
        t2.row(vec![
            format!("T{i}"),
            f(*h, 1),
            f(*k, 1),
            f(*d, 1),
            if h + d <= *k { "DK".into() } else { "DT".into() },
        ]);
    }
    t2.print();

    // Table 5 per device.
    for dev in ["amd_r9", "xeon_phi", "k20c"] {
        println!("\n== Table 5: real-task command time ranges ({dev}, ms) ==");
        let mut t5 = Table::new(&["kernel", "HtD", "K", "DtH", "class"]);
        let profile = profile_by_name(dev)?;
        for row in table5(dev)? {
            let dk = row.k.mid_secs() >= row.htd.mid_secs() + row.dth.mid_secs();
            t5.row(vec![
                row.family.to_string(),
                format!("{:.2}-{:.2}", row.htd.0, row.htd.1),
                format!("{:.2}-{:.2}", row.k.0, row.k.1),
                format!("{:.2}-{:.2}", row.dth.0, row.dth.1),
                if dk { "DK".into() } else { "DT".into() },
            ]);
        }
        t5.print();
        let _ = profile;
    }

    // Live Table 5 on PJRT (optional: needs artifacts).
    if !args.flag("no-live") {
        match PjrtService::start(default_artifact_dir()) {
            Ok(service) => live_table5(&service)?,
            Err(e) => println!("\n(live Table 5 skipped: {e})"),
        }
    }
    Ok(())
}

fn live_table5(service: &PjrtService) -> anyhow::Result<()> {
    use crate::runtime::manifest::Manifest;
    println!("\n== Table 5 (live): PJRT-CPU kernel times per variant ==");
    let manifest = Manifest::load(&default_artifact_dir())?;
    let profile = profile_by_name("cpu_live")?;
    let mut t = Table::new(&[
        "variant", "kernel", "HtD (ms)", "K measured (ms)", "DtH (ms)", "class",
    ]);
    let mut json_rows = Vec::new();
    // Family -> variant mapping mirrors Table 4's eight kernels.
    fn fam_of(k: &str) -> &str {
        match k {
        "matmul" => "MM",
        "black_scholes" => "BS",
        "fwt" => "FWT",
        "floyd_warshall" => "FLW",
        "conv_sep" => "CONV",
        "vecadd" => "VA",
        "transpose" => "MT",
        "dct8x8" => "DCT",
            other => other,
        }
    }
    let _ = FAMILIES;
    for (name, meta) in &manifest.variants {
        service.warmup(name)?;
        let mut samples = Vec::new();
        for _ in 0..3 {
            samples.push(service.execute(name)?.exec_secs);
        }
        let k_ms = crate::util::stats::median(&samples) * 1e3;
        let htd_ms = profile.htd.transfer_secs(meta.htd_bytes) * 1e3;
        let dth_ms = profile.dth.transfer_secs(meta.dth_bytes) * 1e3;
        let dk = k_ms >= htd_ms + dth_ms;
        t.row(vec![
            name.clone(),
            fam_of(&meta.kernel).to_string(),
            f(htd_ms, 3),
            f(k_ms, 3),
            f(dth_ms, 3),
            if dk { "DK".into() } else { "DT".into() },
        ]);
        json_rows.push(Json::obj(vec![
            ("variant", Json::str(name)),
            ("htd_ms", Json::num(htd_ms)),
            ("k_ms", Json::num(k_ms)),
            ("dth_ms", Json::num(dth_ms)),
        ]));
    }
    t.print();
    crate::bench::save_results("table5_live", &Json::arr(json_rows))?;
    Ok(())
}
