//! Table 6 — scheduling overhead of the host proxy: average CPU time of
//! the Batch Reordering heuristic for T = 4/6/8 concurrent tasks, against
//! the average device execution time of the reordered group (paper:
//! 0.06 / 0.10 / 0.22 ms vs 28 / 38 / 50 ms on a K20c — i.e. < 0.4%).

use std::time::Instant;

use crate::config::profile_by_name;
use crate::model::{simulate, EngineState, SimOptions};
use crate::sched::heuristic::batch_reorder;
use crate::task::real::real_benchmark;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::stats;
use crate::util::table::{f, pct, Table};

pub fn run(args: &Args) -> anyhow::Result<()> {
    let reps = args.opt_usize("reps", 50);
    let profile = profile_by_name(&args.opt_or("device", "k20c"))?;
    println!("== Table 6: heuristic scheduling overhead ({}) ==", profile.name);
    let mut table = Table::new(&[
        "T (concurrent tasks)",
        "avg CPU scheduling time (ms)",
        "avg device execution time (ms)",
        "overhead",
    ]);
    let mut json_rows = Vec::new();
    for t in [4usize, 6, 8] {
        let mut sched_times = Vec::new();
        let mut dev_times = Vec::new();
        for rep in 0..reps {
            let mut rng = crate::util::rng::Pcg64::new(0x7AB6 + rep as u64, t as u64);
            let g = real_benchmark("BK50", &profile.name, &profile, t, &mut rng, 1.0)?;
            let t0 = Instant::now();
            let order = batch_reorder(&g.tasks, &profile, EngineState::default());
            sched_times.push(t0.elapsed().as_secs_f64());
            let ordered: Vec<_> =
                order.iter().map(|&i| g.tasks[i].clone()).collect();
            dev_times.push(
                simulate(
                    &ordered,
                    &profile,
                    EngineState::default(),
                    SimOptions::default(),
                )
                .makespan,
            );
        }
        let sched_ms = stats::mean(&sched_times) * 1e3;
        let dev_ms = stats::mean(&dev_times) * 1e3;
        table.row(vec![
            t.to_string(),
            f(sched_ms, 3),
            f(dev_ms, 2),
            pct(sched_ms / dev_ms, 2),
        ]);
        json_rows.push(Json::obj(vec![
            ("t", Json::num(t as f64)),
            ("sched_ms", Json::num(sched_ms)),
            ("device_ms", Json::num(dev_ms)),
        ]));
    }
    table.print();
    println!("paper (K20c): 0.06 / 0.10 / 0.22 ms vs 28.04 / 37.82 / 49.78 ms");
    crate::bench::save_results("table6", &Json::arr(json_rows))?;
    Ok(())
}
