//! Configuration: device profiles (paper Table 1 + LogGP link parameters)
//! and experiment settings, with JSON load/save and built-in defaults.

pub mod profile;

pub use profile::{DeviceProfile, LinkParams, builtin_profiles, profile_by_name};
