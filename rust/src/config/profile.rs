//! Device profiles — the paper's Table 1 testbed, as virtual-device and
//! model parameters.
//!
//! A profile captures everything both sides need:
//!   * the *virtual device* (rust/src/device) paces transfers and kernels
//!     with these parameters plus real OS jitter;
//!   * the *temporal model* (rust/src/model) predicts with the same
//!     parameters, as the paper's model uses LogGP constants measured by a
//!     micro-benchmark (`oclcc profile --loggp` regenerates them).
//!
//! PCIe 2.0 x16 effective bandwidths (~6 GB/s pinned) follow the paper's
//! testbed; per-device asymmetries are modeled after the HtD/DtH time
//! ranges of Table 5.

use crate::util::json::Json;

/// One direction of the host<->device interconnect (LogGP reduced to
/// latency + inverse bandwidth, as in van Werkhoven et al. [21]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkParams {
    /// Fixed per-transfer overhead (seconds): L + o in LogGP terms.
    pub latency: f64,
    /// Asymptotic bandwidth (bytes/second): 1/G.
    pub bytes_per_sec: f64,
}

impl LinkParams {
    /// Solo transfer time for `bytes` (no contention).
    pub fn transfer_secs(&self, bytes: u64) -> f64 {
        self.latency + bytes as f64 / self.bytes_per_sec
    }

    /// Bytes that take `secs` to transfer solo (inverse of transfer_secs).
    pub fn bytes_for_secs(&self, secs: f64) -> u64 {
        (((secs - self.latency).max(0.0)) * self.bytes_per_sec) as u64
    }

    /// Scale this link's transfer *time* by `s` (> 1 = slower): latency
    /// multiplies, bandwidth divides, so in real arithmetic
    /// `scaled(s).transfer_secs(b) == s * transfer_secs(b)` for every
    /// byte count. `scaled(1.0)` is a bitwise identity (IEEE-754
    /// multiplication/division by 1.0 is exact), which is what makes an
    /// identity `model::calibrate::CalibratedProfile` compile
    /// bit-identical tables.
    pub fn scaled(&self, s: f64) -> LinkParams {
        LinkParams { latency: self.latency * s, bytes_per_sec: self.bytes_per_sec / s }
    }
}

/// A device profile (paper Table 1 row + measured link constants).
#[derive(Clone, Debug)]
pub struct DeviceProfile {
    pub name: String,
    /// 1 (Xeon Phi) or 2 (R9, K20c) DMA copy engines.
    pub dma_engines: u8,
    pub htd: LinkParams,
    pub dth: LinkParams,
    /// Per-transfer rate divisor while the opposite direction is active
    /// (sigma >= 1). The partial-overlap model's single constant; measured
    /// on real PCIe by the paper's micro-benchmark, by `oclcc profile`
    /// here. Irrelevant when dma_engines == 1.
    pub duplex_slowdown: f64,
    /// Kernel invocation latency floor (gamma in Eq. 1) the device adds.
    pub kernel_launch_overhead: f64,
    /// CKE emulation: fraction of a kernel's tail that may overlap the next
    /// kernel's head on the *device* (the model deliberately ignores CKE,
    /// paper §4.1). 0.0 disables.
    pub cke_tail_overlap: f64,
    /// Time scale applied to virtual-device execution: 1.0 replays paper
    /// magnitudes (time unit 10 ms), smaller values compress wall-clock for
    /// quick runs while keeping ratios intact.
    pub time_scale: f64,
}

impl DeviceProfile {
    pub fn link(&self, htd: bool) -> &LinkParams {
        if htd {
            &self.htd
        } else {
            &self.dth
        }
    }

    /// Effective transfer rate (bytes/s) given whether the opposite
    /// direction is simultaneously active.
    pub fn rate(&self, htd: bool, opposite_active: bool) -> f64 {
        let base = self.link(htd).bytes_per_sec;
        if opposite_active && self.dma_engines >= 2 {
            base / self.duplex_slowdown
        } else {
            base
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("dma_engines", Json::num(self.dma_engines as f64)),
            ("htd_latency", Json::num(self.htd.latency)),
            ("htd_bandwidth", Json::num(self.htd.bytes_per_sec)),
            ("dth_latency", Json::num(self.dth.latency)),
            ("dth_bandwidth", Json::num(self.dth.bytes_per_sec)),
            ("duplex_slowdown", Json::num(self.duplex_slowdown)),
            ("kernel_launch_overhead", Json::num(self.kernel_launch_overhead)),
            ("cke_tail_overlap", Json::num(self.cke_tail_overlap)),
            ("time_scale", Json::num(self.time_scale)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<DeviceProfile> {
        let f = |k: &str| -> anyhow::Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("profile missing field {k}"))
        };
        // sigma >= 1 is a documented invariant (duplex contention can
        // only slow transfers down); the scheduler's admissible lower
        // bounds assume solo rates are the fastest the model ever
        // grants, so a "duplex speedup" profile must be rejected here
        // rather than silently mis-prune. The loggp calibrator clamps
        // its measurement to >= 1.0 for the same reason.
        let sigma = f("duplex_slowdown")?;
        if sigma < 1.0 || sigma.is_nan() {
            anyhow::bail!(
                "profile duplex_slowdown must be >= 1.0 (got {sigma}): the \
                 partial-overlap model divides solo rates by it"
            );
        }
        Ok(DeviceProfile {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("profile missing name"))?
                .to_string(),
            dma_engines: f("dma_engines")? as u8,
            htd: LinkParams { latency: f("htd_latency")?, bytes_per_sec: f("htd_bandwidth")? },
            dth: LinkParams { latency: f("dth_latency")?, bytes_per_sec: f("dth_bandwidth")? },
            duplex_slowdown: sigma,
            kernel_launch_overhead: f("kernel_launch_overhead")?,
            cke_tail_overlap: f("cke_tail_overlap")?,
            time_scale: f("time_scale")?,
        })
    }
}

/// The three paper devices plus the live PJRT-CPU profile.
pub fn builtin_profiles() -> Vec<DeviceProfile> {
    vec![
        // AMD R9: 2 ACE-fed DMA engines, PCIe 2.0.
        DeviceProfile {
            name: "amd_r9".into(),
            dma_engines: 2,
            htd: LinkParams { latency: 18e-6, bytes_per_sec: 6.2e9 },
            dth: LinkParams { latency: 20e-6, bytes_per_sec: 5.9e9 },
            duplex_slowdown: 1.18,
            kernel_launch_overhead: 12e-6,
            cke_tail_overlap: 0.0,
            time_scale: 1.0,
        },
        // NVIDIA K20c: 2 copy engines, Hyper-Q; slightly slower HtD path
        // (Table 5 HtD ranges are ~2x the R9's for the same tasks).
        DeviceProfile {
            name: "k20c".into(),
            dma_engines: 2,
            htd: LinkParams { latency: 15e-6, bytes_per_sec: 5.6e9 },
            dth: LinkParams { latency: 16e-6, bytes_per_sec: 6.1e9 },
            duplex_slowdown: 1.24,
            kernel_launch_overhead: 8e-6,
            // CKE emulation is available (see device_sweep example) but
            // defaults off: Fig. 7 validates the no-CKE model against a
            // no-CKE device, as the paper's single-kernel-CQ scheme does.
            cke_tail_overlap: 0.0,
            time_scale: 1.0,
        },
        // Intel Xeon Phi 5100: ONE DMA engine — no duplex overlap at all.
        DeviceProfile {
            name: "xeon_phi".into(),
            dma_engines: 1,
            htd: LinkParams { latency: 35e-6, bytes_per_sec: 6.5e9 },
            dth: LinkParams { latency: 35e-6, bytes_per_sec: 6.4e9 },
            duplex_slowdown: 1.0,
            kernel_launch_overhead: 25e-6,
            cke_tail_overlap: 0.0,
            time_scale: 1.0,
        },
        // Live profile: kernels execute real HLO artifacts on PJRT-CPU.
        // The link is paced like a PCIe x4 (1.5 GB/s): PJRT-CPU kernels on
        // this host run in 0.1-4 ms, so a slower link keeps the catalog a
        // genuine DK/DT mix — on an 8 GB/s link every task would be
        // kernel-dominant and ordering (the paper's subject) would be moot.
        DeviceProfile {
            name: "cpu_live".into(),
            dma_engines: 2,
            htd: LinkParams { latency: 10e-6, bytes_per_sec: 1.5e9 },
            dth: LinkParams { latency: 10e-6, bytes_per_sec: 1.5e9 },
            duplex_slowdown: 1.15,
            kernel_launch_overhead: 10e-6,
            cke_tail_overlap: 0.0,
            time_scale: 1.0,
        },
    ]
}

pub fn profile_by_name(name: &str) -> anyhow::Result<DeviceProfile> {
    builtin_profiles()
        .into_iter()
        .find(|p| p.name == name)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "unknown device profile '{name}' (builtin: amd_r9, k20c, xeon_phi, cpu_live)"
            )
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_names() {
        let names: Vec<String> =
            builtin_profiles().into_iter().map(|p| p.name).collect();
        assert_eq!(names, vec!["amd_r9", "k20c", "xeon_phi", "cpu_live"]);
    }

    #[test]
    fn transfer_time_roundtrip() {
        let l = LinkParams { latency: 20e-6, bytes_per_sec: 6e9 };
        let t = l.transfer_secs(6_000_000);
        assert!((t - (20e-6 + 1e-3)).abs() < 1e-12);
        let b = l.bytes_for_secs(t);
        assert!((b as i64 - 6_000_000i64).abs() < 10);
    }

    #[test]
    fn duplex_rate_only_with_two_engines() {
        let r9 = profile_by_name("amd_r9").unwrap();
        assert!(r9.rate(true, true) < r9.rate(true, false));
        let phi = profile_by_name("xeon_phi").unwrap();
        assert_eq!(phi.rate(true, true), phi.rate(true, false));
    }

    #[test]
    fn json_roundtrip() {
        for p in builtin_profiles() {
            let j = p.to_json();
            let q = DeviceProfile::from_json(&j).unwrap();
            assert_eq!(p.name, q.name);
            assert_eq!(p.dma_engines, q.dma_engines);
            assert!((p.duplex_slowdown - q.duplex_slowdown).abs() < 1e-12);
            assert!((p.htd.bytes_per_sec - q.htd.bytes_per_sec).abs() < 1.0);
        }
    }

    #[test]
    fn scaled_link_stretches_time_and_is_identity_at_one() {
        let l = LinkParams { latency: 20e-6, bytes_per_sec: 6e9 };
        let s = l.scaled(2.0);
        let b = 6_000_000u64;
        assert!((s.transfer_secs(b) - 2.0 * l.transfer_secs(b)).abs() < 1e-15);
        let id = l.scaled(1.0);
        assert_eq!(id.latency.to_bits(), l.latency.to_bits());
        assert_eq!(id.bytes_per_sec.to_bits(), l.bytes_per_sec.to_bits());
    }

    #[test]
    fn unknown_profile_errors() {
        assert!(profile_by_name("gtx680").is_err());
    }

    #[test]
    fn duplex_speedup_profiles_are_rejected() {
        // A sigma < 1 would make duplex transfers FASTER than solo,
        // breaking the scheduler's admissible lower bounds.
        let mut p = profile_by_name("amd_r9").unwrap();
        p.duplex_slowdown = 0.9;
        let err = DeviceProfile::from_json(&p.to_json()).unwrap_err().to_string();
        assert!(err.contains("duplex_slowdown"), "{err}");
        p.duplex_slowdown = f64::NAN;
        assert!(DeviceProfile::from_json(&p.to_json()).is_err());
        p.duplex_slowdown = 1.0;
        assert!(DeviceProfile::from_json(&p.to_json()).is_ok());
    }
}
