//! Multi-tenant admission control: bounded per-tenant backlogs,
//! explicit backpressure, and QoS-aware shedding under overload.
//!
//! The paper's premise is many independent clients funnelling tasks into
//! one accelerator; PySchedCL and HTS both argue the admission/scheduling
//! policy must be a *pluggable* component. This module is that layer for
//! the lane/fleet coordinators:
//!
//! * [`TenantId`] / [`Priority`] / a per-task deadline annotate every
//!   [`Submission`]; untagged paths default to one tenant per worker at
//!   [`Priority::Normal`].
//! * [`AdmissionCtl`] holds the validated [`AdmissionOptions`] and the
//!   reservation ledger: a submission *reserves* a slot against its
//!   tenant's cap and the global cap when admitted, holds it while queued
//!   in **any** buffer (so steals and explicit placement move work between
//!   lanes without ever changing a tenant's total — steals cannot violate
//!   caps), and releases it when drained for execution or evicted.
//! * [`AdmissionGate::submit`] is the producer-side choke point. On a full
//!   backlog the [`Overflow`] policy decides: `Block` parks the producer
//!   on an epoch condvar ([`WakeSignal`]-style — no spin, no sleep loop)
//!   until a release makes room; `RejectNew` returns a typed [`Shed`]
//!   receipt immediately; `ShedLowest` evicts the lowest-priority queued
//!   submission (strictly below the incoming class) to make room,
//!   completing the victim's event and stamping its [`ShedSlot`] so the
//!   blocked producer observes the receipt, never a hang.
//! * [`AdmissionPolicy`] orders *drains* of admitted work: FIFO
//!   (bit-identical to the admission-off pipeline), deficit-round-robin
//!   weighted fairness over tenants, strict priority classes, and
//!   deadline-EDF within a class — one impl per policy, selected by
//!   [`DrainPolicyKind`].
//!
//! Exactly-once: an admitted submission lives in exactly one queue at a
//! time, and both draining and eviction remove it under that queue's
//! lock, so a task is either executed (completed by the device path) or
//! shed (completed by the gate with a receipt) — never both. `Event`
//! asserts on double-completion, which the property tests lean on.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};
use std::time::{Duration, Instant};

use crate::coordinator::buffer::{SharedBuffer, Submission};
use crate::coordinator::driver::ConfigError;
use crate::coordinator::lanes::WakeSignal;
use crate::util::stats;

/// A tenant: one independent client (host application / cluster node)
/// submitting work through the shared coordinator.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TenantId(pub u32);

impl std::fmt::Display for TenantId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "tenant{}", self.0)
    }
}

/// Priority class of a submission. Classes are *strictly* ordered by the
/// priority-aware drain policies: no `Normal` work runs while `Hi` work
/// is queued on the same lane, and `BestEffort` is the shed victim pool.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Priority {
    /// Latency-sensitive; drained first, never shed by `ShedLowest`
    /// (nothing outranks it).
    Hi,
    #[default]
    Normal,
    /// Throughput filler; first to be evicted under overload.
    BestEffort,
}

impl Priority {
    /// Drain rank: lower drains first, higher sheds first.
    pub fn rank(self) -> u8 {
        match self {
            Priority::Hi => 0,
            Priority::Normal => 1,
            Priority::BestEffort => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::Hi => "hi",
            Priority::Normal => "normal",
            Priority::BestEffort => "besteffort",
        }
    }

    /// Inverse of [`name`](Priority::name) — the trace-protocol `class`
    /// field decoder. `None` for unknown strings (the trace layer turns
    /// that into a typed schema error with the line number).
    pub fn from_name(s: &str) -> Option<Priority> {
        match s {
            "hi" => Some(Priority::Hi),
            "normal" => Some(Priority::Normal),
            "besteffort" => Some(Priority::BestEffort),
            _ => None,
        }
    }
}

/// Why a submission was shed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// The submitting tenant's own backlog cap was full (`RejectNew`, or
    /// `ShedLowest` with no lower-priority victim of the same tenant).
    TenantCapFull,
    /// The global backlog cap was full.
    GlobalCapFull,
    /// A queued submission was evicted by a higher-priority arrival
    /// (`ShedLowest`).
    Evicted,
}

/// Typed receipt handed to the producer of a shed submission. The task
/// was **not** executed; its completion event fires (so a blocked worker
/// always wakes) with this receipt stamped in the submission's
/// [`ShedSlot`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shed {
    pub tenant: TenantId,
    pub class: Priority,
    pub reason: ShedReason,
}

/// Write-once, shareable shed receipt slot carried by every
/// [`Submission`]. Empty means the task ran (or is still queued); set
/// means it was shed and the completion timestamp is an eviction time,
/// not a device time.
#[derive(Clone, Debug, Default)]
pub struct ShedSlot(Arc<OnceLock<Shed>>);

impl ShedSlot {
    pub fn new() -> Self {
        Self::default()
    }

    /// Stamp the receipt; returns false if one was already set.
    pub fn set(&self, s: Shed) -> bool {
        self.0.set(s).is_ok()
    }

    pub fn get(&self) -> Option<Shed> {
        self.0.get().copied()
    }

    pub fn is_shed(&self) -> bool {
        self.0.get().is_some()
    }
}

/// What `submit` does when a cap is hit.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Overflow {
    /// Park the producer on the admission epoch condvar until a release
    /// makes room (explicit backpressure; no spin, no sleep loop).
    #[default]
    Block,
    /// Evict the lowest-priority queued submission strictly below the
    /// incoming class to make room; if no such victim exists the
    /// *incoming* submission is shed instead. Never blocks.
    ShedLowest,
    /// Shed the incoming submission immediately with a typed receipt.
    RejectNew,
}

/// Which [`AdmissionPolicy`] orders drains of admitted work.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DrainPolicyKind {
    /// Arrival order — bit-identical to the admission-off pipeline.
    Fifo,
    /// Deficit-round-robin over tenants ([`AdmissionOptions::weights`],
    /// default weight 1): every non-empty tenant is served within one
    /// ring rotation (Σ weights picks), the starvation bound.
    #[default]
    WeightedFair,
    /// Strictly ordered priority classes, FIFO within a class.
    StrictPriority,
    /// Strict classes, earliest absolute deadline first within a class
    /// (deadline-less submissions sort last, FIFO among themselves).
    DeadlineEdf,
}

impl DrainPolicyKind {
    pub fn name(self) -> &'static str {
        match self {
            DrainPolicyKind::Fifo => "fifo",
            DrainPolicyKind::WeightedFair => "weighted_fair",
            DrainPolicyKind::StrictPriority => "strict_priority",
            DrainPolicyKind::DeadlineEdf => "deadline_edf",
        }
    }

    /// Inverse of [`name`](DrainPolicyKind::name) — the `--drain` /
    /// trace-option decoder. `None` for unknown strings.
    pub fn from_name(s: &str) -> Option<DrainPolicyKind> {
        match s {
            "fifo" => Some(DrainPolicyKind::Fifo),
            "weighted_fair" => Some(DrainPolicyKind::WeightedFair),
            "strict_priority" => Some(DrainPolicyKind::StrictPriority),
            "deadline_edf" => Some(DrainPolicyKind::DeadlineEdf),
            _ => None,
        }
    }

    /// Instantiate the policy. Each armed buffer owns an independent
    /// instance (DRR ring state is per-queue, protected by that queue's
    /// own lock).
    pub fn build(self, weights: &[(TenantId, u32)]) -> Box<dyn AdmissionPolicy> {
        match self {
            DrainPolicyKind::Fifo => Box::new(FifoPolicy),
            DrainPolicyKind::WeightedFair => {
                Box::new(WeightedFairPolicy::new(weights))
            }
            DrainPolicyKind::StrictPriority => Box::new(StrictPriorityPolicy),
            DrainPolicyKind::DeadlineEdf => Box::new(DeadlineEdfPolicy),
        }
    }
}

/// Validated admission configuration (`LaneOptions::admission` /
/// `FleetCoordOptions::admission`; `None` keeps today's unbounded
/// behavior bit-for-bit).
#[derive(Clone, Debug)]
pub struct AdmissionOptions {
    /// Max queued (admitted, not yet drained for execution) submissions
    /// per tenant. Must be >= 1.
    pub per_tenant_cap: usize,
    /// Max queued submissions across all tenants. Must be >=
    /// `per_tenant_cap`.
    pub global_cap: usize,
    pub overflow: Overflow,
    pub policy: DrainPolicyKind,
    /// DRR weights for [`DrainPolicyKind::WeightedFair`]; unlisted
    /// tenants weigh 1. Weights must be non-zero and tenants unique.
    pub weights: Vec<(TenantId, u32)>,
    /// Collapse byte-identical spec twins across tenants before
    /// compilation on the batch (legacy lane) path, counted in
    /// `LaneStats::n_xtenant_collapsed`.
    pub collapse_twins: bool,
}

impl Default for AdmissionOptions {
    fn default() -> Self {
        AdmissionOptions {
            per_tenant_cap: 64,
            global_cap: 1024,
            overflow: Overflow::default(),
            policy: DrainPolicyKind::default(),
            weights: Vec::new(),
            collapse_twins: true,
        }
    }
}

impl AdmissionOptions {
    /// Check the invariants; `Err` names the offending field.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.per_tenant_cap == 0 {
            return Err(ConfigError::new(
                "admission.per_tenant_cap",
                "must be >= 1",
            ));
        }
        if self.global_cap < self.per_tenant_cap {
            return Err(ConfigError::new(
                "admission.global_cap",
                format!(
                    "global_cap ({}) must be >= per_tenant_cap ({})",
                    self.global_cap, self.per_tenant_cap
                ),
            ));
        }
        let mut seen = Vec::with_capacity(self.weights.len());
        for &(t, w) in &self.weights {
            if w == 0 {
                return Err(ConfigError::new(
                    "admission.weights",
                    format!("weight for {t} must be >= 1"),
                ));
            }
            if seen.contains(&t) {
                return Err(ConfigError::new(
                    "admission.weights",
                    format!("duplicate weight entry for {t}"),
                ));
            }
            seen.push(t);
        }
        Ok(())
    }

    /// By-value form of [`validate`](AdmissionOptions::validate) for
    /// builder chains.
    pub fn validated(self) -> Result<Self, ConfigError> {
        self.validate()?;
        Ok(self)
    }
}

/// Which cap a reservation attempt hit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CapHit {
    Tenant,
    Global,
}

impl CapHit {
    fn reason(self) -> ShedReason {
        match self {
            CapHit::Tenant => ShedReason::TenantCapFull,
            CapHit::Global => ShedReason::GlobalCapFull,
        }
    }
}

/// Drain-ordering policy over one queue of admitted submissions: `pick`
/// returns the index of the next submission to remove. Implementations
/// must serve each tenant oldest-first (per-tenant FIFO) — every policy
/// below scans first-occurrence within its selection class.
pub trait AdmissionPolicy: Send {
    fn name(&self) -> &'static str;
    fn pick(&mut self, queue: &VecDeque<Submission>) -> Option<usize>;
}

struct FifoPolicy;

impl AdmissionPolicy for FifoPolicy {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn pick(&mut self, queue: &VecDeque<Submission>) -> Option<usize> {
        (!queue.is_empty()).then_some(0)
    }
}

/// Deficit-round-robin: tenants join a rotation ring in first-appearance
/// order; the front tenant is served (oldest submission first) until its
/// per-visit credit — its weight — is spent, then the ring rotates.
/// Starvation bound: any non-empty tenant is served within Σ weights
/// consecutive picks.
struct WeightedFairPolicy {
    weights: Vec<(u32, u32)>,
    ring: VecDeque<u32>,
    credit: u32,
}

impl WeightedFairPolicy {
    fn new(weights: &[(TenantId, u32)]) -> Self {
        WeightedFairPolicy {
            weights: weights.iter().map(|&(t, w)| (t.0, w)).collect(),
            ring: VecDeque::new(),
            credit: 0,
        }
    }

    fn weight(&self, t: u32) -> u32 {
        self.weights
            .iter()
            .find(|&&(id, _)| id == t)
            .map_or(1, |&(_, w)| w)
            .max(1)
    }
}

impl AdmissionPolicy for WeightedFairPolicy {
    fn name(&self) -> &'static str {
        "weighted_fair"
    }

    fn pick(&mut self, queue: &VecDeque<Submission>) -> Option<usize> {
        if queue.is_empty() {
            return None;
        }
        for s in queue {
            let t = s.tenant.0;
            if !self.ring.contains(&t) {
                self.ring.push_back(t);
                if self.ring.len() == 1 {
                    self.credit = self.weight(t);
                }
            }
        }
        // Bounded: each iteration either returns or shrinks/rotates the
        // ring, and every queued tenant is in the ring.
        let mut guard = 0usize;
        loop {
            let t = *self.ring.front()?;
            match queue.iter().position(|s| s.tenant.0 == t) {
                Some(i) if self.credit > 0 => {
                    self.credit -= 1;
                    return Some(i);
                }
                Some(_) => {
                    // Quantum spent: rotate to the next tenant.
                    let t = self.ring.pop_front().expect("ring non-empty");
                    self.ring.push_back(t);
                    self.credit = self.weight(*self.ring.front().expect("ring non-empty"));
                }
                None => {
                    // Tenant fully drained away: drop it from the ring.
                    self.ring.pop_front();
                    if let Some(&n) = self.ring.front() {
                        self.credit = self.weight(n);
                    }
                }
            }
            guard += 1;
            if guard > 2 * self.ring.len() + 4 {
                // Unreachable by construction; fail soft to FIFO rather
                // than looping a proxy thread.
                return Some(0);
            }
        }
    }
}

struct StrictPriorityPolicy;

impl AdmissionPolicy for StrictPriorityPolicy {
    fn name(&self) -> &'static str {
        "strict_priority"
    }

    fn pick(&mut self, queue: &VecDeque<Submission>) -> Option<usize> {
        queue
            .iter()
            .enumerate()
            .min_by_key(|(i, s)| (s.class.rank(), *i))
            .map(|(i, _)| i)
    }
}

struct DeadlineEdfPolicy;

impl AdmissionPolicy for DeadlineEdfPolicy {
    fn name(&self) -> &'static str {
        "deadline_edf"
    }

    fn pick(&mut self, queue: &VecDeque<Submission>) -> Option<usize> {
        queue
            .iter()
            .enumerate()
            .min_by(|(i, a), (j, b)| {
                let da = a.deadline.unwrap_or(f64::INFINITY);
                let db = b.deadline.unwrap_or(f64::INFINITY);
                a.class
                    .rank()
                    .cmp(&b.class.rank())
                    .then(da.partial_cmp(&db).unwrap_or(std::cmp::Ordering::Equal))
                    .then(i.cmp(j))
            })
            .map(|(i, _)| i)
    }
}

#[derive(Clone, Copy, Debug, Default)]
struct TenantAcct {
    queued: usize,
    n_admitted: usize,
    n_shed: usize,
    n_blocked: usize,
}

#[derive(Debug, Default)]
struct Accounts {
    global: usize,
    tenants: HashMap<u32, TenantAcct>,
    n_evicted: usize,
    n_block_waits: usize,
}

/// The admission controller: validated options + the reservation ledger.
/// One per coordinator run, shared by every armed buffer and every
/// producer gate.
pub struct AdmissionCtl {
    opts: AdmissionOptions,
    state: Mutex<Accounts>,
    /// Epoch condvar blocked producers park on; bumped by every release.
    wake: WakeSignal,
}

impl AdmissionCtl {
    /// Panics on invalid options (see [`AdmissionOptions::validated`]) —
    /// admission is armed at coordinator construction, where a bad
    /// config is a programming error, not a runtime condition.
    pub fn new(opts: AdmissionOptions) -> Arc<AdmissionCtl> {
        let opts = opts.validated().expect("invalid AdmissionOptions");
        Arc::new(AdmissionCtl {
            opts,
            state: Mutex::new(Accounts::default()),
            wake: WakeSignal::new(),
        })
    }

    pub fn opts(&self) -> &AdmissionOptions {
        &self.opts
    }

    // The ledger is always consistent at lock release, so a poisoned
    // mutex (holder panicked for unrelated reasons) recovers — same
    // idiom as `SharedBuffer::lock_state`.
    fn lock(&self) -> std::sync::MutexGuard<'_, Accounts> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Reserve one backlog slot for `t`, or report which cap is full.
    pub fn try_reserve(&self, t: TenantId) -> Result<(), CapHit> {
        let mut g = self.lock();
        let acct = g.tenants.entry(t.0).or_default();
        if acct.queued >= self.opts.per_tenant_cap {
            return Err(CapHit::Tenant);
        }
        if g.global >= self.opts.global_cap {
            return Err(CapHit::Global);
        }
        let acct = g.tenants.entry(t.0).or_default();
        acct.queued += 1;
        acct.n_admitted += 1;
        g.global += 1;
        Ok(())
    }

    /// Release `n` slots held by `t` (drained for execution or evicted)
    /// and wake blocked producers.
    pub fn release(&self, t: TenantId, n: usize) {
        if n == 0 {
            return;
        }
        {
            let mut g = self.lock();
            let acct = g.tenants.entry(t.0).or_default();
            acct.queued = acct.queued.saturating_sub(n);
            g.global = g.global.saturating_sub(n);
        }
        self.wake.notify();
    }

    /// Batch [`AdmissionCtl::release`] for a drained slice: one lock,
    /// one wakeup.
    pub(crate) fn release_subs(&self, subs: &[Submission]) {
        if subs.is_empty() {
            return;
        }
        {
            let mut g = self.lock();
            for s in subs {
                let acct = g.tenants.entry(s.tenant.0).or_default();
                acct.queued = acct.queued.saturating_sub(1);
                g.global = g.global.saturating_sub(1);
            }
        }
        self.wake.notify();
    }

    /// Re-reserve slots for requeued (already-admitted) work, bypassing
    /// the caps: accepted tasks are never lost, so a quarantine requeue
    /// must succeed even into a momentarily full backlog.
    pub(crate) fn reserve_requeued(&self, subs: &[Submission]) {
        if subs.is_empty() {
            return;
        }
        let mut g = self.lock();
        for s in subs {
            g.tenants.entry(s.tenant.0).or_default().queued += 1;
            g.global += 1;
        }
    }

    fn note_shed(&self, t: TenantId) {
        self.lock().tenants.entry(t.0).or_default().n_shed += 1;
    }

    fn note_evicted(&self, t: TenantId) {
        let mut g = self.lock();
        g.tenants.entry(t.0).or_default().n_shed += 1;
        g.n_evicted += 1;
    }

    fn note_blocked(&self, t: TenantId) {
        let mut g = self.lock();
        g.tenants.entry(t.0).or_default().n_blocked += 1;
        g.n_block_waits += 1;
    }

    /// Currently queued (reserved, undrained) submissions for `t`.
    pub fn queued(&self, t: TenantId) -> usize {
        self.lock().tenants.get(&t.0).map_or(0, |a| a.queued)
    }

    /// Currently queued submissions across all tenants.
    pub fn queued_total(&self) -> usize {
        self.lock().global
    }

    pub(crate) fn wake(&self) -> &WakeSignal {
        &self.wake
    }

    /// Snapshot the per-tenant admission telemetry, joining the tagged
    /// completion latencies (`latencies[i]` belongs to `tenants[i]`).
    pub fn report(&self, latencies: &[f64], tenants: &[u32]) -> AdmissionReport {
        debug_assert_eq!(latencies.len(), tenants.len());
        let mut lat_by: BTreeMap<u32, Vec<f64>> = BTreeMap::new();
        for (&t, &l) in tenants.iter().zip(latencies.iter()) {
            lat_by.entry(t).or_default().push(l);
        }
        let g = self.lock();
        let mut ids: Vec<u32> = g.tenants.keys().copied().collect();
        for &t in lat_by.keys() {
            if !ids.contains(&t) {
                ids.push(t);
            }
        }
        ids.sort_unstable();
        let empty: Vec<f64> = Vec::new();
        let per_tenant: Vec<TenantReport> = ids
            .iter()
            .map(|&t| {
                let acct = g.tenants.get(&t).copied().unwrap_or_default();
                let lats = lat_by.get(&t).unwrap_or(&empty);
                TenantReport {
                    tenant: t,
                    n_admitted: acct.n_admitted,
                    n_completed: lats.len(),
                    n_shed: acct.n_shed,
                    n_blocked: acct.n_blocked,
                    mean_latency: if lats.is_empty() { 0.0 } else { stats::mean(lats) },
                    p50_latency: percentile_or_zero(lats, 50.0),
                    p99_latency: percentile_or_zero(lats, 99.0),
                }
            })
            .collect();
        let means: Vec<f64> = per_tenant
            .iter()
            .filter(|r| r.n_completed > 0)
            .map(|r| r.mean_latency)
            .collect();
        AdmissionReport {
            n_shed: per_tenant.iter().map(|r| r.n_shed).sum(),
            n_evicted: g.n_evicted,
            n_block_waits: g.n_block_waits,
            jain_fairness: stats::jain_index(&means),
            per_tenant,
        }
    }
}

fn percentile_or_zero(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        stats::percentile(xs, p)
    }
}

/// Per-tenant slice of an [`AdmissionReport`].
#[derive(Clone, Debug, PartialEq)]
pub struct TenantReport {
    pub tenant: u32,
    /// Submissions that passed admission (reserved a slot).
    pub n_admitted: usize,
    /// Submissions that ran on a device (one tagged latency each).
    pub n_completed: usize,
    /// Rejected at the gate + evicted from a backlog.
    pub n_shed: usize,
    /// Distinct submissions that blocked at least once (`Block`).
    pub n_blocked: usize,
    pub mean_latency: f64,
    pub p50_latency: f64,
    pub p99_latency: f64,
}

/// End-of-run multi-tenant telemetry, surfaced as
/// `LaneMetrics::admission` / `FleetMetrics::admission`.
#[derive(Clone, Debug, PartialEq)]
pub struct AdmissionReport {
    /// Sorted by tenant id.
    pub per_tenant: Vec<TenantReport>,
    /// Total shed (rejections + evictions) across tenants.
    pub n_shed: usize,
    /// Evictions only (subset of `n_shed`).
    pub n_evicted: usize,
    /// Distinct submissions that blocked at least once.
    pub n_block_waits: usize,
    /// Jain fairness index over per-tenant mean completion latencies
    /// (tenants with >= 1 completion); 1.0 = perfectly fair.
    pub jain_fairness: f64,
}

/// Outcome of [`AdmissionGate::submit`].
#[derive(Clone, Debug, PartialEq)]
pub enum SubmitOutcome {
    /// Queued; the producer should wait on the submission's `done` event
    /// (which may complete with a [`ShedSlot`] receipt if later evicted).
    Admitted,
    /// Not queued; the receipt is also stamped in the submission's slot.
    Shed(Shed),
}

/// Backstop for the `Block` park. Correctness does not depend on it —
/// the epoch is snapshotted *before* the failed reservation, so a
/// concurrent release always either frees the slot before the retry or
/// bumps the epoch after the snapshot — it only bounds the damage of a
/// future bug to a periodic re-check instead of a hang.
const BLOCK_BACKSTOP: Duration = Duration::from_millis(50);

/// Producer-side admission gate: every tenant submission enters the
/// coordinator through [`AdmissionGate::submit`].
pub struct AdmissionGate {
    ctl: Arc<AdmissionCtl>,
    /// Where admitted submissions are enqueued.
    entry: SharedBuffer,
    /// Queues scanned for `ShedLowest` victims (the entry buffer plus
    /// every lane the coordinator may have moved admitted work to).
    evict_from: Vec<SharedBuffer>,
    epoch: Instant,
}

impl AdmissionGate {
    pub fn new(
        ctl: Arc<AdmissionCtl>,
        entry: SharedBuffer,
        evict_from: Vec<SharedBuffer>,
        epoch: Instant,
    ) -> AdmissionGate {
        AdmissionGate { ctl, entry, evict_from, epoch }
    }

    /// Admit, block, or shed `s` per the configured [`Overflow`] policy.
    pub fn submit(&self, s: Submission) -> SubmitOutcome {
        let mut blocked = false;
        loop {
            // Snapshot before the reservation attempt: a release landing
            // after this line bumps the epoch and turns the park into an
            // immediate retry — no lost wakeup.
            let seen = self.ctl.wake.epoch();
            let hit = match self.ctl.try_reserve(s.tenant) {
                Ok(()) => {
                    self.entry.push(s);
                    return SubmitOutcome::Admitted;
                }
                Err(hit) => hit,
            };
            match self.ctl.opts.overflow {
                Overflow::RejectNew => {
                    return self.shed_incoming(s, hit.reason());
                }
                Overflow::Block => {
                    if !blocked {
                        blocked = true;
                        self.ctl.note_blocked(s.tenant);
                    }
                    self.ctl
                        .wake
                        .wait_past(seen, Instant::now() + BLOCK_BACKSTOP);
                }
                Overflow::ShedLowest => {
                    if !self.evict_one(&s, hit) {
                        return self.shed_incoming(s, hit.reason());
                    }
                    // Victim released a slot; retry the reservation.
                }
            }
        }
    }

    fn shed_incoming(&self, s: Submission, reason: ShedReason) -> SubmitOutcome {
        let receipt = Shed { tenant: s.tenant, class: s.class, reason };
        s.shed.set(receipt);
        self.ctl.note_shed(s.tenant);
        SubmitOutcome::Shed(receipt)
    }

    /// Evict the lowest-priority queued submission strictly below the
    /// incoming class. A tenant-cap hit may only evict the same tenant's
    /// work (evicting a peer would not free the right cap); a global-cap
    /// hit considers every tenant. Returns whether a slot was freed.
    fn evict_one(&self, incoming: &Submission, hit: CapHit) -> bool {
        let tenant = match hit {
            CapHit::Tenant => Some(incoming.tenant),
            CapHit::Global => None,
        };
        // Two passes: find the queue holding the globally worst victim,
        // then evict from it. A race that drains the victim in between
        // simply reports no eviction and the submit loop re-checks caps.
        let mut best: Option<(usize, Priority)> = None;
        for (i, buf) in self.evict_from.iter().enumerate() {
            if let Some(c) = buf.peek_lowest_below(incoming.class, tenant) {
                if best.map_or(true, |(_, b)| c.rank() > b.rank()) {
                    best = Some((i, c));
                }
            }
        }
        let Some((i, _)) = best else { return false };
        let Some(victim) = self.evict_from[i].evict_lowest(incoming.class, tenant)
        else {
            return false;
        };
        let receipt = Shed {
            tenant: victim.tenant,
            class: victim.class,
            reason: ShedReason::Evicted,
        };
        // Stamp the receipt before completing: the victim's worker wakes
        // from `done.wait()` and must observe it.
        victim.shed.set(receipt);
        self.ctl.note_evicted(victim.tenant);
        self.ctl.release(victim.tenant, 1);
        victim.done.complete(self.epoch.elapsed().as_secs_f64());
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::queue::event::Event;
    use crate::task::{KernelSpec, TaskSpec};
    use std::sync::Barrier;

    fn sub_t(tenant: u32, class: Priority, seq: usize) -> Submission {
        Submission {
            worker: tenant as usize,
            batch_seq: seq,
            task: TaskSpec::simple("t", 10, KernelSpec::Timed { secs: 1e-4 }, 10),
            done: Event::new(),
            submitted_at: 0.0,
            tenant: TenantId(tenant),
            class,
            deadline: None,
            shed: ShedSlot::new(),
        }
    }

    fn queue_of(subs: Vec<Submission>) -> VecDeque<Submission> {
        subs.into()
    }

    #[test]
    fn options_validation_rejects_bad_configs() {
        let ok = AdmissionOptions::default().validated();
        assert!(ok.is_ok());
        let zero_cap =
            AdmissionOptions { per_tenant_cap: 0, ..AdmissionOptions::default() };
        assert!(zero_cap.validated().is_err());
        let inverted = AdmissionOptions {
            per_tenant_cap: 8,
            global_cap: 4,
            ..AdmissionOptions::default()
        };
        assert!(inverted.validated().is_err());
        let zero_weight = AdmissionOptions {
            weights: vec![(TenantId(0), 0)],
            ..AdmissionOptions::default()
        };
        assert!(zero_weight.validated().is_err());
        let dup = AdmissionOptions {
            weights: vec![(TenantId(0), 1), (TenantId(0), 2)],
            ..AdmissionOptions::default()
        };
        assert!(dup.validated().is_err());
    }

    #[test]
    fn reserve_respects_both_caps_and_release_frees() {
        let ctl = AdmissionCtl::new(AdmissionOptions {
            per_tenant_cap: 2,
            global_cap: 3,
            ..AdmissionOptions::default()
        });
        assert!(ctl.try_reserve(TenantId(0)).is_ok());
        assert!(ctl.try_reserve(TenantId(0)).is_ok());
        assert_eq!(ctl.try_reserve(TenantId(0)), Err(CapHit::Tenant));
        assert!(ctl.try_reserve(TenantId(1)).is_ok());
        assert_eq!(ctl.try_reserve(TenantId(1)), Err(CapHit::Global));
        ctl.release(TenantId(0), 1);
        assert!(ctl.try_reserve(TenantId(1)).is_ok());
        assert_eq!(ctl.queued_total(), 3);
        assert_eq!(ctl.queued(TenantId(0)), 1);
        assert_eq!(ctl.queued(TenantId(1)), 2);
    }

    #[test]
    fn weighted_fair_serves_every_tenant_within_sum_of_weights() {
        // Tenant 0 floods; 1..=3 hold one submission each. With weights
        // (t0: 2, rest 1) every tenant must be served within Σw = 5 picks.
        let weights = vec![(TenantId(0), 2u32)];
        let mut policy = DrainPolicyKind::WeightedFair.build(&weights);
        let mut q = queue_of(
            (0..8)
                .map(|i| sub_t(0, Priority::Normal, i))
                .chain((1..4).map(|t| sub_t(t, Priority::Normal, 0)))
                .collect(),
        );
        let mut first_seen: HashMap<u32, usize> = HashMap::new();
        for round in 0..q.len() {
            let i = policy.pick(&q).expect("non-empty");
            let s = q.remove(i).expect("picked a live index");
            first_seen.entry(s.tenant.0).or_insert(round);
        }
        let k = 5; // sum of weights over the 4 tenants
        for t in 0..4u32 {
            assert!(
                first_seen[&t] < k,
                "tenant {t} first served at round {} (bound {k})",
                first_seen[&t]
            );
        }
    }

    #[test]
    fn weighted_fair_preserves_per_tenant_fifo() {
        let mut policy = DrainPolicyKind::WeightedFair.build(&[]);
        let mut q = queue_of(
            (0..6).map(|i| sub_t(i % 3, Priority::Normal, i / 3)).collect(),
        );
        let mut last_seq: HashMap<u32, usize> = HashMap::new();
        while let Some(i) = policy.pick(&q) {
            let s = q.remove(i).unwrap();
            if let Some(&prev) = last_seq.get(&s.tenant.0) {
                assert!(s.batch_seq > prev, "per-tenant FIFO violated");
            }
            last_seq.insert(s.tenant.0, s.batch_seq);
        }
        assert!(q.is_empty());
    }

    #[test]
    fn strict_priority_orders_classes_fifo_within() {
        let mut policy = DrainPolicyKind::StrictPriority.build(&[]);
        let q = queue_of(vec![
            sub_t(0, Priority::BestEffort, 0),
            sub_t(1, Priority::Normal, 0),
            sub_t(2, Priority::Hi, 0),
            sub_t(3, Priority::Hi, 1),
        ]);
        assert_eq!(policy.pick(&q), Some(2), "first Hi wins");
    }

    #[test]
    fn deadline_edf_orders_within_class_only() {
        let mut policy = DrainPolicyKind::DeadlineEdf.build(&[]);
        let mut early = sub_t(0, Priority::Normal, 0);
        early.deadline = Some(1.0);
        let mut late = sub_t(1, Priority::Normal, 0);
        late.deadline = Some(5.0);
        let none = sub_t(2, Priority::Normal, 0);
        let hi = sub_t(3, Priority::Hi, 0);
        // Hi beats every Normal deadline; within Normal, EDF; deadline-less last.
        let q = queue_of(vec![none.clone(), late.clone(), early.clone(), hi]);
        assert_eq!(policy.pick(&q), Some(3));
        let q = queue_of(vec![none.clone(), late, early]);
        assert_eq!(policy.pick(&q), Some(2));
        let q = queue_of(vec![none]);
        assert_eq!(policy.pick(&q), Some(0));
    }

    #[test]
    fn gate_reject_new_staples_receipt() {
        let ctl = AdmissionCtl::new(AdmissionOptions {
            per_tenant_cap: 1,
            global_cap: 1,
            overflow: Overflow::RejectNew,
            ..AdmissionOptions::default()
        });
        let entry = SharedBuffer::new();
        let gate = AdmissionGate::new(
            ctl.clone(),
            entry.clone(),
            vec![entry.clone()],
            Instant::now(),
        );
        assert_eq!(gate.submit(sub_t(0, Priority::Normal, 0)), SubmitOutcome::Admitted);
        let s = sub_t(0, Priority::Normal, 1);
        let slot = s.shed.clone();
        let out = gate.submit(s);
        let expect = Shed {
            tenant: TenantId(0),
            class: Priority::Normal,
            reason: ShedReason::TenantCapFull,
        };
        assert_eq!(out, SubmitOutcome::Shed(expect));
        assert_eq!(slot.get(), Some(expect));
        assert_eq!(entry.len(), 1, "shed submission never queued");
        let rep = ctl.report(&[], &[]);
        assert_eq!(rep.n_shed, 1);
        assert_eq!(rep.n_evicted, 0);
    }

    #[test]
    fn gate_shed_lowest_evicts_strictly_lower_class() {
        let ctl = AdmissionCtl::new(AdmissionOptions {
            per_tenant_cap: 1,
            global_cap: 1,
            overflow: Overflow::ShedLowest,
            ..AdmissionOptions::default()
        });
        let entry = SharedBuffer::new();
        let gate = AdmissionGate::new(
            ctl.clone(),
            entry.clone(),
            vec![entry.clone()],
            Instant::now(),
        );
        let be = sub_t(0, Priority::BestEffort, 0);
        let (be_done, be_slot) = (be.done.clone(), be.shed.clone());
        assert_eq!(gate.submit(be), SubmitOutcome::Admitted);
        // Same-class arrival cannot evict (strictly lower only): it sheds.
        let peer = sub_t(1, Priority::BestEffort, 0);
        assert!(matches!(gate.submit(peer), SubmitOutcome::Shed(_)));
        assert!(!be_done.is_complete());
        // A Hi arrival evicts the queued BestEffort: receipt + completion.
        assert_eq!(gate.submit(sub_t(1, Priority::Hi, 0)), SubmitOutcome::Admitted);
        assert!(be_done.is_complete(), "evicted worker must be unblocked");
        assert_eq!(
            be_slot.get(),
            Some(Shed {
                tenant: TenantId(0),
                class: Priority::BestEffort,
                reason: ShedReason::Evicted,
            })
        );
        assert_eq!(entry.len(), 1);
        let g = entry.drain(4, Duration::ZERO).unwrap();
        assert_eq!(g[0].class, Priority::Hi);
        let rep = ctl.report(&[], &[]);
        assert_eq!(rep.n_evicted, 1);
        assert_eq!(rep.n_shed, 2, "one rejection + one eviction");
    }

    #[test]
    fn gate_block_parks_until_release_barrier_rendezvous() {
        let ctl = AdmissionCtl::new(AdmissionOptions {
            per_tenant_cap: 1,
            global_cap: 1,
            overflow: Overflow::Block,
            ..AdmissionOptions::default()
        });
        // The entry must be armed: draining it is what releases the
        // reservation the parked submit below is waiting on.
        let entry = SharedBuffer::with_admission(ctl.clone(), true);
        let gate = Arc::new(AdmissionGate::new(
            ctl.clone(),
            entry.clone(),
            vec![entry.clone()],
            Instant::now(),
        ));
        assert_eq!(gate.submit(sub_t(0, Priority::Normal, 0)), SubmitOutcome::Admitted);
        let barrier = Arc::new(Barrier::new(2));
        let (g2, b2) = (gate.clone(), barrier.clone());
        // Whichever side wins after the barrier, the blocked submit must
        // eventually admit once the drain below releases the slot.
        let h = std::thread::spawn(move || {
            b2.wait();
            g2.submit(sub_t(0, Priority::Normal, 1))
        });
        barrier.wait();
        let mut out = Vec::new();
        let drained = entry.drain_into(4, Duration::ZERO, &mut out).unwrap();
        assert_eq!(drained, 1);
        assert_eq!(h.join().unwrap(), SubmitOutcome::Admitted);
        assert_eq!(entry.len(), 1);
        assert_eq!(ctl.report(&[], &[]).n_shed, 0);
    }

    #[test]
    fn report_joins_tagged_latencies_and_jain() {
        let ctl = AdmissionCtl::new(AdmissionOptions::default());
        for _ in 0..2 {
            ctl.try_reserve(TenantId(0)).unwrap();
            ctl.try_reserve(TenantId(1)).unwrap();
        }
        let latencies = [1.0, 3.0, 1.0, 3.0];
        let tenants = [0u32, 1, 0, 1];
        let rep = ctl.report(&latencies, &tenants);
        assert_eq!(rep.per_tenant.len(), 2);
        assert_eq!(rep.per_tenant[0].n_completed, 2);
        assert_eq!(rep.per_tenant[0].mean_latency, 1.0);
        assert_eq!(rep.per_tenant[1].mean_latency, 3.0);
        // J([1, 3]) = 16 / (2 * 10) = 0.8.
        assert!((rep.jain_fairness - 0.8).abs() < 1e-12);
    }
}
