//! The shared submission buffer between worker threads and the host proxy
//! (paper Fig. 8): workers write intercepted "OpenCL API calls" (task
//! submissions); the proxy polls, drains a task group, reorders and
//! submits it to the device queues.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::queue::event::Event;
use crate::task::TaskSpec;

/// One intercepted task submission.
#[derive(Clone, Debug)]
pub struct Submission {
    pub worker: usize,
    /// Position within the worker's dependent batch (0..N).
    pub batch_seq: usize,
    pub task: TaskSpec,
    /// Completed (with the device timestamp) when the task finishes; the
    /// worker blocks on this before submitting its next batch entry.
    pub done: Event,
    /// Wall-clock submission time (secs since coordinator epoch).
    pub submitted_at: f64,
}

#[derive(Default)]
struct State {
    queue: VecDeque<Submission>,
    closed: bool,
}

/// MPSC buffer with blocking drain.
#[derive(Clone, Default)]
pub struct SharedBuffer {
    inner: Arc<(Mutex<State>, Condvar)>,
}

impl SharedBuffer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&self, s: Submission) {
        let (m, cv) = &*self.inner;
        let mut g = m.lock().unwrap();
        assert!(!g.closed, "push after close");
        g.queue.push_back(s);
        cv.notify_all();
    }

    /// Declare no further submissions will arrive.
    pub fn close(&self) {
        let (m, cv) = &*self.inner;
        m.lock().unwrap().closed = true;
        cv.notify_all();
    }

    /// Blocking drain: waits until at least one submission is available
    /// (returning up to `max`) or the buffer is closed and empty (None).
    /// `settle` emulates the proxy's polling window: once something is
    /// available, wait this long for stragglers before draining — this is
    /// what lets all T workers land in the same task group.
    pub fn drain(&self, max: usize, settle: Duration) -> Option<Vec<Submission>> {
        let (m, cv) = &*self.inner;
        let mut g = m.lock().unwrap();
        loop {
            if !g.queue.is_empty() {
                break;
            }
            if g.closed {
                return None;
            }
            g = cv.wait(g).unwrap();
        }
        if !settle.is_zero() {
            // Give other workers a window to join this TG.
            let deadline = std::time::Instant::now() + settle;
            while g.queue.len() < max {
                let left = match deadline.checked_duration_since(std::time::Instant::now()) {
                    Some(d) => d,
                    None => break,
                };
                let (ng, timeout) = cv.wait_timeout(g, left).unwrap();
                g = ng;
                if timeout.timed_out() {
                    break;
                }
            }
        }
        let take = g.queue.len().min(max);
        Some(g.queue.drain(..take).collect())
    }

    pub fn len(&self) -> usize {
        self.inner.0.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::task::KernelSpec;

    fn sub(worker: usize, seq: usize) -> Submission {
        Submission {
            worker,
            batch_seq: seq,
            task: TaskSpec::simple(
                "t",
                10,
                KernelSpec::Timed { secs: 1e-4 },
                10,
            ),
            done: Event::new(),
            submitted_at: 0.0,
        }
    }

    #[test]
    fn push_drain_fifo() {
        let b = SharedBuffer::new();
        b.push(sub(0, 0));
        b.push(sub(1, 0));
        b.push(sub(2, 0));
        let got = b.drain(2, Duration::ZERO).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].worker, 0);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn drain_blocks_until_push() {
        let b = SharedBuffer::new();
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.drain(4, Duration::ZERO));
        std::thread::sleep(Duration::from_millis(5));
        b.push(sub(3, 1));
        let got = h.join().unwrap().unwrap();
        assert_eq!(got[0].worker, 3);
    }

    #[test]
    fn close_unblocks_with_none() {
        let b = SharedBuffer::new();
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.drain(4, Duration::ZERO));
        std::thread::sleep(Duration::from_millis(5));
        b.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn settle_window_gathers_stragglers() {
        let b = SharedBuffer::new();
        b.push(sub(0, 0));
        let b2 = b.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(3));
            b2.push(sub(1, 0));
        });
        let got = b.drain(4, Duration::from_millis(50)).unwrap();
        h.join().unwrap();
        assert_eq!(got.len(), 2, "straggler should join the TG");
    }
}
