//! The shared submission buffer between worker threads and the host proxy
//! (paper Fig. 8): workers write intercepted "OpenCL API calls" (task
//! submissions); the proxy polls, drains a task group, reorders and
//! submits it to the device queues.
//!
//! [`ShardedBuffer`] splits the single buffer into independent per-lane
//! buffers (worker `w` always lands on lane `w % L`, so per-worker
//! submission order is preserved by construction); each lane is drained
//! in batches by its own proxy — see `coordinator::lanes`.
//!
//! The online lanes additionally use [`SharedBuffer::drain_into_timeout`]
//! (bounded-wait drains that never park a proxy which must also poll its
//! device runner) and [`ShardedBuffer::steal_from_hottest`] (bounded
//! work-stealing of uncommitted submissions: oldest first, at most half
//! of the hottest sibling's backlog, never its last entry; per-worker
//! FIFO holds because a worker never has two submissions outstanding).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::queue::event::Event;
use crate::task::TaskSpec;

/// One intercepted task submission.
#[derive(Clone, Debug)]
pub struct Submission {
    pub worker: usize,
    /// Position within the worker's dependent batch (0..N).
    pub batch_seq: usize,
    pub task: TaskSpec,
    /// Completed (with the device timestamp) when the task finishes; the
    /// worker blocks on this before submitting its next batch entry.
    pub done: Event,
    /// Wall-clock submission time (secs since coordinator epoch).
    pub submitted_at: f64,
}

#[derive(Default)]
struct State {
    queue: VecDeque<Submission>,
    closed: bool,
}

/// Outcome of a bounded-wait drain ([`SharedBuffer::drain_into_timeout`]).
#[derive(Debug, PartialEq, Eq)]
pub enum DrainPoll {
    /// Drained this many submissions (>= 1).
    Drained(usize),
    /// Nothing arrived within the wait window; the buffer is still open.
    Empty,
    /// Closed and empty — no submission will ever arrive.
    Closed,
}

/// MPSC buffer with blocking drain.
#[derive(Clone, Default)]
pub struct SharedBuffer {
    inner: Arc<(Mutex<State>, Condvar)>,
}

impl SharedBuffer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&self, s: Submission) {
        let (m, cv) = &*self.inner;
        let mut g = m.lock().unwrap();
        assert!(!g.closed, "push after close");
        g.queue.push_back(s);
        cv.notify_all();
    }

    /// Declare no further submissions will arrive.
    pub fn close(&self) {
        let (m, cv) = &*self.inner;
        m.lock().unwrap().closed = true;
        cv.notify_all();
    }

    /// Blocking drain: waits until at least one submission is available
    /// (returning up to `max`) or the buffer is closed and empty (None).
    /// `settle` emulates the proxy's polling window: once something is
    /// available, wait this long for stragglers before draining — this is
    /// what lets all T workers land in the same task group.
    pub fn drain(&self, max: usize, settle: Duration) -> Option<Vec<Submission>> {
        let mut out = Vec::new();
        self.drain_into(max, settle, &mut out).map(|_| out)
    }

    /// [`SharedBuffer::drain`] into a caller-owned Vec — the batched-drain
    /// hot path of the lane proxies: `out` is cleared and refilled, so a
    /// warm proxy loop performs no allocation per drained group. Returns
    /// the number of submissions drained, or `None` once the buffer is
    /// closed and empty.
    pub fn drain_into(
        &self,
        max: usize,
        settle: Duration,
        out: &mut Vec<Submission>,
    ) -> Option<usize> {
        out.clear();
        let (m, cv) = &*self.inner;
        let mut g = m.lock().unwrap();
        loop {
            if !g.queue.is_empty() {
                break;
            }
            if g.closed {
                return None;
            }
            g = cv.wait(g).unwrap();
        }
        if !settle.is_zero() {
            // Give other workers a window to join this TG. A full batch or
            // a closed buffer ends the window early — no need to sleep out
            // the clock once no straggler can arrive.
            let deadline = std::time::Instant::now() + settle;
            while g.queue.len() < max && !g.closed {
                let left = match deadline.checked_duration_since(std::time::Instant::now()) {
                    Some(d) => d,
                    None => break,
                };
                let (ng, timeout) = cv.wait_timeout(g, left).unwrap();
                g = ng;
                if timeout.timed_out() {
                    break;
                }
            }
        }
        let take = g.queue.len().min(max);
        out.extend(g.queue.drain(..take));
        Some(take)
    }

    /// [`SharedBuffer::drain_into`] with a *bounded* initial wait: blocks
    /// at most `wait` for the first submission (then applies the same
    /// `settle` straggler window), and reports an open-but-empty buffer
    /// as [`DrainPoll::Empty`] instead of blocking forever. The online
    /// lane proxy alternates this with device-completion polling and
    /// steal probes, none of which may park the proxy indefinitely.
    /// `wait == Duration::ZERO` is a pure non-blocking poll.
    pub fn drain_into_timeout(
        &self,
        max: usize,
        wait: Duration,
        settle: Duration,
        out: &mut Vec<Submission>,
    ) -> DrainPoll {
        out.clear();
        let (m, cv) = &*self.inner;
        let mut g = m.lock().unwrap();
        if g.queue.is_empty() {
            let deadline = std::time::Instant::now() + wait;
            loop {
                if !g.queue.is_empty() {
                    break;
                }
                if g.closed {
                    return DrainPoll::Closed;
                }
                let left = match deadline
                    .checked_duration_since(std::time::Instant::now())
                {
                    Some(d) if !d.is_zero() => d,
                    _ => return DrainPoll::Empty,
                };
                let (ng, _) = cv.wait_timeout(g, left).unwrap();
                g = ng;
            }
        }
        if !settle.is_zero() {
            let deadline = std::time::Instant::now() + settle;
            while g.queue.len() < max && !g.closed {
                let left = match deadline
                    .checked_duration_since(std::time::Instant::now())
                {
                    Some(d) => d,
                    None => break,
                };
                let (ng, timeout) = cv.wait_timeout(g, left).unwrap();
                g = ng;
                if timeout.timed_out() {
                    break;
                }
            }
        }
        let take = g.queue.len().min(max);
        out.extend(g.queue.drain(..take));
        DrainPoll::Drained(take)
    }

    /// Steal up to `max` submissions from the *front* of the queue
    /// (oldest first), bounded to half of what is queued so the owning
    /// lane always keeps at least as much as it loses — the "bounded
    /// work-stealing" contract. Appends to `out` (no clear) and returns
    /// the count. Never blocks; an empty or single-entry queue yields 0.
    pub fn steal_into(&self, max: usize, out: &mut Vec<Submission>) -> usize {
        let (m, _cv) = &*self.inner;
        let mut g = m.lock().unwrap();
        let take = max.min(g.queue.len() / 2);
        out.extend(g.queue.drain(..take));
        take
    }

    pub fn len(&self) -> usize {
        self.inner.0.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Per-lane submission buffers (see module docs): lane `w % L` serves
/// worker `w`, so one worker's dependent batch always drains in order
/// through one lane while independent workers' groups form concurrently
/// on other lanes.
#[derive(Clone)]
pub struct ShardedBuffer {
    lanes: Arc<[SharedBuffer]>,
}

impl ShardedBuffer {
    pub fn new(lanes: usize) -> Self {
        let lanes: Vec<SharedBuffer> =
            (0..lanes.max(1)).map(|_| SharedBuffer::new()).collect();
        ShardedBuffer { lanes: lanes.into() }
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    pub fn lane(&self, l: usize) -> &SharedBuffer {
        &self.lanes[l]
    }

    /// The lane that serves worker `w`.
    pub fn lane_for_worker(&self, w: usize) -> &SharedBuffer {
        &self.lanes[w % self.lanes.len()]
    }

    /// Route one submission to its worker's lane.
    pub fn push(&self, s: Submission) {
        self.lane_for_worker(s.worker).push(s);
    }

    /// Close every lane (no further submissions anywhere).
    pub fn close_all(&self) {
        for lane in self.lanes.iter() {
            lane.close();
        }
    }

    /// Bounded work-stealing: an idle lane `thief` takes up to `max`
    /// submissions from the *hottest* sibling lane's buffer (the longest
    /// queue, ties to the lowest lane index), oldest first and capped at
    /// half the victim's backlog ([`SharedBuffer::steal_into`]). Only
    /// queues holding at least two submissions are victims, so a lane is
    /// never stripped of its last buffered task. Per-worker submission
    /// order is preserved unconditionally: a worker never has more than
    /// one submission outstanding (it blocks on the completion event
    /// before submitting the next), so no reordering between a worker's
    /// own tasks is possible wherever they execute. Appends to `out` and
    /// returns the stolen count.
    pub fn steal_from_hottest(
        &self,
        thief: usize,
        max: usize,
        out: &mut Vec<Submission>,
    ) -> usize {
        if max == 0 || self.lanes.len() < 2 {
            return 0;
        }
        let mut victim = None;
        let mut hottest = 1usize; // require >= 2 queued to steal at all
        for (l, lane) in self.lanes.iter().enumerate() {
            if l == thief {
                continue;
            }
            let len = lane.len();
            if len > hottest {
                hottest = len;
                victim = Some(l);
            }
        }
        match victim {
            Some(v) => self.lanes[v].steal_into(max, out),
            None => 0,
        }
    }

    /// Total queued submissions across lanes.
    pub fn len(&self) -> usize {
        self.lanes.iter().map(|l| l.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::task::KernelSpec;
    use std::sync::Barrier;

    fn sub(worker: usize, seq: usize) -> Submission {
        Submission {
            worker,
            batch_seq: seq,
            task: TaskSpec::simple(
                "t",
                10,
                KernelSpec::Timed { secs: 1e-4 },
                10,
            ),
            done: Event::new(),
            submitted_at: 0.0,
        }
    }

    #[test]
    fn push_drain_fifo() {
        let b = SharedBuffer::new();
        b.push(sub(0, 0));
        b.push(sub(1, 0));
        b.push(sub(2, 0));
        let got = b.drain(2, Duration::ZERO).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].worker, 0);
        assert_eq!(b.len(), 1);
    }

    // The concurrency tests rendezvous on a Barrier instead of sleeping:
    // whichever side wins the race after the barrier, the asserted
    // outcome is the same, so they cannot flake under load (the old
    // 3-5 ms `thread::sleep` versions could).

    #[test]
    fn drain_blocks_until_push() {
        let b = SharedBuffer::new();
        let barrier = Arc::new(Barrier::new(2));
        let (b2, barrier2) = (b.clone(), barrier.clone());
        // Whether drain enters its wait before or after the push lands,
        // it must return exactly the pushed submission.
        let h = std::thread::spawn(move || {
            barrier2.wait();
            b2.drain(4, Duration::ZERO)
        });
        barrier.wait();
        b.push(sub(3, 1));
        let got = h.join().unwrap().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].worker, 3);
    }

    #[test]
    fn close_unblocks_with_none() {
        let b = SharedBuffer::new();
        let barrier = Arc::new(Barrier::new(2));
        let (b2, barrier2) = (b.clone(), barrier.clone());
        // Close-before-drain and drain-before-close both end in None.
        let h = std::thread::spawn(move || {
            barrier2.wait();
            b2.drain(4, Duration::ZERO)
        });
        barrier.wait();
        b.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn settle_window_gathers_stragglers() {
        // The straggler pushes after the rendezvous; `max = 2` ends the
        // settle window the moment it lands, so the generous window is an
        // upper bound that is never slept out, not a tuned delay.
        let b = SharedBuffer::new();
        b.push(sub(0, 0));
        let barrier = Arc::new(Barrier::new(2));
        let (b2, barrier2) = (b.clone(), barrier.clone());
        let h = std::thread::spawn(move || {
            barrier2.wait();
            b2.push(sub(1, 0));
        });
        barrier.wait();
        let got = b.drain(2, Duration::from_secs(30)).unwrap();
        h.join().unwrap();
        assert_eq!(got.len(), 2, "straggler should join the TG");
    }

    #[test]
    fn settle_window_ends_at_close() {
        // Once every lane worker has exited, close() must end the settle
        // wait immediately (no straggler can arrive), with the queued
        // submissions still delivered.
        let b = SharedBuffer::new();
        b.push(sub(0, 0));
        let barrier = Arc::new(Barrier::new(2));
        let (b2, barrier2) = (b.clone(), barrier.clone());
        let h = std::thread::spawn(move || {
            barrier2.wait();
            b2.close();
        });
        barrier.wait();
        let got = b.drain(4, Duration::from_secs(30)).unwrap();
        h.join().unwrap();
        assert_eq!(got.len(), 1);
        assert!(b.drain(4, Duration::ZERO).is_none());
    }

    #[test]
    fn sharded_routes_by_worker_and_preserves_lane_fifo() {
        let s = ShardedBuffer::new(2);
        for seq in 0..3 {
            for w in 0..4 {
                s.push(sub(w, seq));
            }
        }
        assert_eq!(s.len(), 12);
        // Lane 0 serves workers 0 and 2, in push order.
        let lane0 = s.lane(0).drain(16, Duration::ZERO).unwrap();
        let got: Vec<(usize, usize)> =
            lane0.iter().map(|x| (x.worker, x.batch_seq)).collect();
        assert_eq!(
            got,
            vec![(0, 0), (2, 0), (0, 1), (2, 1), (0, 2), (2, 2)]
        );
        // Per-worker batch_seq is monotonic within the lane.
        let lane1 = s.lane(1).drain(16, Duration::ZERO).unwrap();
        for w in [1usize, 3] {
            let seqs: Vec<usize> = lane1
                .iter()
                .filter(|x| x.worker == w)
                .map(|x| x.batch_seq)
                .collect();
            assert_eq!(seqs, vec![0, 1, 2]);
        }
        assert!(s.is_empty());
    }

    #[test]
    fn timeout_drain_reports_empty_open_and_closed() {
        let b = SharedBuffer::new();
        let mut out = Vec::new();
        // Open and empty: bounded wait returns Empty (zero wait = poll).
        assert_eq!(
            b.drain_into_timeout(4, Duration::ZERO, Duration::ZERO, &mut out),
            DrainPoll::Empty
        );
        assert_eq!(
            b.drain_into_timeout(
                4,
                Duration::from_millis(1),
                Duration::ZERO,
                &mut out
            ),
            DrainPoll::Empty
        );
        // Queued items drain even after close.
        b.push(sub(0, 0));
        b.push(sub(1, 0));
        b.close();
        assert_eq!(
            b.drain_into_timeout(1, Duration::ZERO, Duration::ZERO, &mut out),
            DrainPoll::Drained(1)
        );
        assert_eq!(out.len(), 1);
        assert_eq!(
            b.drain_into_timeout(4, Duration::ZERO, Duration::ZERO, &mut out),
            DrainPoll::Drained(1)
        );
        // Closed and empty.
        assert_eq!(
            b.drain_into_timeout(4, Duration::from_secs(5), Duration::ZERO, &mut out),
            DrainPoll::Closed
        );
    }

    #[test]
    fn steal_takes_oldest_half_and_leaves_last() {
        let b = SharedBuffer::new();
        let mut out = Vec::new();
        // Empty and singleton queues are never stolen from.
        assert_eq!(b.steal_into(4, &mut out), 0);
        b.push(sub(0, 0));
        assert_eq!(b.steal_into(4, &mut out), 0);
        assert_eq!(b.len(), 1);
        // 5 queued: steal is bounded to floor(5/2) = 2, oldest first.
        for w in 1..5 {
            b.push(sub(w, 0));
        }
        assert_eq!(b.steal_into(4, &mut out), 2);
        let stolen: Vec<usize> = out.iter().map(|s| s.worker).collect();
        assert_eq!(stolen, vec![0, 1]);
        // Victim retains the remainder in FIFO order.
        let rest = b.drain(8, Duration::ZERO).unwrap();
        let kept: Vec<usize> = rest.iter().map(|s| s.worker).collect();
        assert_eq!(kept, vec![2, 3, 4]);
    }

    #[test]
    fn sharded_steals_from_hottest_lane_only() {
        let s = ShardedBuffer::new(3);
        let mut out = Vec::new();
        // All lanes empty: nothing to steal.
        assert_eq!(s.steal_from_hottest(0, 4, &mut out), 0);
        // Lane 1 (workers 1, 4): 2 entries; lane 2 (workers 2, 5): 4.
        for w in [1usize, 4] {
            s.push(sub(w, 0));
        }
        for w in [2usize, 5, 2, 5] {
            s.push(sub(w, 0));
        }
        let got = s.steal_from_hottest(0, 8, &mut out);
        assert_eq!(got, 2, "half of the hottest (lane 2) queue");
        assert!(out.iter().all(|x| x.worker % 3 == 2));
        // The victim keeps the rest; the cooler lane was untouched.
        assert_eq!(s.lane(2).len(), 2);
        assert_eq!(s.lane(1).len(), 2);
        // The thief never steals from itself: with lane 2 as thief, the
        // hottest sibling is now lane 1.
        out.clear();
        assert_eq!(s.steal_from_hottest(2, 8, &mut out), 1);
        assert!(out.iter().all(|x| x.worker % 3 == 1));
    }

    #[test]
    fn sharded_close_all_unblocks_every_lane() {
        let s = ShardedBuffer::new(3);
        let barrier = Arc::new(Barrier::new(4));
        let handles: Vec<_> = (0..3)
            .map(|l| {
                let (s2, barrier2) = (s.clone(), barrier.clone());
                std::thread::spawn(move || {
                    barrier2.wait();
                    s2.lane(l).drain(4, Duration::ZERO)
                })
            })
            .collect();
        barrier.wait();
        s.close_all();
        for h in handles {
            assert!(h.join().unwrap().is_none());
        }
    }
}
