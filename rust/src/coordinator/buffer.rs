//! The shared submission buffer between worker threads and the host proxy
//! (paper Fig. 8): workers write intercepted "OpenCL API calls" (task
//! submissions); the proxy polls, drains a task group, reorders and
//! submits it to the device queues.
//!
//! [`ShardedBuffer`] splits the single buffer into independent per-lane
//! buffers (worker `w` always lands on lane `w % L`, so per-worker
//! submission order is preserved by construction); each lane is drained
//! in batches by its own proxy — see `coordinator::lanes`.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::queue::event::Event;
use crate::task::TaskSpec;

/// One intercepted task submission.
#[derive(Clone, Debug)]
pub struct Submission {
    pub worker: usize,
    /// Position within the worker's dependent batch (0..N).
    pub batch_seq: usize,
    pub task: TaskSpec,
    /// Completed (with the device timestamp) when the task finishes; the
    /// worker blocks on this before submitting its next batch entry.
    pub done: Event,
    /// Wall-clock submission time (secs since coordinator epoch).
    pub submitted_at: f64,
}

#[derive(Default)]
struct State {
    queue: VecDeque<Submission>,
    closed: bool,
}

/// MPSC buffer with blocking drain.
#[derive(Clone, Default)]
pub struct SharedBuffer {
    inner: Arc<(Mutex<State>, Condvar)>,
}

impl SharedBuffer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&self, s: Submission) {
        let (m, cv) = &*self.inner;
        let mut g = m.lock().unwrap();
        assert!(!g.closed, "push after close");
        g.queue.push_back(s);
        cv.notify_all();
    }

    /// Declare no further submissions will arrive.
    pub fn close(&self) {
        let (m, cv) = &*self.inner;
        m.lock().unwrap().closed = true;
        cv.notify_all();
    }

    /// Blocking drain: waits until at least one submission is available
    /// (returning up to `max`) or the buffer is closed and empty (None).
    /// `settle` emulates the proxy's polling window: once something is
    /// available, wait this long for stragglers before draining — this is
    /// what lets all T workers land in the same task group.
    pub fn drain(&self, max: usize, settle: Duration) -> Option<Vec<Submission>> {
        let mut out = Vec::new();
        self.drain_into(max, settle, &mut out).map(|_| out)
    }

    /// [`SharedBuffer::drain`] into a caller-owned Vec — the batched-drain
    /// hot path of the lane proxies: `out` is cleared and refilled, so a
    /// warm proxy loop performs no allocation per drained group. Returns
    /// the number of submissions drained, or `None` once the buffer is
    /// closed and empty.
    pub fn drain_into(
        &self,
        max: usize,
        settle: Duration,
        out: &mut Vec<Submission>,
    ) -> Option<usize> {
        out.clear();
        let (m, cv) = &*self.inner;
        let mut g = m.lock().unwrap();
        loop {
            if !g.queue.is_empty() {
                break;
            }
            if g.closed {
                return None;
            }
            g = cv.wait(g).unwrap();
        }
        if !settle.is_zero() {
            // Give other workers a window to join this TG. A full batch or
            // a closed buffer ends the window early — no need to sleep out
            // the clock once no straggler can arrive.
            let deadline = std::time::Instant::now() + settle;
            while g.queue.len() < max && !g.closed {
                let left = match deadline.checked_duration_since(std::time::Instant::now()) {
                    Some(d) => d,
                    None => break,
                };
                let (ng, timeout) = cv.wait_timeout(g, left).unwrap();
                g = ng;
                if timeout.timed_out() {
                    break;
                }
            }
        }
        let take = g.queue.len().min(max);
        out.extend(g.queue.drain(..take));
        Some(take)
    }

    pub fn len(&self) -> usize {
        self.inner.0.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Per-lane submission buffers (see module docs): lane `w % L` serves
/// worker `w`, so one worker's dependent batch always drains in order
/// through one lane while independent workers' groups form concurrently
/// on other lanes.
#[derive(Clone)]
pub struct ShardedBuffer {
    lanes: Arc<[SharedBuffer]>,
}

impl ShardedBuffer {
    pub fn new(lanes: usize) -> Self {
        let lanes: Vec<SharedBuffer> =
            (0..lanes.max(1)).map(|_| SharedBuffer::new()).collect();
        ShardedBuffer { lanes: lanes.into() }
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    pub fn lane(&self, l: usize) -> &SharedBuffer {
        &self.lanes[l]
    }

    /// The lane that serves worker `w`.
    pub fn lane_for_worker(&self, w: usize) -> &SharedBuffer {
        &self.lanes[w % self.lanes.len()]
    }

    /// Route one submission to its worker's lane.
    pub fn push(&self, s: Submission) {
        self.lane_for_worker(s.worker).push(s);
    }

    /// Close every lane (no further submissions anywhere).
    pub fn close_all(&self) {
        for lane in self.lanes.iter() {
            lane.close();
        }
    }

    /// Total queued submissions across lanes.
    pub fn len(&self) -> usize {
        self.lanes.iter().map(|l| l.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::task::KernelSpec;
    use std::sync::Barrier;

    fn sub(worker: usize, seq: usize) -> Submission {
        Submission {
            worker,
            batch_seq: seq,
            task: TaskSpec::simple(
                "t",
                10,
                KernelSpec::Timed { secs: 1e-4 },
                10,
            ),
            done: Event::new(),
            submitted_at: 0.0,
        }
    }

    #[test]
    fn push_drain_fifo() {
        let b = SharedBuffer::new();
        b.push(sub(0, 0));
        b.push(sub(1, 0));
        b.push(sub(2, 0));
        let got = b.drain(2, Duration::ZERO).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].worker, 0);
        assert_eq!(b.len(), 1);
    }

    // The concurrency tests rendezvous on a Barrier instead of sleeping:
    // whichever side wins the race after the barrier, the asserted
    // outcome is the same, so they cannot flake under load (the old
    // 3-5 ms `thread::sleep` versions could).

    #[test]
    fn drain_blocks_until_push() {
        let b = SharedBuffer::new();
        let barrier = Arc::new(Barrier::new(2));
        let (b2, barrier2) = (b.clone(), barrier.clone());
        // Whether drain enters its wait before or after the push lands,
        // it must return exactly the pushed submission.
        let h = std::thread::spawn(move || {
            barrier2.wait();
            b2.drain(4, Duration::ZERO)
        });
        barrier.wait();
        b.push(sub(3, 1));
        let got = h.join().unwrap().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].worker, 3);
    }

    #[test]
    fn close_unblocks_with_none() {
        let b = SharedBuffer::new();
        let barrier = Arc::new(Barrier::new(2));
        let (b2, barrier2) = (b.clone(), barrier.clone());
        // Close-before-drain and drain-before-close both end in None.
        let h = std::thread::spawn(move || {
            barrier2.wait();
            b2.drain(4, Duration::ZERO)
        });
        barrier.wait();
        b.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn settle_window_gathers_stragglers() {
        // The straggler pushes after the rendezvous; `max = 2` ends the
        // settle window the moment it lands, so the generous window is an
        // upper bound that is never slept out, not a tuned delay.
        let b = SharedBuffer::new();
        b.push(sub(0, 0));
        let barrier = Arc::new(Barrier::new(2));
        let (b2, barrier2) = (b.clone(), barrier.clone());
        let h = std::thread::spawn(move || {
            barrier2.wait();
            b2.push(sub(1, 0));
        });
        barrier.wait();
        let got = b.drain(2, Duration::from_secs(30)).unwrap();
        h.join().unwrap();
        assert_eq!(got.len(), 2, "straggler should join the TG");
    }

    #[test]
    fn settle_window_ends_at_close() {
        // Once every lane worker has exited, close() must end the settle
        // wait immediately (no straggler can arrive), with the queued
        // submissions still delivered.
        let b = SharedBuffer::new();
        b.push(sub(0, 0));
        let barrier = Arc::new(Barrier::new(2));
        let (b2, barrier2) = (b.clone(), barrier.clone());
        let h = std::thread::spawn(move || {
            barrier2.wait();
            b2.close();
        });
        barrier.wait();
        let got = b.drain(4, Duration::from_secs(30)).unwrap();
        h.join().unwrap();
        assert_eq!(got.len(), 1);
        assert!(b.drain(4, Duration::ZERO).is_none());
    }

    #[test]
    fn sharded_routes_by_worker_and_preserves_lane_fifo() {
        let s = ShardedBuffer::new(2);
        for seq in 0..3 {
            for w in 0..4 {
                s.push(sub(w, seq));
            }
        }
        assert_eq!(s.len(), 12);
        // Lane 0 serves workers 0 and 2, in push order.
        let lane0 = s.lane(0).drain(16, Duration::ZERO).unwrap();
        let got: Vec<(usize, usize)> =
            lane0.iter().map(|x| (x.worker, x.batch_seq)).collect();
        assert_eq!(
            got,
            vec![(0, 0), (2, 0), (0, 1), (2, 1), (0, 2), (2, 2)]
        );
        // Per-worker batch_seq is monotonic within the lane.
        let lane1 = s.lane(1).drain(16, Duration::ZERO).unwrap();
        for w in [1usize, 3] {
            let seqs: Vec<usize> = lane1
                .iter()
                .filter(|x| x.worker == w)
                .map(|x| x.batch_seq)
                .collect();
            assert_eq!(seqs, vec![0, 1, 2]);
        }
        assert!(s.is_empty());
    }

    #[test]
    fn sharded_close_all_unblocks_every_lane() {
        let s = ShardedBuffer::new(3);
        let barrier = Arc::new(Barrier::new(4));
        let handles: Vec<_> = (0..3)
            .map(|l| {
                let (s2, barrier2) = (s.clone(), barrier.clone());
                std::thread::spawn(move || {
                    barrier2.wait();
                    s2.lane(l).drain(4, Duration::ZERO)
                })
            })
            .collect();
        barrier.wait();
        s.close_all();
        for h in handles {
            assert!(h.join().unwrap().is_none());
        }
    }
}
