//! The shared submission buffer between worker threads and the host proxy
//! (paper Fig. 8): workers write intercepted "OpenCL API calls" (task
//! submissions); the proxy polls, drains a task group, reorders and
//! submits it to the device queues.
//!
//! [`ShardedBuffer`] splits the single buffer into independent per-lane
//! buffers (worker `w` always lands on lane `w % L`, so per-worker
//! submission order is preserved by construction); each lane is drained
//! in batches by its own proxy — see `coordinator::lanes`.
//!
//! The online lanes additionally use [`SharedBuffer::drain_into_timeout`]
//! (bounded-wait drains that never park a proxy which must also poll its
//! device runner) and [`ShardedBuffer::steal_from_hottest`] (bounded
//! work-stealing of uncommitted submissions: oldest first, at most half
//! of the hottest sibling's backlog, never its last entry; per-worker
//! FIFO holds because a worker never has two submissions outstanding).
//!
//! Fault tolerance (see `coordinator::recovery`) adds three primitives:
//! [`SharedBuffer::requeue_front`] (a quarantined lane hands unstarted
//! work back to the *front* of its own buffer, preserving FIFO),
//! [`SharedBuffer::take_into`] (unbounded front-drain of a quarantined
//! sibling's backlog — the owner cannot make progress, so the
//! half-and-never-last steal bounds are deliberately lifted) and
//! [`ShardedBuffer::steal_with_health`] (prefer quarantined victims).
//! A *poisoned* buffer lock (a worker or proxy panicked mid-operation)
//! maps to the `Closed` drain outcome instead of cascading the panic
//! across every thread parked on the condvar; non-draining operations
//! recover the guard, since the queue itself is never left mid-mutation.
//!
//! Multi-tenancy (see `coordinator::admission`): a buffer built with
//! [`SharedBuffer::with_admission`] carries the shared [`AdmissionCtl`]
//! ledger and an [`AdmissionPolicy`] instance that orders its drains
//! (weighted-fair / strict-priority / EDF instead of raw FIFO). The
//! reservation a submission holds against its tenant's cap follows the
//! submission itself, not the queue it sits in: drains for *execution*
//! release it (`release_on_drain`), while `steal_*`/`take_into` moving
//! work between lanes and the fleet's ingress→lane transfer keep it —
//! so steals never violate tenant caps — and [`SharedBuffer::requeue_front`]
//! re-reserves unconditionally (accepted work is never lost to a cap).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

use crate::coordinator::admission::{
    AdmissionCtl, AdmissionPolicy, Priority, ShedSlot, TenantId,
};
use crate::coordinator::recovery::FleetHealth;
use crate::queue::event::Event;
use crate::task::TaskSpec;

/// One intercepted task submission.
#[derive(Clone, Debug)]
pub struct Submission {
    pub worker: usize,
    /// Position within the worker's dependent batch (0..N).
    pub batch_seq: usize,
    pub task: TaskSpec,
    /// Completed (with the device timestamp) when the task finishes; the
    /// worker blocks on this before submitting its next batch entry.
    pub done: Event,
    /// Wall-clock submission time (secs since coordinator epoch).
    pub submitted_at: f64,
    /// Submitting tenant (defaults to one tenant per worker).
    pub tenant: TenantId,
    /// QoS class consulted by the priority-aware drain policies and the
    /// `ShedLowest` eviction scan.
    pub class: Priority,
    /// Absolute deadline (secs since coordinator epoch) for
    /// deadline-EDF draining; `None` sorts after every deadline.
    pub deadline: Option<f64>,
    /// Stamped with the typed receipt if this submission is shed instead
    /// of executed; its `done` event still fires (eviction time).
    pub shed: ShedSlot,
}

#[derive(Default)]
struct State {
    queue: VecDeque<Submission>,
    closed: bool,
    /// Drain-ordering policy for admitted work; `None` = raw FIFO,
    /// bit-identical to the pre-admission pipeline.
    policy: Option<Box<dyn AdmissionPolicy>>,
}

impl State {
    /// Remove up to `take` submissions in policy order (FIFO when no
    /// policy is armed) and append them to `out`.
    fn take_ordered(&mut self, take: usize, out: &mut Vec<Submission>) {
        match self.policy.as_mut() {
            None => out.extend(self.queue.drain(..take)),
            Some(policy) => {
                for _ in 0..take {
                    let i = policy.pick(&self.queue).unwrap_or(0);
                    match self.queue.remove(i) {
                        Some(s) => out.push(s),
                        None => break,
                    }
                }
            }
        }
    }
}

/// Outcome of a bounded-wait drain ([`SharedBuffer::drain_into_timeout`]).
#[derive(Debug, PartialEq, Eq)]
pub enum DrainPoll {
    /// Drained this many submissions (>= 1).
    Drained(usize),
    /// Nothing arrived within the wait window; the buffer is still open.
    Empty,
    /// Closed and empty — no submission will ever arrive.
    Closed,
}

/// MPSC buffer with blocking drain.
#[derive(Clone, Default)]
pub struct SharedBuffer {
    inner: Arc<(Mutex<State>, Condvar)>,
    /// Shared reservation ledger when admission is armed.
    ctl: Option<Arc<AdmissionCtl>>,
    /// Whether draining this buffer hands work to *execution* (release
    /// the tenant reservation) or merely transfers it to another
    /// admission-tracked queue (the fleet's ingress — keep it).
    release_on_drain: bool,
}

impl SharedBuffer {
    pub fn new() -> Self {
        Self::default()
    }

    /// An admission-armed buffer: drains are ordered by the controller's
    /// [`AdmissionPolicy`] (an independent instance per buffer — DRR ring
    /// state is per-queue) and, when `release_on_drain`, every
    /// submission leaving through `drain_*`/`steal_*`/`take_into`
    /// releases its tenant reservation back to the ledger.
    pub fn with_admission(
        ctl: Arc<AdmissionCtl>,
        release_on_drain: bool,
    ) -> Self {
        let state = State {
            policy: Some(ctl.opts().policy.build(&ctl.opts().weights)),
            ..State::default()
        };
        SharedBuffer {
            inner: Arc::new((Mutex::new(state), Condvar::new())),
            ctl: Some(ctl),
            release_on_drain,
        }
    }

    /// Release drained submissions' reservations (no-op on untracked or
    /// transfer buffers). Called with the state lock already dropped.
    fn note_drained(&self, subs: &[Submission]) {
        if self.release_on_drain {
            if let Some(ctl) = &self.ctl {
                ctl.release_subs(subs);
            }
        }
    }

    // Recovering lock for non-draining operations: every critical
    // section below leaves `State` consistent even if the *holder*
    // panics for unrelated reasons, so poisoning carries no information
    // here — cascading it would turn one dead worker into a fleet-wide
    // abort (exactly what the recovery layer exists to prevent).
    fn lock_state(&self) -> MutexGuard<'_, State> {
        self.inner.0.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn push(&self, s: Submission) {
        let (_, cv) = &*self.inner;
        let mut g = self.lock_state();
        assert!(!g.closed, "push after close");
        g.queue.push_back(s);
        cv.notify_all();
    }

    /// Declare no further submissions will arrive.
    pub fn close(&self) {
        let (_, cv) = &*self.inner;
        self.lock_state().closed = true;
        cv.notify_all();
    }

    /// Blocking drain: waits until at least one submission is available
    /// (returning up to `max`) or the buffer is closed and empty (None).
    /// `settle` emulates the proxy's polling window: once something is
    /// available, wait this long for stragglers before draining — this is
    /// what lets all T workers land in the same task group.
    pub fn drain(&self, max: usize, settle: Duration) -> Option<Vec<Submission>> {
        let mut out = Vec::new();
        self.drain_into(max, settle, &mut out).map(|_| out)
    }

    /// [`SharedBuffer::drain`] into a caller-owned Vec — the batched-drain
    /// hot path of the lane proxies: `out` is cleared and refilled, so a
    /// warm proxy loop performs no allocation per drained group. Returns
    /// the number of submissions drained, or `None` once the buffer is
    /// closed and empty. A poisoned lock (a peer panicked mid-operation)
    /// also reports `None` — the draining proxy winds down instead of
    /// re-raising a panic it did not cause.
    pub fn drain_into(
        &self,
        max: usize,
        settle: Duration,
        out: &mut Vec<Submission>,
    ) -> Option<usize> {
        out.clear();
        let (m, cv) = &*self.inner;
        let Ok(mut g) = m.lock() else { return None };
        loop {
            if !g.queue.is_empty() {
                break;
            }
            if g.closed {
                return None;
            }
            let Ok(ng) = cv.wait(g) else { return None };
            g = ng;
        }
        if !settle.is_zero() {
            // Give other workers a window to join this TG. A full batch or
            // a closed buffer ends the window early — no need to sleep out
            // the clock once no straggler can arrive.
            let deadline = std::time::Instant::now() + settle;
            while g.queue.len() < max && !g.closed {
                let left = match deadline.checked_duration_since(std::time::Instant::now()) {
                    Some(d) => d,
                    None => break,
                };
                let Ok((ng, timeout)) = cv.wait_timeout(g, left) else {
                    return None;
                };
                g = ng;
                if timeout.timed_out() {
                    break;
                }
            }
        }
        let take = g.queue.len().min(max);
        g.take_ordered(take, out);
        drop(g);
        self.note_drained(out);
        Some(take)
    }

    /// [`SharedBuffer::drain_into`] with a *bounded* initial wait: blocks
    /// at most `wait` for the first submission (then applies the same
    /// `settle` straggler window), and reports an open-but-empty buffer
    /// as [`DrainPoll::Empty`] instead of blocking forever. The online
    /// lane proxy alternates this with device-completion polling and
    /// steal probes, none of which may park the proxy indefinitely.
    /// `wait == Duration::ZERO` is a pure non-blocking poll. A poisoned
    /// lock maps to [`DrainPoll::Closed`] — see [`SharedBuffer::drain_into`].
    pub fn drain_into_timeout(
        &self,
        max: usize,
        wait: Duration,
        settle: Duration,
        out: &mut Vec<Submission>,
    ) -> DrainPoll {
        out.clear();
        let (m, cv) = &*self.inner;
        let Ok(mut g) = m.lock() else { return DrainPoll::Closed };
        if g.queue.is_empty() {
            let deadline = std::time::Instant::now() + wait;
            loop {
                if !g.queue.is_empty() {
                    break;
                }
                if g.closed {
                    return DrainPoll::Closed;
                }
                let left = match deadline
                    .checked_duration_since(std::time::Instant::now())
                {
                    Some(d) if !d.is_zero() => d,
                    _ => return DrainPoll::Empty,
                };
                let Ok((ng, _)) = cv.wait_timeout(g, left) else {
                    return DrainPoll::Closed;
                };
                g = ng;
            }
        }
        if !settle.is_zero() {
            let deadline = std::time::Instant::now() + settle;
            while g.queue.len() < max && !g.closed {
                let left = match deadline
                    .checked_duration_since(std::time::Instant::now())
                {
                    Some(d) => d,
                    None => break,
                };
                let Ok((ng, timeout)) = cv.wait_timeout(g, left) else {
                    return DrainPoll::Closed;
                };
                g = ng;
                if timeout.timed_out() {
                    break;
                }
            }
        }
        let take = g.queue.len().min(max);
        g.take_ordered(take, out);
        drop(g);
        self.note_drained(out);
        DrainPoll::Drained(take)
    }

    /// Steal up to `max` submissions from the *front* of the queue
    /// (oldest first), bounded to half of what is queued so the owning
    /// lane always keeps at least as much as it loses — the "bounded
    /// work-stealing" contract. Appends to `out` (no clear) and returns
    /// the count. Never blocks; an empty or single-entry queue yields 0.
    pub fn steal_into(&self, max: usize, out: &mut Vec<Submission>) -> usize {
        let (m, _cv) = &*self.inner;
        let Ok(mut g) = m.lock() else { return 0 };
        let take = max.min(g.queue.len() / 2);
        let start = out.len();
        out.extend(g.queue.drain(..take));
        drop(g);
        // The thief executes the loot immediately, so this is a drain
        // for execution: the tenants' reservations are released. Totals
        // never grow on a steal, so caps cannot be violated by one.
        self.note_drained(&out[start..]);
        take
    }

    /// Unbounded front-drain: take up to `max` submissions oldest-first
    /// with *none* of [`SharedBuffer::steal_into`]'s half/last-entry
    /// bounds. Only correct against a lane that cannot make progress
    /// (quarantined — see [`ShardedBuffer::steal_with_health`]): leaving
    /// work "for the owner" there strands it. Appends to `out`; never
    /// blocks; a poisoned lock yields 0.
    pub fn take_into(&self, max: usize, out: &mut Vec<Submission>) -> usize {
        let (m, _cv) = &*self.inner;
        let Ok(mut g) = m.lock() else { return 0 };
        let take = max.min(g.queue.len());
        let start = out.len();
        out.extend(g.queue.drain(..take));
        drop(g);
        self.note_drained(&out[start..]);
        take
    }

    /// Hand unstarted submissions back to the *front* of the queue in
    /// their original order (element 0 of `subs` drains first again), so
    /// a quarantined lane's undispatched work keeps its FIFO position
    /// ahead of anything queued behind it. Permitted on a closed buffer:
    /// close only promises no *new* worker submissions, and requeued
    /// work is not new. Drains `subs` and returns the count.
    pub fn requeue_front(&self, subs: &mut Vec<Submission>) -> usize {
        // Requeued work was already admitted once: re-reserve its slots
        // unconditionally (never against the caps) so accepted tasks are
        // never lost to a momentarily full backlog, keeping the ledger
        // consistent with the release their earlier drain performed.
        if self.release_on_drain {
            if let Some(ctl) = &self.ctl {
                ctl.reserve_requeued(subs);
            }
        }
        let (_, cv) = &*self.inner;
        let mut g = self.lock_state();
        let n = subs.len();
        for s in subs.drain(..).rev() {
            g.queue.push_front(s);
        }
        if n > 0 {
            cv.notify_all();
        }
        n
    }

    /// Worst (highest-rank) priority class queued strictly below
    /// `below`, optionally restricted to one tenant — the `ShedLowest`
    /// victim scan's first pass. `None` when no evictable entry exists
    /// (or the lock is poisoned — a dying run sheds nothing).
    pub(crate) fn peek_lowest_below(
        &self,
        below: Priority,
        tenant: Option<TenantId>,
    ) -> Option<Priority> {
        let (m, _cv) = &*self.inner;
        let Ok(g) = m.lock() else { return None };
        g.queue
            .iter()
            .filter(|s| s.class.rank() > below.rank())
            .filter(|s| tenant.map_or(true, |t| s.tenant == t))
            .map(|s| s.class)
            .max_by_key(|c| c.rank())
    }

    /// Remove and return the most-recently-enqueued submission of the
    /// worst priority class strictly below `below` (optionally one
    /// tenant's): the `ShedLowest` eviction. Newest-first among equals
    /// keeps the oldest queued work — closest to running — intact. The
    /// caller (the admission gate) owns the receipt + release + event
    /// completion; this only removes under the queue lock, which is what
    /// makes eviction and draining mutually exclusive per submission.
    pub(crate) fn evict_lowest(
        &self,
        below: Priority,
        tenant: Option<TenantId>,
    ) -> Option<Submission> {
        let (m, _cv) = &*self.inner;
        let Ok(mut g) = m.lock() else { return None };
        let mut best: Option<(usize, u8)> = None;
        for (i, s) in g.queue.iter().enumerate() {
            if s.class.rank() <= below.rank() {
                continue;
            }
            if let Some(t) = tenant {
                if s.tenant != t {
                    continue;
                }
            }
            let r = s.class.rank();
            match best {
                Some((_, br)) if r < br => {}
                _ => best = Some((i, r)),
            }
        }
        let (i, _) = best?;
        g.queue.remove(i)
    }

    /// Whether no submission will ever be drained from this buffer again
    /// — the exit condition a quarantined (non-draining) proxy polls.
    pub fn is_closed_and_empty(&self) -> bool {
        let g = self.lock_state();
        g.closed && g.queue.is_empty()
    }

    pub fn len(&self) -> usize {
        self.lock_state().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Per-lane submission buffers (see module docs): lane `w % L` serves
/// worker `w`, so one worker's dependent batch always drains in order
/// through one lane while independent workers' groups form concurrently
/// on other lanes.
#[derive(Clone)]
pub struct ShardedBuffer {
    lanes: Arc<[SharedBuffer]>,
}

/// Provenance of a successful steal
/// ([`ShardedBuffer::steal_with_health_traced`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct StealTrace {
    /// Lane the submissions were taken from.
    pub(crate) victim: usize,
    /// Whether the victim was quarantined (backlog shed via
    /// [`SharedBuffer::take_into`], bounds lifted) rather than a healthy
    /// hottest-lane steal.
    pub(crate) quarantined: bool,
    /// Number of submissions moved into `out`.
    pub(crate) n: usize,
}

impl ShardedBuffer {
    pub fn new(lanes: usize) -> Self {
        let lanes: Vec<SharedBuffer> =
            (0..lanes.max(1)).map(|_| SharedBuffer::new()).collect();
        ShardedBuffer { lanes: lanes.into() }
    }

    /// Admission-armed sharding: every lane shares `ctl` (one ledger,
    /// per-lane policy instances) and releases tenant reservations on
    /// drain — lane drains feed execution.
    pub fn with_admission(lanes: usize, ctl: Arc<AdmissionCtl>) -> Self {
        let lanes: Vec<SharedBuffer> = (0..lanes.max(1))
            .map(|_| SharedBuffer::with_admission(ctl.clone(), true))
            .collect();
        ShardedBuffer { lanes: lanes.into() }
    }

    /// Clones of every lane buffer — the admission gate's `ShedLowest`
    /// eviction scan domain.
    pub(crate) fn lanes_vec(&self) -> Vec<SharedBuffer> {
        self.lanes.to_vec()
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    pub fn lane(&self, l: usize) -> &SharedBuffer {
        &self.lanes[l]
    }

    /// The lane that serves worker `w`.
    pub fn lane_for_worker(&self, w: usize) -> &SharedBuffer {
        &self.lanes[w % self.lanes.len()]
    }

    /// Route one submission to its worker's lane.
    pub fn push(&self, s: Submission) {
        self.lane_for_worker(s.worker).push(s);
    }

    /// Route one submission to an explicit lane, ignoring the `w % L`
    /// worker mapping — the fleet coordinator places each submission on
    /// the device its calibrated model predicts finishes it earliest,
    /// so lane choice is a *scheduling* decision there, not a hash.
    /// Per-worker FIFO still holds for the usual reason: a worker never
    /// has two submissions outstanding.
    pub fn push_to_lane(&self, l: usize, s: Submission) {
        self.lanes[l].push(s);
    }

    /// Close every lane (no further submissions anywhere).
    pub fn close_all(&self) {
        for lane in self.lanes.iter() {
            lane.close();
        }
    }

    /// Bounded work-stealing: an idle lane `thief` takes up to `max`
    /// submissions from the *hottest* sibling lane's buffer (the longest
    /// queue, ties to the lowest lane index), oldest first and capped at
    /// half the victim's backlog ([`SharedBuffer::steal_into`]). Only
    /// queues holding at least two submissions are victims, so a lane is
    /// never stripped of its last buffered task. Per-worker submission
    /// order is preserved unconditionally: a worker never has more than
    /// one submission outstanding (it blocks on the completion event
    /// before submitting the next), so no reordering between a worker's
    /// own tasks is possible wherever they execute. Appends to `out` and
    /// returns the stolen count.
    pub fn steal_from_hottest(
        &self,
        thief: usize,
        max: usize,
        out: &mut Vec<Submission>,
    ) -> usize {
        if max == 0 || self.lanes.len() < 2 {
            return 0;
        }
        let mut victim = None;
        let mut hottest = 1usize; // require >= 2 queued to steal at all
        for (l, lane) in self.lanes.iter().enumerate() {
            if l == thief {
                continue;
            }
            let len = lane.len();
            if len > hottest {
                hottest = len;
                victim = Some(l);
            }
        }
        match victim {
            Some(v) => self.lanes[v].steal_into(max, out),
            None => 0,
        }
    }

    /// Health-aware stealing: prefer a *quarantined* sibling (breaker
    /// Open — see `coordinator::recovery`), taking from the one with the
    /// longest backlog with the steal bounds lifted
    /// ([`SharedBuffer::take_into`]): its owner cannot run anything, so
    /// the half/never-last courtesy of the classic steal would strand
    /// work. With no quarantined sibling this is exactly
    /// [`ShardedBuffer::steal_from_hottest`]. Per-worker FIFO is
    /// preserved for the same reason as every steal: a worker never has
    /// two submissions outstanding.
    pub fn steal_with_health(
        &self,
        thief: usize,
        max: usize,
        health: &FleetHealth,
        out: &mut Vec<Submission>,
    ) -> usize {
        self.steal_with_health_traced(thief, max, health, out)
            .map_or(0, |t| t.n)
    }

    /// [`ShardedBuffer::steal_with_health`] with provenance: returns who
    /// was robbed and whether they were quarantined, or `None` when
    /// nothing moved. The fleet coordinator needs the victim's identity
    /// to price the steal (its calibrated win predicate compares against
    /// the *victim's* predicted remaining horizon) and to hand rejected
    /// loot back to the right lane via [`SharedBuffer::requeue_front`].
    pub(crate) fn steal_with_health_traced(
        &self,
        thief: usize,
        max: usize,
        health: &FleetHealth,
        out: &mut Vec<Submission>,
    ) -> Option<StealTrace> {
        if max == 0 || self.lanes.len() < 2 {
            return None;
        }
        debug_assert_eq!(health.n_lanes(), self.lanes.len());
        let mut victim = None;
        let mut longest = 0usize; // any queued entry of a dead lane counts
        for (l, lane) in self.lanes.iter().enumerate() {
            if l == thief || !health.is_quarantined(l) {
                continue;
            }
            let len = lane.len();
            if len > longest {
                longest = len;
                victim = Some(l);
            }
        }
        if let Some(v) = victim {
            // Matches `steal_with_health`: a quarantined victim is
            // terminal — no fall-through to a healthy steal even when
            // the take races to zero.
            let n = self.lanes[v].take_into(max, out);
            return (n > 0).then_some(StealTrace { victim: v, quarantined: true, n });
        }
        let mut victim = None;
        let mut hottest = 1usize; // require >= 2 queued to steal at all
        for (l, lane) in self.lanes.iter().enumerate() {
            if l == thief {
                continue;
            }
            let len = lane.len();
            if len > hottest {
                hottest = len;
                victim = Some(l);
            }
        }
        let v = victim?;
        let n = self.lanes[v].steal_into(max, out);
        (n > 0).then_some(StealTrace { victim: v, quarantined: false, n })
    }

    /// Total queued submissions across lanes.
    pub fn len(&self) -> usize {
        self.lanes.iter().map(|l| l.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::task::KernelSpec;
    use std::sync::Barrier;

    fn sub(worker: usize, seq: usize) -> Submission {
        Submission {
            worker,
            batch_seq: seq,
            task: TaskSpec::simple(
                "t",
                10,
                KernelSpec::Timed { secs: 1e-4 },
                10,
            ),
            done: Event::new(),
            submitted_at: 0.0,
            tenant: TenantId(worker as u32),
            class: Priority::Normal,
            deadline: None,
            shed: ShedSlot::new(),
        }
    }

    #[test]
    fn push_drain_fifo() {
        let b = SharedBuffer::new();
        b.push(sub(0, 0));
        b.push(sub(1, 0));
        b.push(sub(2, 0));
        let got = b.drain(2, Duration::ZERO).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].worker, 0);
        assert_eq!(b.len(), 1);
    }

    // The concurrency tests rendezvous on a Barrier instead of sleeping:
    // whichever side wins the race after the barrier, the asserted
    // outcome is the same, so they cannot flake under load (the old
    // 3-5 ms `thread::sleep` versions could).

    #[test]
    fn drain_blocks_until_push() {
        let b = SharedBuffer::new();
        let barrier = Arc::new(Barrier::new(2));
        let (b2, barrier2) = (b.clone(), barrier.clone());
        // Whether drain enters its wait before or after the push lands,
        // it must return exactly the pushed submission.
        let h = std::thread::spawn(move || {
            barrier2.wait();
            b2.drain(4, Duration::ZERO)
        });
        barrier.wait();
        b.push(sub(3, 1));
        let got = h.join().unwrap().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].worker, 3);
    }

    #[test]
    fn close_unblocks_with_none() {
        let b = SharedBuffer::new();
        let barrier = Arc::new(Barrier::new(2));
        let (b2, barrier2) = (b.clone(), barrier.clone());
        // Close-before-drain and drain-before-close both end in None.
        let h = std::thread::spawn(move || {
            barrier2.wait();
            b2.drain(4, Duration::ZERO)
        });
        barrier.wait();
        b.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn settle_window_gathers_stragglers() {
        // The straggler pushes after the rendezvous; `max = 2` ends the
        // settle window the moment it lands, so the generous window is an
        // upper bound that is never slept out, not a tuned delay.
        let b = SharedBuffer::new();
        b.push(sub(0, 0));
        let barrier = Arc::new(Barrier::new(2));
        let (b2, barrier2) = (b.clone(), barrier.clone());
        let h = std::thread::spawn(move || {
            barrier2.wait();
            b2.push(sub(1, 0));
        });
        barrier.wait();
        let got = b.drain(2, Duration::from_secs(30)).unwrap();
        h.join().unwrap();
        assert_eq!(got.len(), 2, "straggler should join the TG");
    }

    #[test]
    fn settle_window_ends_at_close() {
        // Once every lane worker has exited, close() must end the settle
        // wait immediately (no straggler can arrive), with the queued
        // submissions still delivered.
        let b = SharedBuffer::new();
        b.push(sub(0, 0));
        let barrier = Arc::new(Barrier::new(2));
        let (b2, barrier2) = (b.clone(), barrier.clone());
        let h = std::thread::spawn(move || {
            barrier2.wait();
            b2.close();
        });
        barrier.wait();
        let got = b.drain(4, Duration::from_secs(30)).unwrap();
        h.join().unwrap();
        assert_eq!(got.len(), 1);
        assert!(b.drain(4, Duration::ZERO).is_none());
    }

    #[test]
    fn sharded_routes_by_worker_and_preserves_lane_fifo() {
        let s = ShardedBuffer::new(2);
        for seq in 0..3 {
            for w in 0..4 {
                s.push(sub(w, seq));
            }
        }
        assert_eq!(s.len(), 12);
        // Lane 0 serves workers 0 and 2, in push order.
        let lane0 = s.lane(0).drain(16, Duration::ZERO).unwrap();
        let got: Vec<(usize, usize)> =
            lane0.iter().map(|x| (x.worker, x.batch_seq)).collect();
        assert_eq!(
            got,
            vec![(0, 0), (2, 0), (0, 1), (2, 1), (0, 2), (2, 2)]
        );
        // Per-worker batch_seq is monotonic within the lane.
        let lane1 = s.lane(1).drain(16, Duration::ZERO).unwrap();
        for w in [1usize, 3] {
            let seqs: Vec<usize> = lane1
                .iter()
                .filter(|x| x.worker == w)
                .map(|x| x.batch_seq)
                .collect();
            assert_eq!(seqs, vec![0, 1, 2]);
        }
        assert!(s.is_empty());
    }

    #[test]
    fn timeout_drain_reports_empty_open_and_closed() {
        let b = SharedBuffer::new();
        let mut out = Vec::new();
        // Open and empty: bounded wait returns Empty (zero wait = poll).
        assert_eq!(
            b.drain_into_timeout(4, Duration::ZERO, Duration::ZERO, &mut out),
            DrainPoll::Empty
        );
        assert_eq!(
            b.drain_into_timeout(
                4,
                Duration::from_millis(1),
                Duration::ZERO,
                &mut out
            ),
            DrainPoll::Empty
        );
        // Queued items drain even after close.
        b.push(sub(0, 0));
        b.push(sub(1, 0));
        b.close();
        assert_eq!(
            b.drain_into_timeout(1, Duration::ZERO, Duration::ZERO, &mut out),
            DrainPoll::Drained(1)
        );
        assert_eq!(out.len(), 1);
        assert_eq!(
            b.drain_into_timeout(4, Duration::ZERO, Duration::ZERO, &mut out),
            DrainPoll::Drained(1)
        );
        // Closed and empty.
        assert_eq!(
            b.drain_into_timeout(4, Duration::from_secs(5), Duration::ZERO, &mut out),
            DrainPoll::Closed
        );
    }

    #[test]
    fn steal_takes_oldest_half_and_leaves_last() {
        let b = SharedBuffer::new();
        let mut out = Vec::new();
        // Empty and singleton queues are never stolen from.
        assert_eq!(b.steal_into(4, &mut out), 0);
        b.push(sub(0, 0));
        assert_eq!(b.steal_into(4, &mut out), 0);
        assert_eq!(b.len(), 1);
        // 5 queued: steal is bounded to floor(5/2) = 2, oldest first.
        for w in 1..5 {
            b.push(sub(w, 0));
        }
        assert_eq!(b.steal_into(4, &mut out), 2);
        let stolen: Vec<usize> = out.iter().map(|s| s.worker).collect();
        assert_eq!(stolen, vec![0, 1]);
        // Victim retains the remainder in FIFO order.
        let rest = b.drain(8, Duration::ZERO).unwrap();
        let kept: Vec<usize> = rest.iter().map(|s| s.worker).collect();
        assert_eq!(kept, vec![2, 3, 4]);
    }

    #[test]
    fn sharded_steals_from_hottest_lane_only() {
        let s = ShardedBuffer::new(3);
        let mut out = Vec::new();
        // All lanes empty: nothing to steal.
        assert_eq!(s.steal_from_hottest(0, 4, &mut out), 0);
        // Lane 1 (workers 1, 4): 2 entries; lane 2 (workers 2, 5): 4.
        for w in [1usize, 4] {
            s.push(sub(w, 0));
        }
        for w in [2usize, 5, 2, 5] {
            s.push(sub(w, 0));
        }
        let got = s.steal_from_hottest(0, 8, &mut out);
        assert_eq!(got, 2, "half of the hottest (lane 2) queue");
        assert!(out.iter().all(|x| x.worker % 3 == 2));
        // The victim keeps the rest; the cooler lane was untouched.
        assert_eq!(s.lane(2).len(), 2);
        assert_eq!(s.lane(1).len(), 2);
        // The thief never steals from itself: with lane 2 as thief, the
        // hottest sibling is now lane 1.
        out.clear();
        assert_eq!(s.steal_from_hottest(2, 8, &mut out), 1);
        assert!(out.iter().all(|x| x.worker % 3 == 1));
    }

    #[test]
    fn poisoned_lock_maps_to_closed_not_panic() {
        // Deliberately poison the state mutex: a thread panics while
        // holding it (the queue is consistent — the panic is unrelated).
        let b = SharedBuffer::new();
        b.push(sub(0, 0));
        let b2 = b.clone();
        let r = std::thread::spawn(move || {
            let _g = b2.inner.0.lock().unwrap();
            panic!("poison the buffer lock");
        })
        .join();
        assert!(r.is_err(), "the poisoning thread must have panicked");
        // Draining paths report end-of-stream instead of cascading.
        let mut out = Vec::new();
        assert!(b.drain_into(4, Duration::ZERO, &mut out).is_none());
        assert_eq!(
            b.drain_into_timeout(4, Duration::ZERO, Duration::ZERO, &mut out),
            DrainPoll::Closed
        );
        assert_eq!(b.steal_into(4, &mut out), 0);
        assert_eq!(b.take_into(4, &mut out), 0);
        assert!(out.is_empty());
        // Non-draining operations recover the guard and keep working.
        assert_eq!(b.len(), 1);
        b.push(sub(1, 0));
        assert_eq!(b.len(), 2);
        b.close();
        assert!(!b.is_closed_and_empty(), "still holds two submissions");
    }

    #[test]
    fn requeue_front_preserves_order_even_after_close() {
        let b = SharedBuffer::new();
        b.push(sub(10, 0));
        b.close();
        // A quarantined lane hands back its undispatched group [1, 2]:
        // it must drain ahead of the older backlog entry, in order.
        let mut back = vec![sub(1, 0), sub(2, 0)];
        assert_eq!(b.requeue_front(&mut back), 2);
        assert!(back.is_empty());
        assert!(!b.is_closed_and_empty());
        let got = b.drain(8, Duration::ZERO).unwrap();
        let order: Vec<usize> = got.iter().map(|s| s.worker).collect();
        assert_eq!(order, vec![1, 2, 10]);
        assert!(b.is_closed_and_empty());
    }

    #[test]
    fn take_into_lifts_steal_bounds() {
        let b = SharedBuffer::new();
        let mut out = Vec::new();
        b.push(sub(0, 0));
        // steal_into refuses a singleton; take_into does not.
        assert_eq!(b.steal_into(4, &mut out), 0);
        assert_eq!(b.take_into(4, &mut out), 1);
        assert!(b.is_empty());
        for w in 0..5 {
            b.push(sub(w, 0));
        }
        out.clear();
        // The whole backlog is takeable, oldest first.
        assert_eq!(b.take_into(8, &mut out), 5);
        let order: Vec<usize> = out.iter().map(|s| s.worker).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn steal_with_health_prefers_quarantined_backlog() {
        use crate::coordinator::recovery::FleetHealth;
        let s = ShardedBuffer::new(3);
        let health = FleetHealth::new(3);
        let mut out = Vec::new();
        // Lane 2 is hottest (4 entries) but healthy; lane 1 holds a
        // single entry and is quarantined.
        s.push(sub(1, 0));
        for w in [2usize, 5, 2, 5] {
            s.push(sub(w, 0));
        }
        health.lane(1).trip();
        // The quarantined singleton is taken in full (bounds lifted).
        assert_eq!(s.steal_with_health(0, 8, &health, &mut out), 1);
        assert_eq!(out[0].worker, 1);
        assert_eq!(s.lane(1).len(), 0);
        // No quarantined victim left: falls back to the classic steal
        // (half of the hottest sibling).
        out.clear();
        assert_eq!(s.steal_with_health(0, 8, &health, &mut out), 2);
        assert!(out.iter().all(|x| x.worker % 3 == 2));
    }

    #[test]
    fn push_to_lane_bypasses_worker_hash() {
        let s = ShardedBuffer::new(3);
        // Worker 5 would hash to lane 2; the fleet coordinator routes it
        // to lane 0 explicitly.
        s.push_to_lane(0, sub(5, 0));
        assert_eq!(s.lane(2).len(), 0);
        let got = s.lane(0).drain(4, Duration::ZERO).unwrap();
        assert_eq!(got[0].worker, 5);
    }

    #[test]
    fn traced_steal_reports_victim_and_quarantine() {
        use crate::coordinator::recovery::FleetHealth;
        let s = ShardedBuffer::new(3);
        let health = FleetHealth::new(3);
        let mut out = Vec::new();
        // Nothing queued anywhere: no trace.
        assert_eq!(s.steal_with_health_traced(0, 8, &health, &mut out), None);
        s.push(sub(1, 0));
        for w in [2usize, 5, 2, 5] {
            s.push(sub(w, 0));
        }
        health.lane(1).trip();
        // Quarantined lane 1 wins over the hotter healthy lane 2.
        assert_eq!(
            s.steal_with_health_traced(0, 8, &health, &mut out),
            Some(StealTrace { victim: 1, quarantined: true, n: 1 })
        );
        out.clear();
        // With lane 1 drained, the classic steal reports lane 2.
        assert_eq!(
            s.steal_with_health_traced(0, 8, &health, &mut out),
            Some(StealTrace { victim: 2, quarantined: false, n: 2 })
        );
        // The wrapper and the traced variant agree on the count.
        out.clear();
        assert_eq!(s.steal_with_health(0, 8, &health, &mut out), 1);
    }

    #[test]
    fn admission_armed_drain_orders_by_policy_and_releases() {
        use crate::coordinator::admission::{
            AdmissionCtl, AdmissionOptions, DrainPolicyKind,
        };
        let ctl = AdmissionCtl::new(AdmissionOptions {
            policy: DrainPolicyKind::StrictPriority,
            ..AdmissionOptions::default()
        });
        let b = SharedBuffer::with_admission(ctl.clone(), true);
        let mut hi = sub(0, 0);
        hi.class = Priority::Hi;
        let lo = sub(1, 0); // Normal
        ctl.try_reserve(lo.tenant).unwrap();
        ctl.try_reserve(hi.tenant).unwrap();
        b.push(lo);
        b.push(hi);
        assert_eq!(ctl.queued_total(), 2);
        let got = b.drain(1, Duration::ZERO).unwrap();
        assert_eq!(got[0].class, Priority::Hi, "policy orders the drain");
        assert_eq!(ctl.queued_total(), 1, "drain released the reservation");
        // Requeueing hands the reservation back unconditionally.
        let mut back = b.drain(1, Duration::ZERO).unwrap();
        assert_eq!(ctl.queued_total(), 0);
        b.requeue_front(&mut back);
        assert_eq!(ctl.queued_total(), 1);
        // A transfer buffer (fleet ingress) keeps reservations on drain.
        let t = SharedBuffer::with_admission(ctl.clone(), false);
        ctl.try_reserve(TenantId(2)).unwrap();
        t.push(sub(2, 0));
        let _ = t.drain(4, Duration::ZERO).unwrap();
        assert_eq!(ctl.queued_total(), 2, "ingress drain is a transfer");
    }

    #[test]
    fn admission_off_buffer_is_plain_fifo() {
        // The default-constructed buffer has no policy box and no ctl:
        // the admission-off path is byte-for-byte the PR-8 pipeline.
        let b = SharedBuffer::new();
        for w in 0..4 {
            let mut s = sub(w, 0);
            s.class = if w % 2 == 0 { Priority::Hi } else { Priority::BestEffort };
            b.push(s);
        }
        let got = b.drain(8, Duration::ZERO).unwrap();
        let order: Vec<usize> = got.iter().map(|s| s.worker).collect();
        assert_eq!(order, vec![0, 1, 2, 3], "classes ignored without admission");
    }

    #[test]
    fn sharded_close_all_unblocks_every_lane() {
        let s = ShardedBuffer::new(3);
        let barrier = Arc::new(Barrier::new(4));
        let handles: Vec<_> = (0..3)
            .map(|l| {
                let (s2, barrier2) = (s.clone(), barrier.clone());
                std::thread::spawn(move || {
                    barrier2.wait();
                    s2.lane(l).drain(4, Duration::ZERO)
                })
            })
            .collect();
        barrier.wait();
        s.close_all();
        for h in handles {
            assert!(h.join().unwrap().is_none());
        }
    }
}
