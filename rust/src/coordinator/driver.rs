//! One submission/completion surface over the coordinator zoo.
//!
//! Four entrypoints grew over nine PRs — [`Coordinator::run`],
//! [`LaneCoordinator::run`]/[`run_tenants`], [`FleetCoordinator::run`]/
//! [`run_tenants`] — with three different metrics structs. Every new
//! caller (the trace service, examples, benches) had to pick a backend
//! at the type level and re-learn its report shape. The [`Driver`]
//! trait collapses that: one `run`/`run_tenants` pair returning one
//! [`RunReport`], implemented by all three coordinators as *pure
//! delegation* — each impl calls the coordinator's own inherent method
//! and repackages the result, so behavior through the façade is
//! bit-identical to calling the backend directly (the existing prop
//! suites keep pinning the inherent paths).
//!
//! [`DriverBuilder`] is the validated construction path: it runs the
//! shared `validate()` sweep ([`LaneOptions::validate`],
//! [`FleetCoordOptions::validate`], recovery + admission) and returns
//! typed [`ConfigError`]s instead of panicking mid-run. Field-struct
//! literals remain fully supported for direct construction — the
//! builder is a front door, not a toll gate.
//!
//! [`run_tenants`]: Driver::run_tenants

use std::fmt;
use std::sync::Arc;

use crate::config::DeviceProfile;
use crate::coordinator::fleet::{
    FleetCoordOptions, FleetCoordinator, FleetMetrics,
};
use crate::coordinator::lanes::{
    LaneCoordinator, LaneMetrics, LaneOptions, TenantWorkload,
};
use crate::coordinator::runner::Coordinator;
use crate::device::{Device, SimDevice};
use crate::sched::search_util::PruneCounters;
use crate::task::TaskSpec;

/// Typed configuration rejection: which knob, and why. Returned by the
/// shared `validate()` path on [`LaneOptions`], [`FleetCoordOptions`],
/// [`RecoveryOptions`] and [`AdmissionOptions`] — the builder-facing
/// replacement for the scattered `assert!`/`String` errors those
/// options used to produce.
///
/// [`RecoveryOptions`]: crate::coordinator::recovery::RecoveryOptions
/// [`AdmissionOptions`]: crate::coordinator::admission::AdmissionOptions
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConfigError {
    /// Dotted path of the offending knob, e.g. `"admission.global_cap"`.
    pub field: &'static str,
    pub reason: String,
}

impl ConfigError {
    pub fn new(field: &'static str, reason: impl Into<String>) -> Self {
        ConfigError { field, reason: reason.into() }
    }
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "invalid config `{}`: {}", self.field, self.reason)
    }
}

impl std::error::Error for ConfigError {}

/// Fleet-only telemetry carried alongside the common metrics when the
/// backend is a [`FleetCoordinator`] (placement decisions have no lane
/// equivalent).
#[derive(Clone, Debug)]
pub struct FleetExtras {
    pub n_placements: usize,
    pub n_place_rounds: usize,
    pub n_steal_considered: usize,
    pub n_steal_rejected: usize,
    /// Measured ingress-to-placement latency per routed submission (s).
    pub placement_latencies: Vec<f64>,
    pub placement_prune: PruneCounters,
}

/// The unified result of one driver run: the lane-shaped common surface
/// (identical fields for every backend; fleet `per_device` maps to
/// `metrics.per_lane`) plus optional fleet placement extras.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Stable backend name: `"coordinator"`, `"lanes"`, `"fleet"`.
    pub backend: &'static str,
    pub metrics: LaneMetrics,
    /// `Some` iff the backend was a fleet.
    pub fleet: Option<FleetExtras>,
}

impl RunReport {
    pub fn from_lanes(backend: &'static str, m: LaneMetrics) -> RunReport {
        RunReport { backend, metrics: m, fleet: None }
    }

    pub fn from_fleet(m: FleetMetrics) -> RunReport {
        let FleetMetrics {
            total_secs,
            tasks_per_sec,
            latencies,
            latency_tenants,
            group_makespans,
            sched_overhead_secs,
            n_groups,
            n_tasks,
            per_device,
            n_placements,
            placement_prune,
            n_steal_considered,
            n_steal_rejected,
            placement_latencies,
            n_place_rounds,
            admission,
        } = m;
        RunReport {
            backend: "fleet",
            metrics: LaneMetrics {
                total_secs,
                tasks_per_sec,
                latencies,
                latency_tenants,
                group_makespans,
                sched_overhead_secs,
                n_groups,
                n_tasks,
                per_lane: per_device,
                admission,
            },
            fleet: Some(FleetExtras {
                n_placements,
                n_place_rounds,
                n_steal_considered,
                n_steal_rejected,
                placement_latencies,
                placement_prune,
            }),
        }
    }
}

/// The unified submission surface. Implementations delegate to their
/// backend's inherent `run`/`run_tenants` — no behavior of their own —
/// so driving a coordinator through `dyn Driver` is bit-identical to
/// calling it directly.
pub trait Driver {
    /// Stable backend name for reports and event streams.
    fn backend(&self) -> &'static str;

    /// Run tenant-attributed workloads to completion.
    fn run_tenants(&self, workloads: Vec<TenantWorkload>) -> RunReport;

    /// Anonymous-tenant form: `workloads[w]` is worker `w`'s dependent
    /// batch, wrapped per [`TenantWorkload::for_worker`] — exactly the
    /// mapping every backend's inherent `run` applies.
    fn run(&self, workloads: Vec<Vec<TaskSpec>>) -> RunReport {
        self.run_tenants(
            workloads
                .into_iter()
                .enumerate()
                .map(|(w, tasks)| TenantWorkload::for_worker(w, tasks))
                .collect(),
        )
    }
}

impl Driver for LaneCoordinator {
    fn backend(&self) -> &'static str {
        "lanes"
    }

    fn run_tenants(&self, workloads: Vec<TenantWorkload>) -> RunReport {
        RunReport::from_lanes("lanes", LaneCoordinator::run_tenants(self, workloads))
    }
}

impl Driver for FleetCoordinator {
    fn backend(&self) -> &'static str {
        "fleet"
    }

    fn run_tenants(&self, workloads: Vec<TenantWorkload>) -> RunReport {
        RunReport::from_fleet(FleetCoordinator::run_tenants(self, workloads))
    }
}

impl Driver for Coordinator {
    fn backend(&self) -> &'static str {
        "coordinator"
    }

    fn run_tenants(&self, workloads: Vec<TenantWorkload>) -> RunReport {
        RunReport::from_lanes(
            "coordinator",
            self.as_lane().run_tenants(workloads),
        )
    }
}

enum BuildMode {
    Lanes(LaneOptions),
    Fleet(FleetCoordOptions),
}

/// Validated construction of a [`Driver`]: pick a backend, attach
/// devices (and optional plan models), `build()`. All option structs
/// pass their `validate()` sweep first, so a bad knob is a typed
/// [`ConfigError`] at build time instead of a panic mid-run.
///
/// ```no_run
/// use oclcc::config::profile_by_name;
/// use oclcc::coordinator::{DriverBuilder, LaneOptions};
///
/// let driver = DriverBuilder::lanes(LaneOptions::default())
///     .sim_device(profile_by_name("amd_r9").unwrap())
///     .build()
///     .unwrap();
/// let report = driver.run(vec![vec![]]);
/// assert_eq!(report.backend, "lanes");
/// ```
pub struct DriverBuilder {
    mode: BuildMode,
    devices: Vec<Arc<dyn Device>>,
    plan_models: Vec<DeviceProfile>,
}

impl DriverBuilder {
    /// Sharded lane backend ([`LaneCoordinator`]); one lane per device.
    pub fn lanes(opts: LaneOptions) -> Self {
        DriverBuilder {
            mode: BuildMode::Lanes(opts),
            devices: Vec::new(),
            plan_models: Vec::new(),
        }
    }

    /// Heterogeneous fleet backend ([`FleetCoordinator`]): one ingress
    /// stream placed across all devices.
    pub fn fleet(opts: FleetCoordOptions) -> Self {
        DriverBuilder {
            mode: BuildMode::Fleet(opts),
            devices: Vec::new(),
            plan_models: Vec::new(),
        }
    }

    /// Attach one execution device (repeatable; order = lane index).
    pub fn device(mut self, d: Arc<dyn Device>) -> Self {
        self.devices.push(d);
        self
    }

    /// Attach several devices at once.
    pub fn devices(
        mut self,
        ds: impl IntoIterator<Item = Arc<dyn Device>>,
    ) -> Self {
        self.devices.extend(ds);
        self
    }

    /// Convenience: attach a bit-deterministic model-backed
    /// [`SimDevice`] for `profile` (the replay/test substrate).
    pub fn sim_device(self, profile: DeviceProfile) -> Self {
        self.device(Arc::new(SimDevice::new(profile)))
    }

    /// Planning-model override (repeatable). Lanes accept at most one
    /// (all lanes plan against it); a fleet needs exactly one per
    /// device or none.
    pub fn plan_model(mut self, p: DeviceProfile) -> Self {
        self.plan_models.push(p);
        self
    }

    /// Validate everything and construct the backend.
    pub fn build(self) -> Result<Box<dyn Driver>, ConfigError> {
        if self.devices.is_empty() {
            return Err(ConfigError::new(
                "devices",
                "at least one device is required",
            ));
        }
        match self.mode {
            BuildMode::Lanes(opts) => {
                opts.validate()?;
                if self.plan_models.len() > 1 {
                    return Err(ConfigError::new(
                        "plan_models",
                        format!(
                            "lane backend takes at most one plan model, got {}",
                            self.plan_models.len()
                        ),
                    ));
                }
                let mut c = LaneCoordinator::with_devices(self.devices, opts);
                if let Some(m) = self.plan_models.into_iter().next() {
                    c = c.with_plan_model(m);
                }
                Ok(Box::new(c))
            }
            BuildMode::Fleet(opts) => {
                opts.validate()?;
                if !self.plan_models.is_empty()
                    && self.plan_models.len() != self.devices.len()
                {
                    return Err(ConfigError::new(
                        "plan_models",
                        format!(
                            "fleet backend needs one plan model per device \
                             ({} devices, {} models)",
                            self.devices.len(),
                            self.plan_models.len()
                        ),
                    ));
                }
                let mut c =
                    FleetCoordinator::with_devices(self.devices, opts);
                if !self.plan_models.is_empty() {
                    c = c.with_plan_models(self.plan_models);
                }
                Ok(Box::new(c))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;
    use crate::coordinator::admission::AdmissionOptions;

    fn profile() -> DeviceProfile {
        profile_by_name("amd_r9").unwrap()
    }

    fn tasks(n: usize) -> Vec<TaskSpec> {
        let g = crate::task::synthetic::synthetic_benchmark(
            "BK50",
            &profile(),
            0.02,
        )
        .unwrap();
        (0..n).map(|i| g.tasks[i % g.len()].clone()).collect()
    }

    #[test]
    fn builder_rejects_empty_devices() {
        let e = DriverBuilder::lanes(LaneOptions::default())
            .build()
            .unwrap_err();
        assert_eq!(e.field, "devices");
    }

    #[test]
    fn builder_rejects_invalid_options_with_typed_field() {
        let opts = LaneOptions {
            scoring_threads: 0,
            ..LaneOptions::default()
        };
        let e = DriverBuilder::lanes(opts)
            .sim_device(profile())
            .build()
            .unwrap_err();
        assert_eq!(e.field, "scoring_threads");

        let adm = AdmissionOptions {
            per_tenant_cap: 0,
            ..AdmissionOptions::default()
        };
        let opts = LaneOptions {
            admission: Some(adm),
            ..LaneOptions::default()
        };
        let e = DriverBuilder::lanes(opts)
            .sim_device(profile())
            .build()
            .unwrap_err();
        assert_eq!(e.field, "admission.per_tenant_cap");
    }

    #[test]
    fn builder_rejects_plan_model_mismatch() {
        let e = DriverBuilder::fleet(FleetCoordOptions::default())
            .sim_device(profile())
            .sim_device(profile())
            .plan_model(profile())
            .build()
            .unwrap_err();
        assert_eq!(e.field, "plan_models");
    }

    #[test]
    fn lane_driver_runs_and_reports() {
        let driver = DriverBuilder::lanes(LaneOptions::default())
            .sim_device(profile())
            .build()
            .unwrap();
        let report = driver.run(vec![tasks(2), tasks(2)]);
        assert_eq!(report.backend, "lanes");
        assert_eq!(report.metrics.n_tasks, 4);
        assert!(report.fleet.is_none());
    }

    #[test]
    fn fleet_driver_carries_extras() {
        let driver = DriverBuilder::fleet(FleetCoordOptions::default())
            .sim_device(profile())
            .sim_device(profile())
            .build()
            .unwrap();
        let report = driver.run(vec![tasks(2), tasks(2)]);
        assert_eq!(report.backend, "fleet");
        assert_eq!(report.metrics.n_tasks, 4);
        let extras = report.fleet.expect("fleet extras");
        assert!(extras.n_placements >= 4);
        assert_eq!(report.metrics.per_lane.len(), 2);
    }

    /// The façade is pure delegation: the group makespans a driver
    /// reports are the same simulated values the backend reports when
    /// called directly. Single worker + NoReorder forces one group per
    /// task (the dependent batch), so grouping is deterministic and the
    /// two runs are comparable group-for-group.
    #[test]
    fn facade_round_trips_lane_behavior_bit_identically() {
        let opts = || LaneOptions {
            policy: crate::coordinator::runner::Policy::NoReorder,
            ..LaneOptions::default()
        };
        let batch = tasks(3);

        let direct = LaneCoordinator::with_devices(
            vec![Arc::new(SimDevice::new(profile())) as Arc<dyn Device>],
            opts(),
        );
        let m_direct = direct.run(vec![batch.clone()]);

        let driver = DriverBuilder::lanes(opts())
            .sim_device(profile())
            .build()
            .unwrap();
        let m_facade = driver.run(vec![batch]).metrics;

        assert_eq!(m_direct.n_tasks, m_facade.n_tasks);
        assert_eq!(m_direct.n_groups, m_facade.n_groups);
        // SimDevice makespans are model-time: bit-identical, not close.
        assert_eq!(m_direct.group_makespans, m_facade.group_makespans);
    }
}
