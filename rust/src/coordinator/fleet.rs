//! The fleet coordinator: one open submission stream scheduled across a
//! *heterogeneous* device fleet with calibrated placement and
//! cross-device work-stealing.
//!
//! Where [`LaneCoordinator`] shards workers over lanes by hash
//! (`w % L`), the fleet coordinator makes lane choice a *scheduling*
//! decision: every worker submits to one central ingress buffer, and a
//! single fleet proxy routes each arrival to the device whose
//! **calibrated earliest-completion-time** grows the least
//! ([`ShardedBuffer::push_to_lane`]). Per device it then reuses the
//! online lane pipeline wholesale — the same
//! `merge_arrivals` / `finalize_plan` commit/replan split over a
//! contiguous planning cursor, the same `device_runner_loop` on a
//! dedicated runner thread, the same recovery/watchdog handling — so a
//! single-device fleet degenerates to the online lane proxy exactly
//! (pinned in rust/tests/prop_fleet.rs).
//!
//! # Calibrated ECT placement
//!
//! Each device keeps its own planning model: a base [`DeviceProfile`]
//! (or an explicit override via
//! [`FleetCoordinator::with_plan_models`]) wrapped in a per-device
//! [`CalibratedProfile`] that its own [`Calibrator`] refreshes at
//! contiguous-timeline boundaries, exactly like the online lane. A
//! candidate task is scored on device `d` by compiling a one-row table
//! against `d`'s calibrated model and appending it to `d`'s current
//! frontier (committed cursor + uncommitted suffix) through
//! `sched::search_util::bounded_append_score` — the bound-gated
//! machinery of the beam searches: admissible floor first, then a
//! bounded rollout under the best completion seen so far this scan.
//! Device model clocks are not aligned (each contiguous timeline starts
//! when its device went busy), so scores are compared as *predicted
//! remaining seconds* — completion clock minus the device's elapsed
//! busy time — and the running cutoff is translated onto each device's
//! local clock before pruning. Quarantined (breaker-Open) devices are
//! skipped; with the whole fleet down, placement falls back to
//! round-robin so arrivals still land somewhere recoverable
//! ([`FleetHealth::n_quarantined`]).
//!
//! # Calibrated work-stealing
//!
//! An idle device steals through the breaker-aware
//! [`ShardedBuffer::steal_with_health`] machinery (traced variant, so
//! the victim is known), but a *healthy* victim's work moves only when
//! the thief's own calibrated model proves a strict win:
//! [`steal_predicts_win`] compares the thief's exact completion of the
//! stolen rows — compiled against the thief's profile, so its own
//! HtD/DtH link seconds (i.e. the transfer cost of moving the bytes)
//! are priced in — against the victim's predicted remaining horizon. A
//! rejected steal is handed back to the victim's queue front
//! (`requeue_front`, FIFO preserved). Backlog shed by a *quarantined*
//! victim is always accepted: its owner cannot run anything, so there
//! is no "leave it where it is" to compare against. On quarantine the
//! device's [`DriftGate`] also forgets its smoothed drift
//! ([`DriftGate::reset_drift`]) — what it learned described the device
//! before it went bad.
//!
//! # Batched joint placement
//!
//! Arrivals are not placed one at a time: the proxy drains up to
//! [`FleetCoordOptions::place_batch`] submissions from the ingress and
//! hands the whole batch to [`BatchPlacer`] (`sched::fleet`), which
//! scores every (candidate × device) pair against *cached* per-device
//! frontiers — each device's committed cursor + incumbent suffix is
//! resumed once per scoring stripe, not re-derived per candidate — in
//! parallel over the PR-2 `ScoringPool`
//! ([`FleetCoordOptions::placement_threads`]), then compares the old
//! per-arrival greedy against two frontier-extending assignment trials
//! on a replayed model clock. A batch of one (and any tie) reproduces
//! the per-arrival decisions bit-identically, and the joint objective
//! is never worse than the greedy baseline by construction — both
//! pinned in rust/tests/prop_fleet.rs.
//!
//! # Threading model
//!
//! One proxy thread serves the whole fleet (placement needs a
//! consistent view of every device's frontier); device execution runs
//! on per-device runner threads, so D devices still execute
//! concurrently and planning overlaps all of them — and the proxy
//! itself never sleeps while there is planning to do:
//!
//! * a `Retry` backoff never blocks the proxy. The group parks on a
//!   **deadline wheel** (a due-time min-heap polled alongside ingress)
//!   and is re-dispatched when its backoff expires; every other
//!   device's placement, merging and stealing proceeds in between.
//! * at the idle edge the proxy parks on a [`WakeSignal`] shared with
//!   the workers and every device runner, so an ingress push or a
//!   `RunDone` wakes planning immediately; `OnlineOptions::poll` (and
//!   the nearest retry due-time) only bounds the park for purely
//!   time-driven work such as breaker cooldown expiry.
//!
//! Benchmarked in `benches/fleet_throughput.rs` (`BENCH_fleet.json`),
//! including a chaos cell asserting placements keep advancing while a
//! device sits in a retry backoff.
//!
//! [`WakeSignal`]: crate::coordinator::lanes::WakeSignal
//! [`BatchPlacer`]: crate::sched::fleet::BatchPlacer
//!
//! [`LaneCoordinator`]: crate::coordinator::lanes::LaneCoordinator
//! [`ShardedBuffer::push_to_lane`]: crate::coordinator::buffer::ShardedBuffer::push_to_lane
//! [`ShardedBuffer::steal_with_health`]: crate::coordinator::buffer::ShardedBuffer::steal_with_health
//! [`Calibrator`]: crate::model::calibrate::Calibrator
//! [`DriftGate`]: crate::sched::online::DriftGate
//! [`DriftGate::reset_drift`]: crate::sched::online::DriftGate::reset_drift
//! [`steal_predicts_win`]: crate::sched::fleet::steal_predicts_win
//! [`FleetHealth::n_quarantined`]: crate::coordinator::recovery::FleetHealth::n_quarantined

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::config::DeviceProfile;
use crate::coordinator::admission::{
    AdmissionCtl, AdmissionGate, AdmissionOptions, AdmissionReport, ShedSlot,
    SubmitOutcome,
};
use crate::coordinator::buffer::{DrainPoll, ShardedBuffer, SharedBuffer, Submission};
use crate::coordinator::driver::ConfigError;
use crate::coordinator::lanes::{
    device_runner_loop, empty_lane_stats, finalize_plan, merge_arrivals,
    record_calib_stats, validate_online, InFlight, LaneStats, RunDone,
    RunOutcome, TenantWorkload, WakeSignal,
};
use crate::coordinator::recovery::{
    BreakerState, FailureCtx, FleetHealth, RecoveryAction, RecoveryOptions,
};
use crate::coordinator::runner::Policy;
use crate::device::Device;
use crate::model::{
    fold_timeline_stage_secs, CalibrateOptions, CalibratedProfile, Calibrator,
    EngineSecs, EngineState, SimCursor, TaskTable,
};
use crate::queue::event::Event;
use crate::sched::fleet::{steal_predicts_win, BatchPlacer};
use crate::sched::online::{DriftGate, OnlineOptions, OnlineScratch};
use crate::sched::search_util::PruneCounters;
use crate::task::TaskSpec;
use crate::util::stats;

/// Knobs of the fleet runtime. The online pipeline is not optional here
/// — calibrated placement needs the per-device contiguous cursors the
/// open-stream pipeline maintains.
#[derive(Clone, Debug)]
pub struct FleetCoordOptions {
    pub policy: Policy,
    /// Ingress settle window is always zero (placement is per-arrival);
    /// this settle applies to nothing yet and is kept for parity with
    /// [`LaneOptions`] group formation semantics.
    ///
    /// [`LaneOptions`]: crate::coordinator::lanes::LaneOptions
    pub settle: Duration,
    /// Max submissions per committed device group. 0 = `ceil(T / D)`.
    pub group_cap: usize,
    /// Open-stream knobs (drift gate, re-plan width, steal bound, poll).
    pub online: OnlineOptions,
    /// Per-device online recalibration (see `coordinator::lanes`).
    pub recalibrate: Option<CalibrateOptions>,
    /// Fault tolerance (see `coordinator::lanes` / `coordinator::recovery`).
    pub recovery: Option<RecoveryOptions>,
    /// Bound-gated placement scoring (floors + bounded rollouts).
    /// Decisions are bit-identical either way (rust/tests/prop_fleet.rs
    /// pins the static scheduler; the coordinator shares the scorer);
    /// off keeps the exact full-probe scan for reference.
    pub prune_placement: bool,
    /// Max ingress submissions drained into one joint placement round.
    /// Must be ≥ 1 (`run` rejects 0). The default `usize::MAX` drains
    /// the whole available backlog, which matches the pre-batching
    /// behavior of draining up to one submission per worker: a worker
    /// blocks on its previous submission's completion event, so the
    /// ingress never holds more than one entry per worker either way.
    /// `1` degenerates to per-arrival greedy placement exactly.
    pub place_batch: usize,
    /// Scoring stripes for the placement grid (worker threads + the
    /// proxy itself, [`ScoringPool`] contract); results are
    /// bit-identical for any value. 1 = fully serial on the proxy.
    ///
    /// [`ScoringPool`]: crate::sched::parallel::ScoringPool
    pub placement_threads: usize,
    /// `Some` arms multi-tenant admission control at the fleet ingress
    /// (`coordinator::admission`): bounded per-tenant backlogs, overflow
    /// policy at the submit gate (ShedLowest evictions scan the ingress
    /// *and* every device queue), and per-tenant telemetry in
    /// [`FleetMetrics::admission`]. `None` (the default) keeps the
    /// untracked unbounded pipeline bit-for-bit.
    pub admission: Option<AdmissionOptions>,
}

impl Default for FleetCoordOptions {
    fn default() -> Self {
        FleetCoordOptions {
            policy: Policy::Heuristic,
            settle: Duration::from_micros(300),
            group_cap: 0,
            online: OnlineOptions::default(),
            recalibrate: None,
            recovery: None,
            prune_placement: true,
            place_batch: usize::MAX,
            placement_threads: 1,
            admission: None,
        }
    }
}

impl FleetCoordOptions {
    /// Check every knob — including nested online / recovery / admission
    /// config — and return the first offender as a typed [`ConfigError`].
    /// The opt-in front door used by `DriverBuilder::build`; field-struct
    /// literals keep working unvalidated (invalid `place_batch` still
    /// panics inside `run`, pinned by the `should_panic` test).
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.place_batch == 0 {
            return Err(ConfigError::new("place_batch", "must be >= 1"));
        }
        if self.placement_threads == 0 {
            return Err(ConfigError::new("placement_threads", "must be >= 1"));
        }
        validate_online(&self.online)?;
        if let Some(recovery) = &self.recovery {
            recovery.validate()?;
        }
        if let Some(admission) = &self.admission {
            admission.validate()?;
        }
        Ok(())
    }
}

/// Aggregate metrics of one fleet run — [`LaneMetrics`] plus the
/// placement/steal observability the fleet adds.
///
/// [`LaneMetrics`]: crate::coordinator::lanes::LaneMetrics
#[derive(Clone, Debug)]
pub struct FleetMetrics {
    pub total_secs: f64,
    /// Executed tasks per second — the paper's "tasks throughput".
    pub tasks_per_sec: f64,
    /// Per-task submission → completion latency (s), all devices.
    pub latencies: Vec<f64>,
    /// Tenant id of each entry of `latencies` (index-aligned) — the
    /// per-tenant breakdown in [`FleetMetrics::admission`] joins on this.
    pub latency_tenants: Vec<u32>,
    /// Device busy time per committed group (s), all devices.
    pub group_makespans: Vec<f64>,
    pub sched_overhead_secs: f64,
    pub n_groups: usize,
    pub n_tasks: usize,
    /// Per-device breakdown (device index = `LaneStats::lane`). The
    /// beam/replan prune counters in here are device-local; the
    /// *placement* scorer's counters are in `placement_prune`.
    pub per_device: Vec<LaneStats>,
    /// Submissions routed by the calibrated ECT placement (including
    /// round-robin fallbacks while the whole fleet was quarantined).
    pub n_placements: usize,
    /// Placement + steal-predicate pruning counters: floor rejections
    /// and early-exited rollouts from the cross-device ECT scan and
    /// from `steal_predicts_win`.
    pub placement_prune: PruneCounters,
    /// Steal-predicate consultations against a *healthy* victim
    /// (quarantine rescues are unconditional and not counted here).
    pub n_steal_considered: usize,
    /// Predicate consultations that rejected the steal (work handed
    /// back to the victim's queue front).
    pub n_steal_rejected: usize,
    /// Measured ingress-to-placement latency per routed submission (s):
    /// `submitted_at` → the instant its batch's assignments were pushed
    /// onto device queues. The scheduling-decision latency HTS calls the
    /// throughput ceiling at high task rates — measured, not derived.
    pub placement_latencies: Vec<f64>,
    /// Joint placement rounds executed (one round places one drained
    /// batch; `n_placements / n_place_rounds` ≈ mean batch size).
    pub n_place_rounds: usize,
    /// Per-tenant admission telemetry (`None` with `admission: None`).
    pub admission: Option<AdmissionReport>,
}

impl FleetMetrics {
    pub fn mean_latency(&self) -> f64 {
        stats::mean(&self.latencies)
    }

    /// Median measured ingress-to-placement latency (s).
    pub fn placement_p50_s(&self) -> f64 {
        stats::percentile(&self.placement_latencies, 50.0)
    }

    /// Tail measured ingress-to-placement latency (s).
    pub fn placement_p99_s(&self) -> f64 {
        stats::percentile(&self.placement_latencies, 99.0)
    }

    pub fn p50_latency(&self) -> f64 {
        stats::percentile(&self.latencies, 50.0)
    }

    pub fn p99_latency(&self) -> f64 {
        stats::percentile(&self.latencies, 99.0)
    }

    /// Submissions stolen across devices (sum over `per_device`).
    pub fn n_stolen(&self) -> usize {
        self.per_device.iter().map(|l| l.n_stolen).sum()
    }

    /// Fraction of wall-clock spent scheduling (Table-6 overhead share).
    pub fn sched_overhead_share(&self) -> f64 {
        if self.total_secs <= 0.0 {
            return 0.0;
        }
        self.sched_overhead_secs / self.total_secs
    }
}

/// Everything the fleet proxy tracks per device: the online lane
/// proxy's planner state verbatim, plus the wall-clock anchor of the
/// device's contiguous model timeline (`live_since`) that placement
/// uses to compare devices whose clocks started at different moments.
struct DevState {
    base_model: DeviceProfile,
    cal_prof: CalibratedProfile,
    calibrator: Option<Calibrator>,
    /// Pending-suffix table (compiled over `pending_tasks`).
    table: TaskTable,
    /// Scoring scratch table: one row per placement candidate, or the
    /// stolen rows during a steal consult. Same calibrated generation
    /// as `table`, so frontier cursors accept rows from either.
    probe_table: TaskTable,
    /// Contiguous planning cursor (committed prefix).
    cursor: SimCursor,
    scratch: OnlineScratch,
    gate: DriftGate,
    calib_probe: SimCursor,
    inflight_pred: Vec<EngineSecs>,
    pending_subs: Vec<Submission>,
    pending_tasks: Vec<TaskSpec>,
    incumbent: Vec<usize>,
    order_buf: Vec<usize>,
    planner_live: bool,
    plan_dirty: bool,
    suffix_planned: bool,
    pred_done: f64,
    last_commit_pred: f64,
    /// Wall instant the current contiguous timeline started (valid
    /// while `planner_live`): model clock `t` ≈ wall `live_since + t`.
    live_since: Instant,
    inflight: Option<InFlight>,
    /// The device's failed group is parked on the retry deadline wheel
    /// until this instant — the device must not be treated as idle
    /// (its committed work is coming back), and the watchdog must not
    /// run (nothing is on the device). Cleared at re-dispatch.
    retry_due: Option<Instant>,
    consec_failures: usize,
    stats: LaneStats,
}

fn new_dev_state(dev: usize, base_model: DeviceProfile, opts: &FleetCoordOptions) -> DevState {
    let cal_prof = CalibratedProfile::identity(&base_model);
    let calibrator = opts.recalibrate.clone().map(Calibrator::new);
    let mut calib_probe = SimCursor::detached();
    calib_probe.set_record_timeline(true);
    DevState {
        base_model,
        cal_prof,
        calibrator,
        table: TaskTable::new(),
        probe_table: TaskTable::new(),
        cursor: SimCursor::detached(),
        scratch: OnlineScratch::new(),
        gate: DriftGate::new(opts.online.drift_threshold),
        calib_probe,
        inflight_pred: Vec::new(),
        pending_subs: Vec::new(),
        pending_tasks: Vec::new(),
        incumbent: Vec::new(),
        order_buf: Vec::new(),
        planner_live: false,
        plan_dirty: false,
        suffix_planned: false,
        pred_done: 0.0,
        last_commit_pred: 0.0,
        live_since: Instant::now(),
        inflight: None,
        retry_due: None,
        consec_failures: 0,
        stats: empty_lane_stats(dev),
    }
}

/// Merge drained/stolen submissions into a device's uncommitted suffix
/// (the online lane's [`merge_arrivals`]), stamping the wall anchor of
/// a freshly (re)started contiguous timeline.
fn merge_into_device(st: &mut DevState, drained: &mut Vec<Submission>, mid_group: bool) {
    let was_live = st.planner_live;
    merge_arrivals(
        &st.cal_prof,
        mid_group,
        drained,
        &mut st.pending_subs,
        &mut st.pending_tasks,
        &mut st.incumbent,
        &mut st.table,
        &mut st.cursor,
        &mut st.planner_live,
        &mut st.last_commit_pred,
        &mut st.plan_dirty,
        &mut st.stats,
    );
    if !was_live && st.planner_live {
        st.live_since = Instant::now();
    }
}

fn finalize_device_plan(st: &mut DevState, policy: Policy, online: &OnlineOptions) {
    finalize_plan(
        policy,
        online,
        &st.table,
        &mut st.cursor,
        &mut st.incumbent,
        &mut st.order_buf,
        &mut st.scratch,
        &mut st.gate,
        &mut st.suffix_planned,
        &mut st.stats,
        &mut st.plan_dirty,
        &mut st.pred_done,
    );
}

/// Quarantine bookkeeping shared by the fault and watchdog paths: shed
/// `back` (the failed group, when there is one) plus the unsubmitted
/// backlog to the device's queue front (FIFO preserved, visible to
/// thieves), clear the plan, and forget the drift the gate learned
/// about the pre-fault device.
fn shed_and_reset(st: &mut DevState, own: &SharedBuffer, mut back: Vec<Submission>) {
    back.append(&mut st.pending_subs);
    st.stats.n_requeued += back.len();
    own.requeue_front(&mut back);
    st.pending_tasks.clear();
    st.incumbent.clear();
    st.planner_live = false;
    st.plan_dirty = false;
    st.suffix_planned = false;
    st.gate.reset_drift();
}

/// A failed group parked on the retry deadline wheel: re-dispatched to
/// its device when `due` passes, so the backoff never blocks the proxy.
/// Ordered by `(due, dev)` — the wheel is a `BinaryHeap<Reverse<..>>`
/// min-heap and `dev` breaks exact due-time ties deterministically.
struct RetryEntry {
    due: Instant,
    dev: usize,
    pred: f64,
    attempt: usize,
    subs: Vec<Submission>,
}

impl PartialEq for RetryEntry {
    fn eq(&self, other: &Self) -> bool {
        self.due == other.due && self.dev == other.dev
    }
}
impl Eq for RetryEntry {}
impl PartialOrd for RetryEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for RetryEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.due, self.dev).cmp(&(other.due, other.dev))
    }
}

/// The fleet runtime (see module docs).
pub struct FleetCoordinator {
    devices: Vec<Arc<dyn Device>>,
    /// Planning-model overrides, one per device (`None` plans each
    /// device against its own profile).
    plan_models: Option<Vec<DeviceProfile>>,
    opts: FleetCoordOptions,
}

impl FleetCoordinator {
    pub fn with_devices(devices: Vec<Arc<dyn Device>>, opts: FleetCoordOptions) -> Self {
        assert!(!devices.is_empty(), "need at least one device");
        FleetCoordinator { devices, plan_models: None, opts }
    }

    /// Plan each device against an explicit model instead of its own
    /// profile — the deliberately-miscalibrated setup of the benches.
    pub fn with_plan_models(mut self, models: Vec<DeviceProfile>) -> Self {
        assert_eq!(models.len(), self.devices.len(), "one plan model per device");
        self.plan_models = Some(models);
        self
    }

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Run `workloads[w]` = the dependent task batch of worker `w`.
    /// Workers are anonymous tenants ([`TenantWorkload::for_worker`]),
    /// so with `admission: None` this is exactly the classic pipeline.
    pub fn run(&self, workloads: Vec<Vec<TaskSpec>>) -> FleetMetrics {
        self.run_tenants(
            workloads
                .into_iter()
                .enumerate()
                .map(|(w, tasks)| TenantWorkload::for_worker(w, tasks))
                .collect(),
        )
    }

    /// [`FleetCoordinator::run`] with tenant attribution: each worker
    /// submits on behalf of its tenant/class through the admission gate
    /// when [`FleetCoordOptions::admission`] is armed. The ingress is a
    /// *transfer* queue — an admitted submission keeps its backlog
    /// reservation while it flows ingress → placement → device queue and
    /// releases it only when a device drains it for execution, so tenant
    /// caps bound the whole queued backlog, not just the ingress.
    pub fn run_tenants(&self, workloads: Vec<TenantWorkload>) -> FleetMetrics {
        let t_workers = workloads.len();
        let d = self.devices.len();
        let ctl = self
            .opts
            .admission
            .as_ref()
            .map(|a| AdmissionCtl::new(a.clone()));
        let ingress = match &ctl {
            // Reservation is *held* across the ingress drain (the proxy
            // transfers to device queues, nothing executes yet).
            Some(c) => SharedBuffer::with_admission(c.clone(), false),
            None => SharedBuffer::new(),
        };
        let lanes = match &ctl {
            Some(c) => ShardedBuffer::with_admission(d, c.clone()),
            None => ShardedBuffer::new(d),
        };
        let health = FleetHealth::new(d);
        let epoch = Instant::now();
        let rec = self.opts.recovery.clone();
        let cap = if self.opts.group_cap == 0 {
            t_workers.div_ceil(d).max(1)
        } else {
            self.opts.group_cap.max(1)
        };
        assert!(
            self.opts.place_batch > 0,
            "FleetCoordOptions::place_batch must be >= 1 \
             (1 = per-arrival greedy, usize::MAX = drain the backlog)"
        );
        let place_batch = self.opts.place_batch;
        let deadline_at = |rec: Option<&RecoveryOptions>, pred: f64| {
            rec.and_then(|r| {
                r.deadline.map(|dl| Instant::now() + dl.deadline_for(pred))
            })
        };

        let mut states: Vec<DevState> = (0..d)
            .map(|dev| {
                let base = match &self.plan_models {
                    Some(models) => models[dev].clone(),
                    None => self.devices[dev].profile().clone(),
                };
                new_dev_state(dev, base, &self.opts)
            })
            .collect();

        let mut latencies: Vec<f64> = Vec::new();
        let mut latency_tenants: Vec<u32> = Vec::new();
        let mut group_makespans: Vec<f64> = Vec::new();
        let mut n_placements = 0usize;
        let mut n_place_rounds = 0usize;
        let mut placement_latencies: Vec<f64> = Vec::new();
        let mut placement_prune = PruneCounters::default();
        let mut n_steal_considered = 0usize;
        let mut n_steal_rejected = 0usize;
        let mut arrivals: Vec<Submission> = Vec::new();
        let mut stolen: Vec<Submission> = Vec::new();
        let mut frontier_buf = SimCursor::detached();
        let mut probe = SimCursor::detached();
        // Joint batch placement scratch: the placer (scoring pool +
        // cached probes), per-device batch frontiers/elapsed/availability
        // and the round's task list + chosen assignment.
        let mut placer = BatchPlacer::new(self.opts.placement_threads);
        let mut batch_tasks: Vec<TaskSpec> = Vec::new();
        let mut batch_frontiers: Vec<SimCursor> =
            (0..d).map(|_| SimCursor::detached()).collect();
        let mut batch_elapsed: Vec<f64> = vec![0.0; d];
        let mut batch_avail: Vec<bool> = vec![false; d];
        let mut assignment: Vec<usize> = Vec::new();
        // Failed groups waiting out their retry backoff (min-heap on
        // due-time) — planning continues while they park here.
        let mut retry_wheel: BinaryHeap<Reverse<RetryEntry>> = BinaryHeap::new();
        // Edge-triggered wakeups for the idle park: workers notify per
        // ingress push (and close), device runners per RunDone.
        let wake = Arc::new(WakeSignal::new());

        std::thread::scope(|s| {
            // ---- workers ----------------------------------------------
            let mut worker_handles = Vec::with_capacity(t_workers);
            for (w, tw) in workloads.into_iter().enumerate() {
                let ingress = ingress.clone();
                let wake = Arc::clone(&wake);
                // Entry queue is the ingress; the ShedLowest eviction
                // scan covers the ingress and every device queue (an
                // admitted-but-unexecuted victim may sit in either).
                let gate = ctl.as_ref().map(|c| {
                    let mut evict_from = vec![ingress.clone()];
                    evict_from.extend(lanes.lanes_vec());
                    AdmissionGate::new(c.clone(), ingress.clone(), evict_from, epoch)
                });
                let h = std::thread::Builder::new()
                    .name(format!("fleet-worker-{w}"))
                    .spawn_scoped(s, move || {
                        for (seq, task) in tw.tasks.into_iter().enumerate() {
                            let done = Event::new();
                            let submitted_at = epoch.elapsed().as_secs_f64();
                            let sub = Submission {
                                worker: w,
                                batch_seq: seq,
                                task,
                                done: done.clone(),
                                submitted_at,
                                tenant: tw.tenant,
                                class: tw.class,
                                deadline: tw
                                    .deadline
                                    .map(|dl| submitted_at + dl),
                                shed: ShedSlot::new(),
                            };
                            match &gate {
                                None => {
                                    ingress.push(sub);
                                    wake.notify();
                                    done.wait();
                                }
                                Some(g) => match g.submit(sub) {
                                    SubmitOutcome::Admitted => {
                                        wake.notify();
                                        done.wait();
                                    }
                                    // Shed at the gate: receipt returned,
                                    // nothing queued, nothing to wait on.
                                    SubmitOutcome::Shed(_) => {}
                                },
                            }
                        }
                    })
                    .expect("spawn fleet worker");
                worker_handles.push(h);
            }

            // ---- janitor: close the ingress once all workers exited ---
            let ingress_j = ingress.clone();
            let wake_j = Arc::clone(&wake);
            std::thread::Builder::new()
                .name("fleet-janitor".into())
                .spawn_scoped(s, move || {
                    let results: Vec<_> =
                        worker_handles.into_iter().map(|h| h.join()).collect();
                    ingress_j.close();
                    wake_j.notify();
                    for r in results {
                        if let Err(payload) = r {
                            std::panic::resume_unwind(payload);
                        }
                    }
                })
                .expect("spawn fleet janitor");

            // ---- per-device runner threads ----------------------------
            let mut job_txs = Vec::with_capacity(d);
            let mut done_rxs = Vec::with_capacity(d);
            for dev in 0..d {
                let (job_tx, job_rx) = mpsc::channel::<Vec<Submission>>();
                let (done_tx, done_rx) = mpsc::channel::<RunDone>();
                let device = Arc::clone(&self.devices[dev]);
                let wake = Arc::clone(&wake);
                std::thread::Builder::new()
                    .name(format!("fleet-device-{dev}"))
                    .spawn_scoped(s, move || {
                        device_runner_loop(
                            device.as_ref(),
                            epoch,
                            job_rx,
                            done_tx,
                            Some(wake),
                        )
                    })
                    .expect("spawn fleet device runner");
                job_txs.push(job_tx);
                done_rxs.push(done_rx);
            }

            // ---- the fleet proxy (this thread) ------------------------
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut closed_ingress = false;
                let mut rr_fallback = 0usize;
                loop {
                    let mut progressed = false;
                    // Snapshot before scanning: a notify landing anywhere
                    // past this line turns the idle park below into an
                    // immediate return instead of being lost.
                    let wake_seen = wake.epoch();

                    // 0. Retry deadline wheel: re-dispatch every parked
                    //    group whose backoff has expired. The proxy never
                    //    sleeps a backoff — parked groups wait here while
                    //    placement and planning continue fleet-wide.
                    while retry_wheel
                        .peek()
                        .is_some_and(|Reverse(e)| e.due <= Instant::now())
                    {
                        let Reverse(e) = retry_wheel.pop().expect("peeked");
                        let st = &mut states[e.dev];
                        st.retry_due = None;
                        st.inflight = Some(InFlight {
                            pred: e.pred,
                            deadline: deadline_at(rec.as_ref(), e.pred),
                            attempt: e.attempt,
                            timed_out: false,
                        });
                        if let Err(mpsc::SendError(subs)) =
                            job_txs[e.dev].send(e.subs)
                        {
                            // Runner thread died: unblock the parked
                            // group's workers, then surface the failure
                            // (liveness before failure — the catch_unwind
                            // tail absorbs the rest of the backlog).
                            let now = epoch.elapsed().as_secs_f64();
                            for sub in &subs {
                                if !sub.done.is_complete() {
                                    sub.done.complete(now);
                                }
                            }
                            panic!("device {} runner died mid-retry", e.dev);
                        }
                        progressed = true;
                    }

                    // 1. Completions and the run-deadline watchdog, for
                    //    every device with a group in flight. Mirrors the
                    //    online lane proxy's RunDone handling exactly.
                    for dev in 0..d {
                        if states[dev].inflight.is_none() {
                            continue;
                        }
                        match done_rxs[dev].try_recv() {
                            Ok(done) => {
                                progressed = true;
                                let st = &mut states[dev];
                                let fl = st.inflight.take().expect("inflight set");
                                let breaker = health.lane(dev);
                                match done.outcome {
                                    RunOutcome::Done {
                                        makespan,
                                        latencies: lat,
                                        timeline,
                                    } => {
                                        if !fl.timed_out
                                            && breaker.state() != BreakerState::Closed
                                        {
                                            breaker.probe_succeeded();
                                        }
                                        if !fl.timed_out {
                                            st.consec_failures = 0;
                                        }
                                        st.stats.busy_secs += makespan;
                                        st.stats.predicted_secs += fl.pred;
                                        if fl.attempt == 1 && !fl.timed_out {
                                            st.gate.observe(makespan, fl.pred);
                                            if let Some(cal) = st.calibrator.as_mut() {
                                                cal.observe_group(
                                                    &st.inflight_pred,
                                                    &timeline,
                                                );
                                            }
                                        }
                                        group_makespans.push(makespan);
                                        for (t, l) in lat {
                                            latency_tenants.push(t);
                                            latencies.push(l);
                                        }
                                        st.stats.n_groups += 1;
                                        st.stats.n_tasks += done.n_tasks;
                                    }
                                    RunOutcome::Fault {
                                        kind,
                                        message,
                                        payload,
                                        subs,
                                    } => {
                                        st.stats.n_faults += 1;
                                        st.consec_failures += 1;
                                        let action = if fl.timed_out {
                                            RecoveryAction::Quarantine
                                        } else {
                                            match rec.as_ref() {
                                                Some(r) => {
                                                    r.policy.on_failure(&FailureCtx {
                                                        lane: dev,
                                                        attempt: fl.attempt,
                                                        lane_consecutive_failures:
                                                            st.consec_failures,
                                                        kind,
                                                    })
                                                }
                                                None => RecoveryAction::FailFast,
                                            }
                                        };
                                        match action {
                                            RecoveryAction::FailFast => {
                                                let now =
                                                    epoch.elapsed().as_secs_f64();
                                                for sub in &subs {
                                                    if !sub.done.is_complete() {
                                                        sub.done.complete(now);
                                                    }
                                                }
                                                match payload {
                                                    Some(p) => {
                                                        std::panic::resume_unwind(p)
                                                    }
                                                    None => panic!(
                                                        "device {dev} fault after \
                                                         {} attempt(s): {message}",
                                                        fl.attempt
                                                    ),
                                                }
                                            }
                                            RecoveryAction::Retry { backoff } => {
                                                st.stats.n_retries += 1;
                                                // Park the group on the
                                                // deadline wheel instead of
                                                // sleeping: planning for
                                                // every other device
                                                // continues through the
                                                // backoff. `retry_due`
                                                // keeps this device out of
                                                // the idle path (and the
                                                // watchdog stays off:
                                                // `inflight` is None until
                                                // re-dispatch).
                                                let due =
                                                    Instant::now() + backoff;
                                                st.retry_due = Some(due);
                                                retry_wheel.push(Reverse(
                                                    RetryEntry {
                                                        due,
                                                        dev,
                                                        pred: fl.pred,
                                                        attempt: fl.attempt + 1,
                                                        subs,
                                                    },
                                                ));
                                            }
                                            RecoveryAction::Quarantine => {
                                                if breaker.trip() {
                                                    st.stats.n_quarantine_trips += 1;
                                                }
                                                shed_and_reset(
                                                    st,
                                                    lanes.lane(dev),
                                                    subs,
                                                );
                                            }
                                        }
                                    }
                                }
                            }
                            Err(mpsc::TryRecvError::Empty) => {
                                let st = &mut states[dev];
                                let fl = st.inflight.as_mut().expect("inflight set");
                                if !fl.timed_out
                                    && fl.deadline.is_some_and(|dl| Instant::now() >= dl)
                                {
                                    fl.timed_out = true;
                                    st.stats.n_timeouts += 1;
                                    if health.lane(dev).trip() {
                                        st.stats.n_quarantine_trips += 1;
                                    }
                                    shed_and_reset(st, lanes.lane(dev), Vec::new());
                                    progressed = true;
                                }
                            }
                            Err(mpsc::TryRecvError::Disconnected) => {
                                unreachable!("fleet device runner exited early")
                            }
                        }
                    }

                    // 2. Ingress: drain a batch of arrivals and place it
                    //    *jointly* on calibrated-ECT frontiers — one grid
                    //    scan over cached per-device frontier resumes,
                    //    fanned across the scoring pool, then the best of
                    //    {frozen greedy, extending greedy, extending LPT}
                    //    on a replayed model clock (`BatchPlacer`).
                    if !closed_ingress {
                        match ingress.drain_into_timeout(
                            place_batch,
                            Duration::ZERO,
                            Duration::ZERO,
                            &mut arrivals,
                        ) {
                            DrainPoll::Drained(_) => {
                                progressed = true;
                                let n = arrivals.len();
                                batch_tasks.clear();
                                batch_tasks
                                    .extend(arrivals.iter().map(|s| s.task.clone()));
                                // Per-device batch table + cached frontier:
                                // committed cursor plus the uncommitted
                                // incumbent suffix, resumed once per round
                                // (the placer's stripes re-resume from
                                // these, never from the live states).
                                for (dev, st) in states.iter_mut().enumerate() {
                                    batch_avail[dev] = !health.is_quarantined(dev);
                                    if !batch_avail[dev] {
                                        continue;
                                    }
                                    st.probe_table.compile_calibrated_into(
                                        &batch_tasks,
                                        &st.cal_prof,
                                    );
                                    batch_elapsed[dev] = if st.planner_live {
                                        batch_frontiers[dev].resume_from(&st.cursor);
                                        for &i in &st.incumbent {
                                            batch_frontiers[dev]
                                                .push_task_compiled(&st.table, i);
                                        }
                                        st.live_since.elapsed().as_secs_f64()
                                    } else {
                                        batch_frontiers[dev].reset_for_table(
                                            &st.probe_table,
                                            EngineState::default(),
                                        );
                                        0.0
                                    };
                                }
                                let tables: Vec<&TaskTable> =
                                    states.iter().map(|st| &st.probe_table).collect();
                                let placed = placer.place_batch(
                                    n,
                                    &tables,
                                    &batch_frontiers,
                                    &batch_elapsed,
                                    &batch_avail,
                                    self.opts.prune_placement,
                                    &mut assignment,
                                );
                                let placed_at = epoch.elapsed().as_secs_f64();
                                match placed {
                                    Some(_) => {
                                        n_place_rounds += 1;
                                        for (k, sub) in
                                            arrivals.drain(..).enumerate()
                                        {
                                            placement_latencies.push(
                                                placed_at - sub.submitted_at,
                                            );
                                            lanes.push_to_lane(assignment[k], sub);
                                            n_placements += 1;
                                        }
                                    }
                                    None => {
                                        // The whole fleet is breaker-Open.
                                        // Round-robin: the backlog parks on
                                        // quarantined queues where half-open
                                        // probes or recovered thieves rescue
                                        // it.
                                        debug_assert_eq!(
                                            health.n_quarantined(),
                                            d
                                        );
                                        for sub in arrivals.drain(..) {
                                            let dev = rr_fallback % d;
                                            rr_fallback = dev + 1;
                                            placement_latencies.push(
                                                placed_at - sub.submitted_at,
                                            );
                                            lanes.push_to_lane(dev, sub);
                                            n_placements += 1;
                                        }
                                    }
                                }
                            }
                            DrainPoll::Empty => {}
                            DrainPoll::Closed => closed_ingress = true,
                        }
                    }

                    // 3. Service every device: busy devices absorb their
                    //    queue into the uncommitted suffix and overlap
                    //    planning; idle devices submit, drain, or steal.
                    for dev in 0..d {
                        // Parked on the retry wheel: the failed group is
                        // coming back, so the device is neither idle (no
                        // submit/steal) nor watchable (nothing on the
                        // device). Its queue stays visible to thieves.
                        if states[dev].retry_due.is_some() {
                            continue;
                        }
                        let breaker = health.lane(dev);
                        if states[dev].inflight.is_some() {
                            let st = &mut states[dev];
                            if breaker.state() == BreakerState::Closed {
                                let room = cap.saturating_sub(st.pending_subs.len());
                                if room > 0 {
                                    if let DrainPoll::Drained(_) =
                                        lanes.lane(dev).drain_into_timeout(
                                            room,
                                            Duration::ZERO,
                                            Duration::ZERO,
                                            &mut arrivals,
                                        )
                                    {
                                        merge_into_device(st, &mut arrivals, true);
                                        progressed = true;
                                    }
                                }
                            }
                            if st.plan_dirty {
                                finalize_device_plan(
                                    st,
                                    self.opts.policy,
                                    &self.opts.online,
                                );
                                progressed = true;
                            }
                            continue;
                        }
                        // Idle + quarantined: admit the half-open probe
                        // after cooldown; while Open this device plans
                        // nothing — its queue belongs to the thieves.
                        if breaker.state() == BreakerState::Open {
                            match rec.as_ref() {
                                Some(r) => {
                                    if breaker.try_half_open(r.quarantine.cooldown) {
                                        states[dev].stats.n_halfopen_probes += 1;
                                        progressed = true;
                                    } else {
                                        continue;
                                    }
                                }
                                // Breakers only trip with recovery armed.
                                None => {}
                            }
                        }
                        // Idle with a pending plan: commit and submit it
                        // (the online lane's submit block verbatim).
                        if !states[dev].pending_subs.is_empty() {
                            let st = &mut states[dev];
                            if st.plan_dirty {
                                finalize_device_plan(
                                    st,
                                    self.opts.policy,
                                    &self.opts.online,
                                );
                            }
                            let mut taken: Vec<Option<Submission>> =
                                std::mem::take(&mut st.pending_subs)
                                    .into_iter()
                                    .map(Some)
                                    .collect();
                            let ordered_subs: Vec<Submission> = st
                                .incumbent
                                .iter()
                                .map(|&i| {
                                    taken[i]
                                        .take()
                                        .expect("incumbent is a permutation")
                                })
                                .collect();
                            for &i in st.incumbent.iter() {
                                st.cursor.push_task_compiled(&st.table, i);
                            }
                            st.cursor.commit_frontier();
                            let contribution =
                                (st.pred_done - st.last_commit_pred).max(0.0);
                            st.last_commit_pred = st.pred_done;
                            st.inflight = Some(InFlight {
                                pred: contribution,
                                deadline: deadline_at(rec.as_ref(), contribution),
                                attempt: 1,
                                timed_out: false,
                            });
                            if let Err(mpsc::SendError(subs)) =
                                job_txs[dev].send(ordered_subs)
                            {
                                // Runner thread died: unblock the group's
                                // workers before surfacing the failure.
                                let now = epoch.elapsed().as_secs_f64();
                                for sub in &subs {
                                    if !sub.done.is_complete() {
                                        sub.done.complete(now);
                                    }
                                }
                                panic!("device {dev} runner died mid-commit");
                            }
                            if st.calibrator.is_some() {
                                st.calib_probe
                                    .reset_for_table(&st.table, EngineState::default());
                                for &i in st.incumbent.iter() {
                                    st.calib_probe.push_task_compiled(&st.table, i);
                                }
                                st.calib_probe.run_to_quiescence();
                                fold_timeline_stage_secs(
                                    st.incumbent.len(),
                                    st.calib_probe.timeline(),
                                    &mut st.inflight_pred,
                                );
                            }
                            st.pending_tasks.clear();
                            st.incumbent.clear();
                            st.suffix_planned = false;
                            progressed = true;
                            continue;
                        }
                        // Fully idle: the contiguous timeline ends — the
                        // only point a corrected model may be adopted.
                        {
                            let st = &mut states[dev];
                            st.planner_live = false;
                            if let Some(cal) = st.calibrator.as_mut() {
                                if let Some(c) = cal.adopt() {
                                    st.cal_prof =
                                        CalibratedProfile::new(&st.base_model, c);
                                    st.stats.n_recalibrations += 1;
                                }
                            }
                            if let DrainPoll::Drained(_) =
                                lanes.lane(dev).drain_into_timeout(
                                    cap,
                                    Duration::ZERO,
                                    Duration::ZERO,
                                    &mut arrivals,
                                )
                            {
                                merge_into_device(st, &mut arrivals, false);
                                progressed = true;
                                continue;
                            }
                        }
                        // Own queue dry: try a calibrated cross-device
                        // steal. A quarantined victim's backlog is
                        // rescued unconditionally; a healthy victim's
                        // work moves only on a predicted strict win.
                        if self.opts.online.steal_max > 0
                            && breaker.state() == BreakerState::Closed
                        {
                            stolen.clear();
                            let max = self.opts.online.steal_max.min(cap);
                            if let Some(tr) = lanes.steal_with_health_traced(
                                dev,
                                max,
                                &health,
                                &mut stolen,
                            ) {
                                if tr.quarantined {
                                    let st = &mut states[dev];
                                    merge_into_device(st, &mut stolen, false);
                                    st.stats.n_stolen += tr.n;
                                    progressed = true;
                                } else {
                                    n_steal_considered += 1;
                                    // The victim's predicted remaining
                                    // horizon for everything it has
                                    // planned, wall-normalized. Its own
                                    // queue backlog is not in the
                                    // horizon — conservative in the
                                    // right direction (a busier victim
                                    // is easier to beat, so an accept
                                    // is still an accept).
                                    let victim_remaining = {
                                        let v = &states[tr.victim];
                                        if v.planner_live {
                                            (v.pred_done
                                                - v.live_since
                                                    .elapsed()
                                                    .as_secs_f64())
                                            .max(0.0)
                                        } else {
                                            0.0
                                        }
                                    };
                                    let st = &mut states[dev];
                                    let loot: Vec<TaskSpec> = stolen
                                        .iter()
                                        .map(|s| s.task.clone())
                                        .collect();
                                    st.probe_table.compile_calibrated_into(
                                        &loot,
                                        &st.cal_prof,
                                    );
                                    let elapsed = if st.planner_live {
                                        frontier_buf.resume_from(&st.cursor);
                                        for &i in &st.incumbent {
                                            frontier_buf
                                                .push_task_compiled(&st.table, i);
                                        }
                                        st.live_since.elapsed().as_secs_f64()
                                    } else {
                                        frontier_buf.reset_for_table(
                                            &st.probe_table,
                                            EngineState::default(),
                                        );
                                        0.0
                                    };
                                    let rows: Vec<usize> = (0..stolen.len()).collect();
                                    let win = steal_predicts_win(
                                        &mut probe,
                                        &frontier_buf,
                                        &st.probe_table,
                                        &rows,
                                        victim_remaining + elapsed,
                                        &mut placement_prune,
                                    );
                                    if win {
                                        st.stats.n_stolen += tr.n;
                                        merge_into_device(st, &mut stolen, false);
                                        progressed = true;
                                    } else {
                                        n_steal_rejected += 1;
                                        lanes
                                            .lane(tr.victim)
                                            .requeue_front(&mut stolen);
                                    }
                                }
                            }
                        }
                    }

                    // 4. Termination: stream closed and every queue,
                    //    suffix, device and parked retry drained.
                    if closed_ingress
                        && lanes.is_empty()
                        && retry_wheel.is_empty()
                        && states.iter().all(|st| {
                            st.pending_subs.is_empty() && st.inflight.is_none()
                        })
                    {
                        lanes.close_all();
                        break;
                    }
                    // Idle edge: park until a producer notifies (ingress
                    // push, RunDone, close) instead of sleeping a fixed
                    // poll. The deadline — the nearest retry due-time,
                    // bounded by `poll` — keeps purely time-driven work
                    // (wheel expiry, breaker cooldowns, the watchdog)
                    // flowing with no producer awake.
                    if !progressed {
                        let mut deadline = Instant::now() + self.opts.online.poll;
                        if let Some(Reverse(e)) = retry_wheel.peek() {
                            deadline = deadline.min(e.due);
                        }
                        wake.wait_past(wake_seen, deadline);
                    }
                }
            }));
            drop(job_txs);
            if let Err(payload) = result {
                // Liveness before failure, as in the lane proxies:
                // complete every unsignalled event and keep absorbing
                // the ingress until all workers exited, then surface
                // the panic. With `done_rxs` dropped, the runners
                // complete their own fault groups' events (the
                // failed-send path of `device_runner_loop`).
                drop(done_rxs);
                let now = epoch.elapsed().as_secs_f64();
                for st in &states {
                    for sub in &st.pending_subs {
                        if !sub.done.is_complete() {
                            sub.done.complete(now);
                        }
                    }
                }
                // Groups parked on the retry wheel hold un-completed
                // events (their fault returned the subs for re-dispatch);
                // no re-dispatch is coming — release their workers.
                for Reverse(e) in retry_wheel.drain() {
                    for sub in &e.subs {
                        if !sub.done.is_complete() {
                            sub.done.complete(now);
                        }
                    }
                }
                loop {
                    let now = epoch.elapsed().as_secs_f64();
                    for sub in arrivals.drain(..).chain(stolen.drain(..)) {
                        if !sub.done.is_complete() {
                            sub.done.complete(now);
                        }
                    }
                    for l in 0..d {
                        lanes.lane(l).take_into(usize::MAX, &mut arrivals);
                    }
                    if !arrivals.is_empty() {
                        continue;
                    }
                    if ingress.drain_into(place_batch, Duration::ZERO, &mut arrivals)
                        .is_none()
                    {
                        break;
                    }
                }
                std::panic::resume_unwind(payload);
            }
        });

        let total_secs = epoch.elapsed().as_secs_f64();
        // Grid-scan + trial pruning lives in the placer; the steal
        // predicate wrote `placement_prune` directly.
        placement_prune.merge(&placer.prune_counters());
        let mut per_device = Vec::with_capacity(d);
        let (mut overhead, mut n_groups, mut n_tasks) = (0.0, 0, 0);
        for st in states.iter_mut() {
            let (fired, considered) = st.gate.counts();
            st.stats.n_replans = fired;
            st.stats.n_replan_considered = considered;
            let pc = st.scratch.prune_counters();
            st.stats.n_cands_pruned = pc.n_cands_pruned;
            st.stats.n_rollouts_early_exit = pc.n_rollouts_early_exit;
            st.stats.n_twin_collapsed = pc.n_twin_collapsed;
            record_calib_stats(&mut st.stats, st.calibrator.as_ref());
            overhead += st.stats.sched_overhead_secs;
            n_groups += st.stats.n_groups;
            n_tasks += st.stats.n_tasks;
        }
        for st in states {
            per_device.push(st.stats);
        }
        let admission = ctl.map(|c| c.report(&latencies, &latency_tenants));
        FleetMetrics {
            total_secs,
            tasks_per_sec: n_tasks as f64 / total_secs,
            latencies,
            latency_tenants,
            group_makespans,
            sched_overhead_secs: overhead,
            n_groups,
            n_tasks,
            per_device,
            n_placements,
            placement_prune,
            n_steal_considered,
            n_steal_rejected,
            placement_latencies,
            n_place_rounds,
            admission,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;
    use crate::device::simdev::SimDevice;
    use crate::task::synthetic::synthetic_benchmark;

    fn workload(t: usize, n: usize, scale: f64) -> Vec<Vec<TaskSpec>> {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, scale).unwrap();
        (0..t)
            .map(|w| (0..n).map(|i| g.tasks[(w + i) % 4].clone()).collect())
            .collect()
    }

    fn sim_fleet(profiles: &[&str], opts: FleetCoordOptions) -> FleetCoordinator {
        let devices: Vec<Arc<dyn Device>> = profiles
            .iter()
            .map(|name| {
                Arc::new(SimDevice::new(profile_by_name(name).unwrap()))
                    as Arc<dyn Device>
            })
            .collect();
        FleetCoordinator::with_devices(devices, opts)
    }

    #[test]
    fn heterogeneous_fleet_completes_all_tasks() {
        let c = sim_fleet(
            &["amd_r9", "xeon_phi", "k20c"],
            FleetCoordOptions::default(),
        );
        let m = c.run(workload(6, 3, 0.1));
        assert_eq!(m.n_tasks, 18);
        assert_eq!(m.latencies.len(), 18);
        assert_eq!(m.per_device.len(), 3);
        assert_eq!(m.per_device.iter().map(|l| l.n_tasks).sum::<usize>(), 18);
        assert_eq!(m.n_placements, 18);
        assert!(m.tasks_per_sec > 0.0);
    }

    #[test]
    fn single_device_fleet_terminates_and_counts() {
        let c = sim_fleet(&["amd_r9"], FleetCoordOptions::default());
        let m = c.run(workload(3, 2, 0.1));
        assert_eq!(m.n_tasks, 6);
        assert_eq!(m.n_placements, 6);
        assert_eq!(m.per_device.len(), 1);
        assert_eq!(m.n_stolen(), 0, "nobody to steal from");
    }

    #[test]
    fn empty_workload_terminates() {
        let c = sim_fleet(&["amd_r9", "k20c"], FleetCoordOptions::default());
        let m = c.run(Vec::new());
        assert_eq!(m.n_tasks, 0);
        assert_eq!(m.n_groups, 0);
        assert_eq!(m.n_placements, 0);
    }

    #[test]
    #[should_panic(expected = "need at least one device")]
    fn empty_fleet_panics() {
        FleetCoordinator::with_devices(Vec::new(), FleetCoordOptions::default());
    }

    #[test]
    #[should_panic(expected = "one plan model per device")]
    fn mismatched_plan_models_panic() {
        sim_fleet(&["amd_r9", "k20c"], FleetCoordOptions::default())
            .with_plan_models(vec![profile_by_name("amd_r9").unwrap()]);
    }

    #[test]
    #[should_panic(expected = "place_batch must be >= 1")]
    fn zero_place_batch_rejected() {
        let c = sim_fleet(
            &["amd_r9"],
            FleetCoordOptions { place_batch: 0, ..FleetCoordOptions::default() },
        );
        c.run(workload(1, 1, 0.1));
    }

    #[test]
    fn small_place_batch_and_parallel_scoring_complete_all_tasks() {
        // place_batch=1 degenerates to per-arrival greedy; 2 exercises
        // partial drains; parallel stripes exercise the scoring pool.
        for (batch, threads) in [(1usize, 1usize), (2, 1), (2, 3), (usize::MAX, 3)] {
            let c = sim_fleet(
                &["amd_r9", "xeon_phi", "k20c"],
                FleetCoordOptions {
                    place_batch: batch,
                    placement_threads: threads,
                    ..FleetCoordOptions::default()
                },
            );
            let m = c.run(workload(6, 3, 0.1));
            assert_eq!(m.n_tasks, 18, "batch {batch} threads {threads}");
            assert_eq!(m.n_placements, 18, "batch {batch} threads {threads}");
            assert!(m.n_place_rounds > 0, "batch {batch} threads {threads}");
            assert_eq!(
                m.placement_latencies.len(),
                18,
                "every routed submission gets a measured placement latency"
            );
            assert!(m.placement_latencies.iter().all(|&l| l >= 0.0));
            assert!(m.placement_p99_s() >= m.placement_p50_s());
        }
    }

    #[test]
    fn fleet_retries_transient_device_error_to_completion() {
        use crate::coordinator::recovery::RetryBackoff;
        use crate::device::{ChaosDevice, ChaosOptions};

        let p = profile_by_name("amd_r9").unwrap();
        // One flaky device in a fleet of two: every first attempt of a
        // faulting group errors, the immediate re-run is clean — the
        // retry policy must absorb it without losing a task.
        let flaky: Arc<dyn Device> = Arc::new(ChaosDevice::new(
            Arc::new(SimDevice::new(p)),
            ChaosOptions {
                seed: 0xf1ee7,
                p_error: 0.8,
                transient: true,
                ..ChaosOptions::default()
            },
        ));
        let steady: Arc<dyn Device> =
            Arc::new(SimDevice::new(profile_by_name("k20c").unwrap()));
        let c = FleetCoordinator::with_devices(
            vec![flaky, steady],
            FleetCoordOptions {
                recovery: Some(RecoveryOptions::retry(RetryBackoff {
                    base: Duration::from_micros(50),
                    cap: Duration::from_micros(200),
                    ..RetryBackoff::default()
                })),
                ..FleetCoordOptions::default()
            },
        );
        let m = c.run(workload(4, 3, 0.1));
        assert_eq!(m.n_tasks, 12, "all tasks complete despite faults");
        assert_eq!(m.latencies.len(), 12);
        let retries: usize = m.per_device.iter().map(|l| l.n_retries).sum();
        let faults: usize = m.per_device.iter().map(|l| l.n_faults).sum();
        assert_eq!(retries, faults, "every fault was retried");
        assert_eq!(
            m.per_device.iter().map(|l| l.n_quarantine_trips).sum::<usize>(),
            0
        );
    }

    #[test]
    fn admission_armed_fleet_accounts_every_submission() {
        use crate::coordinator::admission::{
            AdmissionOptions, DrainPolicyKind, Overflow, Priority, TenantId,
        };
        let c = sim_fleet(
            &["amd_r9", "k20c"],
            FleetCoordOptions {
                admission: Some(AdmissionOptions {
                    per_tenant_cap: 1,
                    overflow: Overflow::ShedLowest,
                    policy: DrainPolicyKind::StrictPriority,
                    ..AdmissionOptions::default()
                }),
                ..FleetCoordOptions::default()
            },
        );
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 0.1).unwrap();
        // Two hi-priority tenants (one worker each, so their one-deep
        // outstanding never hits the cap of 1) + four best-effort workers
        // all submitting as tenant 9 over that same cap: any overflow
        // sheds best-effort, and accepted work is never lost — the
        // completion/shed ledger must account for every submission.
        let mut workloads = Vec::new();
        for w in 0..2u32 {
            workloads.push(TenantWorkload {
                tenant: TenantId(w),
                class: Priority::Hi,
                deadline: None,
                tasks: (0..3)
                    .map(|i| g.tasks[(w as usize + i) % 4].clone())
                    .collect(),
            });
        }
        for w in 0..4usize {
            workloads.push(TenantWorkload {
                tenant: TenantId(9),
                class: Priority::BestEffort,
                deadline: None,
                tasks: (0..3).map(|i| g.tasks[(w + i) % 4].clone()).collect(),
            });
        }
        let total = 6 * 3;
        let m = c.run_tenants(workloads);
        let rep = m.admission.as_ref().expect("armed run carries a report");
        assert_eq!(
            m.n_tasks + rep.n_shed,
            total,
            "every submission completes exactly once or sheds: {rep:?}"
        );
        assert_eq!(m.latencies.len(), m.n_tasks);
        assert_eq!(m.latency_tenants.len(), m.n_tasks);
        // A hi tenant never sheds: its single worker fits its cap, a
        // tenant-cap eviction only targets the overflowing tenant, and
        // nothing outranks Hi for a global-cap eviction.
        for t in &rep.per_tenant {
            if t.tenant != 9 {
                assert_eq!(t.n_shed, 0, "{t:?}");
                assert_eq!(t.n_completed, 3, "{t:?}");
            }
        }
    }
}
