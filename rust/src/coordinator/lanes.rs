//! The multi-lane coordinator — the Fig. 8 proxy runtime sharded so the
//! *scheduler* scales with the host, not just the device.
//!
//! The single-buffer coordinator (`coordinator::runner`) serializes every
//! drained task group through one proxy thread: reorder, submit, signal,
//! repeat. Table 6's premise — reordering overhead stays negligible while
//! task groups keep arriving — breaks on a many-core host the moment one
//! proxy becomes the bottleneck. This module splits the pipeline into
//! `L` independent **lanes**:
//!
//! * worker `w` always submits to lane `w % L`
//!   ([`ShardedBuffer`]), so each worker's dependent batch drains in
//!   order through one lane — per-worker submission order is preserved by
//!   construction, exactly the guarantee the single buffer gave;
//! * each lane runs its own proxy thread with a **batched drain**
//!   (`drain_into` into a reused Vec, up to `group_cap` submissions per
//!   task group), its own reorder arena ([`ParBeamScratch`], so big
//!   groups can additionally fan candidate scoring out over
//!   `scoring_threads` stripes), and its own virtual device — independent
//!   task groups are reordered and executed concurrently on different
//!   lanes;
//! * each lane keeps a persistent paused [`SimCursor`] + [`TaskTable`]
//!   pair: the group is compiled **once** per drain and shared between
//!   the search and the prediction bookkeeping (the heuristic's own
//!   chosen-order makespan is recorded directly; NoReorder drains are
//!   replayed through the lane cursor, allocation-free once warm) — the
//!   per-lane prediction drift is reported in [`LaneStats`].
//!
//! # Online rescheduling ([`LaneOptions::online`])
//!
//! With `online: Some(..)` a lane runs the **open-stream** pipeline
//! instead of drain-then-plan: device execution moves to a per-lane
//! runner thread, and while a committed group executes the proxy keeps
//! draining. Arrivals are merged into the *uncommitted suffix* of the
//! lane's plan rather than queued for a fresh round, and the suffix is
//! re-planned through `sched::online::replan_into` — an incremental beam
//! search seeded from the committed prefix's paused cursor state. The
//! *initial* plan of each fresh suffix always runs; *re*-plans of an
//! already-optimized suffix are admitted by the [`DriftGate`] on the
//! lane's predicted-vs-measured drift (default threshold `0.0` re-plans
//! on every suffix change; raise it to trade re-plan quality for Table-6
//! overhead headroom). The lane's planning
//! cursor is *contiguous across rounds*: submitting a group calls
//! [`SimCursor::commit_frontier`] and the next group is planned on the
//! same timeline via `EngineState` carry, so back-to-back groups are
//! simulated as one busy-device stream instead of restarting from idle;
//! the timeline resets only when the lane goes fully idle (nothing
//! pending, nothing in flight — the physical device has drained). The
//! systematic gap between the contiguous model and the per-group device
//! restart is exactly what [`LaneStats`] drift records and the gate
//! consumes.
//!
//! # Online recalibration ([`LaneOptions::recalibrate`])
//!
//! With `recalibrate: Some(..)` a lane closes the model-accuracy loop the
//! drift gate only *measures*: each executed group's per-command device
//! timeline is folded into per-task measured engine times and fed to a
//! `model::calibrate::Calibrator` (robust per-engine EWMA over
//! implied-rate residuals, outlier-clipped, warm-up-gated). Matured
//! corrections are **adopted atomically at planning-timeline
//! boundaries** — the legacy proxy adopts per drained group, the online
//! proxy only when the lane goes fully idle and the contiguous carry
//! chain restarts — by rebuilding the lane's `CalibratedProfile`,
//! recompiling the pending table against it and rewinding the planning
//! cursor *from that table* ([`SimCursor::reset_for_table`]). Cursor and
//! table therefore always share one model generation, so the bound-gated
//! search's floors and rollouts keep their exactness proofs unchanged.
//! With `recalibrate: None` the pipeline is bit-identical to the
//! pre-calibration code (rust/tests/prop_calibrate.rs).
//! [`LaneCoordinator::with_plan_model`] decouples the planning model from
//! the device profile, which is how the online bench runs deliberately
//! miscalibrated models against a truthful device.
//!
//! # Fault tolerance ([`LaneOptions::recovery`])
//!
//! With `recovery: Some(..)` device-run faults stop being fatal: an
//! `Err` from [`Device::run_group`], a panic out of it, or a hang caught
//! by the run-deadline watchdog is routed through the configured
//! [`RecoveryPolicy`] (`coordinator::recovery`). Retries re-run the
//! *same committed group* on the same lane after a backoff; quarantine
//! trips the lane's circuit breaker ([`FleetHealth`]) — the lane
//! requeues its *unstarted* submissions to the front of its own buffer
//! (FIFO preserved) and stops draining, so idle siblings absorb the
//! backlog through [`ShardedBuffer::steal_with_health`] with the steal
//! bounds lifted; after the cooldown the lane re-probes half-open (the
//! next own-lane group decides: success closes the breaker, failure
//! re-opens it). Online runs additionally execute under a watchdog
//! deadline derived from the group's *predicted* makespan
//! (`predicted × slack + floor`); a deadline miss counts as a timeout
//! fault and quarantines the lane, while the overdue run's eventual
//! completion still unblocks its workers. Failed, retried and timed-out
//! runs **never** feed the [`DriftGate`] or the `Calibrator` — a
//! partial or skewed timeline would register as huge drift. All of it
//! is observable in [`LaneStats`] (`n_faults`, `n_retries`,
//! `n_timeouts`, `n_requeued`, `n_quarantine_trips`,
//! `n_halfopen_probes`). With `recovery: None` (default) any device
//! fault aborts the run — bit-identical to the pre-recovery pipeline.
//!
//! **Steal invariants** (bounded work-stealing, `OnlineOptions::steal_max`):
//! an idle lane steals *whole uncommitted submissions* from the hottest
//! sibling's buffer — never more than half the victim's backlog, never
//! its last entry, and never a task already committed to any device
//! (committed tasks are immovable by construction: stealing happens
//! strictly upstream of `commit_frontier`). Per-worker FIFO is preserved
//! unconditionally because a worker blocks on each submission's
//! completion event before submitting the next, so at most one of its
//! tasks exists anywhere in the system.
//!
//! [`CoordMetrics`]-style aggregates plus per-lane breakdowns come back
//! in [`LaneMetrics`]; `benches/coordinator_throughput.rs` sweeps
//! workers × lanes × group size over this runtime and emits
//! `BENCH_coordinator_throughput.json`, and `benches/online_resched.rs`
//! compares online vs drain-then-plan and emits
//! `BENCH_online_resched.json`.
//!
//! [`CoordMetrics`]: crate::coordinator::runner::CoordMetrics
//! [`ShardedBuffer`]: crate::coordinator::buffer::ShardedBuffer
//! [`ShardedBuffer::steal_with_health`]: crate::coordinator::buffer::ShardedBuffer::steal_with_health
//! [`DriftGate`]: crate::sched::online::DriftGate
//! [`Device::run_group`]: crate::device::Device::run_group
//! [`RecoveryPolicy`]: crate::coordinator::recovery::RecoveryPolicy
//! [`FleetHealth`]: crate::coordinator::recovery::FleetHealth

use std::sync::{mpsc, Arc, Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

use crate::config::DeviceProfile;
use crate::coordinator::admission::{
    AdmissionCtl, AdmissionGate, AdmissionOptions, AdmissionReport, Priority,
    ShedSlot, SubmitOutcome, TenantId,
};
use crate::coordinator::buffer::{DrainPoll, ShardedBuffer, SharedBuffer, Submission};
use crate::coordinator::driver::ConfigError;
use crate::coordinator::recovery::{
    BreakerState, FailureCtx, FaultKind, FleetHealth, LaneBreaker,
    RecoveryAction, RecoveryOptions,
};
use crate::coordinator::runner::Policy;
use crate::device::executor::KernelExecutor;
use crate::device::vdev::VirtualDevice;
use crate::device::{Device, DeviceRun};
use crate::model::{
    fold_timeline_stage_secs, CalibrateOptions, CalibratedProfile, Calibrator,
    CmdRecord, EngineSecs, EngineState, SimCursor, TaskTable,
};
use crate::queue::event::Event;
use crate::sched::heuristic::DEFAULT_BEAM_WIDTH;
use crate::sched::online::{replan_into, DriftGate, OnlineOptions, OnlineScratch};
use crate::sched::parallel::{batch_reorder_table_parallel_into, ParBeamScratch};
use crate::task::TaskSpec;
use crate::util::stats;

/// Knobs of the sharded runtime.
#[derive(Clone, Debug)]
pub struct LaneOptions {
    /// Lane count for [`LaneCoordinator::homogeneous`] (ignored by
    /// [`LaneCoordinator::with_devices`], which derives it from the
    /// device list).
    pub lanes: usize,
    pub policy: Policy,
    /// Proxy settle window while forming a task group (how long a lane
    /// waits for stragglers once something is buffered).
    pub settle: Duration,
    /// Max submissions drained per task group (the batched-drain size).
    /// 0 = one full round of the lane's workers: `ceil(T / lanes)`.
    pub group_cap: usize,
    /// Scoring stripes per lane reorder (1 = serial candidate scoring).
    /// Applies to the classic drain-then-plan path only: online suffix
    /// re-plans (`online: Some(..)`) are deliberately serial — suffixes
    /// are small and re-plans already overlap device execution, so pool
    /// dispatch would cost more than it saves.
    pub scoring_threads: usize,
    /// `Some` switches the lane to the online open-stream pipeline
    /// (mid-group merge + drift-gated suffix re-planning + bounded
    /// work-stealing); `None` keeps the classic drain-then-plan rounds.
    pub online: Option<OnlineOptions>,
    /// `Some` feeds each executed group's measured per-engine times back
    /// into the lane's planning model (`model::calibrate`): robust EWMA
    /// rate corrections are *adopted* only at planning-timeline
    /// boundaries — the table recompile and the cursor rewind happen from
    /// one [`CalibratedProfile`] generation, so the bound-gated search's
    /// exactness proofs apply unchanged. `None` (the default) keeps the
    /// static model, bit-identical to the pre-calibration pipeline
    /// (pinned by rust/tests/prop_calibrate.rs).
    pub recalibrate: Option<CalibrateOptions>,
    /// `Some` arms fault tolerance (see the module docs and
    /// `coordinator::recovery`): device-run faults route through the
    /// pluggable [`RecoveryPolicy`], online runs execute under the
    /// run-deadline watchdog, and quarantined lanes hand their backlog
    /// to healthy siblings. `None` (the default) keeps today's behavior
    /// bit-identical: any device fault aborts the coordinator run.
    ///
    /// [`RecoveryPolicy`]: crate::coordinator::recovery::RecoveryPolicy
    pub recovery: Option<RecoveryOptions>,
    /// `Some` arms multi-tenant admission control
    /// (`coordinator::admission`): bounded per-tenant backlogs, the
    /// configured overflow policy at the submit gate, policy-ordered
    /// drains, and per-tenant telemetry in
    /// [`LaneMetrics::admission`]. `None` (the default) keeps the
    /// untracked unbounded pipeline bit-for-bit.
    pub admission: Option<AdmissionOptions>,
}

impl Default for LaneOptions {
    fn default() -> Self {
        LaneOptions {
            lanes: 1,
            policy: Policy::Heuristic,
            settle: Duration::from_micros(300),
            group_cap: 0,
            scoring_threads: 1,
            online: None,
            recalibrate: None,
            recovery: None,
            admission: None,
        }
    }
}

impl LaneOptions {
    /// Check every knob — including nested online / recovery / admission
    /// config — and return the first offender as a typed [`ConfigError`].
    /// This is the opt-in front door used by `DriverBuilder::build` and
    /// the trace service; field-struct literals keep working unvalidated,
    /// exactly as before.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.lanes == 0 {
            return Err(ConfigError::new("lanes", "must be >= 1"));
        }
        if self.scoring_threads == 0 {
            return Err(ConfigError::new("scoring_threads", "must be >= 1"));
        }
        if let Some(online) = &self.online {
            validate_online(online)?;
        }
        if let Some(recovery) = &self.recovery {
            recovery.validate()?;
        }
        if let Some(admission) = &self.admission {
            admission.validate()?;
        }
        Ok(())
    }
}

/// Shared [`OnlineOptions`] check for both coordinators' validators (the
/// struct lives in `sched::online`, which stays config-error-agnostic).
pub(crate) fn validate_online(o: &OnlineOptions) -> Result<(), ConfigError> {
    if !o.drift_threshold.is_finite() || o.drift_threshold < 0.0 {
        return Err(ConfigError::new(
            "online.drift_threshold",
            format!("must be finite and >= 0, got {}", o.drift_threshold),
        ));
    }
    if o.replan_width == 0 {
        return Err(ConfigError::new("online.replan_width", "must be >= 1"));
    }
    Ok(())
}

/// Per-lane breakdown of one run.
#[derive(Clone, Debug)]
pub struct LaneStats {
    pub lane: usize,
    pub n_groups: usize,
    pub n_tasks: usize,
    /// CPU seconds this lane's proxy spent inside the reorder heuristic.
    pub sched_overhead_secs: f64,
    /// Device-measured busy seconds (sum of group makespans).
    pub busy_secs: f64,
    /// Model-predicted busy seconds for the same orders (paused-cursor
    /// replay); `busy_secs / predicted_secs` is the lane's pacing drift.
    pub predicted_secs: f64,
    /// Online mode: mid-group merge events (arrivals appended to a live
    /// plan — a non-empty suffix or a group in flight). 0 in legacy mode.
    pub n_merges: usize,
    /// Online mode: suffix re-plans fired by the drift gate.
    pub n_replans: usize,
    /// Online mode: gate consultations (changed suffixes eligible for a
    /// re-plan); `n_replans / n_replan_considered` is the gate fire rate.
    pub n_replan_considered: usize,
    /// Online mode: submissions stolen *into* this lane from hotter
    /// siblings' buffers.
    pub n_stolen: usize,
    /// Online mode: wall seconds of each fired re-plan (the online bench
    /// reports p50/p99). Also accumulated into `sched_overhead_secs`.
    pub replan_secs: Vec<f64>,
    /// Candidates the bound-gated search layer skipped outright (static
    /// admissible floor above the admission cutoff).
    pub n_cands_pruned: u64,
    /// Candidate rollouts aborted mid-simulation by the clock cutoff.
    pub n_rollouts_early_exit: u64,
    /// Candidates that reused a spec-twin representative's score (serial
    /// collapse or transposition-memo hit) instead of simulating.
    pub n_twin_collapsed: u64,
    /// Recalibration: corrected-model generations this lane adopted
    /// (0 with `LaneOptions::recalibrate: None`).
    pub n_recalibrations: usize,
    /// Recalibration: accepted per-engine residual observations.
    pub n_calib_obs: u64,
    /// Recalibration: observations whose residual hit the clip bound.
    pub n_calib_clipped: u64,
    /// Recalibration: the correction factors the lane's model carried at
    /// shutdown (`1.0` each when recalibration is off or never adopted;
    /// > 1 = the engine runs slower than the base model claimed).
    pub calib_htd: f64,
    pub calib_kernel: f64,
    pub calib_dth: f64,
    /// Recovery: failed device runs (error, panic or watchdog timeout)
    /// this lane observed. 0 with `LaneOptions::recovery: None`.
    pub n_faults: usize,
    /// Recovery: same-lane re-runs of a failed group (includes the
    /// legacy path's quarantine re-probes of the held group).
    pub n_retries: usize,
    /// Recovery: runs declared dead by the run-deadline watchdog.
    pub n_timeouts: usize,
    /// Recovery: submissions handed back to the lane's buffer front on
    /// quarantine (unstarted work made visible to siblings).
    pub n_requeued: usize,
    /// Recovery: Closed → Open breaker transitions (re-trips of an
    /// already-open breaker are not counted).
    pub n_quarantine_trips: usize,
    /// Recovery: Open → HalfOpen probe admissions after cooldown.
    pub n_halfopen_probes: usize,
    /// Admission: submissions whose compiled row signature was
    /// byte-identical to an earlier submission in the same drained batch
    /// (`TaskTable` spec twins, typically *across* tenants) and were
    /// therefore collapsed onto the representative's device slot instead
    /// of compiled and executed separately. 0 unless
    /// `AdmissionOptions::collapse_twins` is armed on the legacy path.
    pub n_xtenant_collapsed: u64,
}

/// Aggregate metrics of one sharded run (single-lane degenerates to the
/// classic [`CoordMetrics`] numbers; `runner::Coordinator` delegates).
///
/// [`CoordMetrics`]: crate::coordinator::runner::CoordMetrics
#[derive(Clone, Debug)]
pub struct LaneMetrics {
    pub total_secs: f64,
    /// Executed tasks per second — the paper's "tasks throughput".
    pub tasks_per_sec: f64,
    /// Per-task submission → completion latency (s), all lanes.
    pub latencies: Vec<f64>,
    /// Tenant id of each entry of `latencies` (index-aligned) — the
    /// per-tenant p50/p99 breakdown in [`LaneMetrics::admission`] joins
    /// on this.
    pub latency_tenants: Vec<u32>,
    /// Device busy time per group (s), all lanes.
    pub group_makespans: Vec<f64>,
    pub sched_overhead_secs: f64,
    pub n_groups: usize,
    pub n_tasks: usize,
    pub per_lane: Vec<LaneStats>,
    /// Per-tenant admission telemetry (`None` with `admission: None`).
    pub admission: Option<AdmissionReport>,
}

impl LaneMetrics {
    pub fn mean_latency(&self) -> f64 {
        stats::mean(&self.latencies)
    }

    pub fn p50_latency(&self) -> f64 {
        stats::percentile(&self.latencies, 50.0)
    }

    pub fn p99_latency(&self) -> f64 {
        stats::percentile(&self.latencies, 99.0)
    }

    /// Fraction of wall-clock the proxies spent scheduling (the Table-6
    /// "overhead share" extended to the multi-lane runtime).
    pub fn sched_overhead_share(&self) -> f64 {
        if self.total_secs <= 0.0 {
            return 0.0;
        }
        self.sched_overhead_secs / self.total_secs
    }
}

/// What one lane proxy hands back when its buffer closes.
struct LaneOutcome {
    stats: LaneStats,
    /// (tenant, submission → completion latency) per executed task.
    latencies: Vec<(u32, f64)>,
    group_makespans: Vec<f64>,
}

pub(crate) fn empty_lane_stats(lane: usize) -> LaneStats {
    LaneStats {
        lane,
        n_groups: 0,
        n_tasks: 0,
        sched_overhead_secs: 0.0,
        busy_secs: 0.0,
        predicted_secs: 0.0,
        n_merges: 0,
        n_replans: 0,
        n_replan_considered: 0,
        n_stolen: 0,
        replan_secs: Vec::new(),
        n_cands_pruned: 0,
        n_rollouts_early_exit: 0,
        n_twin_collapsed: 0,
        n_recalibrations: 0,
        n_calib_obs: 0,
        n_calib_clipped: 0,
        calib_htd: 1.0,
        calib_kernel: 1.0,
        calib_dth: 1.0,
        n_faults: 0,
        n_retries: 0,
        n_timeouts: 0,
        n_requeued: 0,
        n_quarantine_trips: 0,
        n_halfopen_probes: 0,
        n_xtenant_collapsed: 0,
    }
}

/// One tenant-attributed worker workload for
/// [`LaneCoordinator::run_tenants`] /
/// [`FleetCoordinator::run_tenants`](crate::coordinator::fleet::FleetCoordinator::run_tenants):
/// a dependent task batch submitted by one worker thread on behalf of
/// `tenant` at QoS class `class`.
#[derive(Clone, Debug)]
pub struct TenantWorkload {
    pub tenant: TenantId,
    pub class: Priority,
    /// Relative deadline applied to every task of this workload (secs
    /// from its submission instant), consulted by deadline-EDF draining.
    pub deadline: Option<f64>,
    pub tasks: Vec<TaskSpec>,
}

impl TenantWorkload {
    /// The untagged default the anonymous `run` path uses: one tenant
    /// per worker, `Normal` class, no deadline.
    pub fn for_worker(w: usize, tasks: Vec<TaskSpec>) -> Self {
        TenantWorkload {
            tenant: TenantId(w as u32),
            class: Priority::Normal,
            deadline: None,
            tasks,
        }
    }
}

/// The sharded multi-worker runtime (see module docs).
pub struct LaneCoordinator {
    devices: Vec<Arc<dyn Device>>,
    /// Planning model override: the profile the lane proxies *predict*
    /// with, decoupled from the device they execute on. `None` plans
    /// against each device's own profile (the pre-calibration behavior).
    plan_model: Option<DeviceProfile>,
    opts: LaneOptions,
}

impl LaneCoordinator {
    /// One lane per entry of `devices` (heterogeneous lanes allowed; each
    /// proxy schedules against its own device's profile).
    pub fn with_devices(devices: Vec<Arc<dyn Device>>, opts: LaneOptions) -> Self {
        assert!(!devices.is_empty(), "need at least one lane device");
        LaneCoordinator { devices, plan_model: None, opts }
    }

    /// `opts.lanes` identical lanes over copies of one profile/executor.
    pub fn homogeneous(
        profile: DeviceProfile,
        executor: Arc<dyn KernelExecutor>,
        opts: LaneOptions,
    ) -> Self {
        let devices = (0..opts.lanes.max(1))
            .map(|_| {
                Arc::new(VirtualDevice::new(profile.clone(), executor.clone()))
                    as Arc<dyn Device>
            })
            .collect();
        LaneCoordinator { devices, plan_model: None, opts }
    }

    /// Plan against `model` instead of each device's own profile — the
    /// fitted-model-vs-reality split online recalibration corrects for.
    /// The online bench uses this to run deliberately *miscalibrated*
    /// models against a truthful device; with `LaneOptions::recalibrate`
    /// the measured-rate feedback pulls the model back toward reality.
    pub fn with_plan_model(mut self, model: DeviceProfile) -> Self {
        self.plan_model = Some(model);
        self
    }

    pub fn n_lanes(&self) -> usize {
        self.devices.len()
    }

    /// Run `workloads[w]` = the dependent task batch of worker `w` (each
    /// worker submits its next task only after the previous completed).
    /// Workers are anonymous tenants (`TenantWorkload::for_worker`), so
    /// with `admission: None` this is exactly the classic pipeline.
    pub fn run(&self, workloads: Vec<Vec<TaskSpec>>) -> LaneMetrics {
        self.run_tenants(
            workloads
                .into_iter()
                .enumerate()
                .map(|(w, tasks)| TenantWorkload::for_worker(w, tasks))
                .collect(),
        )
    }

    /// [`LaneCoordinator::run`] with tenant attribution: worker `w`
    /// submits `workloads[w].tasks` on behalf of its tenant/class, every
    /// submission passing the admission gate when
    /// [`LaneOptions::admission`] is armed. A worker whose submission is
    /// shed receives the typed receipt (stamped in the submission's
    /// [`ShedSlot`]) and moves on to its next task; admitted work is
    /// never lost.
    pub fn run_tenants(&self, workloads: Vec<TenantWorkload>) -> LaneMetrics {
        let t_workers = workloads.len();
        let lanes = self.devices.len();
        let ctl = self
            .opts
            .admission
            .as_ref()
            .map(|a| AdmissionCtl::new(a.clone()));
        let sharded = match &ctl {
            Some(c) => ShardedBuffer::with_admission(lanes, c.clone()),
            None => ShardedBuffer::new(lanes),
        };
        let health = FleetHealth::new(lanes);
        let epoch = Instant::now();

        let mut outcomes: Vec<LaneOutcome> = Vec::with_capacity(lanes);
        std::thread::scope(|s| {
            // ---- workers ------------------------------------------------
            let mut worker_handles = Vec::with_capacity(t_workers);
            for (w, tw) in workloads.into_iter().enumerate() {
                let sharded = sharded.clone();
                // Producers enter through the admission gate when armed:
                // their entry queue is their own lane, and the ShedLowest
                // eviction scan covers every lane's backlog.
                let gate = ctl.as_ref().map(|c| {
                    AdmissionGate::new(
                        c.clone(),
                        sharded.lane_for_worker(w).clone(),
                        sharded.lanes_vec(),
                        epoch,
                    )
                });
                let h = std::thread::Builder::new()
                    .name(format!("worker-{w}"))
                    .spawn_scoped(s, move || {
                        for (seq, task) in tw.tasks.into_iter().enumerate() {
                            let done = Event::new();
                            let submitted_at = epoch.elapsed().as_secs_f64();
                            let sub = Submission {
                                worker: w,
                                batch_seq: seq,
                                task,
                                done: done.clone(),
                                submitted_at,
                                tenant: tw.tenant,
                                class: tw.class,
                                deadline: tw
                                    .deadline
                                    .map(|d| submitted_at + d),
                                shed: ShedSlot::new(),
                            };
                            match &gate {
                                None => {
                                    sharded.push(sub);
                                    // Dependency: wait before the next.
                                    done.wait();
                                }
                                Some(g) => match g.submit(sub) {
                                    // Admitted work completes exactly
                                    // once — by the device, or by an
                                    // eviction receipt.
                                    SubmitOutcome::Admitted => {
                                        done.wait();
                                    }
                                    // Shed at the gate: receipt returned,
                                    // nothing queued, nothing to wait on.
                                    SubmitOutcome::Shed(_) => {}
                                },
                            }
                        }
                    })
                    .expect("spawn worker");
                worker_handles.push(h);
            }

            // ---- janitor: close every lane once all workers exited ----
            let sharded_j = sharded.clone();
            std::thread::Builder::new()
                .name("lane-janitor".into())
                .spawn_scoped(s, move || {
                    // Collect results first and close the lanes even when a
                    // worker panicked: re-raising before close_all would
                    // leave every proxy blocked in drain_into forever and
                    // hang the scope instead of surfacing the panic.
                    let results: Vec<_> =
                        worker_handles.into_iter().map(|h| h.join()).collect();
                    sharded_j.close_all();
                    for r in results {
                        if let Err(payload) = r {
                            std::panic::resume_unwind(payload);
                        }
                    }
                })
                .expect("spawn janitor");

            // ---- lane proxies ------------------------------------------
            let proxy_handles: Vec<_> = (0..lanes)
                .map(|l| {
                    let device = Arc::clone(&self.devices[l]);
                    // Base planning model: the override, or the device's
                    // own profile (model == reality, as before).
                    let base_model = self
                        .plan_model
                        .clone()
                        .unwrap_or_else(|| device.profile().clone());
                    let opts = self.opts.clone();
                    // group_cap = 0: one full round of THIS lane's workers
                    // (those with w % lanes == l) — a global ceil(T/lanes)
                    // would make under-populated lanes sleep out the whole
                    // settle window on every group.
                    let cap = if opts.group_cap == 0 {
                        t_workers.saturating_sub(l).div_ceil(lanes).max(1)
                    } else {
                        opts.group_cap.max(1)
                    };
                    // Online proxies get the whole sharded buffer (they
                    // steal from sibling lanes); legacy proxies only see
                    // their own lane.
                    let sharded = sharded.clone();
                    let health = health.clone();
                    std::thread::Builder::new()
                        .name(format!("lane-proxy-{l}"))
                        .spawn_scoped(s, move || match opts.online {
                            Some(online) => online_lane_proxy(
                                l, sharded, device, base_model, opts, online,
                                health, cap, epoch,
                            ),
                            None => lane_proxy(
                                l,
                                sharded.lane(l).clone(),
                                device,
                                base_model,
                                opts,
                                health,
                                cap,
                                epoch,
                            ),
                        })
                        .expect("spawn lane proxy")
                })
                .collect();
            // Join EVERY proxy before surfacing any panic: aborting the
            // loop at the first poisoned handle would drop the remaining
            // JoinHandles while their threads still run, and the scope
            // would re-join them only after the panic already unwound
            // through `outcomes` bookkeeping.
            let joined: Vec<_> =
                proxy_handles.into_iter().map(|h| h.join()).collect();
            let mut first_panic = None;
            for r in joined {
                match r {
                    Ok(o) => outcomes.push(o),
                    Err(payload) if first_panic.is_none() => {
                        first_panic = Some(payload)
                    }
                    Err(_) => {}
                }
            }
            if let Some(payload) = first_panic {
                std::panic::resume_unwind(payload);
            }
        });

        let total_secs = epoch.elapsed().as_secs_f64();
        let mut latencies = Vec::new();
        let mut latency_tenants = Vec::new();
        let mut group_makespans = Vec::new();
        let mut per_lane = Vec::with_capacity(lanes);
        let (mut overhead, mut n_groups, mut n_tasks) = (0.0, 0, 0);
        for o in outcomes {
            for (t, l) in o.latencies {
                latency_tenants.push(t);
                latencies.push(l);
            }
            group_makespans.extend(o.group_makespans);
            overhead += o.stats.sched_overhead_secs;
            n_groups += o.stats.n_groups;
            n_tasks += o.stats.n_tasks;
            per_lane.push(o.stats);
        }
        let admission =
            ctl.map(|c| c.report(&latencies, &latency_tenants));
        LaneMetrics {
            total_secs,
            tasks_per_sec: n_tasks as f64 / total_secs,
            latencies,
            latency_tenants,
            group_makespans,
            sched_overhead_secs: overhead,
            n_groups,
            n_tasks,
            per_lane,
            admission,
        }
    }
}

/// One lane's proxy loop: batched drain → reorder (persistent arena) →
/// device run → completion signals. All per-group buffers are reused, so
/// a warm lane performs no allocation on its drain path beyond the task
/// clones handed to the device.
#[allow(clippy::too_many_arguments)]
fn lane_proxy(
    lane: usize,
    buffer: SharedBuffer,
    device: Arc<dyn Device>,
    base_model: DeviceProfile,
    opts: LaneOptions,
    health: FleetHealth,
    cap: usize,
    epoch: Instant,
) -> LaneOutcome {
    // Recovery state. The legacy proxy owns its buffer exclusively, so
    // "quarantine" degenerates to holding the failed group and re-probing
    // after cooldown — there is no sibling to requeue toward.
    let breaker = health.lane(lane);
    let mut consec_failures = 0usize;
    let mut scratch = ParBeamScratch::new(opts.scoring_threads);
    let mut order: Vec<usize> = Vec::new();
    let mut drained: Vec<Submission> = Vec::new();
    let mut tasks: Vec<TaskSpec> = Vec::new();
    let mut ordered: Vec<TaskSpec> = Vec::new();
    // Cross-tenant spec-twin collapse scratch (admission's
    // `collapse_twins`): maps drained rows onto their unique compiled
    // representatives. All reused; zero cost when no twins are drained.
    let collapse_twins =
        opts.admission.as_ref().map_or(false, |a| a.collapse_twins);
    let mut rep_of: Vec<usize> = Vec::new();
    let mut pos_of: Vec<usize> = Vec::new();
    let mut inv_slot: Vec<usize> = Vec::new();
    let mut exec_tasks: Vec<TaskSpec> = Vec::new();
    // Persistent paused-cursor pair: the table is compiled once per
    // drained group (shared with the search); the cursor replays
    // NoReorder orders for the predicted-makespan record (the heuristic
    // reports its chosen order's makespan itself).
    let mut lane_table = TaskTable::new();
    let mut lane_cursor = SimCursor::detached();
    // Calibration: identity profile when off (bit-identical compiles);
    // corrections adopt atomically at each group boundary — the compile
    // below and any cursor rewind read the same model generation. The
    // recorded probe replays each submitted order through the model so
    // predicted per-command durations carry the *modeled* duplex
    // contention, symmetric with the device's measured durations.
    let mut cal_prof = CalibratedProfile::identity(&base_model);
    let mut calibrator = opts.recalibrate.map(Calibrator::new);
    let mut calib_probe = SimCursor::detached();
    calib_probe.set_record_timeline(true);
    let mut pred_stages: Vec<EngineSecs> = Vec::new();

    let mut latencies = Vec::new();
    let mut group_makespans = Vec::new();
    let mut stats = empty_lane_stats(lane);

    while buffer.drain_into(cap, opts.settle, &mut drained).is_some() {
        let group = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            tasks.clear();
            tasks.extend(drained.iter().map(|s| s.task.clone()));
            // Group boundary = timeline boundary: adopt any matured rate
            // corrections before compiling this group's table.
            if let Some(cal) = calibrator.as_mut() {
                if let Some(c) = cal.adopt() {
                    cal_prof = CalibratedProfile::new(&base_model, c);
                    stats.n_recalibrations += 1;
                }
            }
            // Compiled once per drained group; shared by the search and
            // the prediction bookkeeping.
            lane_table.compile_calibrated_into(&tasks, &cal_prof);
            // Cross-tenant spec-twin collapse: when several drained
            // submissions compiled to byte-identical rows (typically the
            // same kernel + sizes arriving from different tenants), run
            // one representative per class and fan its completion out to
            // every twin — the ROADMAP "free throughput" note.
            let mut collapsed = false;
            if collapse_twins {
                rep_of.clear();
                rep_of.extend(
                    (0..tasks.len()).map(|i| lane_table.twin_class(i) as usize),
                );
                let n_unique =
                    rep_of.iter().enumerate().filter(|&(i, &r)| r == i).count();
                if n_unique < tasks.len() {
                    stats.n_xtenant_collapsed += (tasks.len() - n_unique) as u64;
                    pos_of.clear();
                    pos_of.resize(tasks.len(), usize::MAX);
                    exec_tasks.clear();
                    for i in 0..tasks.len() {
                        if rep_of[i] == i {
                            pos_of[i] = exec_tasks.len();
                            exec_tasks.push(tasks[i].clone());
                        }
                    }
                    // Recompile over the representatives only: search,
                    // prediction replay and device all see the collapsed
                    // group. Twin-free groups never reach this recompile.
                    lane_table.compile_calibrated_into(&exec_tasks, &cal_prof);
                    collapsed = true;
                }
            }
            let n_rows = if collapsed { exec_tasks.len() } else { tasks.len() };
            match opts.policy {
                Policy::NoReorder => {
                    order.clear();
                    order.extend(0..n_rows);
                    // Model prediction for the arrival order
                    // (allocation-free replay through the lane cursor).
                    lane_cursor.reset_for_table(&lane_table, EngineState::default());
                    for &i in &order {
                        lane_cursor.push_task_compiled(&lane_table, i);
                    }
                    stats.predicted_secs += lane_cursor.run_to_quiescence();
                }
                Policy::Heuristic => {
                    let t0 = Instant::now();
                    let predicted = batch_reorder_table_parallel_into(
                        &lane_table,
                        EngineState::default(),
                        DEFAULT_BEAM_WIDTH,
                        &mut scratch,
                        &mut order,
                    );
                    stats.sched_overhead_secs += t0.elapsed().as_secs_f64();
                    stats.predicted_secs += predicted;
                }
            }

            let run_tasks: &[TaskSpec] =
                if collapsed { &exec_tasks } else { &tasks };
            ordered.clear();
            ordered.extend(order.iter().map(|&i| run_tasks[i].clone()));
            let (run, attempts) = match opts.recovery.as_ref() {
                Some(rec) => run_group_with_recovery(
                    device.as_ref(),
                    &ordered,
                    lane,
                    rec,
                    breaker,
                    &mut consec_failures,
                    &mut stats,
                ),
                None => match device.run_group(&ordered) {
                    Ok(run) => (run, 1),
                    Err(e) => panic!("lane {lane} device fault: {e:#}"),
                },
            };
            group_makespans.push(run.makespan);
            stats.busy_secs += run.makespan;
            let now = epoch.elapsed().as_secs_f64();
            // Signal completions (device timestamps are group-relative;
            // the workers only need the ordering, latency uses wall time).
            if collapsed {
                // Fan the representative's completion out to every twin:
                // `drained[i]` finished when its class rep's slot did.
                inv_slot.clear();
                inv_slot.resize(order.len(), 0);
                for (slot, &row) in order.iter().enumerate() {
                    inv_slot[row] = slot;
                }
                for (i, sub) in drained.iter().enumerate() {
                    let slot = inv_slot[pos_of[rep_of[i]]];
                    sub.done.complete(now - run.makespan + run.task_end[slot]);
                    latencies.push((sub.tenant.0, now - sub.submitted_at));
                }
            } else {
                for (slot, &orig) in order.iter().enumerate() {
                    let sub = &drained[orig];
                    sub.done.complete(now - run.makespan + run.task_end[slot]);
                    latencies.push((sub.tenant.0, now - sub.submitted_at));
                }
            }
            // Measured-rate feedback, after the completion signals so
            // the replay never delays worker unblocking: predicted
            // per-slot stage seconds from a recorded model replay of
            // the submitted order (so modeled duplex contention matches
            // the measured side — solo stage secs would double-count
            // sigma) against the device's measured per-command
            // timeline. The device runs each group from idle, so the
            // replay starts from idle too. Retried groups (attempts > 1)
            // are excluded: their wall-clock includes the failed attempts
            // and backoff sleeps, which would poison the rate estimate.
            if attempts == 1 {
                if let Some(cal) = calibrator.as_mut() {
                    calib_probe
                        .reset_for_table(&lane_table, EngineState::default());
                    for &i in &order {
                        calib_probe.push_task_compiled(&lane_table, i);
                    }
                    calib_probe.run_to_quiescence();
                    fold_timeline_stage_secs(
                        order.len(),
                        calib_probe.timeline(),
                        &mut pred_stages,
                    );
                    cal.observe_group(&pred_stages, &run.timeline);
                }
            }
            stats.n_groups += 1;
            stats.n_tasks += drained.len();
        }));
        if let Err(payload) = group {
            // Liveness before failure: workers routed to this lane block
            // in `done.wait()` and would hang `run`'s scope forever if
            // the proxy just died. Complete this group's events and keep
            // draining-and-completing until every worker exited, then
            // surface the panic through the proxy's join.
            loop {
                let now = epoch.elapsed().as_secs_f64();
                for sub in &drained {
                    if !sub.done.is_complete() {
                        sub.done.complete(now);
                    }
                }
                if buffer.drain_into(cap, Duration::ZERO, &mut drained).is_none()
                {
                    break;
                }
            }
            std::panic::resume_unwind(payload);
        }
    }
    let pc = scratch.prune_counters();
    stats.n_cands_pruned = pc.n_cands_pruned;
    stats.n_rollouts_early_exit = pc.n_rollouts_early_exit;
    stats.n_twin_collapsed = pc.n_twin_collapsed;
    record_calib_stats(&mut stats, calibrator.as_ref());
    LaneOutcome { stats, latencies, group_makespans }
}

/// Drive one group to completion under a [`RecoveryPolicy`] (the legacy
/// blocking proxy's recovery loop; the online proxy re-submits through
/// its runner channel instead). Returns the successful run plus the
/// attempt count — callers skip calibration feedback when `attempts > 1`
/// because a retried group's wall-clock carries the failed attempts.
///
/// The legacy proxy has no sibling lane to hand work to, so a
/// `Quarantine` verdict degenerates to the breaker's cooldown +
/// half-open-probe cycle on the *held* group: the lane sleeps out the
/// cooldown and re-probes with the same tasks. A persistently faulting
/// device therefore re-probes forever here — by design, the fail-fast
/// escape is picking a policy that says so.
fn run_group_with_recovery(
    device: &dyn Device,
    ordered: &[TaskSpec],
    lane: usize,
    rec: &RecoveryOptions,
    breaker: &LaneBreaker,
    consec_failures: &mut usize,
    stats: &mut LaneStats,
) -> (DeviceRun, usize) {
    let mut attempt = 1usize;
    loop {
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            device.run_group(ordered)
        }));
        let (kind, message, payload) = match res {
            Ok(Ok(run)) => {
                if breaker.state() != BreakerState::Closed {
                    breaker.probe_succeeded();
                }
                *consec_failures = 0;
                return (run, attempt);
            }
            Ok(Err(e)) => (FaultKind::Error, format!("{e:#}"), None),
            Err(p) => (FaultKind::Panic, "device panicked".to_string(), Some(p)),
        };
        stats.n_faults += 1;
        *consec_failures += 1;
        let ctx = FailureCtx {
            lane,
            attempt,
            lane_consecutive_failures: *consec_failures,
            kind,
        };
        match rec.policy.on_failure(&ctx) {
            RecoveryAction::FailFast => match payload {
                Some(p) => std::panic::resume_unwind(p),
                None => panic!(
                    "lane {lane} device fault after {attempt} attempt(s): \
                     {message}"
                ),
            },
            RecoveryAction::Retry { backoff } => {
                stats.n_retries += 1;
                std::thread::sleep(backoff);
            }
            RecoveryAction::Quarantine => {
                if breaker.trip() {
                    stats.n_quarantine_trips += 1;
                }
                std::thread::sleep(rec.quarantine.cooldown);
                if breaker.try_half_open(rec.quarantine.cooldown) {
                    stats.n_halfopen_probes += 1;
                }
                stats.n_retries += 1;
            }
        }
        attempt += 1;
    }
}

/// Fold a lane's final calibration state into its [`LaneStats`].
pub(crate) fn record_calib_stats(
    stats: &mut LaneStats,
    calibrator: Option<&Calibrator>,
) {
    if let Some(cal) = calibrator {
        let c = cal.counts();
        stats.n_calib_obs = c.n_obs;
        stats.n_calib_clipped = c.n_clipped;
        let f = cal.applied();
        stats.calib_htd = f.htd;
        stats.calib_kernel = f.k;
        stats.calib_dth = f.dth;
    }
}

// ---------------------------------------------------------------------------
// Online (open-stream) lane proxy
// ---------------------------------------------------------------------------

/// Completion notice from a lane's device-runner thread. On success the
/// runner signals the submissions' completion events itself (so workers
/// unblock without waiting for the proxy, which may be mid-re-plan),
/// then reports the measured numbers back. On a fault it hands the
/// *unsignalled* submissions back so the proxy can retry or requeue them
/// — a retried run must produce bit-identical completions, so the events
/// stay pending until a successful attempt (or a fail-fast unwind).
/// Shared with the fleet coordinator (`coordinator::fleet`), which runs
/// one such runner thread per device.
pub(crate) struct RunDone {
    pub(crate) n_tasks: usize,
    pub(crate) outcome: RunOutcome,
}

pub(crate) enum RunOutcome {
    Done {
        makespan: f64,
        /// `(tenant id, wall latency)` per completed submission.
        latencies: Vec<(u32, f64)>,
        /// Measured per-command records (slot-indexed in submitted
        /// order) — the calibrator's feedback substrate.
        timeline: Vec<CmdRecord>,
    },
    Fault {
        kind: FaultKind,
        message: String,
        /// The device panic payload, deferred so the proxy can decide
        /// between retry, quarantine and fail-fast re-raise.
        payload: Option<Box<dyn std::any::Any + Send>>,
        /// The submitted group, returned un-completed for re-dispatch.
        subs: Vec<Submission>,
    },
}

/// Proxy-side record of the group in flight on the runner thread.
/// Shared with the fleet coordinator, which keeps one per device.
pub(crate) struct InFlight {
    /// Predicted makespan contribution on the contiguous lane timeline.
    pub(crate) pred: f64,
    /// Watchdog deadline (`predicted × slack + floor` past submit), when
    /// a run-deadline is configured.
    pub(crate) deadline: Option<Instant>,
    /// 1 on first submission; grows on same-lane retries.
    pub(crate) attempt: usize,
    /// The watchdog already declared this run dead (the lane is
    /// quarantined and its backlog requeued); when the zombie run
    /// eventually surfaces, its numbers must not feed the drift gate or
    /// the calibrator.
    pub(crate) timed_out: bool,
}

/// Edge-triggered wakeup channel for a planning loop that would otherwise
/// sleep a fixed `poll` at its idle edge. Producers (workers pushing into
/// ingress, device runners posting `RunDone`) bump an epoch and notify;
/// the planner snapshots the epoch at the top of its iteration and parks
/// in [`WakeSignal::wait_past`] only while the epoch is unchanged — an
/// event that lands anywhere between snapshot and park is therefore never
/// lost, it just turns the park into an immediate return. The deadline
/// keeps time-driven work (retry due-times, breaker cooldowns, the `poll`
/// backstop) flowing with no producer awake.
pub(crate) struct WakeSignal {
    epoch: Mutex<u64>,
    cv: Condvar,
}

impl WakeSignal {
    pub(crate) fn new() -> WakeSignal {
        WakeSignal { epoch: Mutex::new(0), cv: Condvar::new() }
    }

    /// Snapshot the current epoch (take before scanning for work).
    pub(crate) fn epoch(&self) -> u64 {
        *self.epoch.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Signal that new work may exist (push, completion, close).
    pub(crate) fn notify(&self) {
        let mut g = self.epoch.lock().unwrap_or_else(PoisonError::into_inner);
        *g = g.wrapping_add(1);
        self.cv.notify_all();
    }

    /// Park until the epoch moves past `seen` or `deadline` passes.
    /// Returns immediately if a notify already landed since the snapshot.
    pub(crate) fn wait_past(&self, seen: u64, deadline: Instant) {
        let mut g = self.epoch.lock().unwrap_or_else(PoisonError::into_inner);
        while *g == seen {
            let now = Instant::now();
            if now >= deadline {
                return;
            }
            let (guard, timeout) = self
                .cv
                .wait_timeout(g, deadline - now)
                .unwrap_or_else(PoisonError::into_inner);
            g = guard;
            if timeout.timed_out() {
                return;
            }
        }
    }
}

/// The device-runner thread body: execute each submitted group, signal
/// successful completions, and report a [`RunDone`] per group. Extracted
/// from the online lane proxy so the fleet coordinator spawns the exact
/// same runner per device. `wake`, when provided, is notified after every
/// posted `RunDone` so a parked planning loop resumes immediately instead
/// of sleeping out its poll interval. If the proxy side already unwound
/// (receiver gone), any still-pending fault events are completed here so
/// blocked workers can exit.
pub(crate) fn device_runner_loop(
    device: &dyn Device,
    epoch: Instant,
    job_rx: mpsc::Receiver<Vec<Submission>>,
    done_tx: mpsc::Sender<RunDone>,
    wake: Option<Arc<WakeSignal>>,
) {
    for subs in job_rx {
        // Built here, off the proxy's planning path (the device API
        // wants a contiguous TaskSpec slice).
        let tasks: Vec<TaskSpec> = subs.iter().map(|sub| sub.task.clone()).collect();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            device.run_group(&tasks)
        }));
        let now = epoch.elapsed().as_secs_f64();
        let msg = match res {
            Ok(Ok(run)) => {
                let mut lat = Vec::with_capacity(subs.len());
                for (slot, sub) in subs.iter().enumerate() {
                    sub.done.complete(now - run.makespan + run.task_end[slot]);
                    lat.push((sub.tenant.0, now - sub.submitted_at));
                }
                RunDone {
                    n_tasks: subs.len(),
                    outcome: RunOutcome::Done {
                        makespan: run.makespan,
                        latencies: lat,
                        timeline: run.timeline,
                    },
                }
            }
            // Faulted runs hand their submissions back with the
            // completion events still pending: the proxy may retry the
            // exact group, and a re-run must be the one that signals the
            // workers (an event can complete only once).
            Ok(Err(e)) => RunDone {
                n_tasks: subs.len(),
                outcome: RunOutcome::Fault {
                    kind: FaultKind::Error,
                    message: format!("{e:#}"),
                    payload: None,
                    subs,
                },
            },
            Err(p) => RunDone {
                n_tasks: subs.len(),
                outcome: RunOutcome::Fault {
                    kind: FaultKind::Panic,
                    message: "device panicked".to_string(),
                    payload: Some(p),
                    subs,
                },
            },
        };
        // If the proxy already unwound (receiver gone), no retry will
        // ever happen: complete any still-pending events ourselves so
        // blocked workers can exit.
        let fault_events: Vec<Event> = match &msg.outcome {
            RunOutcome::Fault { subs, .. } => {
                subs.iter().map(|s| s.done.clone()).collect()
            }
            RunOutcome::Done { .. } => Vec::new(),
        };
        if done_tx.send(msg).is_err() {
            let now = epoch.elapsed().as_secs_f64();
            for ev in &fault_events {
                if !ev.is_complete() {
                    ev.complete(now);
                }
            }
            break;
        }
        if let Some(w) = &wake {
            w.notify();
        }
    }
}

/// One lane's online proxy loop (see the module docs): device execution
/// on a dedicated runner thread, continuous draining with mid-group
/// merge into the uncommitted suffix, drift-gated incremental re-plans
/// seeded from the committed prefix, cross-round `EngineState` carry on a
/// contiguous planning cursor, and bounded work-stealing when idle.
#[allow(clippy::too_many_arguments)]
fn online_lane_proxy(
    lane: usize,
    sharded: ShardedBuffer,
    device: Arc<dyn Device>,
    base_model: DeviceProfile,
    opts: LaneOptions,
    online: OnlineOptions,
    health: FleetHealth,
    cap: usize,
    epoch: Instant,
) -> LaneOutcome {
    let own = sharded.lane(lane).clone();
    let rec = opts.recovery.clone();
    let breaker = health.lane(lane);
    let mut consec_failures = 0usize;
    // Watchdog deadline for a group predicted to take `pred` seconds.
    let deadline_at = |rec: Option<&RecoveryOptions>, pred: f64| {
        rec.and_then(|r| {
            r.deadline.map(|d| Instant::now() + d.deadline_for(pred))
        })
    };

    // Planner state: the contiguous lane cursor carries EngineState
    // across back-to-back groups (committed prefix = everything handed to
    // the runner); the table is recompiled over the pending suffix on
    // every merge. Calibration adopts a corrected model only when the
    // contiguous timeline restarts (lane fully idle), so the cursor and
    // every table it pairs with always share one model generation.
    let mut table = TaskTable::new();
    let mut lane_cursor = SimCursor::detached();
    let mut scratch = OnlineScratch::new();
    let mut gate = DriftGate::new(online.drift_threshold);
    let mut cal_prof = CalibratedProfile::identity(&base_model);
    let mut calibrator = opts.recalibrate.map(Calibrator::new);
    // Recorded replay probe + predicted per-slot stage seconds of the
    // group in flight (captured at submit: the table may be recompiled
    // by merges while it runs). The replay carries the modeled duplex
    // contention, symmetric with the device's measured durations, and
    // starts from idle because the device runs each group from idle.
    let mut calib_probe = SimCursor::detached();
    calib_probe.set_record_timeline(true);
    let mut inflight_pred: Vec<EngineSecs> = Vec::new();

    let mut pending_subs: Vec<Submission> = Vec::new();
    let mut pending_tasks: Vec<TaskSpec> = Vec::new();
    let mut incumbent: Vec<usize> = Vec::new();
    let mut order_buf: Vec<usize> = Vec::new();
    let mut drained: Vec<Submission> = Vec::new();

    let mut latencies: Vec<(u32, f64)> = Vec::new();
    let mut group_makespans: Vec<f64> = Vec::new();
    let mut stats = empty_lane_stats(lane);

    std::thread::scope(|s| {
        let (job_tx, job_rx) = mpsc::channel::<Vec<Submission>>();
        let (done_tx, done_rx) = mpsc::channel::<RunDone>();
        std::thread::Builder::new()
            .name(format!("lane-device-{lane}"))
            .spawn_scoped(s, move || {
                device_runner_loop(device.as_ref(), epoch, job_rx, done_tx, None)
            })
            .expect("spawn lane device runner");

        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // Absolute predicted completion clocks on the contiguous
            // planning timeline.
            let mut planner_live = false;
            let mut plan_dirty = false;
            let mut suffix_planned = false;
            let mut pred_done = 0.0f64;
            let mut last_commit_pred = 0.0f64;
            // The group in flight on the runner thread, if any.
            let mut inflight: Option<InFlight> = None;
            let mut closed = false;

            loop {
                if inflight.is_some() {
                    match done_rx.recv_timeout(online.poll) {
                        Ok(done) => {
                            let fl = inflight.take().expect("inflight set");
                            match done.outcome {
                                RunOutcome::Done {
                                    makespan,
                                    latencies: lat,
                                    timeline,
                                } => {
                                    if !fl.timed_out && breaker.state() != BreakerState::Closed {
                                        breaker.probe_succeeded();
                                    }
                                    if !fl.timed_out {
                                        consec_failures = 0;
                                    }
                                    stats.busy_secs += makespan;
                                    stats.predicted_secs += fl.pred;
                                    // Drift-gate and measured-rate
                                    // feedback come ONLY from clean
                                    // first-attempt runs: retried groups
                                    // carry backoff sleeps and zombie
                                    // (timed-out) runs by definition blew
                                    // their prediction for reasons the
                                    // model shouldn't learn.
                                    if fl.attempt == 1 && !fl.timed_out {
                                        gate.observe(makespan, fl.pred);
                                        if let Some(cal) = calibrator.as_mut()
                                        {
                                            cal.observe_group(
                                                &inflight_pred,
                                                &timeline,
                                            );
                                        }
                                    }
                                    group_makespans.push(makespan);
                                    latencies.extend(lat);
                                    stats.n_groups += 1;
                                    stats.n_tasks += done.n_tasks;
                                }
                                RunOutcome::Fault {
                                    kind,
                                    message,
                                    payload,
                                    subs,
                                } => {
                                    stats.n_faults += 1;
                                    consec_failures += 1;
                                    // A watchdog-condemned run that then
                                    // faults stays condemned: quarantine,
                                    // never a same-lane retry.
                                    let action = if fl.timed_out {
                                        RecoveryAction::Quarantine
                                    } else {
                                        match rec.as_ref() {
                                            Some(r) => {
                                                r.policy.on_failure(&FailureCtx {
                                                    lane,
                                                    attempt: fl.attempt,
                                                    lane_consecutive_failures:
                                                        consec_failures,
                                                    kind,
                                                })
                                            }
                                            None => RecoveryAction::FailFast,
                                        }
                                    };
                                    match action {
                                        RecoveryAction::FailFast => {
                                            // No retry is coming: unblock
                                            // the group's workers before
                                            // unwinding.
                                            let now =
                                                epoch.elapsed().as_secs_f64();
                                            for sub in &subs {
                                                if !sub.done.is_complete() {
                                                    sub.done.complete(now);
                                                }
                                            }
                                            match payload {
                                                Some(p) => {
                                                    std::panic::resume_unwind(p)
                                                }
                                                None => panic!(
                                                    "lane {lane} device fault \
                                                     after {} attempt(s): \
                                                     {message}",
                                                    fl.attempt
                                                ),
                                            }
                                        }
                                        RecoveryAction::Retry { backoff } => {
                                            stats.n_retries += 1;
                                            std::thread::sleep(backoff);
                                            inflight = Some(InFlight {
                                                pred: fl.pred,
                                                deadline: deadline_at(
                                                    rec.as_ref(),
                                                    fl.pred,
                                                ),
                                                attempt: fl.attempt + 1,
                                                timed_out: false,
                                            });
                                            if let Err(mpsc::SendError(subs)) =
                                                job_tx.send(subs)
                                            {
                                                // Runner thread died:
                                                // unblock the group's
                                                // workers, then surface the
                                                // failure (liveness before
                                                // failure).
                                                let now = epoch
                                                    .elapsed()
                                                    .as_secs_f64();
                                                for sub in &subs {
                                                    if !sub.done.is_complete()
                                                    {
                                                        sub.done.complete(now);
                                                    }
                                                }
                                                panic!(
                                                    "lane {lane} device \
                                                     runner died mid-retry"
                                                );
                                            }
                                        }
                                        RecoveryAction::Quarantine => {
                                            if breaker.trip() {
                                                stats.n_quarantine_trips += 1;
                                            }
                                            // Requeue the failed group in
                                            // front of the unsubmitted
                                            // backlog so per-worker FIFO
                                            // survives, then make it all
                                            // visible to sibling thieves.
                                            let mut back = subs;
                                            back.append(&mut pending_subs);
                                            stats.n_requeued += back.len();
                                            own.requeue_front(&mut back);
                                            pending_tasks.clear();
                                            incumbent.clear();
                                            planner_live = false;
                                            plan_dirty = false;
                                            suffix_planned = false;
                                        }
                                    }
                                }
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            // Run-deadline watchdog: a run past its
                            // deadline is declared dead — quarantine the
                            // lane and requeue the *unstarted* backlog so
                            // siblings can rescue it. The zombie run
                            // itself cannot be cancelled (the runner
                            // thread is blocked inside the device); its
                            // eventual RunDone is handled above with
                            // `timed_out` set.
                            if let Some(fl) = inflight.as_mut() {
                                if !fl.timed_out
                                    && fl.deadline.is_some_and(|dl| Instant::now() >= dl)
                                {
                                    fl.timed_out = true;
                                    stats.n_timeouts += 1;
                                    if breaker.trip() {
                                        stats.n_quarantine_trips += 1;
                                    }
                                    stats.n_requeued += pending_subs.len();
                                    own.requeue_front(&mut pending_subs);
                                    pending_tasks.clear();
                                    incumbent.clear();
                                    planner_live = false;
                                    plan_dirty = false;
                                    suffix_planned = false;
                                }
                            }
                            // Device busy: absorb arrivals into the
                            // uncommitted suffix (stealing when our own
                            // stream runs dry), and overlap the re-plan
                            // with the device run. A quarantined lane
                            // absorbs nothing — its backlog belongs to
                            // the thieves now.
                            if !closed && breaker.state() == BreakerState::Closed {
                                let room = cap.saturating_sub(pending_subs.len());
                                if room > 0 {
                                    match own.drain_into_timeout(
                                        room,
                                        Duration::ZERO,
                                        Duration::ZERO,
                                        &mut drained,
                                    ) {
                                        DrainPoll::Drained(_) => merge_arrivals(
                                            &cal_prof,
                                            true,
                                            &mut drained,
                                            &mut pending_subs,
                                            &mut pending_tasks,
                                            &mut incumbent,
                                            &mut table,
                                            &mut lane_cursor,
                                            &mut planner_live,
                                            &mut last_commit_pred,
                                            &mut plan_dirty,
                                            &mut stats,
                                        ),
                                        DrainPoll::Empty => {
                                            if pending_subs.is_empty()
                                                && online.steal_max > 0
                                            {
                                                // Bounded by the lane's
                                                // group cap as well.
                                                let got = sharded
                                                    .steal_with_health(
                                                        lane,
                                                        online.steal_max.min(cap),
                                                        &health,
                                                        &mut drained,
                                                    );
                                                if got > 0 {
                                                    stats.n_stolen += got;
                                                    merge_arrivals(
                                                        &cal_prof,
                                                        true,
                                                        &mut drained,
                                                        &mut pending_subs,
                                                        &mut pending_tasks,
                                                        &mut incumbent,
                                                        &mut table,
                                                        &mut lane_cursor,
                                                        &mut planner_live,
                                                        &mut last_commit_pred,
                                                        &mut plan_dirty,
                                                        &mut stats,
                                                    );
                                                }
                                            }
                                        }
                                        DrainPoll::Closed => closed = true,
                                    }
                                }
                            }
                            if plan_dirty {
                                finalize_plan(
                                    opts.policy,
                                    &online,
                                    &table,
                                    &mut lane_cursor,
                                    &mut incumbent,
                                    &mut order_buf,
                                    &mut scratch,
                                    &mut gate,
                                    &mut suffix_planned,
                                    &mut stats,
                                    &mut plan_dirty,
                                    &mut pred_done,
                                );
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            unreachable!("lane device runner exited early")
                        }
                    }
                    continue;
                }

                // ---- quarantined & idle: sit out the cooldown, then
                // admit ONE probe group (half-open). While open, this
                // lane plans and submits nothing — its requeued backlog
                // is rescued by sibling thieves via steal_with_health.
                if let Some(r) = rec.as_ref() {
                    if breaker.state() == BreakerState::Open {
                        if breaker.try_half_open(r.quarantine.cooldown) {
                            stats.n_halfopen_probes += 1;
                        } else {
                            if own.is_closed_and_empty() {
                                break;
                            }
                            std::thread::sleep(online.poll);
                            continue;
                        }
                    }
                }

                // ---- device idle: submit the planned suffix, if any.
                if !pending_subs.is_empty() {
                    if plan_dirty {
                        finalize_plan(
                            opts.policy,
                            &online,
                            &table,
                            &mut lane_cursor,
                            &mut incumbent,
                            &mut order_buf,
                            &mut scratch,
                            &mut gate,
                            &mut suffix_planned,
                            &mut stats,
                            &mut plan_dirty,
                            &mut pred_done,
                        );
                    }
                    // The order becomes committed (immovable) here: push
                    // it into the contiguous cursor and pin the frontier.
                    // Submissions are *moved* out in planned order (no
                    // task clones on the submit path; the runner thread
                    // derives its TaskSpec slice from them).
                    let mut taken: Vec<Option<Submission>> =
                        std::mem::take(&mut pending_subs).into_iter().map(Some).collect();
                    let ordered_subs: Vec<Submission> = incumbent
                        .iter()
                        .map(|&i| taken[i].take().expect("incumbent is a permutation"))
                        .collect();
                    for &i in incumbent.iter() {
                        lane_cursor.push_task_compiled(&table, i);
                    }
                    lane_cursor.commit_frontier();
                    let contribution = (pred_done - last_commit_pred).max(0.0);
                    last_commit_pred = pred_done;
                    inflight = Some(InFlight {
                        pred: contribution,
                        deadline: deadline_at(rec.as_ref(), contribution),
                        attempt: 1,
                        timed_out: false,
                    });
                    if let Err(mpsc::SendError(subs)) = job_tx.send(ordered_subs)
                    {
                        // Runner thread died: unblock the group's workers,
                        // then surface the failure (liveness before
                        // failure — the catch_unwind tail completes the
                        // rest of the backlog).
                        let now = epoch.elapsed().as_secs_f64();
                        for sub in &subs {
                            if !sub.done.is_complete() {
                                sub.done.complete(now);
                            }
                        }
                        panic!("lane {lane} device runner died mid-commit");
                    }
                    // Capture the order's predicted per-slot stage
                    // seconds for calibration feedback via a recorded
                    // model replay — AFTER the send, so the replay
                    // overlaps the device run instead of delaying it
                    // (the proxy is single-threaded: `table` and
                    // `incumbent` cannot change before this finishes,
                    // and the earliest RunDone is received on the next
                    // loop iteration).
                    if calibrator.is_some() {
                        calib_probe.reset_for_table(&table, EngineState::default());
                        for &i in incumbent.iter() {
                            calib_probe.push_task_compiled(&table, i);
                        }
                        calib_probe.run_to_quiescence();
                        fold_timeline_stage_secs(
                            incumbent.len(),
                            calib_probe.timeline(),
                            &mut inflight_pred,
                        );
                    }
                    pending_tasks.clear();
                    incumbent.clear();
                    suffix_planned = false;
                    continue;
                }

                if closed {
                    break;
                }
                // Fully idle: the physical device has drained, so the
                // contiguous planning timeline ends; the next arrival
                // starts a fresh one. This is the only place a corrected
                // model may be adopted — the next merge rewinds the
                // cursor from a table compiled against it, so cursor and
                // table always share one model generation. Probe our own
                // lane briefly, then steal from the hottest sibling if we
                // stay dry.
                planner_live = false;
                if let Some(cal) = calibrator.as_mut() {
                    if let Some(c) = cal.adopt() {
                        cal_prof = CalibratedProfile::new(&base_model, c);
                        stats.n_recalibrations += 1;
                    }
                }
                match own.drain_into_timeout(
                    cap,
                    online.poll,
                    opts.settle,
                    &mut drained,
                ) {
                    DrainPoll::Drained(_) => merge_arrivals(
                        &cal_prof,
                        false,
                        &mut drained,
                        &mut pending_subs,
                        &mut pending_tasks,
                        &mut incumbent,
                        &mut table,
                        &mut lane_cursor,
                        &mut planner_live,
                        &mut last_commit_pred,
                        &mut plan_dirty,
                        &mut stats,
                    ),
                    DrainPoll::Closed => closed = true,
                    DrainPoll::Empty => {
                        // A half-open lane only drains its own backlog
                        // (one probe group at a time) — no stealing until
                        // a probe closes the breaker again.
                        if online.steal_max > 0 && breaker.state() == BreakerState::Closed {
                            let got = sharded.steal_with_health(
                                lane,
                                online.steal_max.min(cap),
                                &health,
                                &mut drained,
                            );
                            if got > 0 {
                                stats.n_stolen += got;
                                merge_arrivals(
                                    &cal_prof,
                                    false,
                                    &mut drained,
                                    &mut pending_subs,
                                    &mut pending_tasks,
                                    &mut incumbent,
                                    &mut table,
                                    &mut lane_cursor,
                                    &mut planner_live,
                                    &mut last_commit_pred,
                                    &mut plan_dirty,
                                    &mut stats,
                                );
                            }
                        }
                    }
                }
            }
        }));
        drop(job_tx);
        if let Err(payload) = result {
            // Liveness before failure, as in the legacy proxy: workers
            // routed to this lane block in done.wait() and would hang the
            // run scope forever if the proxy just died. Complete every
            // unsignalled event (the runner thread handles its own
            // in-flight group) and keep absorbing until all workers
            // exited, then surface the panic through the proxy's join.
            let now = epoch.elapsed().as_secs_f64();
            for sub in &pending_subs {
                if !sub.done.is_complete() {
                    sub.done.complete(now);
                }
            }
            loop {
                let now = epoch.elapsed().as_secs_f64();
                for sub in &drained {
                    if !sub.done.is_complete() {
                        sub.done.complete(now);
                    }
                }
                if own.drain_into(cap, Duration::ZERO, &mut drained).is_none() {
                    break;
                }
            }
            std::panic::resume_unwind(payload);
        }
    });

    let (fired, considered) = gate.counts();
    stats.n_replans = fired;
    stats.n_replan_considered = considered;
    let pc = scratch.prune_counters();
    stats.n_cands_pruned = pc.n_cands_pruned;
    stats.n_rollouts_early_exit = pc.n_rollouts_early_exit;
    stats.n_twin_collapsed = pc.n_twin_collapsed;
    record_calib_stats(&mut stats, calibrator.as_ref());
    LaneOutcome { stats, latencies, group_makespans }
}

/// Append drained (or stolen) submissions to the lane's uncommitted
/// suffix and recompile the pending table against the lane's current
/// (possibly calibrated) planning model. Starts a fresh contiguous
/// planning timeline when the lane was idle — rewinding the cursor *from
/// the freshly compiled table* so cursor and table can never disagree
/// about the model generation. `mid_group` marks arrivals that extend a
/// live plan (suffix non-empty or a group in flight) — the "merge into
/// the uncommitted suffix instead of queueing a fresh group" events
/// counted by [`LaneStats::n_merges`]. Shared with the fleet
/// coordinator, which calls it once per device.
#[allow(clippy::too_many_arguments)]
pub(crate) fn merge_arrivals(
    cal_prof: &CalibratedProfile,
    mid_group: bool,
    drained: &mut Vec<Submission>,
    pending_subs: &mut Vec<Submission>,
    pending_tasks: &mut Vec<TaskSpec>,
    incumbent: &mut Vec<usize>,
    table: &mut TaskTable,
    lane_cursor: &mut SimCursor,
    planner_live: &mut bool,
    last_commit_pred: &mut f64,
    plan_dirty: &mut bool,
    stats: &mut LaneStats,
) {
    if drained.is_empty() {
        return;
    }
    if mid_group || !pending_subs.is_empty() {
        stats.n_merges += 1;
    }
    for sub in drained.drain(..) {
        incumbent.push(pending_tasks.len());
        pending_tasks.push(sub.task.clone());
        pending_subs.push(sub);
    }
    table.compile_calibrated_into(pending_tasks, cal_prof);
    if !*planner_live {
        // Idle device: engines free now; the carry chain restarts on the
        // current model generation.
        lane_cursor.reset_for_table(table, EngineState::default());
        lane_cursor.commit_frontier();
        *planner_live = true;
        *last_commit_pred = 0.0;
    }
    *plan_dirty = true;
}

/// Turn the dirty suffix into a finalized plan: consult the drift gate
/// and either re-plan through `sched::online::replan_into` (overlapped
/// with device execution whenever possible) or keep the incumbent order,
/// in both cases recording the exact predicted completion clock on the
/// contiguous lane timeline. Shared with the fleet coordinator, which
/// calls it once per device.
#[allow(clippy::too_many_arguments)]
pub(crate) fn finalize_plan(
    policy: Policy,
    online: &OnlineOptions,
    table: &TaskTable,
    lane_cursor: &mut SimCursor,
    incumbent: &mut Vec<usize>,
    order_buf: &mut Vec<usize>,
    scratch: &mut OnlineScratch,
    gate: &mut DriftGate,
    suffix_planned: &mut bool,
    stats: &mut LaneStats,
    plan_dirty: &mut bool,
    pred_done: &mut f64,
) {
    let replan_allowed = policy == Policy::Heuristic && incumbent.len() > 1;
    // A never-planned suffix (fresh group, incumbent = arrival order)
    // gets its initial plan unconditionally; the drift threshold only
    // gates re-plans of an already-optimized incumbent.
    let fire = replan_allowed
        && if *suffix_planned {
            gate.should_replan()
        } else {
            gate.should_plan_initial()
        };
    if fire {
        let t0 = Instant::now();
        let r = replan_into(
            table,
            lane_cursor,
            incumbent,
            online.replan_width,
            scratch,
            order_buf,
        );
        let dt = t0.elapsed().as_secs_f64();
        stats.sched_overhead_secs += dt;
        stats.replan_secs.push(dt);
        std::mem::swap(incumbent, order_buf);
        *pred_done = r.predicted_done;
        *suffix_planned = true;
    } else {
        // Incumbent kept (gate closed, NoReorder, or trivial suffix):
        // exact predicted completion via push + finish + retract on the
        // committed cursor.
        for &i in incumbent.iter() {
            lane_cursor.push_task_compiled(table, i);
        }
        *pred_done = lane_cursor.run_to_quiescence();
        lane_cursor.replan_suffix();
    }
    *plan_dirty = false;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;
    use crate::device::executor::SpinExecutor;
    use crate::task::synthetic::synthetic_benchmark;

    fn workload(t: usize, n: usize, scale: f64) -> Vec<Vec<TaskSpec>> {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, scale).unwrap();
        (0..t)
            .map(|w| (0..n).map(|i| g.tasks[(w + i) % 4].clone()).collect())
            .collect()
    }

    fn coordinator(lanes: usize, policy: Policy) -> LaneCoordinator {
        LaneCoordinator::homogeneous(
            profile_by_name("amd_r9").unwrap(),
            Arc::new(SpinExecutor),
            LaneOptions { lanes, policy, ..LaneOptions::default() },
        )
    }

    #[test]
    fn two_lanes_complete_all_tasks() {
        let c = coordinator(2, Policy::Heuristic);
        let m = c.run(workload(4, 2, 0.1));
        assert_eq!(m.n_tasks, 8);
        assert_eq!(m.latencies.len(), 8);
        assert_eq!(m.per_lane.len(), 2);
        assert_eq!(m.per_lane.iter().map(|l| l.n_tasks).sum::<usize>(), 8);
        assert!(m.tasks_per_sec > 0.0);
        // Every lane that executed groups must carry a prediction.
        for l in &m.per_lane {
            if l.n_groups > 0 {
                assert!(l.predicted_secs > 0.0);
                assert!(l.busy_secs > 0.0);
            }
        }
    }

    #[test]
    fn lanes_partition_workers_evenly() {
        let c = coordinator(2, Policy::NoReorder);
        let m = c.run(workload(4, 3, 0.05));
        assert_eq!(m.n_tasks, 12);
        // Workers 0,2 → lane 0; workers 1,3 → lane 1: 6 tasks each.
        for l in &m.per_lane {
            assert_eq!(l.n_tasks, 6, "lane {}: {:?}", l.lane, m.per_lane);
        }
        assert_eq!(m.sched_overhead_secs, 0.0);
    }

    #[test]
    fn single_lane_matches_runner_semantics() {
        let c = coordinator(1, Policy::Heuristic);
        let m = c.run(workload(3, 2, 0.1));
        assert_eq!(m.n_tasks, 6);
        assert!(m.n_groups >= 2, "batch deps force >= 2 rounds");
        assert!(m.sched_overhead_secs > 0.0);
        assert!(m.p50_latency() <= m.p99_latency() + 1e-12);
    }

    #[test]
    fn group_cap_splits_large_drains() {
        let p = profile_by_name("amd_r9").unwrap();
        let c = LaneCoordinator::homogeneous(
            p,
            Arc::new(SpinExecutor),
            LaneOptions {
                lanes: 1,
                group_cap: 2,
                // No settle: groups form from whatever is buffered, the
                // cap bounds each batch.
                settle: Duration::ZERO,
                ..LaneOptions::default()
            },
        );
        let m = c.run(workload(4, 1, 0.05));
        assert_eq!(m.n_tasks, 4);
        for g in &m.group_makespans {
            assert!(*g > 0.0);
        }
        assert!(m.n_groups >= 2, "cap 2 over 4 tasks needs >= 2 groups");
    }

    #[test]
    fn empty_workload_terminates() {
        let c = coordinator(2, Policy::Heuristic);
        let m = c.run(Vec::new());
        assert_eq!(m.n_tasks, 0);
        assert_eq!(m.n_groups, 0);
        assert!(m.latencies.is_empty());
    }

    // ---- online (open-stream) mode ----------------------------------

    fn online_coordinator(
        lanes: usize,
        policy: Policy,
        online: OnlineOptions,
    ) -> LaneCoordinator {
        LaneCoordinator::homogeneous(
            profile_by_name("amd_r9").unwrap(),
            Arc::new(SpinExecutor),
            LaneOptions {
                lanes,
                policy,
                online: Some(online),
                ..LaneOptions::default()
            },
        )
    }

    #[test]
    fn online_completes_all_tasks_across_lanes() {
        let c = online_coordinator(2, Policy::Heuristic, OnlineOptions::default());
        let m = c.run(workload(4, 2, 0.1));
        assert_eq!(m.n_tasks, 8);
        assert_eq!(m.latencies.len(), 8);
        assert_eq!(m.per_lane.len(), 2);
        assert_eq!(m.per_lane.iter().map(|l| l.n_tasks).sum::<usize>(), 8);
        assert!(m.tasks_per_sec > 0.0);
        for l in &m.per_lane {
            if l.n_groups > 0 {
                assert!(l.predicted_secs > 0.0, "lane {}: {l:?}", l.lane);
                assert!(l.busy_secs > 0.0);
            }
        }
    }

    #[test]
    fn online_noreorder_never_replans() {
        let c = online_coordinator(1, Policy::NoReorder, OnlineOptions::default());
        let m = c.run(workload(3, 2, 0.05));
        assert_eq!(m.n_tasks, 6);
        assert_eq!(m.sched_overhead_secs, 0.0);
        let replans: usize = m.per_lane.iter().map(|l| l.n_replans).sum();
        let considered: usize =
            m.per_lane.iter().map(|l| l.n_replan_considered).sum();
        assert_eq!(replans, 0);
        assert_eq!(considered, 0, "NoReorder must never consult the gate");
        // Predictions still recorded for drift bookkeeping.
        assert!(m.per_lane[0].predicted_secs > 0.0);
    }

    #[test]
    fn online_infinite_drift_threshold_gates_off_replans() {
        let c = online_coordinator(
            1,
            Policy::Heuristic,
            OnlineOptions {
                drift_threshold: f64::INFINITY,
                ..OnlineOptions::default()
            },
        );
        let m = c.run(workload(4, 2, 0.05));
        assert_eq!(m.n_tasks, 8);
        assert_eq!(m.per_lane.iter().map(|l| l.n_replans).sum::<usize>(), 0);
        assert_eq!(m.sched_overhead_secs, 0.0);
        assert!(m.per_lane[0].replan_secs.is_empty());
    }

    #[test]
    fn online_finite_threshold_still_plans_fresh_groups() {
        // Regression: the drift gate must not suppress the *initial*
        // plan of a fresh suffix — with an accurate model and a finite
        // threshold, re-plans are gated off but every new multi-task
        // group still gets beam-planned (not raw FIFO).
        let c = online_coordinator(
            1,
            Policy::Heuristic,
            OnlineOptions { drift_threshold: 1e9, ..OnlineOptions::default() },
        );
        let m = c.run(workload(4, 2, 0.05));
        assert_eq!(m.n_tasks, 8);
        let fired: usize = m.per_lane.iter().map(|l| l.n_replans).sum();
        assert!(fired >= 1, "fresh groups went unplanned: {:?}", m.per_lane);
        assert!(m.sched_overhead_secs > 0.0);
    }

    #[test]
    fn online_steals_rebalance_skewed_lanes() {
        let _t = crate::util::timing::timing_test_lock();
        // 12 worker slots, but only even workers (all routed to lane 0 of
        // 2) carry tasks; group_cap 2 keeps lane 0's drains small so its
        // buffer stays hot while its device runs — the starved lane 1
        // must pick up part of the backlog through steals.
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 0.2).unwrap();
        let workloads: Vec<Vec<TaskSpec>> = (0..12)
            .map(|w| {
                if w % 2 == 0 {
                    (0..2).map(|i| g.tasks[(w + i) % 4].clone()).collect()
                } else {
                    Vec::new()
                }
            })
            .collect();
        let c = LaneCoordinator::homogeneous(
            p,
            Arc::new(SpinExecutor),
            LaneOptions {
                lanes: 2,
                policy: Policy::Heuristic,
                group_cap: 2,
                online: Some(OnlineOptions::default()),
                ..LaneOptions::default()
            },
        );
        let m = c.run(workloads);
        assert_eq!(m.n_tasks, 12, "{:?}", m.per_lane);
        assert_eq!(m.latencies.len(), 12);
        let stolen: usize = m.per_lane.iter().map(|l| l.n_stolen).sum();
        assert!(stolen > 0, "starved lane never stole: {:?}", m.per_lane);
        // The thief executed what it stole.
        assert!(m.per_lane[1].n_tasks > 0, "{:?}", m.per_lane);
    }

    #[test]
    fn online_merges_mid_group_with_trickling_arrivals() {
        let _t = crate::util::timing::timing_test_lock();
        // One lane, group_cap 2, four workers: the first drain commits
        // two submissions to the device and the other two are still
        // buffered while it runs — they must merge into the uncommitted
        // suffix (n_merges > 0) rather than wait out a fresh
        // settle-window round.
        let c = LaneCoordinator::homogeneous(
            profile_by_name("amd_r9").unwrap(),
            Arc::new(SpinExecutor),
            LaneOptions {
                lanes: 1,
                policy: Policy::Heuristic,
                group_cap: 2,
                online: Some(OnlineOptions::default()),
                ..LaneOptions::default()
            },
        );
        let m = c.run(workload(4, 3, 0.2));
        assert_eq!(m.n_tasks, 12);
        let merges: usize = m.per_lane.iter().map(|l| l.n_merges).sum();
        assert!(merges > 0, "no mid-group merges: {:?}", m.per_lane);
        let considered: usize =
            m.per_lane.iter().map(|l| l.n_replan_considered).sum();
        assert!(considered > 0);
        // Default gate (threshold 0) fires on every considered change.
        let fired: usize = m.per_lane.iter().map(|l| l.n_replans).sum();
        assert_eq!(fired, considered);
        assert_eq!(m.per_lane[0].replan_secs.len(), fired);
    }

    #[test]
    fn online_empty_workload_terminates() {
        let c = online_coordinator(2, Policy::Heuristic, OnlineOptions::default());
        let m = c.run(Vec::new());
        assert_eq!(m.n_tasks, 0);
        assert_eq!(m.n_groups, 0);
    }

    // ---- online recalibration --------------------------------------

    /// amd_r9 with both link bandwidths doubled: a model that believes
    /// transfers run twice as fast as the device actually paces them.
    fn miscalibrated_model() -> crate::config::DeviceProfile {
        let mut m = profile_by_name("amd_r9").unwrap();
        m.htd.bytes_per_sec *= 2.0;
        m.dth.bytes_per_sec *= 2.0;
        m
    }

    #[test]
    fn recalibration_off_reports_identity_factors() {
        let c = coordinator(1, Policy::Heuristic);
        let m = c.run(workload(3, 2, 0.1));
        assert_eq!(m.n_tasks, 6);
        for l in &m.per_lane {
            assert_eq!(l.n_recalibrations, 0);
            assert_eq!(l.n_calib_obs, 0);
            assert_eq!(l.calib_htd, 1.0);
            assert_eq!(l.calib_kernel, 1.0);
            assert_eq!(l.calib_dth, 1.0);
        }
    }

    #[test]
    fn recalibration_corrects_miscalibrated_links_legacy_path() {
        let _t = crate::util::timing::timing_test_lock();
        // Device executes the true amd_r9 pacing; the lane plans with a
        // model whose links are 2x too fast. The measured-rate feedback
        // must pull the transfer corrections well above 1 (toward ~2)
        // and adopt at least one corrected generation.
        let c = LaneCoordinator::homogeneous(
            profile_by_name("amd_r9").unwrap(),
            Arc::new(SpinExecutor),
            LaneOptions {
                lanes: 1,
                policy: Policy::Heuristic,
                recalibrate: Some(crate::model::CalibrateOptions::default()),
                ..LaneOptions::default()
            },
        )
        .with_plan_model(miscalibrated_model());
        let m = c.run(workload(4, 3, 0.2));
        assert_eq!(m.n_tasks, 12);
        let l = &m.per_lane[0];
        assert!(l.n_calib_obs > 0, "{l:?}");
        assert!(l.n_recalibrations >= 1, "{l:?}");
        assert!(
            l.calib_htd > 1.3 && l.calib_dth > 1.3,
            "transfer corrections should move toward ~2x: {l:?}"
        );
        // Kernel pacing is truthful, so its correction stays near 1.
        assert!(
            l.calib_kernel > 0.5 && l.calib_kernel < 1.5,
            "kernel correction should stay near identity: {l:?}"
        );
    }

    #[test]
    fn recalibration_on_truthful_model_keeps_factors_near_identity() {
        let _t = crate::util::timing::timing_test_lock();
        // Model == device: the feedback must NOT absorb the duplex
        // contention stretch into link corrections — the predicted side
        // comes from a recorded replay that models the same contention,
        // so residuals stay near 1 and factors near identity. (With
        // solo-stage predictions this drifts toward 1 + overlap*(σ-1).)
        let c = LaneCoordinator::homogeneous(
            profile_by_name("amd_r9").unwrap(),
            Arc::new(SpinExecutor),
            LaneOptions {
                lanes: 1,
                policy: Policy::Heuristic,
                recalibrate: Some(crate::model::CalibrateOptions::default()),
                ..LaneOptions::default()
            },
        );
        let m = c.run(workload(4, 3, 0.2));
        assert_eq!(m.n_tasks, 12);
        let l = &m.per_lane[0];
        assert!(l.n_calib_obs > 0, "{l:?}");
        for (name, f) in [
            ("htd", l.calib_htd),
            ("kernel", l.calib_kernel),
            ("dth", l.calib_dth),
        ] {
            assert!(
                f > 0.7 && f < 1.3,
                "{name} factor drifted on a truthful model: {l:?}"
            );
        }
    }

    #[test]
    fn recalibration_online_mode_observes_and_completes() {
        let _t = crate::util::timing::timing_test_lock();
        let c = LaneCoordinator::homogeneous(
            profile_by_name("amd_r9").unwrap(),
            Arc::new(SpinExecutor),
            LaneOptions {
                lanes: 1,
                policy: Policy::Heuristic,
                online: Some(OnlineOptions::default()),
                recalibrate: Some(crate::model::CalibrateOptions::default()),
                ..LaneOptions::default()
            },
        )
        .with_plan_model(miscalibrated_model());
        let m = c.run(workload(4, 3, 0.2));
        assert_eq!(m.n_tasks, 12);
        assert_eq!(m.latencies.len(), 12);
        let l = &m.per_lane[0];
        assert!(l.n_calib_obs > 0, "online lane never observed: {l:?}");
    }

    #[test]
    fn fault_free_run_with_recovery_armed_reports_zero_fault_counters() {
        // Arming recovery on a healthy device must be free: same task
        // count, all six fault counters at zero, on both pipelines.
        for online in [None, Some(OnlineOptions::default())] {
            let c = LaneCoordinator::homogeneous(
                profile_by_name("amd_r9").unwrap(),
                Arc::new(SpinExecutor),
                LaneOptions {
                    lanes: 2,
                    policy: Policy::Heuristic,
                    online,
                    recovery: Some(RecoveryOptions::default()),
                    ..LaneOptions::default()
                },
            );
            let m = c.run(workload(4, 2, 0.1));
            assert_eq!(m.n_tasks, 8);
            for l in &m.per_lane {
                assert_eq!(l.n_faults, 0, "{l:?}");
                assert_eq!(l.n_retries, 0, "{l:?}");
                assert_eq!(l.n_timeouts, 0, "{l:?}");
                assert_eq!(l.n_requeued, 0, "{l:?}");
                assert_eq!(l.n_quarantine_trips, 0, "{l:?}");
                assert_eq!(l.n_halfopen_probes, 0, "{l:?}");
            }
        }
    }

    #[test]
    fn legacy_lane_retries_transient_device_error_to_completion() {
        use crate::coordinator::recovery::RetryBackoff;
        use crate::device::{ChaosDevice, ChaosOptions, SimDevice};

        let p = profile_by_name("amd_r9").unwrap();
        // Transient chaos: every first attempt of a faulting group errors,
        // the immediate re-run is clean — the retry policy must absorb it.
        let dev: Arc<dyn Device> = Arc::new(ChaosDevice::new(
            Arc::new(SimDevice::new(p)),
            ChaosOptions {
                seed: 0xfab1e,
                p_error: 0.8,
                transient: true,
                ..ChaosOptions::default()
            },
        ));
        let c = LaneCoordinator::with_devices(
            vec![dev],
            LaneOptions {
                lanes: 1,
                policy: Policy::Heuristic,
                recovery: Some(RecoveryOptions::retry(RetryBackoff {
                    base: Duration::from_micros(50),
                    cap: Duration::from_micros(200),
                    ..RetryBackoff::default()
                })),
                ..LaneOptions::default()
            },
        );
        let m = c.run(workload(3, 2, 0.1));
        assert_eq!(m.n_tasks, 6, "all tasks complete despite faults");
        let l = &m.per_lane[0];
        assert_eq!(l.n_retries, l.n_faults, "every fault was retried: {l:?}");
        assert!(l.n_faults > 0, "chaos at p=0.8 never fired: {l:?}");
        // Retried groups are excluded from calibration (none armed here,
        // but the quarantine machinery must have stayed silent).
        assert_eq!(l.n_quarantine_trips, 0, "{l:?}");
    }

    // ---- multi-tenant admission -------------------------------------

    #[test]
    fn wake_signal_survives_poisoning() {
        // A producer panicking inside notify() poisons the epoch mutex;
        // a parked planner must still wake and later waits must not
        // panic — the poison-recovery liveness regression test.
        let w = Arc::new(WakeSignal::new());
        let w2 = w.clone();
        let poisoner = std::thread::spawn(move || {
            let _g = w2.epoch.lock().unwrap();
            panic!("poison the wake-signal lock");
        })
        .join();
        assert!(poisoner.is_err());
        let seen = w.epoch();
        let w3 = w.clone();
        let parker = std::thread::spawn(move || {
            w3.wait_past(seen, Instant::now() + Duration::from_secs(5));
        });
        w.notify();
        parker.join().expect("parked waiter woke across poisoning");
        assert!(w.epoch() > seen);
    }

    #[test]
    fn admission_armed_lanes_complete_and_report() {
        use crate::coordinator::admission::{
            AdmissionOptions, DrainPolicyKind, Priority, TenantId,
        };
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 0.05).unwrap();
        let c = LaneCoordinator::homogeneous(
            p,
            Arc::new(SpinExecutor),
            LaneOptions {
                lanes: 1,
                policy: Policy::NoReorder,
                admission: Some(AdmissionOptions {
                    policy: DrainPolicyKind::StrictPriority,
                    ..AdmissionOptions::default()
                }),
                ..LaneOptions::default()
            },
        );
        let workloads: Vec<TenantWorkload> = (0..3)
            .map(|w| TenantWorkload {
                tenant: TenantId(w as u32),
                class: if w == 0 { Priority::Hi } else { Priority::BestEffort },
                deadline: None,
                tasks: (0..2).map(|i| g.tasks[(w + i) % 4].clone()).collect(),
            })
            .collect();
        let m = c.run_tenants(workloads);
        assert_eq!(m.n_tasks, 6, "caps are ample: nothing sheds or blocks");
        assert_eq!(m.latency_tenants.len(), m.latencies.len());
        let rep = m.admission.as_ref().expect("armed run carries a report");
        assert_eq!(rep.n_shed, 0);
        assert_eq!(rep.per_tenant.len(), 3);
        for t in &rep.per_tenant {
            assert_eq!(t.n_completed, 2, "{t:?}");
            assert!(t.p99_latency >= t.p50_latency - 1e-12);
        }
        assert!(rep.jain_fairness > 0.0 && rep.jain_fairness <= 1.0 + 1e-12);
    }

    #[test]
    fn collapse_twins_dedups_identical_rows_across_tenants() {
        use crate::coordinator::admission::{
            AdmissionOptions, Priority, TenantId,
        };
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 0.05).unwrap();
        let c = LaneCoordinator::homogeneous(
            p,
            Arc::new(SpinExecutor),
            LaneOptions {
                lanes: 1,
                policy: Policy::NoReorder,
                // Let all four workers' submissions settle into one group
                // so the cross-tenant twins actually meet in a drain.
                settle: Duration::from_millis(40),
                admission: Some(AdmissionOptions::default()),
                ..LaneOptions::default()
            },
        );
        // Four tenants submit the *same* task spec: one representative
        // should execute per drained group, completions fan out to all.
        let workloads: Vec<TenantWorkload> = (0..4)
            .map(|w| TenantWorkload {
                tenant: TenantId(w as u32),
                class: Priority::Normal,
                deadline: None,
                tasks: vec![g.tasks[0].clone()],
            })
            .collect();
        let m = c.run_tenants(workloads);
        assert_eq!(m.n_tasks, 4, "every submission completes");
        assert_eq!(m.latencies.len(), 4);
        let collapsed: u64 =
            m.per_lane.iter().map(|l| l.n_xtenant_collapsed).sum();
        assert!(collapsed > 0, "identical rows never collapsed: {m:?}");
    }
}
