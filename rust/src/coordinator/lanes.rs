//! The multi-lane coordinator — the Fig. 8 proxy runtime sharded so the
//! *scheduler* scales with the host, not just the device.
//!
//! The single-buffer coordinator (`coordinator::runner`) serializes every
//! drained task group through one proxy thread: reorder, submit, signal,
//! repeat. Table 6's premise — reordering overhead stays negligible while
//! task groups keep arriving — breaks on a many-core host the moment one
//! proxy becomes the bottleneck. This module splits the pipeline into
//! `L` independent **lanes**:
//!
//! * worker `w` always submits to lane `w % L`
//!   ([`ShardedBuffer`]), so each worker's dependent batch drains in
//!   order through one lane — per-worker submission order is preserved by
//!   construction, exactly the guarantee the single buffer gave;
//! * each lane runs its own proxy thread with a **batched drain**
//!   (`drain_into` into a reused Vec, up to `group_cap` submissions per
//!   task group), its own reorder arena ([`ParBeamScratch`], so big
//!   groups can additionally fan candidate scoring out over
//!   `scoring_threads` stripes), and its own virtual device — independent
//!   task groups are reordered and executed concurrently on different
//!   lanes;
//! * each lane keeps a persistent paused [`SimCursor`] + [`TaskTable`]
//!   pair: the group is compiled **once** per drain and shared between
//!   the search and the prediction bookkeeping (the heuristic's own
//!   chosen-order makespan is recorded directly; NoReorder drains are
//!   replayed through the lane cursor, allocation-free once warm) — the
//!   per-lane prediction drift is reported in [`LaneStats`], and the
//!   paused-cursor substrate is what the upcoming online-rescheduling
//!   work resumes mid-group.
//!
//! [`CoordMetrics`]-style aggregates plus per-lane breakdowns come back
//! in [`LaneMetrics`]; `benches/coordinator_throughput.rs` sweeps
//! workers × lanes × group size over this runtime and emits
//! `BENCH_coordinator_throughput.json`.
//!
//! [`CoordMetrics`]: crate::coordinator::runner::CoordMetrics
//! [`ShardedBuffer`]: crate::coordinator::buffer::ShardedBuffer

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::DeviceProfile;
use crate::coordinator::buffer::{ShardedBuffer, SharedBuffer, Submission};
use crate::coordinator::runner::Policy;
use crate::device::executor::KernelExecutor;
use crate::device::vdev::VirtualDevice;
use crate::model::{EngineState, SimCursor, TaskTable};
use crate::queue::event::Event;
use crate::sched::heuristic::DEFAULT_BEAM_WIDTH;
use crate::sched::parallel::{batch_reorder_table_parallel_into, ParBeamScratch};
use crate::task::TaskSpec;
use crate::util::stats;

/// Knobs of the sharded runtime.
#[derive(Clone, Copy, Debug)]
pub struct LaneOptions {
    /// Lane count for [`LaneCoordinator::homogeneous`] (ignored by
    /// [`LaneCoordinator::with_devices`], which derives it from the
    /// device list).
    pub lanes: usize,
    pub policy: Policy,
    /// Proxy settle window while forming a task group (how long a lane
    /// waits for stragglers once something is buffered).
    pub settle: Duration,
    /// Max submissions drained per task group (the batched-drain size).
    /// 0 = one full round of the lane's workers: `ceil(T / lanes)`.
    pub group_cap: usize,
    /// Scoring stripes per lane reorder (1 = serial candidate scoring).
    pub scoring_threads: usize,
}

impl Default for LaneOptions {
    fn default() -> Self {
        LaneOptions {
            lanes: 1,
            policy: Policy::Heuristic,
            settle: Duration::from_micros(300),
            group_cap: 0,
            scoring_threads: 1,
        }
    }
}

/// Per-lane breakdown of one run.
#[derive(Clone, Debug)]
pub struct LaneStats {
    pub lane: usize,
    pub n_groups: usize,
    pub n_tasks: usize,
    /// CPU seconds this lane's proxy spent inside the reorder heuristic.
    pub sched_overhead_secs: f64,
    /// Device-measured busy seconds (sum of group makespans).
    pub busy_secs: f64,
    /// Model-predicted busy seconds for the same orders (paused-cursor
    /// replay); `busy_secs / predicted_secs` is the lane's pacing drift.
    pub predicted_secs: f64,
}

/// Aggregate metrics of one sharded run (single-lane degenerates to the
/// classic [`CoordMetrics`] numbers; `runner::Coordinator` delegates).
///
/// [`CoordMetrics`]: crate::coordinator::runner::CoordMetrics
#[derive(Clone, Debug)]
pub struct LaneMetrics {
    pub total_secs: f64,
    /// Executed tasks per second — the paper's "tasks throughput".
    pub tasks_per_sec: f64,
    /// Per-task submission → completion latency (s), all lanes.
    pub latencies: Vec<f64>,
    /// Device busy time per group (s), all lanes.
    pub group_makespans: Vec<f64>,
    pub sched_overhead_secs: f64,
    pub n_groups: usize,
    pub n_tasks: usize,
    pub per_lane: Vec<LaneStats>,
}

impl LaneMetrics {
    pub fn mean_latency(&self) -> f64 {
        stats::mean(&self.latencies)
    }

    pub fn p50_latency(&self) -> f64 {
        stats::percentile(&self.latencies, 50.0)
    }

    pub fn p99_latency(&self) -> f64 {
        stats::percentile(&self.latencies, 99.0)
    }

    /// Fraction of wall-clock the proxies spent scheduling (the Table-6
    /// "overhead share" extended to the multi-lane runtime).
    pub fn sched_overhead_share(&self) -> f64 {
        if self.total_secs <= 0.0 {
            return 0.0;
        }
        self.sched_overhead_secs / self.total_secs
    }
}

/// What one lane proxy hands back when its buffer closes.
struct LaneOutcome {
    stats: LaneStats,
    latencies: Vec<f64>,
    group_makespans: Vec<f64>,
}

/// The sharded multi-worker runtime (see module docs).
pub struct LaneCoordinator {
    devices: Vec<Arc<VirtualDevice>>,
    opts: LaneOptions,
}

impl LaneCoordinator {
    /// One lane per entry of `devices` (heterogeneous lanes allowed; each
    /// proxy schedules against its own device's profile).
    pub fn with_devices(devices: Vec<Arc<VirtualDevice>>, opts: LaneOptions) -> Self {
        assert!(!devices.is_empty(), "need at least one lane device");
        LaneCoordinator { devices, opts }
    }

    /// `opts.lanes` identical lanes over copies of one profile/executor.
    pub fn homogeneous(
        profile: DeviceProfile,
        executor: Arc<dyn KernelExecutor>,
        opts: LaneOptions,
    ) -> Self {
        let devices = (0..opts.lanes.max(1))
            .map(|_| {
                Arc::new(VirtualDevice::new(profile.clone(), executor.clone()))
            })
            .collect();
        LaneCoordinator { devices, opts }
    }

    pub fn n_lanes(&self) -> usize {
        self.devices.len()
    }

    /// Run `workloads[w]` = the dependent task batch of worker `w` (each
    /// worker submits its next task only after the previous completed).
    pub fn run(&self, workloads: Vec<Vec<TaskSpec>>) -> LaneMetrics {
        let t_workers = workloads.len();
        let lanes = self.devices.len();
        let sharded = ShardedBuffer::new(lanes);
        let epoch = Instant::now();

        let mut outcomes: Vec<LaneOutcome> = Vec::with_capacity(lanes);
        std::thread::scope(|s| {
            // ---- workers ------------------------------------------------
            let mut worker_handles = Vec::with_capacity(t_workers);
            for (w, batch) in workloads.into_iter().enumerate() {
                let sharded = sharded.clone();
                let h = std::thread::Builder::new()
                    .name(format!("worker-{w}"))
                    .spawn_scoped(s, move || {
                        for (seq, task) in batch.into_iter().enumerate() {
                            let done = Event::new();
                            sharded.push(Submission {
                                worker: w,
                                batch_seq: seq,
                                task,
                                done: done.clone(),
                                submitted_at: epoch.elapsed().as_secs_f64(),
                            });
                            // Dependency: wait before submitting the next.
                            done.wait();
                        }
                    })
                    .expect("spawn worker");
                worker_handles.push(h);
            }

            // ---- janitor: close every lane once all workers exited ----
            let sharded_j = sharded.clone();
            std::thread::Builder::new()
                .name("lane-janitor".into())
                .spawn_scoped(s, move || {
                    // Collect results first and close the lanes even when a
                    // worker panicked: re-raising before close_all would
                    // leave every proxy blocked in drain_into forever and
                    // hang the scope instead of surfacing the panic.
                    let results: Vec<_> =
                        worker_handles.into_iter().map(|h| h.join()).collect();
                    sharded_j.close_all();
                    for r in results {
                        if let Err(payload) = r {
                            std::panic::resume_unwind(payload);
                        }
                    }
                })
                .expect("spawn janitor");

            // ---- lane proxies ------------------------------------------
            let proxy_handles: Vec<_> = (0..lanes)
                .map(|l| {
                    let buffer = sharded.lane(l).clone();
                    let device = Arc::clone(&self.devices[l]);
                    let opts = self.opts;
                    // group_cap = 0: one full round of THIS lane's workers
                    // (those with w % lanes == l) — a global ceil(T/lanes)
                    // would make under-populated lanes sleep out the whole
                    // settle window on every group.
                    let cap = if opts.group_cap == 0 {
                        t_workers.saturating_sub(l).div_ceil(lanes).max(1)
                    } else {
                        opts.group_cap.max(1)
                    };
                    std::thread::Builder::new()
                        .name(format!("lane-proxy-{l}"))
                        .spawn_scoped(s, move || {
                            lane_proxy(l, buffer, device, opts, cap, epoch)
                        })
                        .expect("spawn lane proxy")
                })
                .collect();
            for h in proxy_handles {
                outcomes.push(h.join().expect("lane proxy panicked"));
            }
        });

        let total_secs = epoch.elapsed().as_secs_f64();
        let mut latencies = Vec::new();
        let mut group_makespans = Vec::new();
        let mut per_lane = Vec::with_capacity(lanes);
        let (mut overhead, mut n_groups, mut n_tasks) = (0.0, 0, 0);
        for o in outcomes {
            latencies.extend(o.latencies);
            group_makespans.extend(o.group_makespans);
            overhead += o.stats.sched_overhead_secs;
            n_groups += o.stats.n_groups;
            n_tasks += o.stats.n_tasks;
            per_lane.push(o.stats);
        }
        LaneMetrics {
            total_secs,
            tasks_per_sec: n_tasks as f64 / total_secs,
            latencies,
            group_makespans,
            sched_overhead_secs: overhead,
            n_groups,
            n_tasks,
            per_lane,
        }
    }
}

/// One lane's proxy loop: batched drain → reorder (persistent arena) →
/// device run → completion signals. All per-group buffers are reused, so
/// a warm lane performs no allocation on its drain path beyond the task
/// clones handed to the device.
fn lane_proxy(
    lane: usize,
    buffer: SharedBuffer,
    device: Arc<VirtualDevice>,
    opts: LaneOptions,
    cap: usize,
    epoch: Instant,
) -> LaneOutcome {
    let profile = device.profile().clone();
    let mut scratch = ParBeamScratch::new(opts.scoring_threads);
    let mut order: Vec<usize> = Vec::new();
    let mut drained: Vec<Submission> = Vec::new();
    let mut tasks: Vec<TaskSpec> = Vec::new();
    let mut ordered: Vec<TaskSpec> = Vec::new();
    // Persistent paused-cursor pair: the table is compiled once per
    // drained group (shared with the search); the cursor replays
    // NoReorder orders for the predicted-makespan record (the heuristic
    // reports its chosen order's makespan itself).
    let mut lane_table = TaskTable::new();
    let mut lane_cursor = SimCursor::detached();

    let mut latencies = Vec::new();
    let mut group_makespans = Vec::new();
    let mut stats = LaneStats {
        lane,
        n_groups: 0,
        n_tasks: 0,
        sched_overhead_secs: 0.0,
        busy_secs: 0.0,
        predicted_secs: 0.0,
    };

    while buffer.drain_into(cap, opts.settle, &mut drained).is_some() {
        let group = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            tasks.clear();
            tasks.extend(drained.iter().map(|s| s.task.clone()));
            // Compiled once per drained group; shared by the search and
            // the prediction bookkeeping.
            lane_table.compile_into(&tasks, &profile);
            match opts.policy {
                Policy::NoReorder => {
                    order.clear();
                    order.extend(0..tasks.len());
                    // Model prediction for the arrival order
                    // (allocation-free replay through the lane cursor).
                    lane_cursor.reset(&profile, EngineState::default());
                    for &i in &order {
                        lane_cursor.push_task_compiled(&lane_table, i);
                    }
                    stats.predicted_secs += lane_cursor.run_to_quiescence();
                }
                Policy::Heuristic => {
                    let t0 = Instant::now();
                    let predicted = batch_reorder_table_parallel_into(
                        &lane_table,
                        EngineState::default(),
                        DEFAULT_BEAM_WIDTH,
                        &mut scratch,
                        &mut order,
                    );
                    stats.sched_overhead_secs += t0.elapsed().as_secs_f64();
                    stats.predicted_secs += predicted;
                }
            }

            ordered.clear();
            ordered.extend(order.iter().map(|&i| tasks[i].clone()));
            let run = device.run_group(&ordered);
            group_makespans.push(run.makespan);
            stats.busy_secs += run.makespan;
            let now = epoch.elapsed().as_secs_f64();
            // Signal completions (device timestamps are group-relative;
            // the workers only need the ordering, latency uses wall time).
            for (slot, &orig) in order.iter().enumerate() {
                let sub = &drained[orig];
                sub.done.complete(now - run.makespan + run.task_end[slot]);
                latencies.push(now - sub.submitted_at);
            }
            stats.n_groups += 1;
            stats.n_tasks += drained.len();
        }));
        if let Err(payload) = group {
            // Liveness before failure: workers routed to this lane block
            // in `done.wait()` and would hang `run`'s scope forever if
            // the proxy just died. Complete this group's events and keep
            // draining-and-completing until every worker exited, then
            // surface the panic through the proxy's join.
            loop {
                let now = epoch.elapsed().as_secs_f64();
                for sub in &drained {
                    if !sub.done.is_complete() {
                        sub.done.complete(now);
                    }
                }
                if buffer.drain_into(cap, Duration::ZERO, &mut drained).is_none()
                {
                    break;
                }
            }
            std::panic::resume_unwind(payload);
        }
    }
    LaneOutcome { stats, latencies, group_makespans }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;
    use crate::device::executor::SpinExecutor;
    use crate::task::synthetic::synthetic_benchmark;

    fn workload(t: usize, n: usize, scale: f64) -> Vec<Vec<TaskSpec>> {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, scale).unwrap();
        (0..t)
            .map(|w| (0..n).map(|i| g.tasks[(w + i) % 4].clone()).collect())
            .collect()
    }

    fn coordinator(lanes: usize, policy: Policy) -> LaneCoordinator {
        LaneCoordinator::homogeneous(
            profile_by_name("amd_r9").unwrap(),
            Arc::new(SpinExecutor),
            LaneOptions { lanes, policy, ..LaneOptions::default() },
        )
    }

    #[test]
    fn two_lanes_complete_all_tasks() {
        let c = coordinator(2, Policy::Heuristic);
        let m = c.run(workload(4, 2, 0.1));
        assert_eq!(m.n_tasks, 8);
        assert_eq!(m.latencies.len(), 8);
        assert_eq!(m.per_lane.len(), 2);
        assert_eq!(m.per_lane.iter().map(|l| l.n_tasks).sum::<usize>(), 8);
        assert!(m.tasks_per_sec > 0.0);
        // Every lane that executed groups must carry a prediction.
        for l in &m.per_lane {
            if l.n_groups > 0 {
                assert!(l.predicted_secs > 0.0);
                assert!(l.busy_secs > 0.0);
            }
        }
    }

    #[test]
    fn lanes_partition_workers_evenly() {
        let c = coordinator(2, Policy::NoReorder);
        let m = c.run(workload(4, 3, 0.05));
        assert_eq!(m.n_tasks, 12);
        // Workers 0,2 → lane 0; workers 1,3 → lane 1: 6 tasks each.
        for l in &m.per_lane {
            assert_eq!(l.n_tasks, 6, "lane {}: {:?}", l.lane, m.per_lane);
        }
        assert_eq!(m.sched_overhead_secs, 0.0);
    }

    #[test]
    fn single_lane_matches_runner_semantics() {
        let c = coordinator(1, Policy::Heuristic);
        let m = c.run(workload(3, 2, 0.1));
        assert_eq!(m.n_tasks, 6);
        assert!(m.n_groups >= 2, "batch deps force >= 2 rounds");
        assert!(m.sched_overhead_secs > 0.0);
        assert!(m.p50_latency() <= m.p99_latency() + 1e-12);
    }

    #[test]
    fn group_cap_splits_large_drains() {
        let p = profile_by_name("amd_r9").unwrap();
        let c = LaneCoordinator::homogeneous(
            p,
            Arc::new(SpinExecutor),
            LaneOptions {
                lanes: 1,
                group_cap: 2,
                // No settle: groups form from whatever is buffered, the
                // cap bounds each batch.
                settle: Duration::ZERO,
                ..LaneOptions::default()
            },
        );
        let m = c.run(workload(4, 1, 0.05));
        assert_eq!(m.n_tasks, 4);
        for g in &m.group_makespans {
            assert!(*g > 0.0);
        }
        assert!(m.n_groups >= 2, "cap 2 over 4 tasks needs >= 2 groups");
    }

    #[test]
    fn empty_workload_terminates() {
        let c = coordinator(2, Policy::Heuristic);
        let m = c.run(Vec::new());
        assert_eq!(m.n_tasks, 0);
        assert_eq!(m.n_groups, 0);
        assert!(m.latencies.is_empty());
    }
}
