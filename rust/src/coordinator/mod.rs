//! The §6.2 multi-worker runtime: worker threads offload dependent task
//! batches through a shared buffer; a host proxy thread forms task groups,
//! reorders them with the Batch Reordering heuristic and drives the
//! virtual device.

pub mod buffer;
pub mod runner;

pub use buffer::{SharedBuffer, Submission};
pub use runner::{CoordMetrics, Coordinator, Policy};
