//! The §6.2 multi-worker runtime: worker threads offload dependent task
//! batches through a shared buffer; host proxy threads form task groups,
//! reorder them with the Batch Reordering heuristic and drive the virtual
//! device.
//!
//! * `buffer` — the MPSC submission buffer ([`SharedBuffer`]) and its
//!   per-lane sharding ([`ShardedBuffer`]), with bounded-wait drains and
//!   the bounded work-stealing primitive the online lanes use.
//! * `lanes` — the sharded runtime ([`LaneCoordinator`]): per-lane proxy
//!   threads with batched drains, persistent reorder arenas (optionally
//!   parallel candidate scoring), paused prediction cursors, and the
//!   online open-stream pipeline (mid-group merge, drift-gated suffix
//!   re-plans, cross-round `EngineState` carry, lane work-stealing).
//! * `runner` — the classic single-proxy harness, now a single-lane
//!   facade over `lanes`.

pub mod buffer;
pub mod lanes;
pub mod runner;

pub use buffer::{DrainPoll, ShardedBuffer, SharedBuffer, Submission};
pub use lanes::{LaneCoordinator, LaneMetrics, LaneOptions, LaneStats};
pub use runner::{CoordMetrics, Coordinator, Policy};
