//! The §6.2 multi-worker runtime: worker threads offload dependent task
//! batches through a shared buffer; host proxy threads form task groups,
//! reorder them with the Batch Reordering heuristic and drive the virtual
//! device.
//!
//! * `buffer` — the MPSC submission buffer ([`SharedBuffer`]) and its
//!   per-lane sharding ([`ShardedBuffer`]), with bounded-wait drains and
//!   the bounded work-stealing primitive the online lanes use.
//! * `lanes` — the sharded runtime ([`LaneCoordinator`]): per-lane proxy
//!   threads with batched drains, persistent reorder arenas (optionally
//!   parallel candidate scoring), paused prediction cursors, and the
//!   online open-stream pipeline (mid-group merge, drift-gated suffix
//!   re-plans, cross-round `EngineState` carry, lane work-stealing).
//! * `fleet` — the heterogeneous multi-device runtime
//!   ([`FleetCoordinator`]): one ingress stream placed across per-device
//!   lanes by calibrated earliest-completion-time, each device running
//!   its own online pipeline, with breaker-aware cross-device stealing
//!   gated on the thief's calibrated win prediction.
//! * `admission` — the multi-tenant ingress-robustness layer: bounded
//!   per-tenant backlogs with a validated [`AdmissionOptions`]
//!   (per-tenant and global caps, `Block` / `ShedLowest` / `RejectNew`
//!   overflow), pluggable drain ordering ([`AdmissionPolicy`]:
//!   weighted-fair DRR over tenants, strict priority classes,
//!   deadline-EDF), typed [`Shed`] receipts, and the reservation ledger
//!   ([`AdmissionCtl`]) that makes steals cap-neutral and accepted
//!   tasks lose-proof. `admission: None` keeps the untracked pipeline
//!   bit-for-bit.
//! * `recovery` — fault tolerance: the pluggable [`RecoveryPolicy`]
//!   trait (fail-fast / retry-with-backoff / blacklist-after-N), the
//!   run-deadline watchdog formula, and the per-lane circuit breaker
//!   ([`FleetHealth`]) behind lane quarantine and health-aware stealing.
//! * `driver` — the unified submission surface: one [`Driver`] trait
//!   (`run` / `run_tenants` → [`RunReport`]) implemented by all three
//!   coordinators as pure delegation, the validated [`DriverBuilder`]
//!   construction path, and the typed [`ConfigError`] returned by the
//!   shared `validate()` sweep on every options struct. The trace
//!   service (`crate::trace`) and the examples target this surface.
//! * `runner` — the classic single-proxy harness, now a single-lane
//!   facade over `lanes`.

pub mod admission;
pub mod buffer;
pub mod driver;
pub mod fleet;
pub mod lanes;
pub mod recovery;
pub mod runner;

pub use admission::{
    AdmissionCtl, AdmissionGate, AdmissionOptions, AdmissionPolicy,
    AdmissionReport, CapHit, DrainPolicyKind, Overflow, Priority, Shed,
    ShedReason, ShedSlot, SubmitOutcome, TenantId, TenantReport,
};
pub use buffer::{DrainPoll, ShardedBuffer, SharedBuffer, Submission};
pub use driver::{ConfigError, Driver, DriverBuilder, FleetExtras, RunReport};
pub use fleet::{FleetCoordOptions, FleetCoordinator, FleetMetrics};
pub use lanes::{LaneCoordinator, LaneMetrics, LaneOptions, LaneStats};
pub use recovery::{
    BlacklistAfterN, BreakerState, DeadlineOptions, FailFast, FailureCtx,
    FaultKind, FleetHealth, LaneBreaker, QuarantineOptions, RecoveryAction,
    RecoveryOptions, RecoveryPolicy, RetryBackoff,
};
pub use runner::{CoordMetrics, Coordinator, Policy};
