//! Fault-tolerance policy for the lane coordinator.
//!
//! The lane runtime (`coordinator::lanes`) executes device runs that can
//! fail three ways: an `Err` from [`Device::run_group`], a panic out of
//! it, or a hang (detected by the run-deadline watchdog). This module
//! holds everything the runtime consults to decide what happens next:
//!
//! * [`RecoveryPolicy`] — a pluggable trait mapping a failure context to
//!   an action, in the PySchedCL spirit of policy-as-trait. Shipped
//!   impls: [`FailFast`] (today's behavior: re-raise), [`RetryBackoff`]
//!   (exponential backoff with a per-group attempt cap) and
//!   [`BlacklistAfterN`] (retry until a lane looks sick, then quarantine
//!   it).
//! * [`LaneBreaker`] / [`FleetHealth`] — a per-lane circuit breaker with
//!   the classic three states: **Closed** (healthy), **Open**
//!   (quarantined: the lane runs nothing and its backlog is fair game
//!   for siblings via `ShardedBuffer::steal_with_health`), **HalfOpen**
//!   (cooldown elapsed; the next own-lane group is a probe — success
//!   closes the breaker, failure re-opens it).
//! * [`DeadlineOptions`] — the watchdog formula
//!   `deadline = predicted × slack + floor`: the predicted group
//!   makespan comes from the planning model that scheduled the group, so
//!   a hung run is declared dead relative to what the plan *promised*,
//!   not a global constant.
//!
//! Failed, retried and timed-out runs never feed
//! [`Calibrator`](crate::model::Calibrator) or
//! [`DriftGate`](crate::sched::online::DriftGate) — a partial timeline
//! would register as huge drift (the same bug class as the PR 5 zero
//! makespan fix). The exclusion is enforced in `coordinator::lanes` and
//! tested in `model::calibrate` and `rust/tests/prop_recovery.rs`.

use std::fmt;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::driver::ConfigError;

/// How a device run failed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// `run_group` returned `Err` (transient transport/backend fault).
    Error,
    /// `run_group` panicked (driver abort).
    Panic,
    /// The run-deadline watchdog fired before the run completed.
    Timeout,
}

/// Everything a policy may condition on when a run fails.
#[derive(Clone, Debug)]
pub struct FailureCtx {
    /// Lane the failure happened on.
    pub lane: usize,
    /// Attempt number of the failed run, starting at 1 (so `attempt`
    /// runs of this group have now failed when the policy is consulted).
    pub attempt: usize,
    /// Consecutive failed runs on this lane (across groups), including
    /// this one; reset by any clean completion.
    pub lane_consecutive_failures: usize,
    pub kind: FaultKind,
}

/// What the lane runtime should do about a failed run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecoveryAction {
    /// Re-raise: propagate the fault as a lane panic (today's behavior).
    FailFast,
    /// Re-run the same group on the same lane after `backoff`.
    Retry { backoff: Duration },
    /// Trip the lane's breaker; requeue its unstarted work for siblings.
    Quarantine,
}

/// Pluggable recovery policy (one impl per strategy, PySchedCL-style).
pub trait RecoveryPolicy: Send + Sync + fmt::Debug {
    fn on_failure(&self, ctx: &FailureCtx) -> RecoveryAction;
    /// Stable name for stats/bench rows.
    fn name(&self) -> &'static str;
    /// Reject nonsense knob combinations with a typed error. Defaulted
    /// to `Ok(())` so existing third-party impls stay source-compatible;
    /// consulted by [`RecoveryOptions::validate`] on the builder path.
    fn validate(&self) -> Result<(), ConfigError> {
        Ok(())
    }
}

/// Today's behavior: any fault aborts the coordinator run.
#[derive(Clone, Copy, Debug, Default)]
pub struct FailFast;

impl RecoveryPolicy for FailFast {
    fn on_failure(&self, _ctx: &FailureCtx) -> RecoveryAction {
        RecoveryAction::FailFast
    }

    fn name(&self) -> &'static str {
        "fail_fast"
    }
}

/// Retry with exponential backoff, capped per group.
#[derive(Clone, Copy, Debug)]
pub struct RetryBackoff {
    /// Backoff before the first retry.
    pub base: Duration,
    /// Multiplier per further attempt.
    pub factor: f64,
    /// Upper bound on any single backoff.
    pub cap: Duration,
    /// Total attempts allowed per group (including the first run);
    /// exhausting them falls back to [`RecoveryAction::FailFast`].
    pub max_attempts: usize,
}

impl Default for RetryBackoff {
    fn default() -> Self {
        RetryBackoff {
            base: Duration::from_micros(500),
            factor: 2.0,
            cap: Duration::from_millis(20),
            max_attempts: 4,
        }
    }
}

impl RetryBackoff {
    /// Backoff after failed attempt `attempt` (1-based):
    /// `base × factor^(attempt−1)`, capped.
    pub fn backoff_for(&self, attempt: usize) -> Duration {
        let exp = attempt.saturating_sub(1).min(i32::MAX as usize) as i32;
        self.base.mul_f64(self.factor.powi(exp)).min(self.cap)
    }
}

impl RecoveryPolicy for RetryBackoff {
    fn on_failure(&self, ctx: &FailureCtx) -> RecoveryAction {
        if ctx.attempt >= self.max_attempts {
            return RecoveryAction::FailFast;
        }
        RecoveryAction::Retry { backoff: self.backoff_for(ctx.attempt) }
    }

    fn name(&self) -> &'static str {
        "retry_backoff"
    }

    fn validate(&self) -> Result<(), ConfigError> {
        if !self.factor.is_finite() || self.factor < 1.0 {
            return Err(ConfigError::new(
                "recovery.retry.factor",
                format!("must be finite and >= 1.0, got {}", self.factor),
            ));
        }
        if self.max_attempts == 0 {
            return Err(ConfigError::new(
                "recovery.retry.max_attempts",
                "must be >= 1 (the first run counts as an attempt)",
            ));
        }
        if self.cap < self.base {
            return Err(ConfigError::new(
                "recovery.retry.cap",
                format!(
                    "must be >= base ({:?} < {:?})",
                    self.cap, self.base
                ),
            ));
        }
        Ok(())
    }
}

/// Retry like [`RetryBackoff`], but quarantine a lane once it has failed
/// `n_failures` consecutive runs — the HTS move: drain around the sick
/// unit instead of burning attempts on it.
#[derive(Clone, Copy, Debug)]
pub struct BlacklistAfterN {
    pub retry: RetryBackoff,
    pub n_failures: usize,
}

impl Default for BlacklistAfterN {
    fn default() -> Self {
        BlacklistAfterN { retry: RetryBackoff::default(), n_failures: 3 }
    }
}

impl RecoveryPolicy for BlacklistAfterN {
    fn on_failure(&self, ctx: &FailureCtx) -> RecoveryAction {
        if ctx.lane_consecutive_failures >= self.n_failures {
            return RecoveryAction::Quarantine;
        }
        self.retry.on_failure(ctx)
    }

    fn name(&self) -> &'static str {
        "blacklist_after_n"
    }

    fn validate(&self) -> Result<(), ConfigError> {
        if self.n_failures == 0 {
            return Err(ConfigError::new(
                "recovery.blacklist.n_failures",
                "must be >= 1",
            ));
        }
        self.retry.validate()
    }
}

/// Watchdog configuration: `deadline = predicted × slack + floor`.
#[derive(Clone, Copy, Debug)]
pub struct DeadlineOptions {
    /// Multiplier on the predicted group makespan. Generous by default:
    /// the virtual device adds real scheduling jitter on a loaded host,
    /// and a false timeout costs a full quarantine round-trip.
    pub slack: f64,
    /// Absolute floor so near-zero predictions keep a usable deadline.
    pub floor: Duration,
}

impl Default for DeadlineOptions {
    fn default() -> Self {
        DeadlineOptions { slack: 8.0, floor: Duration::from_millis(250) }
    }
}

impl DeadlineOptions {
    /// Deadline for a group whose plan predicts `pred_secs` of makespan.
    pub fn deadline_for(&self, pred_secs: f64) -> Duration {
        Duration::from_secs_f64(pred_secs.max(0.0) * self.slack) + self.floor
    }
}

/// Quarantine (breaker) configuration.
#[derive(Clone, Copy, Debug)]
pub struct QuarantineOptions {
    /// How long a tripped lane stays Open before a half-open probe.
    pub cooldown: Duration,
}

impl Default for QuarantineOptions {
    fn default() -> Self {
        QuarantineOptions { cooldown: Duration::from_millis(10) }
    }
}

/// Everything `LaneOptions::recovery` carries into the lane runtime.
#[derive(Clone, Debug)]
pub struct RecoveryOptions {
    pub policy: Arc<dyn RecoveryPolicy>,
    /// `None` disables the watchdog (hangs are then only bounded by the
    /// coordinator's caller).
    pub deadline: Option<DeadlineOptions>,
    pub quarantine: QuarantineOptions,
}

impl Default for RecoveryOptions {
    fn default() -> Self {
        RecoveryOptions {
            policy: Arc::new(RetryBackoff::default()),
            deadline: Some(DeadlineOptions::default()),
            quarantine: QuarantineOptions::default(),
        }
    }
}

impl RecoveryOptions {
    /// Explicit fail-fast (distinct from `recovery: None` only in that
    /// the watchdog still arms).
    pub fn fail_fast() -> Self {
        RecoveryOptions { policy: Arc::new(FailFast), ..Default::default() }
    }

    pub fn retry(retry: RetryBackoff) -> Self {
        RecoveryOptions { policy: Arc::new(retry), ..Default::default() }
    }

    pub fn blacklist(b: BlacklistAfterN) -> Self {
        RecoveryOptions { policy: Arc::new(b), ..Default::default() }
    }

    /// Typed validation for the builder path: delegates to the policy's
    /// own [`RecoveryPolicy::validate`] and checks the watchdog knobs.
    pub fn validate(&self) -> Result<(), ConfigError> {
        self.policy.validate()?;
        if let Some(d) = &self.deadline {
            if !d.slack.is_finite() || d.slack <= 0.0 {
                return Err(ConfigError::new(
                    "recovery.deadline.slack",
                    format!("must be finite and > 0, got {}", d.slack),
                ));
            }
        }
        Ok(())
    }
}

/// Circuit-breaker state of one lane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: the lane plans and runs its own work.
    Closed,
    /// Quarantined: the lane runs nothing; siblings may take its backlog.
    Open,
    /// Cooldown elapsed: the next own-lane group is a probe.
    HalfOpen,
}

#[derive(Debug)]
struct BreakerInner {
    state: BreakerState,
    opened_at: Option<Instant>,
}

/// One lane's circuit breaker. All transitions are mutex-serialized;
/// a poisoned lock recovers (breaker state stays valid across a lane
/// panic — that is exactly when siblings need to read it).
#[derive(Debug)]
pub struct LaneBreaker {
    inner: Mutex<BreakerInner>,
}

impl Default for LaneBreaker {
    fn default() -> Self {
        Self::new()
    }
}

impl LaneBreaker {
    pub fn new() -> Self {
        LaneBreaker {
            inner: Mutex::new(BreakerInner {
                state: BreakerState::Closed,
                opened_at: None,
            }),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, BreakerInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn state(&self) -> BreakerState {
        self.lock().state
    }

    /// Quarantine the lane (from any state; a failed half-open probe
    /// re-opens with a fresh cooldown). Returns `true` only on the
    /// Closed → Open edge — that is what counts as a "trip" in stats.
    pub fn trip(&self) -> bool {
        let mut g = self.lock();
        let was_closed = g.state == BreakerState::Closed;
        g.state = BreakerState::Open;
        g.opened_at = Some(Instant::now());
        was_closed
    }

    /// Open → HalfOpen once `cooldown` has elapsed since the trip.
    /// Returns `true` iff the transition happened now.
    pub fn try_half_open(&self, cooldown: Duration) -> bool {
        let mut g = self.lock();
        if g.state != BreakerState::Open {
            return false;
        }
        let elapsed_ok =
            g.opened_at.map(|t| t.elapsed() >= cooldown).unwrap_or(true);
        if elapsed_ok {
            g.state = BreakerState::HalfOpen;
            return true;
        }
        false
    }

    /// Any clean, non-timed-out completion closes the breaker.
    pub fn probe_succeeded(&self) {
        let mut g = self.lock();
        g.state = BreakerState::Closed;
        g.opened_at = None;
    }
}

/// Shared view of every lane's breaker — what `steal_with_health` and
/// the proxies consult.
#[derive(Clone)]
pub struct FleetHealth {
    lanes: Arc<[LaneBreaker]>,
}

impl FleetHealth {
    pub fn new(n_lanes: usize) -> Self {
        FleetHealth {
            lanes: (0..n_lanes).map(|_| LaneBreaker::new()).collect(),
        }
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    pub fn lane(&self, lane: usize) -> &LaneBreaker {
        &self.lanes[lane]
    }

    /// Whether a lane's backlog is up for grabs. Only **Open** counts:
    /// a HalfOpen lane is about to probe and keeps its own backlog.
    pub fn is_quarantined(&self, lane: usize) -> bool {
        self.lanes[lane].state() == BreakerState::Open
    }

    /// How many lanes are currently quarantined (Open). The fleet
    /// coordinator uses this to detect the everyone-is-down case, where
    /// calibrated placement has no healthy candidate and falls back to
    /// round-robin so arrivals still land somewhere recoverable.
    pub fn n_quarantined(&self) -> usize {
        (0..self.lanes.len()).filter(|&l| self.is_quarantined(l)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(attempt: usize, consec: usize) -> FailureCtx {
        FailureCtx {
            lane: 0,
            attempt,
            lane_consecutive_failures: consec,
            kind: FaultKind::Error,
        }
    }

    #[test]
    fn backoff_grows_exponentially_and_caps() {
        let r = RetryBackoff {
            base: Duration::from_millis(1),
            factor: 2.0,
            cap: Duration::from_millis(6),
            max_attempts: 10,
        };
        assert_eq!(r.backoff_for(1), Duration::from_millis(1));
        assert_eq!(r.backoff_for(2), Duration::from_millis(2));
        assert_eq!(r.backoff_for(3), Duration::from_millis(4));
        assert_eq!(r.backoff_for(4), Duration::from_millis(6)); // capped
        assert_eq!(r.backoff_for(9), Duration::from_millis(6));
    }

    #[test]
    fn retry_policy_respects_attempt_cap() {
        let r = RetryBackoff { max_attempts: 3, ..RetryBackoff::default() };
        assert!(matches!(
            r.on_failure(&ctx(1, 1)),
            RecoveryAction::Retry { .. }
        ));
        assert!(matches!(
            r.on_failure(&ctx(2, 2)),
            RecoveryAction::Retry { .. }
        ));
        assert_eq!(r.on_failure(&ctx(3, 3)), RecoveryAction::FailFast);
    }

    #[test]
    fn blacklist_quarantines_at_threshold_else_delegates() {
        let b = BlacklistAfterN {
            retry: RetryBackoff { max_attempts: 10, ..RetryBackoff::default() },
            n_failures: 2,
        };
        assert!(matches!(
            b.on_failure(&ctx(1, 1)),
            RecoveryAction::Retry { .. }
        ));
        assert_eq!(b.on_failure(&ctx(1, 2)), RecoveryAction::Quarantine);
        assert_eq!(b.on_failure(&ctx(5, 7)), RecoveryAction::Quarantine);
    }

    #[test]
    fn deadline_formula_applies_slack_and_floor() {
        let d = DeadlineOptions { slack: 2.0, floor: Duration::from_millis(10) };
        assert_eq!(d.deadline_for(0.0), Duration::from_millis(10));
        assert_eq!(d.deadline_for(-1.0), Duration::from_millis(10));
        let dl = d.deadline_for(0.5);
        assert!((dl.as_secs_f64() - 1.01).abs() < 1e-9, "{dl:?}");
    }

    #[test]
    fn breaker_walks_closed_open_halfopen_closed() {
        let b = LaneBreaker::new();
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.trip(), "Closed -> Open is the counted trip");
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.trip(), "re-trip while Open is not a new trip");
        // Cooldown not yet elapsed: stays Open.
        assert!(!b.try_half_open(Duration::from_secs(3600)));
        assert_eq!(b.state(), BreakerState::Open);
        // Zero cooldown: probe allowed immediately.
        assert!(b.try_half_open(Duration::ZERO));
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(!b.try_half_open(Duration::ZERO), "only from Open");
        b.probe_succeeded();
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn failed_probe_reopens_with_fresh_cooldown() {
        let b = LaneBreaker::new();
        assert!(b.trip());
        assert!(b.try_half_open(Duration::ZERO));
        // The probe failed: back to Open, and it was not a fresh "trip".
        assert!(!b.trip());
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.try_half_open(Duration::from_secs(3600)));
    }

    #[test]
    fn fleet_health_only_open_counts_as_quarantined() {
        let h = FleetHealth::new(3);
        assert_eq!(h.n_lanes(), 3);
        assert!(!h.is_quarantined(1));
        h.lane(1).trip();
        assert!(h.is_quarantined(1));
        h.lane(1).try_half_open(Duration::ZERO);
        assert!(!h.is_quarantined(1), "HalfOpen keeps its backlog");
        h.lane(1).probe_succeeded();
        assert!(!h.is_quarantined(1));
    }

    #[test]
    fn n_quarantined_counts_open_lanes_only() {
        let h = FleetHealth::new(3);
        assert_eq!(h.n_quarantined(), 0);
        h.lane(0).trip();
        h.lane(2).trip();
        assert_eq!(h.n_quarantined(), 2);
        h.lane(2).try_half_open(Duration::ZERO);
        assert_eq!(h.n_quarantined(), 1, "HalfOpen is not quarantined");
        h.lane(0).probe_succeeded();
        assert_eq!(h.n_quarantined(), 0);
    }

    #[test]
    fn breaker_survives_a_poisoning_panic() {
        let b = Arc::new(LaneBreaker::new());
        let b2 = Arc::clone(&b);
        let _ = std::thread::spawn(move || {
            let _g = b2.inner.lock().unwrap();
            panic!("poison the breaker lock");
        })
        .join();
        assert!(b.trip());
        assert_eq!(b.state(), BreakerState::Open);
    }
}
