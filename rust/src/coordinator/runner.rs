//! The host proxy runtime (paper §6.2, Fig. 8): T worker threads submit N
//! dependent tasks each through the shared buffer; the proxy thread drains
//! task groups, optionally reorders them with the heuristic, submits them
//! to the virtual device, and signals per-task completion events back to
//! the workers.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::DeviceProfile;
use crate::device::vdev::VirtualDevice;
use crate::model::EngineState;
use crate::sched::heuristic::{batch_reorder_beam_into, BeamScratch, DEFAULT_BEAM_WIDTH};
use crate::coordinator::buffer::{SharedBuffer, Submission};
use crate::queue::event::Event;
use crate::task::TaskSpec;
use crate::util::stats;

/// Ordering policy applied by the proxy to each drained task group.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Submit in arrival order (the NoReorder setup).
    NoReorder,
    /// Apply the Batch Reordering heuristic (Algorithm 1).
    Heuristic,
}

/// Aggregate metrics of one coordinator run.
#[derive(Clone, Debug)]
pub struct CoordMetrics {
    /// Wall-clock of the whole workload (s).
    pub total_secs: f64,
    /// Executed tasks per second — the paper's "tasks throughput".
    pub tasks_per_sec: f64,
    /// Per-task latency submission -> completion (s).
    pub latencies: Vec<f64>,
    /// Device busy time per group (s).
    pub group_makespans: Vec<f64>,
    /// CPU time the proxy spent inside the reordering heuristic (s).
    pub sched_overhead_secs: f64,
    /// Number of task groups formed.
    pub n_groups: usize,
    pub n_tasks: usize,
}

impl CoordMetrics {
    pub fn mean_latency(&self) -> f64 {
        stats::mean(&self.latencies)
    }
}

/// The multi-worker runtime harness.
pub struct Coordinator {
    device: Arc<VirtualDevice>,
    profile: DeviceProfile,
    policy: Policy,
    /// Proxy settle window while forming a TG (paper: the proxy "samples"
    /// the buffer; this bounds how long it waits for stragglers).
    pub settle: Duration,
}

impl Coordinator {
    pub fn new(device: Arc<VirtualDevice>, policy: Policy) -> Self {
        let profile = device.profile().clone();
        Coordinator { device, profile, policy, settle: Duration::from_micros(300) }
    }

    /// Run `workloads[w]` = the dependent task batch of worker `w`.
    /// Each worker submits its next task only after the previous one
    /// completed (the paper's batch dependency).
    pub fn run(&self, workloads: Vec<Vec<TaskSpec>>) -> CoordMetrics {
        let t_workers = workloads.len();
        let buffer = SharedBuffer::new();
        let epoch = Instant::now();

        // ---- workers ----------------------------------------------------
        let mut worker_handles = Vec::new();
        for (w, batch) in workloads.into_iter().enumerate() {
            let buffer = buffer.clone();
            worker_handles.push(
                std::thread::Builder::new()
                    .name(format!("worker-{w}"))
                    .spawn(move || {
                        for (seq, task) in batch.into_iter().enumerate() {
                            let done = Event::new();
                            buffer.push(Submission {
                                worker: w,
                                batch_seq: seq,
                                task,
                                done: done.clone(),
                                submitted_at: epoch.elapsed().as_secs_f64(),
                            });
                            // Dependency: wait before submitting the next.
                            done.wait();
                        }
                    })
                    .expect("spawn worker"),
            );
        }

        // ---- proxy (this thread) ---------------------------------------
        let mut latencies = Vec::new();
        let mut group_makespans = Vec::new();
        let mut sched_overhead = 0.0;
        let mut n_tasks = 0usize;
        // Workers are tracked via the buffer-closing janitor below.

        // Close the buffer once all workers have drained: do it from a
        // janitor thread joining the workers.
        let closer = {
            let buffer = buffer.clone();
            std::thread::spawn(move || {
                for h in worker_handles {
                    h.join().expect("worker panicked");
                }
                buffer.close();
            })
        };

        // The reorder arena persists across task groups: after the first
        // round the heuristic performs zero heap allocations per group
        // (cursor pools, beam entries and the order buffer are all reused).
        let mut scratch = BeamScratch::new();
        let mut order: Vec<usize> = Vec::new();
        while let Some(subs) = buffer.drain(t_workers, self.settle) {
            let tasks: Vec<TaskSpec> =
                subs.iter().map(|s| s.task.clone()).collect();
            match self.policy {
                Policy::NoReorder => {
                    order.clear();
                    order.extend(0..tasks.len());
                }
                Policy::Heuristic => {
                    let t0 = Instant::now();
                    batch_reorder_beam_into(
                        &tasks,
                        &self.profile,
                        EngineState::default(),
                        DEFAULT_BEAM_WIDTH,
                        &mut scratch,
                        &mut order,
                    );
                    sched_overhead += t0.elapsed().as_secs_f64();
                }
            };
            let ordered: Vec<TaskSpec> =
                order.iter().map(|&i| tasks[i].clone()).collect();
            let run = self.device.run_group(&ordered);
            group_makespans.push(run.makespan);
            let now = epoch.elapsed().as_secs_f64();
            // Signal completions (device timestamps are group-relative;
            // workers only need the ordering, the latency uses wall time).
            for (slot, &orig) in order.iter().enumerate() {
                let sub = &subs[orig];
                sub.done.complete(now - run.makespan + run.task_end[slot]);
                latencies.push(now - sub.submitted_at);
            }
            n_tasks += subs.len();
        }
        closer.join().unwrap();

        let total_secs = epoch.elapsed().as_secs_f64();
        CoordMetrics {
            total_secs,
            tasks_per_sec: n_tasks as f64 / total_secs,
            latencies,
            n_groups: group_makespans.len(),
            group_makespans,
            sched_overhead_secs: sched_overhead,
            n_tasks,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;
    use crate::device::executor::SpinExecutor;
    use crate::task::synthetic::synthetic_benchmark;

    fn coordinator(policy: Policy) -> Coordinator {
        let device = Arc::new(VirtualDevice::new(
            profile_by_name("amd_r9").unwrap(),
            Arc::new(SpinExecutor),
        ));
        Coordinator::new(device, policy)
    }

    fn workload(t: usize, n: usize, scale: f64) -> Vec<Vec<TaskSpec>> {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, scale).unwrap();
        (0..t)
            .map(|w| (0..n).map(|i| g.tasks[(w + i) % 4].clone()).collect())
            .collect()
    }

    #[test]
    fn completes_all_tasks() {
        let c = coordinator(Policy::Heuristic);
        let m = c.run(workload(4, 2, 0.1));
        assert_eq!(m.n_tasks, 8);
        assert_eq!(m.latencies.len(), 8);
        assert!(m.tasks_per_sec > 0.0);
        assert!(m.n_groups >= 2, "batch deps force >= 2 rounds");
    }

    #[test]
    fn noreorder_has_zero_sched_overhead() {
        let c = coordinator(Policy::NoReorder);
        let m = c.run(workload(3, 1, 0.1));
        assert_eq!(m.sched_overhead_secs, 0.0);
        assert_eq!(m.n_tasks, 3);
    }

    #[test]
    fn heuristic_not_slower_than_noreorder_bad_order() {
        let _t = crate::util::timing::timing_test_lock();
        // Workers submit in a transfer-heavy-first order; the heuristic
        // should recover a faster schedule.
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK25", &p, 0.2).unwrap();
        // Reversed = DT first (bad).
        let bad: Vec<Vec<TaskSpec>> =
            vec![g.tasks.iter().rev().cloned().collect::<Vec<_>>()];
        // Single worker with a 4-task batch -> each task its own group, so
        // instead use 4 workers with 1 task each to form one TG.
        let mk = |_| -> Vec<Vec<TaskSpec>> {
            g.tasks.iter().rev().map(|t| vec![t.clone()]).collect()
        };
        let _ = bad;
        let t_no = coordinator(Policy::NoReorder).run(mk(())).total_secs;
        let t_h = coordinator(Policy::Heuristic).run(mk(())).total_secs;
        assert!(
            t_h < t_no * 1.05,
            "heuristic {t_h:.4}s vs noreorder {t_no:.4}s"
        );
    }
}
