//! The host proxy runtime (paper §6.2, Fig. 8): T worker threads submit N
//! dependent tasks each through the shared buffer; the proxy drains task
//! groups, optionally reorders them with the heuristic, submits them to
//! the virtual device, and signals per-task completion events back to the
//! workers.
//!
//! Since the sharded refactor this is a thin facade over
//! [`LaneCoordinator`] with a single lane: same buffer semantics, same
//! policies, same metrics — `coordinator::lanes` is the actual runtime.

use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::lanes::{LaneCoordinator, LaneOptions};
use crate::device::vdev::VirtualDevice;
use crate::task::TaskSpec;
use crate::util::stats;

/// Ordering policy applied by the proxy to each drained task group.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Submit in arrival order (the NoReorder setup).
    NoReorder,
    /// Apply the Batch Reordering heuristic (Algorithm 1).
    Heuristic,
}

/// Aggregate metrics of one coordinator run.
#[derive(Clone, Debug)]
pub struct CoordMetrics {
    /// Wall-clock of the whole workload (s).
    pub total_secs: f64,
    /// Executed tasks per second — the paper's "tasks throughput".
    pub tasks_per_sec: f64,
    /// Per-task latency submission -> completion (s).
    pub latencies: Vec<f64>,
    /// Device busy time per group (s).
    pub group_makespans: Vec<f64>,
    /// CPU time the proxy spent inside the reordering heuristic (s).
    pub sched_overhead_secs: f64,
    /// Number of task groups formed.
    pub n_groups: usize,
    pub n_tasks: usize,
}

impl CoordMetrics {
    pub fn mean_latency(&self) -> f64 {
        stats::mean(&self.latencies)
    }
}

/// The multi-worker runtime harness (single-lane facade over
/// [`LaneCoordinator`]).
pub struct Coordinator {
    device: Arc<VirtualDevice>,
    policy: Policy,
    /// Proxy settle window while forming a TG (paper: the proxy "samples"
    /// the buffer; this bounds how long it waits for stragglers).
    pub settle: Duration,
}

impl Coordinator {
    pub fn new(device: Arc<VirtualDevice>, policy: Policy) -> Self {
        Coordinator { device, policy, settle: Duration::from_micros(300) }
    }

    /// The single-lane [`LaneCoordinator`] this facade delegates to —
    /// also the delegation target of the `Driver` impl, so the facade
    /// and the trait surface share one construction path.
    pub(crate) fn as_lane(&self) -> LaneCoordinator {
        LaneCoordinator::with_devices(
            vec![Arc::clone(&self.device) as Arc<dyn crate::device::Device>],
            LaneOptions {
                lanes: 1,
                policy: self.policy,
                settle: self.settle,
                group_cap: 0,
                scoring_threads: 1,
                online: None,
                recalibrate: None,
                recovery: None,
                admission: None,
            },
        )
    }

    /// Run `workloads[w]` = the dependent task batch of worker `w`.
    /// Each worker submits its next task only after the previous one
    /// completed (the paper's batch dependency).
    pub fn run(&self, workloads: Vec<Vec<TaskSpec>>) -> CoordMetrics {
        let m = self.as_lane().run(workloads);
        CoordMetrics {
            total_secs: m.total_secs,
            tasks_per_sec: m.tasks_per_sec,
            latencies: m.latencies,
            group_makespans: m.group_makespans,
            sched_overhead_secs: m.sched_overhead_secs,
            n_groups: m.n_groups,
            n_tasks: m.n_tasks,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;
    use crate::device::executor::SpinExecutor;
    use crate::task::synthetic::synthetic_benchmark;

    fn coordinator(policy: Policy) -> Coordinator {
        let device = Arc::new(VirtualDevice::new(
            profile_by_name("amd_r9").unwrap(),
            Arc::new(SpinExecutor),
        ));
        Coordinator::new(device, policy)
    }

    fn workload(t: usize, n: usize, scale: f64) -> Vec<Vec<TaskSpec>> {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, scale).unwrap();
        (0..t)
            .map(|w| (0..n).map(|i| g.tasks[(w + i) % 4].clone()).collect())
            .collect()
    }

    #[test]
    fn completes_all_tasks() {
        let c = coordinator(Policy::Heuristic);
        let m = c.run(workload(4, 2, 0.1));
        assert_eq!(m.n_tasks, 8);
        assert_eq!(m.latencies.len(), 8);
        assert!(m.tasks_per_sec > 0.0);
        assert!(m.n_groups >= 2, "batch deps force >= 2 rounds");
    }

    #[test]
    fn noreorder_has_zero_sched_overhead() {
        let c = coordinator(Policy::NoReorder);
        let m = c.run(workload(3, 1, 0.1));
        assert_eq!(m.sched_overhead_secs, 0.0);
        assert_eq!(m.n_tasks, 3);
    }

    #[test]
    fn heuristic_not_slower_than_noreorder_bad_order() {
        let _t = crate::util::timing::timing_test_lock();
        // Workers submit in a transfer-heavy-first order; the heuristic
        // should recover a faster schedule.
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK25", &p, 0.2).unwrap();
        // Reversed = DT first (bad).
        let bad: Vec<Vec<TaskSpec>> =
            vec![g.tasks.iter().rev().cloned().collect::<Vec<_>>()];
        // Single worker with a 4-task batch -> each task its own group, so
        // instead use 4 workers with 1 task each to form one TG.
        let mk = |_| -> Vec<Vec<TaskSpec>> {
            g.tasks.iter().rev().map(|t| vec![t.clone()]).collect()
        };
        let _ = bad;
        let t_no = coordinator(Policy::NoReorder).run(mk(())).total_secs;
        let t_h = coordinator(Policy::Heuristic).run(mk(())).total_secs;
        assert!(
            t_h < t_no * 1.05,
            "heuristic {t_h:.4}s vs noreorder {t_no:.4}s"
        );
    }
}
