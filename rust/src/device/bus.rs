//! The shared host<->device interconnect: tracks which directions are in
//! flight and serves the current per-direction rate. A generation counter
//! bumps on every change so paced transfers re-plan immediately — the
//! real-time analogue of the simulator's end-time re-estimation.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::DeviceProfile;

#[derive(Debug, Default)]
struct BusState {
    active_htd: usize,
    active_dth: usize,
    generation: u64,
}

/// Cloneable handle to the interconnect state.
#[derive(Clone)]
pub struct Bus {
    profile: Arc<DeviceProfile>,
    state: Arc<(Mutex<BusState>, Condvar)>,
}

impl Bus {
    pub fn new(profile: Arc<DeviceProfile>) -> Self {
        Bus { profile, state: Arc::new((Mutex::new(BusState::default()), Condvar::new())) }
    }

    pub fn profile(&self) -> &DeviceProfile {
        &self.profile
    }

    /// Register an in-flight transfer; returns a guard that deregisters.
    pub fn begin_transfer(&self, htd: bool) -> TransferGuard {
        let (m, cv) = &*self.state;
        let mut g = m.lock().unwrap();
        if htd {
            g.active_htd += 1;
        } else {
            g.active_dth += 1;
        }
        g.generation += 1;
        cv.notify_all();
        TransferGuard { bus: self.clone(), htd }
    }

    fn end_transfer(&self, htd: bool) {
        let (m, cv) = &*self.state;
        let mut g = m.lock().unwrap();
        if htd {
            g.active_htd -= 1;
        } else {
            g.active_dth -= 1;
        }
        g.generation += 1;
        cv.notify_all();
    }

    /// Current (rate for `htd` direction, generation).
    pub fn rate(&self, htd: bool) -> (f64, u64) {
        let (m, _) = &*self.state;
        let g = m.lock().unwrap();
        let opposite = if htd { g.active_dth > 0 } else { g.active_htd > 0 };
        (self.profile.rate(htd, opposite), g.generation)
    }

    /// Pace `bytes` through the bus in direction `htd`, fluidly adapting
    /// to contention changes; blocks for the (real) transfer duration.
    /// Returns when the last byte would have arrived.
    pub fn pace(&self, htd: bool, bytes: u64) {
        // Fixed per-transfer latency first (uncontended overhead).
        crate::util::timing::precise_wait(Duration::from_secs_f64(
            self.profile.link(htd).latency,
        ));
        let mut remaining = bytes as f64;
        let (m, cv) = &*self.state;
        while remaining > 1.0 {
            let (rate, gen) = self.rate(htd);
            let eta = remaining / rate;
            let started = Instant::now();
            if eta > 200e-6 {
                // Sleep on the condvar: wake early if the active set
                // changes, otherwise up to ~eta (leave a spin tail).
                let budget = Duration::from_secs_f64(eta - 120e-6);
                let g = m.lock().unwrap();
                let _unused = cv
                    .wait_timeout_while(g, budget, |s| s.generation == gen)
                    .unwrap();
            } else {
                // Short tail: spin to the deadline, accept a potentially
                // stale rate for <=200 us.
                crate::util::timing::precise_wait_until(
                    started + Duration::from_secs_f64(eta),
                );
            }
            let elapsed = started.elapsed().as_secs_f64();
            remaining -= elapsed * rate;
        }
    }

    /// Snapshot (active_htd, active_dth) — used by tests.
    pub fn active(&self) -> (usize, usize) {
        let (m, _) = &*self.state;
        let g = m.lock().unwrap();
        (g.active_htd, g.active_dth)
    }
}

/// RAII registration of an in-flight transfer.
pub struct TransferGuard {
    bus: Bus,
    htd: bool,
}

impl Drop for TransferGuard {
    fn drop(&mut self) {
        self.bus.end_transfer(self.htd);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;

    fn bus(name: &str) -> Bus {
        Bus::new(Arc::new(profile_by_name(name).unwrap()))
    }

    #[test]
    fn registration_changes_rate() {
        let b = bus("amd_r9");
        let (solo, _) = b.rate(true);
        let _g = b.begin_transfer(false);
        let (contended, _) = b.rate(true);
        assert!(contended < solo);
        assert!((solo / contended - b.profile().duplex_slowdown).abs() < 1e-9);
    }

    #[test]
    fn guard_drop_restores() {
        let b = bus("amd_r9");
        {
            let _g = b.begin_transfer(true);
            assert_eq!(b.active(), (1, 0));
        }
        assert_eq!(b.active(), (0, 0));
    }

    #[test]
    fn pace_matches_loggp_solo() {
        let _t = crate::util::timing::timing_test_lock();
        let b = bus("cpu_live");
        let bytes = 16_000_000; // 2 ms at 8 GB/s
        let want = b.profile().htd.transfer_secs(bytes);
        let t0 = Instant::now();
        let _g = b.begin_transfer(true);
        b.pace(true, bytes);
        let got = t0.elapsed().as_secs_f64();
        assert!(
            (got - want).abs() / want < 0.08,
            "paced {got:.6}s vs model {want:.6}s"
        );
    }

    #[test]
    fn contended_pace_stretches() {
        let _t = crate::util::timing::timing_test_lock();
        let b = bus("amd_r9");
        let bytes = 12_400_000; // 2 ms solo HtD on r9
        let solo = b.profile().htd.transfer_secs(bytes);
        let b2 = b.clone();
        let other = std::thread::spawn(move || {
            let _g = b2.begin_transfer(false);
            // Hold DtH active longer than the HtD transfer.
            std::thread::sleep(Duration::from_millis(8));
        });
        std::thread::sleep(Duration::from_millis(1));
        let t0 = Instant::now();
        let _g = b.begin_transfer(true);
        b.pace(true, bytes);
        let got = t0.elapsed().as_secs_f64();
        other.join().unwrap();
        let want = b.profile().htd.latency
            + bytes as f64
                / (b.profile().htd.bytes_per_sec / b.profile().duplex_slowdown);
        assert!(
            (got - want).abs() / want < 0.12,
            "contended pace {got:.6}s vs {want:.6}s (solo {solo:.6}s)"
        );
    }
}
