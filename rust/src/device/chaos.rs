//! Deterministic fault injection: [`ChaosDevice`] wraps any [`Device`].
//!
//! The wrapper draws a fixed number of uniforms per task from a seeded
//! [`Pcg64`](crate::util::rng::Pcg64) **per `run_group` call**, so the
//! fault schedule is a pure function of `(seed, call index, group size)`
//! — never of wall-clock time or thread interleaving. That makes chaos
//! runs replayable: the same seed injects the same faults at the same
//! calls, which is what lets `rust/tests/prop_recovery.rs` assert exact
//! properties (no task lost, retries bit-identical) instead of
//! statistical ones.
//!
//! Injected failure modes, in decision order per call:
//!
//! 1. **hang** — sleep [`ChaosOptions::hang`] before proceeding
//!    (emulates a stuck command queue; the recovery watchdog's prey);
//! 2. **transient error** — return `Err` without running the group;
//! 3. **panic** — unwind out of `run_group` (emulates a driver abort);
//! 4. otherwise run the inner device, optionally **skewing** result
//!    timestamps per task (emulates measurement jitter — exercises the
//!    calibration-exclusion paths without failing the run).
//!
//! With [`ChaosOptions::transient`] set (the default), a call directly
//! following a faulted call suppresses all injection and passes through
//! bit-identically — modelling faults that clear on retry, and making
//! "retry equals clean run" provable on a deterministic inner device.
//! All probabilities default to zero; a zero-probability wrapper is a
//! bitwise-transparent passthrough.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::anyhow;

use crate::config::DeviceProfile;
use crate::device::{Device, DeviceRun};
use crate::task::TaskSpec;
use crate::util::rng::Pcg64;

/// Fault-injection configuration. All probabilities are per *task* in
/// the submitted group (a bigger group is likelier to fault, mirroring
/// real exposure); at most one terminal fault fires per call.
#[derive(Clone, Debug)]
pub struct ChaosOptions {
    /// RNG seed; every fault schedule is a deterministic function of it.
    pub seed: u64,
    /// Per-task probability of a transient `Err` return.
    pub p_error: f64,
    /// Per-task probability of a panic out of `run_group`.
    pub p_panic: f64,
    /// Per-task probability of an artificial hang before the run.
    pub p_hang: f64,
    /// How long a hang stalls the call.
    pub hang: Duration,
    /// Per-task probability of result-time skew (run still succeeds).
    pub p_skew: f64,
    /// Max fractional stretch of a skewed task's command durations.
    pub skew_max: f64,
    /// Suppress all injection on the call after a fault (fault clears on
    /// retry); `false` makes faults persistent.
    pub transient: bool,
}

impl Default for ChaosOptions {
    fn default() -> Self {
        ChaosOptions {
            seed: 0x5eed,
            p_error: 0.0,
            p_panic: 0.0,
            p_hang: 0.0,
            hang: Duration::from_millis(50),
            p_skew: 0.0,
            skew_max: 0.2,
            transient: true,
        }
    }
}

/// What the wrapper has injected so far (cumulative, all calls).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChaosCounts {
    pub n_runs: u64,
    pub n_errors: u64,
    pub n_panics: u64,
    pub n_hangs: u64,
    pub n_skewed_tasks: u64,
    /// Calls where `transient` suppressed a would-be fault schedule.
    pub n_suppressed: u64,
}

struct ChaosState {
    rng: Pcg64,
    last_faulted: bool,
    counts: ChaosCounts,
}

/// The per-call injection decision, fully drawn under the state lock so
/// the schedule depends only on the call index.
struct Decision {
    hang: bool,
    error: Option<usize>,
    panic_at: Option<usize>,
    /// (task index, duration stretch factor) for skewed tasks.
    skew: Vec<(usize, f64)>,
}

/// A [`Device`] wrapper injecting deterministic faults around `inner`.
pub struct ChaosDevice {
    inner: Arc<dyn Device>,
    opts: ChaosOptions,
    state: Mutex<ChaosState>,
}

impl ChaosDevice {
    pub fn new(inner: Arc<dyn Device>, opts: ChaosOptions) -> Self {
        let rng = Pcg64::seeded(opts.seed);
        ChaosDevice {
            inner,
            opts,
            state: Mutex::new(ChaosState {
                rng,
                last_faulted: false,
                counts: ChaosCounts::default(),
            }),
        }
    }

    /// Cumulative injection counters (test/bench introspection).
    pub fn counts(&self) -> ChaosCounts {
        self.lock_state().counts
    }

    // A panic mid-`run_group` (injected or from the inner device) can
    // poison the state mutex; the counters and RNG stay valid, so
    // recover the guard instead of cascading the panic to later calls.
    fn lock_state(&self) -> std::sync::MutexGuard<'_, ChaosState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Draw this call's full fault schedule. Exactly four uniforms per
    /// task are consumed in a fixed order regardless of outcomes, so
    /// call `k` always sees the same draws whatever calls `0..k` did.
    fn decide(&self, n_tasks: usize) -> Decision {
        let mut st = self.lock_state();
        st.counts.n_runs += 1;
        let mut d = Decision {
            hang: false,
            error: None,
            panic_at: None,
            skew: Vec::new(),
        };
        let mut raw_fault = false;
        for i in 0..n_tasks {
            let e = st.rng.next_f64();
            let p = st.rng.next_f64();
            let h = st.rng.next_f64();
            let s = st.rng.next_f64();
            if h < self.opts.p_hang {
                d.hang = true;
            }
            if e < self.opts.p_error && d.error.is_none() {
                d.error = Some(i);
            }
            if p < self.opts.p_panic && d.panic_at.is_none() {
                d.panic_at = Some(i);
            }
            if s < self.opts.p_skew {
                // Reuse the draw to pick the stretch inside (1, 1+max]:
                // s / p_skew is uniform in [0, 1) given s < p_skew.
                d.skew.push((i, 1.0 + self.opts.skew_max * (s / self.opts.p_skew)));
            }
            raw_fault |= d.hang || d.error.is_some() || d.panic_at.is_some();
        }
        if self.opts.transient && st.last_faulted {
            // Fault cleared: this call is a bitwise-clean passthrough.
            if raw_fault || !d.skew.is_empty() {
                st.counts.n_suppressed += 1;
            }
            st.last_faulted = false;
            return Decision { hang: false, error: None, panic_at: None, skew: Vec::new() };
        }
        st.last_faulted = d.hang || d.error.is_some() || d.panic_at.is_some();
        if d.hang {
            st.counts.n_hangs += 1;
        }
        if st.last_faulted {
            // A terminal fault means the run never completes normally;
            // drop the skew so accounting reflects what actually fired.
            d.skew.clear();
        }
        if d.error.is_some() {
            st.counts.n_errors += 1;
        } else if d.panic_at.is_some() {
            st.counts.n_panics += 1;
        }
        st.counts.n_skewed_tasks += d.skew.len() as u64;
        d
    }
}

impl Device for ChaosDevice {
    fn profile(&self) -> &DeviceProfile {
        self.inner.profile()
    }

    fn run_group(&self, tasks: &[TaskSpec]) -> anyhow::Result<DeviceRun> {
        let d = self.decide(tasks.len());
        if d.hang {
            // The hang is not terminal by itself: the call proceeds after
            // the stall (a real stuck queue eventually drains too). The
            // recovery watchdog decides whether the stall was fatal.
            std::thread::sleep(self.opts.hang);
        }
        if let Some(i) = d.error {
            return Err(anyhow!(
                "chaos: injected transient error at task {i} (seed {:#x})",
                self.opts.seed
            ));
        }
        if let Some(i) = d.panic_at {
            panic!(
                "chaos: injected panic at task {i} (seed {:#x})",
                self.opts.seed
            );
        }
        let mut run = self.inner.run_group(tasks)?;
        for &(task, factor) in &d.skew {
            for rec in run.timeline.iter_mut().filter(|r| r.task == task) {
                rec.end = rec.start + (rec.end - rec.start) * factor;
            }
            let end = run
                .timeline
                .iter()
                .filter(|r| r.task == task)
                .map(|r| r.end)
                .fold(f64::NEG_INFINITY, f64::max);
            if end.is_finite() {
                run.task_end[task] = end;
            }
        }
        if !d.skew.is_empty() {
            run.makespan = run
                .timeline
                .iter()
                .map(|r| r.end)
                .fold(run.makespan, f64::max);
        }
        Ok(run)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;
    use crate::device::SimDevice;
    use crate::task::synthetic::synthetic_benchmark;

    fn sim() -> Arc<dyn Device> {
        Arc::new(SimDevice::new(profile_by_name("amd_r9").unwrap()))
    }

    fn group() -> Vec<TaskSpec> {
        let p = profile_by_name("amd_r9").unwrap();
        synthetic_benchmark("BK50", &p, 0.25).unwrap().tasks
    }

    fn bitwise_eq(a: &DeviceRun, b: &DeviceRun) {
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.task_end.len(), b.task_end.len());
        for (x, y) in a.task_end.iter().zip(&b.task_end) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(a.timeline.len(), b.timeline.len());
        for (x, y) in a.timeline.iter().zip(&b.timeline) {
            assert_eq!(x.task, y.task);
            assert_eq!(x.start.to_bits(), y.start.to_bits());
            assert_eq!(x.end.to_bits(), y.end.to_bits());
        }
    }

    #[test]
    fn zero_probability_wrapper_is_bitwise_transparent() {
        let tasks = group();
        let clean = sim().run_group(&tasks).unwrap();
        let chaos = ChaosDevice::new(sim(), ChaosOptions::default());
        for _ in 0..3 {
            bitwise_eq(&chaos.run_group(&tasks).unwrap(), &clean);
        }
        assert_eq!(chaos.counts().n_errors, 0);
        assert_eq!(chaos.counts().n_runs, 3);
    }

    #[test]
    fn fault_schedule_is_deterministic_per_seed() {
        let tasks = group();
        let opts = ChaosOptions {
            seed: 42,
            p_error: 0.3,
            transient: false,
            ..ChaosOptions::default()
        };
        let a = ChaosDevice::new(sim(), opts.clone());
        let b = ChaosDevice::new(sim(), opts);
        for _ in 0..20 {
            let ra = a.run_group(&tasks);
            let rb = b.run_group(&tasks);
            assert_eq!(ra.is_err(), rb.is_err());
        }
        assert_eq!(a.counts(), b.counts());
        assert!(a.counts().n_errors > 0, "schedule never fired at p=0.3");
    }

    #[test]
    fn transient_fault_clears_on_retry_bit_identically() {
        let tasks = group();
        let clean = sim().run_group(&tasks).unwrap();
        let chaos = ChaosDevice::new(
            sim(),
            ChaosOptions { p_error: 1.0, ..ChaosOptions::default() },
        );
        assert!(chaos.run_group(&tasks).is_err());
        let retry = chaos.run_group(&tasks).unwrap();
        bitwise_eq(&retry, &clean);
        assert_eq!(chaos.counts().n_errors, 1);
        assert_eq!(chaos.counts().n_suppressed, 1);
    }

    #[test]
    fn persistent_faults_keep_firing_without_transient() {
        let tasks = group();
        let chaos = ChaosDevice::new(
            sim(),
            ChaosOptions {
                p_error: 1.0,
                transient: false,
                ..ChaosOptions::default()
            },
        );
        for _ in 0..4 {
            assert!(chaos.run_group(&tasks).is_err());
        }
        assert_eq!(chaos.counts().n_errors, 4);
    }

    #[test]
    fn skew_stretches_results_but_run_succeeds() {
        let tasks = group();
        let clean = sim().run_group(&tasks).unwrap();
        let chaos = ChaosDevice::new(
            sim(),
            ChaosOptions {
                p_skew: 1.0,
                skew_max: 0.5,
                ..ChaosOptions::default()
            },
        );
        let skewed = chaos.run_group(&tasks).unwrap();
        assert_eq!(chaos.counts().n_skewed_tasks, tasks.len() as u64);
        assert!(skewed.makespan >= clean.makespan);
        // task_end stays consistent with the (stretched) timeline.
        for (t, &end) in skewed.task_end.iter().enumerate() {
            let max_rec = skewed
                .timeline
                .iter()
                .filter(|r| r.task == t)
                .map(|r| r.end)
                .fold(f64::NEG_INFINITY, f64::max);
            assert!((end - max_rec).abs() < 1e-12, "task {t}");
        }
    }

    #[test]
    fn injected_panic_unwinds_and_later_calls_still_work() {
        let tasks = group();
        let chaos = Arc::new(ChaosDevice::new(
            sim(),
            ChaosOptions { p_panic: 1.0, ..ChaosOptions::default() },
        ));
        let c2 = Arc::clone(&chaos);
        let t2 = tasks.clone();
        let r = std::thread::spawn(move || {
            let _ = c2.run_group(&t2);
        })
        .join();
        assert!(r.is_err(), "expected injected panic");
        // transient: the call after the fault passes through.
        assert!(chaos.run_group(&tasks).is_ok());
        assert_eq!(chaos.counts().n_panics, 1);
    }
}
