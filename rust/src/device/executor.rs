//! Kernel execution backends for the virtual device's compute engine.
//!
//! `SpinExecutor` burns the calibrated duration with precise waiting —
//! used by the three paper-device profiles where kernel times come from
//! Table 2/Table 5. The PJRT-backed executor (in `runtime::PjrtExecutor`)
//! runs real AOT artifacts on the CPU client for the `cpu_live` profile.

use std::time::Duration;

use crate::task::KernelSpec;
use crate::util::timing;

/// A compute-engine backend.
pub trait KernelExecutor: Send + Sync {
    /// Execute one kernel command; blocks for its (real) duration.
    /// `launch_overhead` is the device's fixed invocation cost.
    fn execute(&self, spec: &KernelSpec, launch_overhead: f64) -> anyhow::Result<()>;
}

/// Burn exactly the estimated duration.
#[derive(Default)]
pub struct SpinExecutor;

impl KernelExecutor for SpinExecutor {
    fn execute(&self, spec: &KernelSpec, launch_overhead: f64) -> anyhow::Result<()> {
        let secs = spec.est_secs() + launch_overhead;
        timing::precise_wait(Duration::from_secs_f64(secs));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn spin_executor_burns_duration() {
        let _t = crate::util::timing::timing_test_lock();
        let ex = SpinExecutor;
        let spec = KernelSpec::Timed { secs: 2e-3 };
        let t0 = Instant::now();
        ex.execute(&spec, 100e-6).unwrap();
        let got = t0.elapsed().as_secs_f64();
        assert!((got - 2.1e-3).abs() < 200e-6, "{got}");
    }
}
