//! The virtual accelerator — the hardware-substitution substrate
//! (DESIGN.md §Hardware-substitution).
//!
//! Real OS threads play the device engines: one thread per DMA engine and
//! one compute thread. Transfers are *paced* against the profile's LogGP
//! link with cross-direction contention applied fluidly (a bus generation
//! counter wakes in-flight transfers whenever the active set changes, so
//! rates re-integrate exactly like the model's re-estimation — but in real
//! time, with real scheduling jitter). Kernels either spin for their
//! calibrated duration or execute an AOT artifact on PJRT-CPU.
//!
//! The device is intentionally *not* the model: prediction error measured
//! against it (Fig. 7) reflects genuine asynchrony, jitter and pacing
//! granularity, as the paper measures against real hardware.

pub mod bus;
pub mod executor;
pub mod vdev;

pub use bus::Bus;
pub use executor::{KernelExecutor, SpinExecutor};
pub use vdev::{DeviceRun, VirtualDevice};
