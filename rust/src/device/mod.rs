//! The virtual accelerator — the hardware-substitution substrate
//! (DESIGN.md §Hardware-substitution).
//!
//! Real OS threads play the device engines: one thread per DMA engine and
//! one compute thread. Transfers are *paced* against the profile's LogGP
//! link with cross-direction contention applied fluidly (a bus generation
//! counter wakes in-flight transfers whenever the active set changes, so
//! rates re-integrate exactly like the model's re-estimation — but in real
//! time, with real scheduling jitter). Kernels either spin for their
//! calibrated duration or execute an AOT artifact on PJRT-CPU.
//!
//! The device is intentionally *not* the model: prediction error measured
//! against it (Fig. 7) reflects genuine asynchrony, jitter and pacing
//! granularity, as the paper measures against real hardware.
//!
//! The coordinator drives devices through the [`Device`] trait so the
//! execution substrate is swappable: [`VirtualDevice`] (threads + paced
//! transfers), [`SimDevice`] (instant, bit-deterministic model replay —
//! the substrate for bit-identity property tests) and
//! [`chaos::ChaosDevice`] (deterministic fault injection around any
//! inner device — the substrate for the recovery tests and benches).

pub mod bus;
pub mod chaos;
pub mod executor;
pub mod simdev;
pub mod vdev;

pub use bus::Bus;
pub use chaos::{ChaosCounts, ChaosDevice, ChaosOptions};
pub use executor::{KernelExecutor, SpinExecutor};
pub use simdev::SimDevice;
pub use vdev::{DeviceRun, VirtualDevice};

use crate::config::DeviceProfile;
use crate::task::TaskSpec;

/// An execution substrate the coordinator can drive.
///
/// `run_group` executes an ordered task group to completion and reports
/// measured per-command timestamps. It is *fallible*: a device may
/// refuse a run (transient transport error, backend fault) by returning
/// `Err`, and may panic or hang — the recovery layer
/// (`coordinator::recovery`) is responsible for containing all three.
/// The inherent `VirtualDevice::run_group` remains infallible for
/// direct (non-coordinated) callers.
pub trait Device: Send + Sync {
    /// The device profile groups are compiled/planned against.
    fn profile(&self) -> &DeviceProfile;

    /// Execute `tasks` in order; blocks until the group drains.
    fn run_group(&self, tasks: &[TaskSpec]) -> anyhow::Result<DeviceRun>;
}
