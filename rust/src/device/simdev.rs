//! A model-backed [`Device`]: instant, bit-deterministic "execution".
//!
//! `SimDevice` answers `run_group` by running the §4 temporal simulator
//! instead of real engine threads, so a "run" finishes in microseconds
//! and two identical calls return bit-identical results. It is the
//! substrate for the recovery property tests
//! (`rust/tests/prop_recovery.rs`): bit-identity claims — a retried
//! transient fault replays to exactly the clean-run result, a fault-free
//! pipeline with the recovery policy enabled matches today's — are only
//! provable on a deterministic device, never on the jittery
//! [`VirtualDevice`](crate::device::VirtualDevice).
//!
//! It is *not* a measurement substrate: calibration against it converges
//! to identity by construction (measured == predicted).

use std::sync::Arc;

use crate::config::DeviceProfile;
use crate::device::{Device, DeviceRun};
use crate::model::{simulate, EngineState, SimOptions};
use crate::task::TaskSpec;

/// Device whose "measurements" are the temporal model's predictions.
pub struct SimDevice {
    profile: Arc<DeviceProfile>,
}

impl SimDevice {
    pub fn new(profile: DeviceProfile) -> Self {
        SimDevice { profile: Arc::new(profile) }
    }
}

impl Device for SimDevice {
    fn profile(&self) -> &DeviceProfile {
        &self.profile
    }

    fn run_group(&self, tasks: &[TaskSpec]) -> anyhow::Result<DeviceRun> {
        let r = simulate(
            tasks,
            &self.profile,
            EngineState::default(),
            SimOptions { record_timeline: true },
        );
        Ok(DeviceRun {
            makespan: r.makespan,
            timeline: r.timeline,
            task_end: r.task_end,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;
    use crate::task::synthetic::synthetic_benchmark;

    #[test]
    fn sim_device_is_bit_deterministic() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 0.25).unwrap();
        let dev = SimDevice::new(p);
        let a = dev.run_group(&g.tasks).unwrap();
        let b = dev.run_group(&g.tasks).unwrap();
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.task_end.len(), b.task_end.len());
        for (x, y) in a.task_end.iter().zip(&b.task_end) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(a.timeline.len(), b.timeline.len());
    }

    #[test]
    fn sim_device_matches_direct_simulation() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK25", &p, 0.25).unwrap();
        let direct = simulate(
            &g.tasks,
            &p,
            EngineState::default(),
            SimOptions { record_timeline: true },
        );
        let dev = SimDevice::new(p);
        let run = dev.run_group(&g.tasks).unwrap();
        assert_eq!(run.makespan.to_bits(), direct.makespan.to_bits());
        assert_eq!(run.timeline.len(), direct.timeline.len());
    }
}
