//! The virtual device proper: engine threads consuming command queues.
//!
//! `run_group` executes an ordered task group exactly as the host proxy
//! would submit it (via `queue::submission_plan`) and returns measured
//! per-command timestamps — the ground truth the temporal model is
//! validated against (Fig. 7) and the measurement substrate for the
//! speedup experiments (Figs. 9-11).

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::config::DeviceProfile;
use crate::device::bus::Bus;
use crate::device::executor::KernelExecutor;
use crate::model::timeline::{CmdKind, CmdRecord};
use crate::queue::command::{Command, CommandKind};
use crate::queue::submit::submission_plan;
use crate::task::TaskSpec;

/// Measured execution of one task group.
#[derive(Clone, Debug)]
pub struct DeviceRun {
    /// Wall-clock makespan (first submission -> last completion), seconds.
    pub makespan: f64,
    /// Per-command records on the device clock (t=0 at group start).
    pub timeline: Vec<CmdRecord>,
    /// Completion time of each task, submission order.
    pub task_end: Vec<f64>,
}

/// A virtual accelerator bound to a device profile and kernel backend.
pub struct VirtualDevice {
    profile: Arc<DeviceProfile>,
    executor: Arc<dyn KernelExecutor>,
}

impl VirtualDevice {
    pub fn new(profile: DeviceProfile, executor: Arc<dyn KernelExecutor>) -> Self {
        VirtualDevice { profile: Arc::new(profile), executor }
    }

    pub fn profile(&self) -> &DeviceProfile {
        &self.profile
    }

    /// Execute `tasks` in the given order; blocks until the group drains.
    pub fn run_group(&self, tasks: &[TaskSpec]) -> DeviceRun {
        let plan = submission_plan(tasks, &self.profile);
        let task_done = plan.task_done_events(tasks.len());
        let bus = Bus::new(self.profile.clone());
        let records: Arc<Mutex<Vec<CmdRecord>>> =
            Arc::new(Mutex::new(Vec::with_capacity(plan.total_commands())));
        let epoch = Instant::now();

        // Engine threads: Transfer0, Transfer1 (2-DMA only), Compute.
        let mut handles = Vec::new();
        let spawn_engine = |name: &str,
                            cmds: Vec<Command>,
                            htd_queue: bool|
         -> std::thread::JoinHandle<()> {
            let bus = bus.clone();
            let records = records.clone();
            let executor = self.executor.clone();
            let overhead = self.profile.kernel_launch_overhead;
            let cke = self.profile.cke_tail_overlap;
            std::thread::Builder::new()
                .name(format!("vdev-{name}"))
                .spawn(move || {
                    engine_loop(cmds, htd_queue, bus, records, executor, overhead, cke, epoch)
                })
                .expect("spawn engine thread")
        };

        handles.push(spawn_engine("xfer0", plan.transfer0, true));
        if !plan.transfer1.is_empty() {
            handles.push(spawn_engine("xfer1", plan.transfer1, false));
        }
        handles.push(spawn_engine("compute", plan.compute, false));
        for h in handles {
            h.join().expect("engine thread panicked");
        }

        let timeline = Arc::try_unwrap(records).unwrap().into_inner().unwrap();
        let makespan = timeline.iter().map(|r| r.end).fold(0.0, f64::max);
        let task_end =
            task_done.iter().map(|e| e.timestamp().unwrap_or(0.0)).collect();
        DeviceRun { makespan, timeline, task_end }
    }
}

impl crate::device::Device for VirtualDevice {
    fn profile(&self) -> &DeviceProfile {
        VirtualDevice::profile(self)
    }

    // The virtual device has no failure modes of its own (an engine-thread
    // panic propagates as a panic, which the recovery layer also contains),
    // so the trait impl simply wraps the infallible inherent method.
    fn run_group(&self, tasks: &[TaskSpec]) -> anyhow::Result<DeviceRun> {
        Ok(VirtualDevice::run_group(self, tasks))
    }
}

/// In-order consumption of one engine's command queue.
#[allow(clippy::too_many_arguments)]
fn engine_loop(
    cmds: Vec<Command>,
    htd_queue: bool,
    bus: Bus,
    records: Arc<Mutex<Vec<CmdRecord>>>,
    executor: Arc<dyn KernelExecutor>,
    launch_overhead: f64,
    cke_tail_overlap: f64,
    epoch: Instant,
) {
    let mut prev_kernel_end: f64 = 0.0;
    let mut prev_kernel_dur: f64 = 0.0;
    for cmd in cmds {
        // Honour explicit dependency events (green arrows).
        let mut ready_at: f64 = 0.0;
        for e in &cmd.waits {
            ready_at = ready_at.max(e.wait());
        }
        let start = epoch.elapsed().as_secs_f64();
        let (kind, end) = match &cmd.kind {
            CommandKind::HtD { bytes } => {
                let _g = bus.begin_transfer(true);
                bus.pace(true, *bytes);
                (CmdKind::HtD, epoch.elapsed().as_secs_f64())
            }
            CommandKind::DtH { bytes } => {
                // On the 1-DMA scheme DtH commands live in the HtD queue;
                // direction comes from the command, not the queue.
                let _ = htd_queue;
                let _g = bus.begin_transfer(false);
                bus.pace(false, *bytes);
                (CmdKind::DtH, epoch.elapsed().as_secs_f64())
            }
            CommandKind::Kernel { spec } => {
                // Optional CKE emulation: if this kernel was ready while
                // the previous one still ran, the hardware would have
                // overlapped its head with the predecessor's tail; shorten
                // the burn by that overlap (bounded by the tail fraction).
                let mut dur = spec.est_secs() + launch_overhead;
                if cke_tail_overlap > 0.0 && ready_at < prev_kernel_end {
                    let credit = (prev_kernel_end - ready_at)
                        .min(cke_tail_overlap * prev_kernel_dur);
                    dur = (dur - credit).max(0.0);
                    executor
                        .execute(
                            &crate::task::KernelSpec::Timed { secs: dur },
                            0.0,
                        )
                        .expect("kernel execution failed");
                } else {
                    executor
                        .execute(spec, launch_overhead)
                        .expect("kernel execution failed");
                }
                let end = epoch.elapsed().as_secs_f64();
                prev_kernel_end = end;
                prev_kernel_dur = dur;
                (CmdKind::Kernel, end)
            }
        };
        cmd.completion.complete(end);
        records.lock().unwrap().push(CmdRecord {
            task: cmd.task,
            kind,
            seq: cmd.seq,
            start,
            end,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;
    use crate::device::executor::SpinExecutor;
    use crate::model::{simulate, EngineState, SimOptions};
    use crate::task::synthetic::synthetic_benchmark;
    use crate::util::stats::rel_err;

    fn device(name: &str) -> VirtualDevice {
        VirtualDevice::new(
            profile_by_name(name).unwrap(),
            Arc::new(SpinExecutor),
        )
    }

    #[test]
    fn measured_close_to_model_two_dma() {
        let _t = crate::util::timing::timing_test_lock();
        let p = profile_by_name("amd_r9").unwrap();
        let dev = device("amd_r9");
        // Compressed time scale keeps the test fast (~6 ms per run).
        let g = synthetic_benchmark("BK50", &p, 0.25).unwrap();
        let predicted =
            simulate(&g.tasks, &p, EngineState::default(), SimOptions::default())
                .makespan;
        let measured = dev.run_group(&g.tasks).makespan;
        assert!(
            rel_err(predicted, measured) < 0.08,
            "pred {predicted:.6} vs meas {measured:.6}"
        );
    }

    #[test]
    fn measured_close_to_model_one_dma() {
        let _t = crate::util::timing::timing_test_lock();
        let p = profile_by_name("xeon_phi").unwrap();
        let dev = device("xeon_phi");
        let g = synthetic_benchmark("BK25", &p, 0.25).unwrap();
        let predicted =
            simulate(&g.tasks, &p, EngineState::default(), SimOptions::default())
                .makespan;
        let measured = dev.run_group(&g.tasks).makespan;
        assert!(
            rel_err(predicted, measured) < 0.08,
            "pred {predicted:.6} vs meas {measured:.6}"
        );
    }

    #[test]
    fn device_respects_dependencies() {
        let _t = crate::util::timing::timing_test_lock();
        let dev = device("amd_r9");
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK75", &p, 0.15).unwrap();
        let run = dev.run_group(&g.tasks);
        for t in 0..g.len() {
            let h_end = run
                .timeline
                .iter()
                .filter(|c| c.task == t && c.kind == CmdKind::HtD)
                .map(|c| c.end)
                .fold(0.0, f64::max);
            let k = run
                .timeline
                .iter()
                .find(|c| c.task == t && c.kind == CmdKind::Kernel)
                .unwrap();
            // Small epsilon: thread wakeup after event completion.
            assert!(k.start >= h_end - 200e-6, "task {t}");
        }
        // Task-end bookkeeping matches the last DtH of each task.
        for t in 0..g.len() {
            let d_end = run
                .timeline
                .iter()
                .filter(|c| c.task == t && c.kind == CmdKind::DtH)
                .map(|c| c.end)
                .fold(0.0, f64::max);
            assert!((run.task_end[t] - d_end).abs() < 1e-9);
        }
    }

    #[test]
    fn ordering_changes_measured_makespan() {
        let _t = crate::util::timing::timing_test_lock();
        let p = profile_by_name("amd_r9").unwrap();
        let dev = device("amd_r9");
        let g = synthetic_benchmark("BK25", &p, 0.2).unwrap();
        // Good order: T0 (DK) first; bad order: all transfers first.
        let good = dev.run_group(&g.tasks).makespan;
        let bad_order: Vec<TaskSpec> =
            [3, 2, 1, 0].iter().map(|&i| g.tasks[i].clone()).collect();
        let bad = dev.run_group(&bad_order).makespan;
        assert!(
            bad > good * 1.03,
            "expected ordering effect: good {good:.6} bad {bad:.6}"
        );
    }
}
