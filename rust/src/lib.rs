//! # oclcc — task-throughput scheduling via command concurrency
//!
//! Production-grade reproduction of *"Improving tasks throughput on
//! accelerators using OpenCL command concurrency"* (Lázaro-Muñoz,
//! González-Linares, Gómez-Luna, Guil — 2018).
//!
//! The crate provides, in dependency order:
//!
//! * [`util`] — RNG / stats / JSON / CLI / bench substrate (offline build).
//! * [`config`] — device profiles (paper Table 1 + LogGP constants).
//! * [`task`] — tasks, task groups, and the synthetic (Tables 2-3) and
//!   real (Tables 4-5) catalogs.
//! * [`model`] — the §4 temporal execution model: transfer models
//!   (Fig. 6), the linear kernel model (Eq. 1) and the event-driven
//!   simulator (Figs. 4-5).
//! * [`sched`] — the §5 Batch Reordering heuristic plus brute-force and
//!   baseline orderings.
//! * [`queue`] — OpenCL-style command queues and events (§3.2 submission
//!   schemes).
//! * [`device`] — the virtual accelerator: DMA-engine/compute threads
//!   with paced transfers and optional live PJRT kernel execution.
//! * [`runtime`] — PJRT artifact registry (HLO text -> compiled
//!   executables) over the `xla` crate.
//! * [`coordinator`] — the §6.2 multi-worker proxy-thread runtime, now
//!   behind the unified [`Driver`](coordinator::Driver) façade.
//! * [`trace`] — the streaming NDJSON trace protocol: record workloads,
//!   replay them deterministically, or serve them live.
//! * [`profiling`] — LogGP / Eq. 1 calibration against the virtual device.
//! * [`bench`] — harnesses regenerating every paper table and figure.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod model;
pub mod profiling;
pub mod queue;
pub mod runtime;
pub mod sched;
pub mod task;
pub mod trace;
pub mod util;
