//! `oclcc` — launcher CLI for the command-concurrency scheduling stack.
//!
//! Subcommands:
//!   devices                      list device profiles (Table 1)
//!   tasks [--device D]           print task catalogs (Tables 2-5)
//!   simulate --benchmark BK50    model a group; print timeline + Gantt
//!   schedule --benchmark BK50    heuristic order + predicted speedup
//!   run --benchmark BK50         execute on the virtual device
//!   serve                        multi-worker proxy runtime (§6.2);
//!                                with --trace FILE or --stdin: live
//!                                NDJSON trace service (docs/TRACE.md)
//!   replay --trace FILE          deterministic virtual-clock replay of
//!                                a recorded NDJSON trace
//!   profile [--loggp|--kernels]  calibrate link/kernel constants
//!   bench <fig6|fig7|fig9|fig10|fig11|table5|table6|ablation|all>
//!
//! Common options: --device <amd_r9|k20c|xeon_phi|cpu_live>, --scale S,
//! --seed N, --quick, --real (sample real tasks instead of synthetic).
//! Trace options: --devices a,b (fleet), --policy heuristic|noreorder,
//! --drain fifo|weighted_fair|strict_priority|deadline_edf, --width W,
//! --group-cap N, --tenant-cap N, --global-cap N,
//! --overflow block|shed_lowest|reject_new, --out FILE.

use std::io::Write as _;
use std::sync::Arc;

use anyhow::Result;

use oclcc::bench;
use oclcc::config::{builtin_profiles, profile_by_name, DeviceProfile};
use oclcc::coordinator::{
    AdmissionOptions, DrainPolicyKind, DriverBuilder, FleetCoordOptions,
    LaneOptions, Overflow, Policy,
};
use oclcc::device::{Device, SpinExecutor, VirtualDevice};
use oclcc::model::timeline::Timeline;
use oclcc::model::{simulate, EngineState, SimOptions};
use oclcc::runtime::manifest::default_artifact_dir;
use oclcc::runtime::{PjrtExecutor, PjrtService};
use oclcc::sched::bruteforce::OrderStats;
use oclcc::sched::heuristic::{batch_reorder, DEFAULT_BEAM_WIDTH};
use oclcc::task::real::real_benchmark;
use oclcc::task::synthetic::synthetic_benchmark;
use oclcc::task::{TaskGroup, TaskSpec};
use oclcc::trace::{parse_trace, ReplayOptions};
use oclcc::util::cli::Args;
use oclcc::util::rng::Pcg64;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
        std::process::exit(2);
    }
    let cmd = argv[0].clone();
    let args = Args::parse(argv.into_iter().skip(1));
    let result = match cmd.as_str() {
        "devices" => cmd_devices(),
        "tasks" => cmd_tasks(&args),
        "simulate" => cmd_simulate(&args),
        "schedule" => cmd_schedule(&args),
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "replay" => cmd_replay(&args),
        "profile" => cmd_profile(&args),
        "bench" => cmd_bench(&args),
        "help" | "--help" | "-h" => {
            usage();
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand '{other}'");
            usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "usage: oclcc <devices|tasks|simulate|schedule|run|serve|replay|profile|bench> [options]\n\
         serve --trace FILE [--fleet]   live NDJSON trace service\n\
         replay --trace FILE [--out F]  deterministic trace replay\n\
         see `oclcc help`, README.md and docs/TRACE.md"
    );
}

/// Resolve the task group named by --benchmark on --device.
fn group_from_args(args: &Args) -> Result<(oclcc::config::DeviceProfile, TaskGroup)> {
    let device = args.opt_or("device", "amd_r9");
    let profile = profile_by_name(&device)?;
    let label = args.opt_or("benchmark", "BK50");
    let scale = args.opt_f64("scale", 1.0);
    let group = if args.flag("real") {
        let t = args.opt_usize("t", 4);
        let mut rng = Pcg64::seeded(args.opt_u64("seed", 7));
        let table_dev = if device == "cpu_live" { "amd_r9" } else { &device };
        real_benchmark(&label, table_dev, &profile, t, &mut rng, scale)?
    } else {
        synthetic_benchmark(&label, &profile, scale)?
    };
    Ok((profile, group))
}

fn cmd_devices() -> Result<()> {
    let mut t = oclcc::util::table::Table::new(&[
        "name", "DMA", "HtD GB/s", "DtH GB/s", "sigma", "kernel backend",
    ]);
    for p in builtin_profiles() {
        t.row(vec![
            p.name.clone(),
            p.dma_engines.to_string(),
            format!("{:.1}", p.htd.bytes_per_sec / 1e9),
            format!("{:.1}", p.dth.bytes_per_sec / 1e9),
            format!("{:.2}", p.duplex_slowdown),
            if p.name == "cpu_live" {
                "PJRT artifacts".into()
            } else {
                "calibrated spin".into()
            },
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_tasks(args: &Args) -> Result<()> {
    bench::table5::run(args)
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let (profile, group) = group_from_args(args)?;
    let r = simulate(
        &group.tasks,
        &profile,
        EngineState::default(),
        SimOptions { record_timeline: true },
    );
    println!("device {} / {} tasks", profile.name, group.len());
    print!("{}", Timeline(&r.timeline).gantt(72));
    println!("predicted makespan: {:.3} ms", r.makespan * 1e3);
    Ok(())
}

fn cmd_schedule(args: &Args) -> Result<()> {
    let (profile, group) = group_from_args(args)?;
    let mut rng = Pcg64::seeded(args.opt_u64("seed", 7));
    let st = OrderStats::exhaustive(&group.tasks, &profile, 720, &mut rng);
    let order = batch_reorder(&group.tasks, &profile, EngineState::default());
    let h_tasks: Vec<TaskSpec> =
        order.iter().map(|&i| group.tasks[i].clone()).collect();
    let h = simulate(&h_tasks, &profile, EngineState::default(), SimOptions::default())
        .makespan;
    println!("device {}: {} tasks", profile.name, group.len());
    println!(
        "heuristic order: {:?}",
        order
            .iter()
            .map(|&i| group.tasks[i].name.as_str())
            .collect::<Vec<_>>()
    );
    println!(
        "predicted: heuristic {:.3} ms | best {:.3} | mean {:.3} | worst {:.3}",
        h * 1e3,
        st.best * 1e3,
        st.mean * 1e3,
        st.worst * 1e3
    );
    println!(
        "speedup vs worst: {:.3}x (best possible {:.3}x)",
        st.worst / h,
        st.worst / st.best
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let (profile, group) = group_from_args(args)?;
    let device = make_device(&profile)?;
    let order = if args.opt_or("policy", "heuristic") == "heuristic" {
        batch_reorder(&group.tasks, &profile, EngineState::default())
    } else {
        (0..group.len()).collect()
    };
    let ordered: Vec<TaskSpec> =
        order.iter().map(|&i| group.tasks[i].clone()).collect();
    let pred = simulate(&ordered, &profile, EngineState::default(), SimOptions::default())
        .makespan;
    let run = device.run_group(&ordered);
    print!("{}", Timeline(&run.timeline).gantt(72));
    println!(
        "measured {:.3} ms | predicted {:.3} ms | error {:.2}%",
        run.makespan * 1e3,
        pred * 1e3,
        (run.makespan - pred).abs() / run.makespan * 100.0
    );
    Ok(())
}

/// Parse --policy (default heuristic).
fn policy_from_args(args: &Args) -> Result<Policy> {
    match args.opt_or("policy", "heuristic").as_str() {
        "heuristic" => Ok(Policy::Heuristic),
        "noreorder" => Ok(Policy::NoReorder),
        other => anyhow::bail!("unknown --policy '{other}' (heuristic|noreorder)"),
    }
}

/// Device profile list for the trace subcommands: `--devices a,b,c`
/// wins over the single `--device` (default amd_r9).
fn trace_profiles(args: &Args) -> Result<Vec<DeviceProfile>> {
    let spec = match args.opt("devices") {
        Some(s) => s.to_string(),
        None => args.opt_or("device", "amd_r9"),
    };
    spec.split(',')
        .map(|name| profile_by_name(name.trim()))
        .collect()
}

/// Admission knobs shared by `serve --trace` and `replay`. Armed only
/// when at least one of --tenant-cap / --global-cap / --overflow is
/// given; unset caps fall back to the library defaults.
fn admission_from_args(args: &Args) -> Result<Option<AdmissionOptions>> {
    let armed = args.opt("tenant-cap").is_some()
        || args.opt("global-cap").is_some()
        || args.opt("overflow").is_some();
    if !armed {
        return Ok(None);
    }
    let overflow = match args.opt_or("overflow", "block").as_str() {
        "block" => Overflow::Block,
        "shed_lowest" => Overflow::ShedLowest,
        "reject_new" => Overflow::RejectNew,
        other => anyhow::bail!(
            "unknown --overflow '{other}' (block|shed_lowest|reject_new)"
        ),
    };
    let defaults = AdmissionOptions::default();
    Ok(Some(AdmissionOptions {
        per_tenant_cap: args.opt_usize("tenant-cap", defaults.per_tenant_cap),
        global_cap: args.opt_usize("global-cap", defaults.global_cap),
        overflow,
        ..defaults
    }))
}

fn cmd_serve(args: &Args) -> Result<()> {
    if args.opt("trace").is_some() || args.flag("stdin") {
        return cmd_serve_trace(args);
    }
    // Legacy demo: synthetic batches through both policies, via the
    // Driver façade so this path and the trace service share a stack.
    let (profile, group) = group_from_args(args)?;
    let t = args.opt_usize("t", 4);
    let n = args.opt_usize("n", 2);
    let device: Arc<dyn Device> = Arc::new(make_device(&profile)?);
    let mut rng = Pcg64::seeded(args.opt_u64("seed", 7));
    let batches: Vec<Vec<TaskSpec>> = (0..t)
        .map(|_| {
            (0..n)
                .map(|_| group.tasks[rng.below(group.len() as u64) as usize].clone())
                .collect()
        })
        .collect();
    for policy in [Policy::NoReorder, Policy::Heuristic] {
        let driver = DriverBuilder::lanes(LaneOptions {
            policy,
            ..LaneOptions::default()
        })
        .device(device.clone())
        .build()
        .map_err(|e| anyhow::anyhow!("{e}"))?;
        let m = driver.run(batches.clone()).metrics;
        println!(
            "{policy:?}: {} tasks in {:.1} ms -> {:.1} tasks/s, mean latency {:.2} ms",
            m.n_tasks,
            m.total_secs * 1e3,
            m.tasks_per_sec,
            m.mean_latency() * 1e3,
        );
    }
    Ok(())
}

/// `serve --trace FILE` / `serve --stdin`: run a recorded trace live
/// through a lane or fleet coordinator, streaming NDJSON telemetry to
/// stdout. Wall-clock, not bit-stable — see `oclcc replay` for the
/// deterministic path.
fn cmd_serve_trace(args: &Args) -> Result<()> {
    let text = match args.opt("trace") {
        Some(path) => std::fs::read_to_string(path)?,
        None => {
            let mut s = String::new();
            std::io::Read::read_to_string(&mut std::io::stdin(), &mut s)?;
            s
        }
    };
    let trace = parse_trace(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let profiles = trace_profiles(args)?;
    let policy = policy_from_args(args)?;
    let admission = admission_from_args(args)?;
    let fleet = args.flag("fleet") || profiles.len() > 1;
    let driver = if fleet {
        let mut b = DriverBuilder::fleet(FleetCoordOptions {
            policy,
            admission,
            ..FleetCoordOptions::default()
        });
        for p in &profiles {
            b = b.device(Arc::new(make_device(p)?) as Arc<dyn Device>);
        }
        b.build().map_err(|e| anyhow::anyhow!("{e}"))?
    } else {
        DriverBuilder::lanes(LaneOptions {
            policy,
            admission,
            ..LaneOptions::default()
        })
        .device(Arc::new(make_device(&profiles[0])?) as Arc<dyn Device>)
        .build()
        .map_err(|e| anyhow::anyhow!("{e}"))?
    };
    let mut out = std::io::stdout().lock();
    oclcc::trace::serve(&trace, driver.as_ref(), &mut out)?;
    Ok(())
}

/// `replay --trace FILE`: deterministic virtual-clock replay. The same
/// trace and options reproduce the event stream bit-for-bit; write it
/// with --out and diff runs with `cmp`.
fn cmd_replay(args: &Args) -> Result<()> {
    let path = args
        .opt("trace")
        .ok_or_else(|| anyhow::anyhow!("replay needs --trace FILE"))?;
    let text = std::fs::read_to_string(path)?;
    let trace = parse_trace(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let drain_name = args.opt_or("drain", "fifo");
    let drain = DrainPolicyKind::from_name(&drain_name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown --drain '{drain_name}' \
             (fifo|weighted_fair|strict_priority|deadline_edf)"
        )
    })?;
    let opts = ReplayOptions {
        devices: trace_profiles(args)?,
        policy: policy_from_args(args)?,
        width: args.opt_usize("width", DEFAULT_BEAM_WIDTH),
        group_cap: args.opt_usize("group-cap", 0),
        drain,
        admission: admission_from_args(args)?,
    };
    let r = oclcc::trace::replay(&trace, &opts).map_err(|e| anyhow::anyhow!("{e}"))?;
    match args.opt("out") {
        Some(path) => {
            let mut body = r.events.join("\n");
            body.push('\n');
            std::fs::write(path, body)?;
            eprintln!(
                "replayed {} tasks / {} groups ({} shed), makespan {:.3} ms -> {path}",
                r.n_tasks,
                r.n_groups,
                r.n_shed,
                r.makespan_s * 1e3
            );
        }
        None => {
            let mut out = std::io::stdout().lock();
            for line in &r.events {
                writeln!(out, "{line}")?;
            }
        }
    }
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let device = args.opt_or("device", "cpu_live");
    let profile = profile_by_name(&device)?;
    if args.flag("loggp") || !args.flag("kernels") {
        let sizes: Vec<u64> = vec![4, 8, 12, 16]
            .into_iter()
            .map(|mb: u64| mb * 1_000_000)
            .collect();
        let cal = oclcc::profiling::calibrate_link(&profile, &sizes);
        println!(
            "link calibration ({device}): HtD {:.2} GB/s lat {:.0} us | DtH {:.2} GB/s lat {:.0} us | sigma {:.3}",
            cal.htd.bytes_per_sec / 1e9,
            cal.htd.latency * 1e6,
            cal.dth.bytes_per_sec / 1e9,
            cal.dth.latency * 1e6,
            cal.duplex_slowdown
        );
    }
    if args.flag("kernels") || !args.flag("loggp") {
        let runtime = oclcc::runtime::PjrtRuntime::new(&default_artifact_dir())?;
        println!("PJRT platform: {}", runtime.platform());
        let cal =
            oclcc::profiling::calibrate_kernels(&runtime, args.opt_usize("reps", 3))?;
        let mut t = oclcc::util::table::Table::new(&["variant", "median (ms)"]);
        for (name, secs) in &cal.variant_secs {
            t.row(vec![name.clone(), format!("{:.3}", secs * 1e3)]);
        }
        t.print();
        let mut t2 =
            oclcc::util::table::Table::new(&["family", "eta (ns/B)", "gamma (us)"]);
        for (fam, m) in &cal.models {
            t2.row(vec![
                fam.clone(),
                format!("{:.3}", m.eta * 1e9),
                format!("{:.1}", m.gamma * 1e6),
            ]);
        }
        t2.print();
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    match which {
        "fig6" => bench::fig6::run(args),
        "fig7" => bench::fig7::run(args),
        "fig9" => bench::fig9::run(args),
        "fig10" => bench::fig10::run(args),
        "fig11" => bench::fig11::run(args),
        "table5" => bench::table5::run(args),
        "table6" => bench::table6::run(args),
        "ablation" => bench::ablation::run(args),
        "all" => {
            bench::fig6::run(args)?;
            bench::fig7::run(args)?;
            bench::fig9::run(args)?;
            bench::fig10::run(args)?;
            bench::fig11::run(args)?;
            bench::table5::run(args)?;
            bench::table6::run(args)?;
            bench::ablation::run(args)
        }
        other => anyhow::bail!("unknown bench '{other}'"),
    }
}

/// Device factory: the three paper profiles spin their calibrated kernel
/// durations; `cpu_live` executes real AOT artifacts via PJRT.
fn make_device(profile: &oclcc::config::DeviceProfile) -> Result<VirtualDevice> {
    if profile.name == "cpu_live" {
        let service = PjrtService::start(default_artifact_dir())?;
        Ok(VirtualDevice::new(
            profile.clone(),
            Arc::new(PjrtExecutor::new(service)),
        ))
    } else {
        Ok(VirtualDevice::new(profile.clone(), Arc::new(SpinExecutor)))
    }
}
