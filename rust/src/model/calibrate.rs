//! Online recalibration of the temporal model — measured-rate feedback
//! from executed groups into the per-device rate model.
//!
//! The paper treats the model's LogGP constants as fixed, measured once
//! by a micro-benchmark; PR 3's `DriftGate` already *measures* how far
//! reality has drifted from those constants but only uses the signal to
//! admit re-plans. This module closes the loop, the way OpenCL
//! performance-prediction systems (Johnston et al.) and PySchedCL treat
//! per-device rate models: as fitted, updatable artifacts.
//!
//! * [`Calibrator`] ingests each completed task's measured per-engine
//!   times (HtD, kernel, DtH — summed from the device's [`CmdRecord`]
//!   timeline) against the model's predicted per-engine times for the
//!   same order (summed from a *recorded model replay*, so duplex
//!   contention appears symmetrically on both sides — see
//!   [`Calibrator::observe_group`]), and maintains one robust EWMA per
//!   engine over the *implied-rate residuals* `measured / predicted`.
//!   Residuals are outlier-clipped
//!   (a single jittered µs-scale transfer must not yank the model) and
//!   the resulting corrections are warm-up-gated (identity until enough
//!   samples accumulated) and clamped to a bounded range so the derived
//!   profile always satisfies every `DeviceProfile` invariant.
//! * [`CalibratedProfile`] turns a correction triple into a planning
//!   model: an *effective* [`DeviceProfile`] whose link times are scaled
//!   (latency multiplied, bandwidth divided — see [`LinkParams::scaled`])
//!   plus a kernel time scale applied at [`TaskTable`] compilation
//!   (kernel estimates live per task, not in the profile, so the scale
//!   rides with the compile). `duplex_slowdown` is never touched, so the
//!   sigma >= 1 invariant behind `SimCursor::lower_bound` admissibility
//!   is preserved by construction.
//!
//! # Atomic adoption, and why the bound-gated search stays exact
//!
//! A correction is *adopted* only at a planning-timeline boundary: the
//! lane recompiles the group's [`TaskTable`] against the calibrated
//! profile **and** rewinds its planning cursor from that same table
//! ([`SimCursor::reset_for_table`]) in one step. Every floor the pruning
//! layer consults (`lower_bound_with_remaining` busy sums, the table's
//! group aggregates, `remaining_floor` row scans) and every rollout it
//! scores then derive from one `(table, ProfileParams)` generation, so
//! the admissibility and bit-exactness proofs of `sched::search_util`
//! apply unchanged — corrections may speed *or* slow engine rates
//! without ever mixing generations inside one search. Envelopes from an
//! older generation are never compared against scores from a newer one
//! (the reset is the generation barrier).
//!
//! With recalibration off (`LaneOptions::recalibrate: None`) the
//! pipeline is **bit-identical** to the pre-calibration code: an
//! identity [`CalibratedProfile`] compiles bitwise-equal tables
//! (`x * 1.0` and `x / 1.0` are exact in IEEE-754), pinned by
//! `rust/tests/prop_calibrate.rs`.
//!
//! Calibrated planning is table-path only: `SimCursor::push_task` (the
//! `TaskSpec` walk) knows nothing of the kernel scale, so calibrated
//! simulation must go through [`SimCursor::push_task_compiled`] — which
//! is the only push every scheduler hot path uses.
//!
//! In a heterogeneous fleet each device owns one `Calibrator` and one
//! adopted [`CalibratedProfile`] generation (`coordinator::fleet`): the
//! fleet's earliest-completion-time placement and its steal predicate
//! score candidates against the *destination* device's calibrated
//! model, so systematic per-device drift (a slow PCIe link, an
//! optimistic kernel estimate) shifts placement decisions instead of
//! silently skewing them.
//!
//! [`CmdRecord`]: crate::model::timeline::CmdRecord
//! [`LinkParams::scaled`]: crate::config::LinkParams::scaled
//! [`TaskTable`]: crate::model::TaskTable
//! [`SimCursor::reset_for_table`]: crate::model::SimCursor::reset_for_table
//! [`SimCursor::push_task_compiled`]: crate::model::SimCursor::push_task_compiled

use crate::config::DeviceProfile;
use crate::model::timeline::{CmdKind, CmdRecord};

/// Knobs of the online recalibration loop. Consumed by
/// `coordinator::lanes` via `LaneOptions::recalibrate`.
#[derive(Clone, Copy, Debug)]
pub struct CalibrateOptions {
    /// EWMA smoothing factor over per-task implied-rate residuals,
    /// in (0, 1]. Higher = faster adaptation, noisier corrections.
    pub alpha: f64,
    /// Accepted observations an engine needs before its correction
    /// leaves identity (warm-up gate: a single jittered sample must not
    /// start steering the model).
    pub warmup: usize,
    /// Per-observation residual clip: `measured / predicted` is clamped
    /// into `[1/clip, clip]` before entering the EWMA (>= 1). Clipped
    /// observations still count — a persistent regime shift beyond the
    /// clip converges to the clip bound instead of being discarded.
    pub clip: f64,
    /// Bound on the *applied* correction factor: corrections are clamped
    /// into `[1/max_correction, max_correction]`, keeping the effective
    /// profile's bandwidths finite and positive (>= 1).
    pub max_correction: f64,
    /// Relative dead-band of [`Calibrator::adopt`]: a fresh correction
    /// replaces the applied one only when some engine's factor moved by
    /// more than this fraction — otherwise every EWMA tick would churn a
    /// new model generation per group for noise-level changes.
    pub adopt_margin: f64,
}

impl Default for CalibrateOptions {
    fn default() -> Self {
        CalibrateOptions {
            alpha: 0.3,
            warmup: 3,
            clip: 4.0,
            max_correction: 8.0,
            adopt_margin: 0.02,
        }
    }
}

/// Per-engine seconds triple: predicted solo stage times (from a
/// compiled [`TaskTable`] row) or measured engine-busy times (summed
/// from a device timeline).
///
/// [`TaskTable`]: crate::model::TaskTable
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EngineSecs {
    pub htd: f64,
    pub k: f64,
    pub dth: f64,
}

/// Per-engine time-scale corrections relative to the *base* model
/// (> 1 = the engine runs slower than modeled, so modeled times stretch).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Corrections {
    pub htd: f64,
    pub k: f64,
    pub dth: f64,
}

impl Corrections {
    pub fn identity() -> Corrections {
        Corrections { htd: 1.0, k: 1.0, dth: 1.0 }
    }

    pub fn is_identity(&self) -> bool {
        self.htd == 1.0 && self.k == 1.0 && self.dth == 1.0
    }
}

impl Default for Corrections {
    fn default() -> Self {
        Corrections::identity()
    }
}

/// Observation counters, surfaced through `LaneStats` and the online
/// bench trajectory.
#[derive(Clone, Copy, Debug, Default)]
pub struct CalibCounts {
    /// Accepted per-engine residual observations.
    pub n_obs: u64,
    /// Observations whose residual hit the `clip` bound.
    pub n_clipped: u64,
}

/// One engine's residual estimator.
#[derive(Clone, Copy, Debug, Default)]
struct Ewma {
    value: Option<f64>,
    n: usize,
}

impl Ewma {
    fn observe(&mut self, residual: f64, alpha: f64) {
        self.value = Some(match self.value {
            None => residual,
            Some(e) => e + alpha * (residual - e),
        });
        self.n += 1;
    }

    /// Warm-up-gated, clamped correction factor.
    fn correction(&self, warmup: usize, max_correction: f64) -> f64 {
        match self.value {
            Some(e) if self.n >= warmup => e.clamp(1.0 / max_correction, max_correction),
            _ => 1.0,
        }
    }
}

/// Robust per-engine rate-residual tracker (see module docs). One per
/// lane; feed it every completed group, consult [`Calibrator::adopt`] at
/// planning-timeline boundaries.
#[derive(Clone, Debug)]
pub struct Calibrator {
    opts: CalibrateOptions,
    htd: Ewma,
    k: Ewma,
    dth: Ewma,
    /// Corrections the caller's current model generation already carries
    /// — incoming predictions are divided back to base-model units so the
    /// EWMA always estimates the *total* scale vs the base model (no
    /// compounding feedback).
    applied: Corrections,
    counts: CalibCounts,
    /// Reused per-group measured-seconds scratch (slot-indexed).
    meas: Vec<EngineSecs>,
}

/// Predicted stage times below this are too small for a meaningful rate
/// residual (µs-scale OS jitter would dominate the implied rate).
const MIN_PREDICTED_SECS: f64 = 1e-9;

impl Calibrator {
    pub fn new(opts: CalibrateOptions) -> Calibrator {
        assert!(
            opts.alpha > 0.0 && opts.alpha <= 1.0,
            "calibration alpha must be in (0, 1]"
        );
        assert!(opts.clip >= 1.0, "residual clip must be >= 1");
        assert!(opts.max_correction >= 1.0, "max_correction must be >= 1");
        assert!(opts.adopt_margin >= 0.0, "adopt_margin must be >= 0");
        Calibrator {
            opts,
            htd: Ewma::default(),
            k: Ewma::default(),
            dth: Ewma::default(),
            applied: Corrections::identity(),
            counts: CalibCounts::default(),
            meas: Vec::new(),
        }
    }

    /// Record one completed task: `predicted` in *current-model* units
    /// (the compiled table rows the plan used), `measured` from the
    /// device. Degenerate samples (non-finite, non-positive, or predicted
    /// below the meaningful-rate floor) are skipped per engine.
    pub fn observe_task(&mut self, predicted: EngineSecs, measured: EngineSecs) {
        let applied = self.applied;
        let (opts, counts) = (self.opts, &mut self.counts);
        let mut one = |est: &mut Ewma, pred: f64, meas: f64, scale: f64| {
            // Back to base-model units, so the EWMA estimates the total
            // correction vs the base model, not a compounding increment.
            let pred_base = pred / scale;
            if !(pred_base.is_finite() && meas.is_finite())
                || pred_base < MIN_PREDICTED_SECS
                || meas <= 0.0
            {
                return;
            }
            let raw = meas / pred_base;
            let clipped = raw.clamp(1.0 / opts.clip, opts.clip);
            if clipped != raw {
                counts.n_clipped += 1;
            }
            counts.n_obs += 1;
            est.observe(clipped, opts.alpha);
        };
        one(&mut self.htd, predicted.htd, measured.htd, applied.htd);
        one(&mut self.k, predicted.k, measured.k, applied.k);
        one(&mut self.dth, predicted.dth, measured.dth, applied.dth);
    }

    /// Record one executed group: `predicted[slot]` is the submitted
    /// order's per-slot predicted stage seconds (current-model units);
    /// `timeline` is the device's measured per-command record, whose
    /// `task` indices are slots in the same order. Slots missing from
    /// the timeline contribute zero measured time and are skipped by the
    /// per-engine degenerate-sample guard.
    ///
    /// **Contention symmetry:** measured transfer durations include the
    /// device's duplex-contention stretch (commands paced at `bw/sigma`
    /// while the opposite direction is active), so `predicted` must
    /// include the *modeled* contention too — fold a recorded model
    /// replay of the same order via [`fold_timeline_stage_secs`], do NOT
    /// pass solo stage seconds. Solo predictions would double-count
    /// sigma into the corrections: a perfectly calibrated model on an
    /// overlap-rich workload would read as "links too slow", adopt a
    /// slowed generation, and then over-predict once the simulator
    /// applies sigma on top of the absorbed correction.
    pub fn observe_group(&mut self, predicted: &[EngineSecs], timeline: &[CmdRecord]) {
        let mut meas = std::mem::take(&mut self.meas);
        fold_timeline_stage_secs(predicted.len(), timeline, &mut meas);
        for (slot, &pred) in predicted.iter().enumerate() {
            self.observe_task(pred, meas[slot]);
        }
        self.meas = meas;
    }

    /// Current warm-up-gated, clamped correction triple vs the base
    /// model (identity until each engine has `warmup` accepted samples).
    pub fn corrections(&self) -> Corrections {
        let (w, m) = (self.opts.warmup, self.opts.max_correction);
        Corrections {
            htd: self.htd.correction(w, m),
            k: self.k.correction(w, m),
            dth: self.dth.correction(w, m),
        }
    }

    /// Corrections the caller last adopted (identity initially).
    pub fn applied(&self) -> Corrections {
        self.applied
    }

    /// Consult at a planning-timeline boundary: returns `Some(fresh)` —
    /// and records it as applied — when some engine's correction moved by
    /// more than `adopt_margin` relative to the applied one, else `None`
    /// (keep the current model generation). The caller must rebuild its
    /// [`CalibratedProfile`] (and recompile tables / reset cursors) from
    /// the returned triple before planning anything else.
    pub fn adopt(&mut self) -> Option<Corrections> {
        let fresh = self.corrections();
        let moved = |a: f64, b: f64| (a - b).abs() > self.opts.adopt_margin * b.abs();
        if moved(fresh.htd, self.applied.htd)
            || moved(fresh.k, self.applied.k)
            || moved(fresh.dth, self.applied.dth)
        {
            self.applied = fresh;
            Some(fresh)
        } else {
            None
        }
    }

    pub fn counts(&self) -> CalibCounts {
        self.counts
    }
}

/// Fold a per-command timeline (simulated or device-measured; `task`
/// indices are submission-order slots) into per-slot engine seconds —
/// the duration substrate both sides of a calibration observation are
/// built from. Out-of-range slots are ignored; `out` is cleared and
/// resized (capacity reused across calls).
pub fn fold_timeline_stage_secs(
    n_slots: usize,
    timeline: &[CmdRecord],
    out: &mut Vec<EngineSecs>,
) {
    out.clear();
    out.resize(n_slots, EngineSecs::default());
    for r in timeline {
        let Some(m) = out.get_mut(r.task) else { continue };
        match r.kind {
            CmdKind::HtD => m.htd += r.dur(),
            CmdKind::Kernel => m.k += r.dur(),
            CmdKind::DtH => m.dth += r.dur(),
        }
    }
}

/// A base model plus adopted corrections, materialized as the planning
/// profile a lane compiles tables against (see module docs).
#[derive(Clone, Debug)]
pub struct CalibratedProfile {
    scales: Corrections,
    effective: DeviceProfile,
}

impl CalibratedProfile {
    /// Corrections applied to `base`. Scales must be finite and positive
    /// (the [`Calibrator`] clamp guarantees this for adopted triples);
    /// `duplex_slowdown` is deliberately untouched.
    pub fn new(base: &DeviceProfile, scales: Corrections) -> CalibratedProfile {
        for s in [scales.htd, scales.k, scales.dth] {
            assert!(
                s.is_finite() && s > 0.0,
                "calibration scale must be finite and positive (got {s})"
            );
        }
        let effective = DeviceProfile {
            htd: base.htd.scaled(scales.htd),
            dth: base.dth.scaled(scales.dth),
            ..base.clone()
        };
        CalibratedProfile { scales, effective }
    }

    /// Identity calibration: the effective profile is bitwise equal to
    /// `base` (scaling by 1.0 is exact), so planning through an identity
    /// [`CalibratedProfile`] is bit-identical to planning on `base`.
    pub fn identity(base: &DeviceProfile) -> CalibratedProfile {
        CalibratedProfile::new(base, Corrections::identity())
    }

    /// The corrected [`DeviceProfile`] (link scales baked in): reset
    /// cursors and read engine rates from this.
    pub fn effective(&self) -> &DeviceProfile {
        &self.effective
    }

    /// Kernel time scale, applied at [`TaskTable`] compilation (kernel
    /// estimates are per task, not in the profile).
    ///
    /// [`TaskTable`]: crate::model::TaskTable
    pub fn kernel_scale(&self) -> f64 {
        self.scales.k
    }

    /// The correction triple this profile carries.
    pub fn scales(&self) -> Corrections {
        self.scales
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;

    fn secs(htd: f64, k: f64, dth: f64) -> EngineSecs {
        EngineSecs { htd, k, dth }
    }

    #[test]
    fn warmup_gates_then_converges() {
        let mut c = Calibrator::new(CalibrateOptions::default());
        assert!(c.corrections().is_identity());
        // measured = 1.8x predicted on every engine.
        for _ in 0..2 {
            c.observe_task(secs(1e-3, 2e-3, 0.5e-3), secs(1.8e-3, 3.6e-3, 0.9e-3));
            assert!(c.corrections().is_identity(), "warm-up must gate");
        }
        for _ in 0..10 {
            c.observe_task(secs(1e-3, 2e-3, 0.5e-3), secs(1.8e-3, 3.6e-3, 0.9e-3));
        }
        let f = c.corrections();
        for s in [f.htd, f.k, f.dth] {
            assert!((s - 1.8).abs() < 1e-9, "converged factor {s}");
        }
        assert_eq!(c.counts().n_clipped, 0);
        assert_eq!(c.counts().n_obs, 36);
    }

    #[test]
    fn outliers_clip_and_count() {
        let opts = CalibrateOptions { warmup: 1, ..CalibrateOptions::default() };
        let mut c = Calibrator::new(opts);
        c.observe_task(secs(1e-3, 0.0, 0.0), secs(1.0, 0.0, 0.0)); // 1000x
        assert_eq!(c.counts().n_clipped, 1);
        assert!(c.corrections().htd <= opts.clip);
        // Non-positive / non-finite / sub-floor samples are skipped.
        let before = c.counts().n_obs;
        c.observe_task(secs(1e-3, 1e-3, 1e-3), secs(-1.0, f64::NAN, 0.0));
        c.observe_task(secs(0.0, f64::INFINITY, 1e-12), secs(1e-3, 1e-3, 1e-3));
        assert_eq!(c.counts().n_obs, before);
    }

    #[test]
    fn observations_rebase_against_applied_scales() {
        // After adopting a 2x correction, predictions arrive in
        // corrected units; residuals must keep estimating the TOTAL
        // scale vs base, not compound toward 4x.
        let opts = CalibrateOptions {
            warmup: 1,
            adopt_margin: 0.0,
            ..CalibrateOptions::default()
        };
        let mut c = Calibrator::new(opts);
        for _ in 0..20 {
            c.observe_task(secs(1e-3, 1e-3, 1e-3), secs(2e-3, 2e-3, 2e-3));
        }
        let adopted = c.adopt().expect("2x shift must adopt");
        assert!((adopted.htd - 2.0).abs() < 1e-6);
        // Model now predicts 2e-3 (corrected units); device still 2e-3.
        for _ in 0..20 {
            c.observe_task(secs(2e-3, 2e-3, 2e-3), secs(2e-3, 2e-3, 2e-3));
        }
        let f = c.corrections();
        assert!((f.htd - 2.0).abs() < 1e-6, "stable at total scale: {f:?}");
        assert!(c.adopt().is_none(), "no further adoption when stable");
    }

    #[test]
    fn adopt_dead_band() {
        let opts =
            CalibrateOptions { warmup: 1, adopt_margin: 0.05, ..Default::default() };
        let mut c = Calibrator::new(opts);
        for _ in 0..20 {
            c.observe_task(secs(1e-3, 1e-3, 1e-3), secs(1.03e-3, 1e-3, 1e-3));
        }
        assert!(c.adopt().is_none(), "3% drift inside 5% dead-band");
        for _ in 0..20 {
            c.observe_task(secs(1e-3, 1e-3, 1e-3), secs(1.4e-3, 1e-3, 1e-3));
        }
        let a = c.adopt().expect("40% drift adopts");
        assert!(a.htd > 1.2);
        assert_eq!(c.applied(), a);
    }

    #[test]
    fn group_observation_folds_timeline_by_slot() {
        let mut c = Calibrator::new(CalibrateOptions {
            warmup: 1,
            ..CalibrateOptions::default()
        });
        let predicted = [secs(1e-3, 2e-3, 0.0), secs(0.0, 1e-3, 1e-3)];
        let rec = |task, kind, start: f64, end: f64| CmdRecord {
            task,
            kind,
            seq: 0,
            start,
            end,
        };
        let timeline = vec![
            // Slot 0: two HtD commands summing 1.5e-3, kernel 2e-3.
            rec(0, CmdKind::HtD, 0.0, 1e-3),
            rec(0, CmdKind::HtD, 1e-3, 1.5e-3),
            rec(0, CmdKind::Kernel, 1.5e-3, 3.5e-3),
            // Slot 1: kernel 1e-3, DtH 2e-3.
            rec(1, CmdKind::Kernel, 3.5e-3, 4.5e-3),
            rec(1, CmdKind::DtH, 4.5e-3, 6.5e-3),
            // Out-of-range slot is ignored, not a panic.
            rec(9, CmdKind::DtH, 0.0, 1.0),
        ];
        c.observe_group(&predicted, &timeline);
        let f = c.corrections();
        assert!((f.htd - 1.5).abs() < 1e-9, "{f:?}");
        assert!((f.k - 1.0).abs() < 1e-9, "kernel 2e-3/2e-3 then 1e-3/1e-3");
        assert!((f.dth - 2.0).abs() < 1e-9);
    }

    #[test]
    fn calibrated_profile_scales_links_and_keeps_invariants() {
        let base = profile_by_name("amd_r9").unwrap();
        let cal =
            CalibratedProfile::new(&base, Corrections { htd: 2.0, k: 1.5, dth: 1.0 });
        let e = cal.effective();
        assert_eq!(e.htd.bytes_per_sec, base.htd.bytes_per_sec / 2.0);
        assert_eq!(e.htd.latency, base.htd.latency * 2.0);
        // dth scale 1.0 is bitwise identity.
        assert_eq!(e.dth.bytes_per_sec.to_bits(), base.dth.bytes_per_sec.to_bits());
        assert_eq!(e.dth.latency.to_bits(), base.dth.latency.to_bits());
        assert_eq!(e.duplex_slowdown, base.duplex_slowdown, "sigma untouched");
        assert_eq!(cal.kernel_scale(), 1.5);
        // The effective profile still passes every from_json invariant.
        assert!(crate::config::DeviceProfile::from_json(&e.to_json()).is_ok());
        // Identity is bitwise equal to base everywhere.
        let id = CalibratedProfile::identity(&base);
        assert_eq!(id.effective().htd.bytes_per_sec.to_bits(), base.htd.bytes_per_sec.to_bits());
        assert_eq!(id.effective().htd.latency.to_bits(), base.htd.latency.to_bits());
        assert_eq!(id.kernel_scale(), 1.0);
    }

    #[test]
    #[should_panic(expected = "finite and positive")]
    fn degenerate_scale_rejected() {
        let base = profile_by_name("k20c").unwrap();
        let _ = CalibratedProfile::new(&base, Corrections { htd: 0.0, k: 1.0, dth: 1.0 });
    }

    #[test]
    fn aborted_group_timeline_yields_zero_observations() {
        // An aborted or faulted device run hands back an empty (or
        // truncated) timeline. The recovery layer never calls
        // observe_group for such runs, but even if a partial timeline
        // slipped through, slots with zero measured seconds must be
        // skipped by the degenerate-sample guard — the corrections stay
        // identity and n_obs stays 0.
        let mut c = Calibrator::new(CalibrateOptions::default());
        let predicted =
            vec![secs(1e-3, 2e-3, 0.5e-3), secs(1e-3, 2e-3, 0.5e-3)];
        // Empty timeline: the whole group aborted before any command ran.
        c.observe_group(&predicted, &[]);
        assert_eq!(c.counts().n_obs, 0, "empty timeline observed");
        assert!(c.corrections().is_identity());
        // Truncated timeline: only slot 0's HtD ever executed — exactly
        // one engine of one slot may observe, every other slot/engine is
        // guarded out.
        let partial = [CmdRecord {
            task: 0,
            kind: CmdKind::HtD,
            seq: 0,
            start: 0.0,
            end: 1.5e-3,
        }];
        c.observe_group(&predicted, &partial);
        assert_eq!(c.counts().n_obs, 1, "only the executed command counts");
        assert!(c.adopt().is_none(), "one sample can't mature past warm-up");
    }
}
