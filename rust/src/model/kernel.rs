//! Linear kernel-time model `T = eta * m + gamma` (paper Eq. 1, after Liu
//! et al. [13]): `eta` is the computing rate (seconds per unit data),
//! `gamma` the kernel invocation latency. Calibrated offline per kernel by
//! least squares over (size, time) observations — `oclcc profile` collects
//! them on the live PJRT device, mirroring the paper's offline profiling.

use crate::util::stats;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinearKernelModel {
    /// Seconds per unit of data size.
    pub eta: f64,
    /// Invocation latency (seconds).
    pub gamma: f64,
}

impl LinearKernelModel {
    pub fn new(eta: f64, gamma: f64) -> Self {
        LinearKernelModel { eta, gamma }
    }

    /// Least-squares fit over (size m, measured seconds) pairs.
    /// Negative intercepts are clamped to zero (a kernel cannot launch in
    /// negative time; noise on two close sizes can otherwise produce one).
    pub fn fit(sizes: &[f64], times: &[f64]) -> Self {
        let (eta, gamma) = stats::linfit(sizes, times);
        LinearKernelModel { eta, gamma: gamma.max(0.0) }
    }

    /// Predicted execution time for input size `m`.
    pub fn predict(&self, m: f64) -> f64 {
        self.eta * m + self.gamma
    }

    /// Mean relative error of the fit over a validation set.
    pub fn validation_error(&self, sizes: &[f64], times: &[f64]) -> f64 {
        assert_eq!(sizes.len(), times.len());
        let errs: Vec<f64> = sizes
            .iter()
            .zip(times)
            .map(|(&m, &t)| stats::rel_err(self.predict(m), t))
            .collect();
        stats::mean(&errs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn fit_recovers_eta_gamma() {
        let sizes: Vec<f64> = (1..20).map(|i| (i * 1024) as f64).collect();
        let times: Vec<f64> =
            sizes.iter().map(|m| 2e-9 * m + 30e-6).collect();
        let model = LinearKernelModel::fit(&sizes, &times);
        assert!((model.eta - 2e-9).abs() < 1e-13);
        assert!((model.gamma - 30e-6).abs() < 1e-9);
        assert!(model.validation_error(&sizes, &times) < 1e-9);
    }

    #[test]
    fn fit_with_noise_stays_close() {
        let mut rng = Pcg64::seeded(2);
        let sizes: Vec<f64> = (1..100).map(|i| (i * 4096) as f64).collect();
        let times: Vec<f64> = sizes
            .iter()
            .map(|m| (1e-9 * m + 50e-6) * rng.uniform(0.98, 1.02))
            .collect();
        let model = LinearKernelModel::fit(&sizes, &times);
        assert!(model.validation_error(&sizes, &times) < 0.03);
    }

    #[test]
    fn gamma_clamped_nonnegative() {
        // Two points implying a negative intercept.
        let model = LinearKernelModel::fit(&[10.0, 20.0], &[0.5e-3, 1.5e-3]);
        assert!(model.gamma >= 0.0);
    }
}
