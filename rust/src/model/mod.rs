//! The paper's §4 temporal execution model.
//!
//! * `transfer` — PCIe transfer-time models: LogGP solo times plus the
//!   three bidirectional-overlap predictors compared in Fig. 6
//!   (non-overlapped, fully-overlapped, and the paper's partially
//!   overlapped model).
//! * `kernel` — the linear kernel-time model `T = eta * m + gamma` (Eq. 1)
//!   with least-squares calibration.
//! * `simulator` — the event-driven simulator over three FIFO command
//!   queues (Figs. 4-5) that predicts the makespan of an ordered task
//!   group, with overlap re-estimation at every step. Exposed both as
//!   one-shot wrappers (`simulate` / `simulate_order`) and as the
//!   resumable [`SimCursor`] (push tasks incrementally, snapshot, resume)
//!   that the scheduler hot path builds on.
//! * `timeline` — per-command records, ASCII Gantt rendering and overlap
//!   metrics used by reports and tests.

pub mod kernel;
pub mod simulator;
pub mod timeline;
pub mod transfer;

pub use simulator::{
    simulate, simulate_order, EngineState, SimCursor, SimOptions, SimResult,
};
pub use timeline::{CmdKind, CmdRecord};
