//! The paper's §4 temporal execution model.
//!
//! * `transfer` — PCIe transfer-time models: LogGP solo times plus the
//!   three bidirectional-overlap predictors compared in Fig. 6
//!   (non-overlapped, fully-overlapped, and the paper's partially
//!   overlapped model).
//! * `kernel` — the linear kernel-time model `T = eta * m + gamma` (Eq. 1)
//!   with least-squares calibration.
//! * `simulator` — the event-driven simulator over three FIFO command
//!   queues (Figs. 4-5) that predicts the makespan of an ordered task
//!   group, with overlap re-estimation at every step. Exposed both as
//!   one-shot wrappers (`simulate` / `simulate_order`) and as the
//!   resumable [`SimCursor`] (push tasks incrementally, snapshot, resume)
//!   that the scheduler hot path builds on.
//! * `tasktable` — [`TaskTable`], a task group compiled against a device
//!   profile into structure-of-arrays form (flat command-size arenas,
//!   pre-resolved kernel durations, precomputed stage seconds and
//!   dominance) so the scheduler hot path pushes tasks from contiguous
//!   slices instead of walking `TaskSpec` structs.
//! * `calibrate` — online recalibration of the model: measured per-engine
//!   times from executed groups feed robust EWMA rate corrections
//!   ([`Calibrator`]) that materialize as a [`CalibratedProfile`] the
//!   lane coordinator recompiles its tables against.
//! * `timeline` — per-command records, ASCII Gantt rendering and overlap
//!   metrics used by reports and tests.

pub mod calibrate;
pub mod kernel;
pub mod simulator;
pub mod tasktable;
pub mod timeline;
pub mod transfer;

pub use calibrate::{
    fold_timeline_stage_secs, CalibCounts, CalibrateOptions, CalibratedProfile,
    Calibrator, Corrections, EngineSecs,
};
pub use simulator::{
    simulate, simulate_order, simulate_order_compiled, EngineState, SimCursor,
    SimOptions, SimResult,
};
pub use tasktable::TaskTable;
pub use timeline::{CmdKind, CmdRecord};
