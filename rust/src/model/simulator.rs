//! Event-driven simulator of a task group's concurrent execution
//! (paper §4.1, Figs. 4-5).
//!
//! Three FIFO software queues (HtD, K, DtH) mirror the OpenCL submission
//! schemes of §3.2:
//!
//! * **2 DMA engines** (grouped-by-task submission): the HtD and DtH
//!   queues are served by independent engines; while both directions are
//!   in flight each runs at `bw / sigma` — the partial-overlap transfer
//!   model — and rates are *re-estimated* at every completion event,
//!   exactly the Fig.-5 re-annotation of end times.
//! * **1 DMA engine** (grouped-by-type submission): one engine serves the
//!   HtD queue to exhaustion before the DtH queue (the paper's explicit
//!   red-arrow dependency), with in-order head-of-line blocking.
//!
//! Intra-task dependencies (K after its last HtD, DtH after K) are the
//! green arrows of Fig. 4. Kernel commands never overlap each other: the
//! model deliberately excludes CKE (§4.1).
//!
//! Transfers are fluid: a command is `latency` seconds of fixed overhead
//! followed by `bytes` drained at the current rate. The virtual device
//! (rust/src/device) implements the same semantics with real threads, so
//! prediction error measures model fidelity against a live asynchronous
//! system, as in the paper.

use crate::config::DeviceProfile;
use crate::model::timeline::{CmdKind, CmdRecord};
use crate::task::TaskSpec;

/// Initial completion times of the three queues — lets the heuristic and
/// multi-round coordinator simulate "appending to a device that is already
/// busy" (Algorithm 1's t_HTD / t_K / t_DTH state).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EngineState {
    pub htd_free: f64,
    pub k_free: f64,
    pub dth_free: f64,
}

/// Simulation knobs.
#[derive(Clone, Copy, Debug)]
pub struct SimOptions {
    /// Record per-command start/end times (skip for scheduling hot path).
    pub record_timeline: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { record_timeline: false }
    }
}

#[derive(Clone, Debug)]
pub struct SimResult {
    /// Total execution time of the group (first submission -> last DtH).
    pub makespan: f64,
    /// Completion time of each task (its last command), submission order.
    pub task_end: Vec<f64>,
    /// Engine availability after the group (for carry-over simulation).
    pub end_state: EngineState,
    /// Per-command records if requested.
    pub timeline: Vec<CmdRecord>,
}

/// A command in flight or waiting.
#[derive(Clone, Copy, Debug)]
struct Cmd {
    task: usize,
    kind: CmdKind,
    seq: usize,
    /// Remaining fixed-latency seconds.
    lat_left: f64,
    /// Remaining fluid work: bytes for transfers, seconds for kernels.
    work_left: f64,
    start: f64,
}

/// Predict the execution of `tasks` submitted in the given vector order on
/// `profile`, starting from `init` engine state.
pub fn simulate(
    tasks: &[TaskSpec],
    profile: &DeviceProfile,
    init: EngineState,
    opts: SimOptions,
) -> SimResult {
    let order: Vec<usize> = (0..tasks.len()).collect();
    simulate_order(tasks, &order, profile, init, opts)
}

/// Zero-copy variant: predict `tasks` submitted in `order` (a permutation
/// of indices into `tasks`). This is the scheduler's hot path — the
/// heuristic calls it O(w * T^2) times per reordering, so it must not
/// clone task specs (String names alone would dominate). Record/task_end
/// indices are *slots* (positions in `order`), matching `simulate`.
pub fn simulate_order(
    all_tasks: &[TaskSpec],
    order: &[usize],
    profile: &DeviceProfile,
    init: EngineState,
    opts: SimOptions,
) -> SimResult {
    struct IndexView<'a> {
        all: &'a [TaskSpec],
        order: &'a [usize],
    }
    impl<'a> IndexView<'a> {
        #[inline]
        fn get(&self, slot: usize) -> &TaskSpec {
            &self.all[self.order[slot]]
        }
    }
    let tasks = IndexView { all: all_tasks, order };
    let n = order.len();
    let mut result = SimResult {
        makespan: 0.0,
        task_end: vec![0.0; n],
        end_state: init,
        timeline: Vec::new(),
    };
    if n == 0 {
        return result;
    }

    // Flattened FIFO queues. Entries are (task, seq, bytes).
    let mut q_htd: Vec<(usize, usize, u64)> = Vec::new();
    let mut q_dth: Vec<(usize, usize, u64)> = Vec::new();
    for t in 0..n {
        let task = tasks.get(t);
        for (j, &b) in task.htd_bytes.iter().enumerate() {
            q_htd.push((t, j, b));
        }
        for (j, &b) in task.dth_bytes.iter().enumerate() {
            q_dth.push((t, j, b));
        }
    }
    // Queue cursors.
    let mut h_next = 0usize;
    let mut d_next = 0usize;
    let mut k_next = 0usize;

    // Dependency bookkeeping.
    let mut htd_pending: Vec<usize> =
        (0..n).map(|t| tasks.get(t).htd_bytes.len()).collect();
    let mut k_done: Vec<bool> = vec![false; n];
    let mut dth_pending: Vec<usize> =
        (0..n).map(|t| tasks.get(t).dth_bytes.len()).collect();
    let single_dma = profile.dma_engines < 2;
    let total_htd_cmds = q_htd.len();
    let mut htd_cmds_done = 0usize;

    // Active slots: at most one command per engine.
    let mut act_h: Option<Cmd> = None;
    let mut act_d: Option<Cmd> = None;
    let mut act_k: Option<Cmd> = None;

    let mut now = 0.0f64;
    let eps = 1e-12;

    loop {
        // ---- Activation phase: move ready queue heads into free engines.
        // HtD engine.
        if act_h.is_none() && h_next < q_htd.len() {
            let (t, j, b) = q_htd[h_next];
            let free_at = init.htd_free;
            // Single-DMA: the transfer engine is shared; it must not carry
            // an active DtH (act_d) either.
            let engine_ok = !single_dma || act_d.is_none();
            if engine_ok && now + eps >= free_at {
                act_h = Some(Cmd {
                    task: t,
                    kind: CmdKind::HtD,
                    seq: j,
                    lat_left: profile.htd.latency,
                    work_left: b as f64,
                    start: now.max(free_at),
                });
                h_next += 1;
            }
        }
        // DtH engine: head must satisfy (a) its kernel done, (b) on 1-DMA
        // devices all HtD commands done AND the shared engine free.
        if act_d.is_none() && d_next < q_dth.len() {
            let (t, j, b) = q_dth[d_next];
            let dep_ok = k_done[t]
                && (!single_dma
                    || (htd_cmds_done == total_htd_cmds && act_h.is_none()));
            if dep_ok && now + eps >= init.dth_free {
                act_d = Some(Cmd {
                    task: t,
                    kind: CmdKind::DtH,
                    seq: j,
                    lat_left: profile.dth.latency,
                    work_left: b as f64,
                    start: now.max(init.dth_free),
                });
                d_next += 1;
            }
        }
        // Compute engine: strictly serial, K_t after all its HtD commands.
        if act_k.is_none() && k_next < n {
            if htd_pending[k_next] == 0 && now + eps >= init.k_free {
                let dur = tasks.get(k_next).kernel.est_secs()
                    + profile.kernel_launch_overhead;
                act_k = Some(Cmd {
                    task: k_next,
                    kind: CmdKind::Kernel,
                    seq: 0,
                    lat_left: 0.0,
                    work_left: dur,
                    start: now.max(init.k_free),
                });
                k_next += 1;
            }
        }

        // ---- Termination: nothing active and nothing activatable.
        if act_h.is_none() && act_d.is_none() && act_k.is_none() {
            if h_next >= q_htd.len() && d_next >= q_dth.len() && k_next >= n {
                break;
            }
            // Engines blocked purely by init free-times: jump forward.
            // Only consider queue heads whose *dependencies* are already
            // satisfied — others can never unblock while nothing runs.
            let mut jump = f64::INFINITY;
            if h_next < q_htd.len() {
                jump = jump.min(init.htd_free);
            }
            if d_next < q_dth.len() {
                let (t, _, _) = q_dth[d_next];
                if k_done[t] && (!single_dma || htd_cmds_done == total_htd_cmds)
                {
                    jump = jump.min(init.dth_free);
                }
            }
            if k_next < n && htd_pending[k_next] == 0 {
                jump = jump.min(init.k_free);
            }
            assert!(
                jump.is_finite() && jump > now,
                "simulator deadlock at t={now}"
            );
            now = jump;
            continue;
        }

        // ---- Rate assignment (re-estimated every event, Fig. 5).
        let both_transfers = act_h.is_some() && act_d.is_some();
        let rate_h = profile.rate(true, both_transfers);
        let rate_d = profile.rate(false, both_transfers);

        // ---- Earliest completion among active commands.
        let eta = |c: &Cmd, rate: f64| c.lat_left + c.work_left / rate;
        let mut dt = f64::INFINITY;
        if let Some(c) = &act_h {
            dt = dt.min(eta(c, rate_h));
        }
        if let Some(c) = &act_d {
            dt = dt.min(eta(c, rate_d));
        }
        if let Some(c) = &act_k {
            dt = dt.min(eta(c, 1.0));
        }
        debug_assert!(dt.is_finite() && dt >= 0.0);
        now += dt;

        // ---- Advance in-flight work and collect completions.
        let complete = |c: &mut Option<Cmd>, rate: f64| -> Option<Cmd> {
            if let Some(cmd) = c.as_mut() {
                let lat_used = dt.min(cmd.lat_left);
                cmd.lat_left -= lat_used;
                cmd.work_left -= (dt - lat_used).max(0.0) * rate;
                if cmd.lat_left <= eps && cmd.work_left <= rate.max(1.0) * eps {
                    let done = *cmd;
                    *c = None;
                    return Some(done);
                }
            }
            None
        };
        let done_h = complete(&mut act_h, rate_h);
        let done_d = complete(&mut act_d, rate_d);
        let done_k = complete(&mut act_k, 1.0);

        for done in [done_h, done_d, done_k].into_iter().flatten() {
            match done.kind {
                CmdKind::HtD => {
                    htd_pending[done.task] -= 1;
                    htd_cmds_done += 1;
                    result.end_state.htd_free = now;
                }
                CmdKind::Kernel => {
                    k_done[done.task] = true;
                    result.end_state.k_free = now;
                    if tasks.get(done.task).dth_bytes.is_empty() {
                        result.task_end[done.task] = now;
                    }
                }
                CmdKind::DtH => {
                    dth_pending[done.task] -= 1;
                    result.end_state.dth_free = now;
                    if dth_pending[done.task] == 0 {
                        result.task_end[done.task] = now;
                    }
                }
            }
            if opts.record_timeline {
                result.timeline.push(CmdRecord {
                    task: done.task,
                    kind: done.kind,
                    seq: done.seq,
                    start: done.start,
                    end: now,
                });
            }
        }
    }

    result.makespan = now;
    result
}

/// Convenience: makespan of an order over a task group.
pub fn makespan_of_order(
    tasks: &[TaskSpec],
    order: &[usize],
    profile: &DeviceProfile,
) -> f64 {
    simulate_order(tasks, order, profile, EngineState::default(), SimOptions::default())
        .makespan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;
    use crate::task::synthetic::{synthetic_benchmark, synthetic_task};
    use crate::task::{KernelSpec, TaskSpec};

    fn timed(name: &str, htd: u64, k: f64, dth: u64) -> TaskSpec {
        TaskSpec::simple(name, htd, KernelSpec::Timed { secs: k }, dth)
    }

    fn opts() -> SimOptions {
        SimOptions { record_timeline: true }
    }

    #[test]
    fn single_task_is_sequential() {
        let p = profile_by_name("amd_r9").unwrap();
        let t = synthetic_task(0, &p, 1.0);
        let r = simulate(&[t.clone()], &p, EngineState::default(), opts());
        let want = t.sequential_secs(&p);
        assert!(
            (r.makespan - want).abs() < 1e-9,
            "{} vs {want}",
            r.makespan
        );
        assert_eq!(r.timeline.len(), 3);
    }

    #[test]
    fn pipeline_overlaps_on_two_dma() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK100", &p, 1.0).unwrap();
        let r = simulate(&g.tasks, &p, EngineState::default(), opts());
        let serial: f64 =
            g.tasks.iter().map(|t| t.sequential_secs(&p)).sum();
        // Dominant-kernel tasks pipeline almost perfectly: makespan must be
        // well below the serial floor but above the kernel-sum lower bound.
        let k_sum: f64 =
            g.tasks.iter().map(|t| t.stage_secs(&p).k).sum();
        assert!(r.makespan < 0.85 * serial, "{} vs {serial}", r.makespan);
        assert!(r.makespan >= k_sum - 1e-9);
    }

    #[test]
    fn kernels_never_overlap() {
        let p = profile_by_name("k20c").unwrap();
        let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
        let r = simulate(&g.tasks, &p, EngineState::default(), opts());
        let mut kernels: Vec<&CmdRecord> = r
            .timeline
            .iter()
            .filter(|c| c.kind == CmdKind::Kernel)
            .collect();
        kernels.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
        for w in kernels.windows(2) {
            assert!(
                w[1].start >= w[0].end - 1e-9,
                "CKE in model: {:?} then {:?}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn intra_task_dependencies_hold() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK25", &p, 1.0).unwrap();
        let r = simulate(&g.tasks, &p, EngineState::default(), opts());
        for t in 0..g.len() {
            let h_end = r
                .timeline
                .iter()
                .filter(|c| c.task == t && c.kind == CmdKind::HtD)
                .map(|c| c.end)
                .fold(0.0, f64::max);
            let k = r
                .timeline
                .iter()
                .find(|c| c.task == t && c.kind == CmdKind::Kernel)
                .unwrap();
            let d_start = r
                .timeline
                .iter()
                .filter(|c| c.task == t && c.kind == CmdKind::DtH)
                .map(|c| c.start)
                .fold(f64::INFINITY, f64::min);
            assert!(k.start >= h_end - 1e-9, "task {t}: K before HtD done");
            assert!(d_start >= k.end - 1e-9, "task {t}: DtH before K done");
        }
    }

    #[test]
    fn one_dma_serializes_all_transfers() {
        let p = profile_by_name("xeon_phi").unwrap();
        let g = synthetic_benchmark("BK0", &p, 1.0).unwrap();
        let r = simulate(&g.tasks, &p, EngineState::default(), opts());
        let mut xfers: Vec<&CmdRecord> = r
            .timeline
            .iter()
            .filter(|c| c.kind != CmdKind::Kernel)
            .collect();
        xfers.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
        for w in xfers.windows(2) {
            assert!(
                w[1].start >= w[0].end - 1e-9,
                "transfers overlap on 1-DMA device: {:?} / {:?}",
                w[0],
                w[1]
            );
        }
        // And all HtD precede all DtH (grouped-by-type submission).
        let last_htd = r
            .timeline
            .iter()
            .filter(|c| c.kind == CmdKind::HtD)
            .map(|c| c.end)
            .fold(0.0, f64::max);
        let first_dth = r
            .timeline
            .iter()
            .filter(|c| c.kind == CmdKind::DtH)
            .map(|c| c.start)
            .fold(f64::INFINITY, f64::min);
        assert!(first_dth >= last_htd - 1e-9);
    }

    #[test]
    fn duplex_contention_stretches_transfers() {
        let p = profile_by_name("amd_r9").unwrap();
        // Task 0: long HtD; task 1's DtH will overlap task 0's... build a
        // pair where overlap is forced: t0 tiny kernel + big DtH, t1 big HtD.
        let t0 = timed("t0", 1_000, 0.1e-3, 40_000_000);
        let t1 = timed("t1", 40_000_000, 0.1e-3, 1_000);
        let r = simulate(
            &[t0.clone(), t1.clone()],
            &p,
            EngineState::default(),
            opts(),
        );
        // DtH of t0 and HtD of t1 overlap -> both stretched vs solo.
        let dth0 = r
            .timeline
            .iter()
            .find(|c| c.task == 0 && c.kind == CmdKind::DtH)
            .unwrap();
        assert!(dth0.dur() > p.dth.transfer_secs(40_000_000) + 0.2e-3);
    }

    #[test]
    fn order_changes_makespan() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK25", &p, 1.0).unwrap();
        let forward = makespan_of_order(&g.tasks, &[0, 1, 2, 3], &p);
        let mut best = f64::INFINITY;
        let mut worst: f64 = 0.0;
        let perms = crate::sched::bruteforce::permutations(4);
        for perm in &perms {
            let m = makespan_of_order(&g.tasks, perm, &p);
            best = best.min(m);
            worst = worst.max(m);
        }
        assert!(worst > best * 1.02, "ordering should matter: {best}..{worst}");
        assert!(forward >= best - 1e-12 && forward <= worst + 1e-12);
    }

    #[test]
    fn engine_state_carryover_delays_start() {
        let p = profile_by_name("amd_r9").unwrap();
        let t = synthetic_task(0, &p, 1.0);
        let delayed = simulate(
            &[t.clone()],
            &p,
            EngineState { htd_free: 5e-3, k_free: 0.0, dth_free: 0.0 },
            opts(),
        );
        let fresh =
            simulate(&[t], &p, EngineState::default(), opts());
        assert!(
            (delayed.makespan - (fresh.makespan + 5e-3)).abs() < 1e-9,
            "{} vs {}",
            delayed.makespan,
            fresh.makespan
        );
    }

    #[test]
    fn null_transfer_stages() {
        let p = profile_by_name("k20c").unwrap();
        let t = timed("konly", 0, 2e-3, 0);
        let r = simulate(&[t], &p, EngineState::default(), opts());
        assert_eq!(r.timeline.len(), 1);
        assert!((r.makespan - (2e-3 + p.kernel_launch_overhead)).abs() < 1e-9);
    }

    #[test]
    fn empty_group() {
        let p = profile_by_name("amd_r9").unwrap();
        let r = simulate(&[], &p, EngineState::default(), opts());
        assert_eq!(r.makespan, 0.0);
    }
}
