//! Event-driven simulator of a task group's concurrent execution
//! (paper §4.1, Figs. 4-5) — as an explicit, *resumable* engine.
//!
//! Three FIFO software queues (HtD, K, DtH) mirror the OpenCL submission
//! schemes of §3.2:
//!
//! * **2 DMA engines** (grouped-by-task submission): the HtD and DtH
//!   queues are served by independent engines; while both directions are
//!   in flight each runs at `bw / sigma` — the partial-overlap transfer
//!   model — and rates are *re-estimated* at every completion event,
//!   exactly the Fig.-5 re-annotation of end times.
//! * **1 DMA engine** (grouped-by-type submission): one engine serves the
//!   HtD queue to exhaustion before the DtH queue (the paper's explicit
//!   red-arrow dependency), with in-order head-of-line blocking.
//!
//! Intra-task dependencies (K after its last HtD, DtH after K) are the
//! green arrows of Fig. 4. Kernel commands never overlap each other: the
//! model deliberately excludes CKE (§4.1).
//!
//! # Resumable simulation ([`SimCursor`])
//!
//! The scheduler's hot path is no longer "replay the whole prefix from
//! scratch per candidate". A [`SimCursor`] owns the three queues, the
//! dependency counters, the three active-engine slots and the clock;
//! [`SimCursor::push_task`] appends a task and advances the simulation up
//! to the *committed frontier* — the instant the HtD engine would go idle,
//! which is exactly where a later-pushed task's first HtD command would
//! start and perturb downstream transfer rates. Everything before the
//! frontier is invariant under future pushes, so a paused cursor can be
//! snapshotted ([`SimCursor::resume_from`] is an allocation-free
//! `clone_from`) and each candidate extension scored by resuming instead
//! of replaying: the beam search in `sched/heuristic.rs` pays for each
//! prefix **once**, turning its former O(w·T³·C) total event work into
//! amortized O(w·T²·C), with zero heap allocations per candidate after
//! warm-up (cursor buffers are reused, never reallocated at steady state).
//!
//! # Committed/uncommitted split ([`SimCursor::commit_frontier`])
//!
//! The online rescheduler needs to *retract* planned-but-not-yet-submitted
//! tasks while keeping the prefix that was already handed to the device.
//! [`SimCursor::commit_frontier`] pins every task pushed so far as
//! **committed** (an internal paused snapshot, lazily allocated once and
//! reused, so warm commit/replan cycles stay allocation-free);
//! [`SimCursor::replan_suffix`] restores that snapshot bit-for-bit,
//! undoing every later push *and any `run_to_quiescence`* — so a planner
//! can score its current uncommitted suffix by pushing it, finishing, and
//! retracting, then try a different suffix order against the same
//! committed prefix. Back-to-back task groups pushed through one cursor
//! (committing between rounds, never restarting from an idle device) are
//! simulated as one contiguous timeline, bit-identical to a single
//! concatenated from-scratch run — see rust/tests/prop_online.rs.
//!
//! # Bounded probes ([`SimCursor::run_to_quiescence_bounded`])
//!
//! The schedulers' branch-and-bound layer scores candidate rollouts with
//! a *cutoff*: the simulated clock is monotone and never exceeds the
//! final makespan, so the instant it strictly passes the cutoff the
//! rollout is proven strictly worse than an already-admitted score and
//! the event loop aborts — admissibly, leaving the cursor resumable
//! bit-for-bit. [`SimCursor::lower_bound`] complements it with an O(1)
//! incrementally-maintained makespan envelope (max of per-engine
//! busy-work sums from their initial free times and the committed clock)
//! that the schedulers consult before paying for any simulation at all.
//!
//! `simulate` / `simulate_order` / `makespan_of_order` remain as thin
//! wrappers that drive a fresh cursor, and
//! [`simulate_order_fromscratch`] preserves the pre-refactor single-shot
//! loop as an independently-coded reference: the equivalence property
//! tests (rust/tests/prop_incremental.rs) pin the cursor to it at 1e-12,
//! and the `table6_overhead` bench uses it as the speedup baseline.
//!
//! Transfers are fluid: a command is `latency` seconds of fixed overhead
//! followed by `bytes` drained at the current rate. The virtual device
//! (rust/src/device) implements the same semantics with real threads, so
//! prediction error measures model fidelity against a live asynchronous
//! system, as in the paper.

use crate::config::DeviceProfile;
use crate::model::tasktable::TaskTable;
use crate::model::timeline::{CmdKind, CmdRecord};
use crate::task::TaskSpec;

/// Initial completion times of the three queues — lets the heuristic and
/// multi-round coordinator simulate "appending to a device that is already
/// busy" (Algorithm 1's t_HTD / t_K / t_DTH state).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EngineState {
    pub htd_free: f64,
    pub k_free: f64,
    pub dth_free: f64,
}

/// Simulation knobs.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimOptions {
    /// Record per-command start/end times (skip for scheduling hot path).
    pub record_timeline: bool,
}

#[derive(Clone, Debug)]
pub struct SimResult {
    /// Total execution time of the group (first submission -> last DtH).
    pub makespan: f64,
    /// Completion time of each task (its last command), submission order.
    pub task_end: Vec<f64>,
    /// Engine availability after the group (for carry-over simulation).
    pub end_state: EngineState,
    /// Per-command records if requested.
    pub timeline: Vec<CmdRecord>,
}

/// A command in flight or waiting.
#[derive(Clone, Copy, Debug)]
struct Cmd {
    task: usize,
    kind: CmdKind,
    seq: usize,
    /// Remaining fixed-latency seconds.
    lat_left: f64,
    /// Remaining fluid work: bytes for transfers, seconds for kernels.
    work_left: f64,
    start: f64,
}

const EPS: f64 = 1e-12;

/// Device constants the event loop consumes, copied out of a
/// [`DeviceProfile`] so a cursor is plain `Copy` data plus buffers (no
/// lifetimes, cheap `clone_from`). `PartialEq` backs the debug assertion
/// that a [`TaskTable`] is only pushed into cursors compiled for the same
/// device.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub(crate) struct ProfileParams {
    single_dma: bool,
    htd_latency: f64,
    dth_latency: f64,
    htd_bps: f64,
    dth_bps: f64,
    duplex_slowdown: f64,
    kernel_launch_overhead: f64,
}

impl ProfileParams {
    pub(crate) fn of(p: &DeviceProfile) -> Self {
        // The admissible busy-sum envelope (`SimCursor::lower_bound`)
        // relies on solo rates being the fastest the model grants, i.e.
        // sigma >= 1 — enforced at every profile ingress (builtins,
        // `DeviceProfile::from_json`, loggp calibration clamp).
        debug_assert!(
            p.dma_engines < 2 || p.duplex_slowdown >= 1.0,
            "duplex_slowdown < 1.0 breaks lower-bound admissibility"
        );
        ProfileParams {
            single_dma: p.dma_engines < 2,
            htd_latency: p.htd.latency,
            dth_latency: p.dth.latency,
            htd_bps: p.htd.bytes_per_sec,
            dth_bps: p.dth.bytes_per_sec,
            duplex_slowdown: p.duplex_slowdown,
            kernel_launch_overhead: p.kernel_launch_overhead,
        }
    }

    /// Effective transfer rate (bytes/s), same semantics as
    /// `DeviceProfile::rate`.
    #[inline]
    fn rate(&self, htd: bool, opposite_active: bool) -> f64 {
        let base = if htd { self.htd_bps } else { self.dth_bps };
        if opposite_active && !self.single_dma {
            base / self.duplex_slowdown
        } else {
            base
        }
    }
}

/// Resumable incremental simulation state: queues, cursors, dependency
/// counters, three active-command slots and the clock. See the module
/// docs for the committed-frontier invariant that makes pause/resume
/// bit-identical to a from-scratch run.
#[derive(Debug, Default)]
pub struct SimCursor {
    prof: ProfileParams,
    init: EngineState,
    record: bool,
    /// Flattened FIFO queues; entries are (slot, seq, bytes). Slots are
    /// positions in push order, matching `simulate_order`'s indexing.
    q_htd: Vec<(usize, usize, u64)>,
    q_dth: Vec<(usize, usize, u64)>,
    h_next: usize,
    d_next: usize,
    k_next: usize,
    /// Per-slot dependency bookkeeping.
    htd_pending: Vec<u32>,
    k_done: Vec<bool>,
    dth_pending: Vec<u32>,
    /// Kernel duration per slot (est_secs + launch overhead), captured at
    /// push time so the cursor never re-touches the TaskSpec.
    kernel_secs: Vec<f64>,
    htd_cmds_done: usize,
    /// Active slots: at most one command per engine.
    act_h: Option<Cmd>,
    act_d: Option<Cmd>,
    act_k: Option<Cmd>,
    now: f64,
    end_state: EngineState,
    task_end: Vec<f64>,
    timeline: Vec<CmdRecord>,
    finished: bool,
    /// A bounded finishing drain ([`SimCursor::run_to_quiescence_bounded`])
    /// was aborted mid-run: the cursor may be *finished* again (the event
    /// loop continues bit-exactly) but must not accept pushes — on 1-DMA
    /// devices a finishing drain may already have released DtH commands
    /// that a longer order would have held back.
    mid_finish: bool,
    /// Per-engine busy-work sums (solo-rate seconds) over every task
    /// pushed so far, maintained incrementally by the push paths and
    /// backing [`SimCursor::lower_bound`]. Pure bound metadata: never read
    /// by the event loop, so it cannot perturb simulation results.
    busy_htd: f64,
    busy_k: f64,
    busy_dth: f64,
    /// Paused snapshot at the committed frontier (see
    /// [`SimCursor::commit_frontier`]). Lazily boxed once and retained
    /// across resets/retractions so warm commit/replan cycles perform no
    /// heap allocation. Never nests: a snapshot's own commit fields are
    /// always empty.
    commit_snap: Option<Box<SimCursor>>,
    /// Whether `commit_snap` currently holds a live committed frontier
    /// (the box itself is kept allocated even when invalid).
    commit_valid: bool,
}

impl SimCursor {
    /// Fresh cursor over `profile` starting from `init` engine state.
    pub fn new(profile: &DeviceProfile, init: EngineState) -> SimCursor {
        Self::with_options(profile, init, SimOptions::default())
    }

    pub fn with_options(
        profile: &DeviceProfile,
        init: EngineState,
        opts: SimOptions,
    ) -> SimCursor {
        SimCursor {
            prof: ProfileParams::of(profile),
            init,
            record: opts.record_timeline,
            end_state: init,
            ..SimCursor::default()
        }
    }

    /// Placeholder cursor for scratch arenas: carries zeroed device
    /// parameters and must be [`SimCursor::reset`] (or `resume_from`) to a
    /// real profile before use.
    pub fn detached() -> SimCursor {
        SimCursor::default()
    }

    /// Rewind to an empty simulation, keeping every buffer's capacity (so
    /// this is NOT `*self = default()` — the Vec clears below deliberately
    /// retain their allocations for the scheduler hot path).
    pub fn reset(&mut self, profile: &DeviceProfile, init: EngineState) {
        self.reset_params(ProfileParams::of(profile), init);
    }

    /// [`SimCursor::reset`] against the device constants a [`TaskTable`]
    /// was compiled with. This is the adoption-safe rewind for calibrated
    /// planning (`model::calibrate`): resetting from the table itself
    /// makes it impossible to pair a cursor from one model generation
    /// with a table from another — the pair the
    /// [`SimCursor::push_task_compiled`] params assertion guards.
    pub fn reset_for_table(&mut self, table: &TaskTable, init: EngineState) {
        self.reset_params(table.params(), init);
    }

    /// Toggle per-command timeline recording on an existing cursor
    /// (construction-time `SimOptions::record_timeline` for pooled
    /// cursors that are `reset` rather than rebuilt — e.g. the lanes'
    /// calibration replay, which needs the model's predicted
    /// per-command durations). Takes effect from the next push; the
    /// recorded timeline is cleared by every reset.
    pub fn set_record_timeline(&mut self, on: bool) {
        self.record = on;
    }

    /// [`SimCursor::reset`] with pre-extracted device constants — lets a
    /// [`TaskTable`] holder rewind a cursor without re-touching the
    /// `DeviceProfile`.
    pub(crate) fn reset_params(&mut self, prof: ProfileParams, init: EngineState) {
        self.prof = prof;
        self.init = init;
        self.q_htd.clear();
        self.q_dth.clear();
        self.h_next = 0;
        self.d_next = 0;
        self.k_next = 0;
        self.htd_pending.clear();
        self.k_done.clear();
        self.dth_pending.clear();
        self.kernel_secs.clear();
        self.htd_cmds_done = 0;
        self.act_h = None;
        self.act_d = None;
        self.act_k = None;
        self.now = 0.0;
        self.end_state = init;
        self.task_end.clear();
        self.timeline.clear();
        self.finished = false;
        self.mid_finish = false;
        self.busy_htd = 0.0;
        self.busy_k = 0.0;
        self.busy_dth = 0.0;
        // Keep the snapshot box (its buffers are warm) but invalidate it.
        self.commit_valid = false;
    }

    /// Number of tasks pushed so far.
    pub fn n_tasks(&self) -> usize {
        self.task_end.len()
    }

    /// Current simulation clock (the makespan once finished).
    pub fn clock(&self) -> f64 {
        self.now
    }

    pub fn is_finished(&self) -> bool {
        self.finished
    }

    /// Engine availability after the events processed so far.
    pub fn end_state(&self) -> EngineState {
        self.end_state
    }

    /// Per-slot completion times (valid for slots whose last command has
    /// completed; 0.0 otherwise).
    pub fn task_end(&self) -> &[f64] {
        &self.task_end
    }

    /// Recorded per-command timeline (empty unless constructed with
    /// `record_timeline`).
    pub fn timeline(&self) -> &[CmdRecord] {
        &self.timeline
    }

    /// Append one task and advance the committed frontier. Panics (debug)
    /// after `run_to_quiescence`: pushing into a drained simulation would
    /// diverge from the equivalent from-scratch run (on 1-DMA devices the
    /// drained run already released DtH commands that a longer order would
    /// have held back).
    pub fn push_task(&mut self, task: &TaskSpec) {
        debug_assert!(
            !self.finished,
            "SimCursor::push_task after run_to_quiescence; snapshot before \
             finishing instead"
        );
        debug_assert!(
            !self.mid_finish,
            "SimCursor::push_task after an aborted bounded finish; \
             resume_from/reset the cursor first"
        );
        let slot = self.task_end.len();
        for (j, &b) in task.htd_bytes.iter().enumerate() {
            self.q_htd.push((slot, j, b));
            self.busy_htd += self.prof.htd_latency + b as f64 / self.prof.htd_bps;
        }
        for (j, &b) in task.dth_bytes.iter().enumerate() {
            self.q_dth.push((slot, j, b));
            self.busy_dth += self.prof.dth_latency + b as f64 / self.prof.dth_bps;
        }
        self.htd_pending.push(task.htd_bytes.len() as u32);
        self.dth_pending.push(task.dth_bytes.len() as u32);
        self.k_done.push(false);
        let k = task.kernel.est_secs() + self.prof.kernel_launch_overhead;
        self.kernel_secs.push(k);
        self.busy_k += k;
        self.task_end.push(0.0);
        self.drain(false);
    }

    /// [`SimCursor::push_task`] from a compiled [`TaskTable`] row: the
    /// same state transitions fed from two contiguous slices and one
    /// pre-resolved kernel duration instead of a `TaskSpec` walk. This is
    /// the scheduler hot path's push; it is bit-identical to
    /// `push_task(&tasks[i])` because the table stores the exact values
    /// `push_task` computes (see `model/tasktable.rs`).
    pub fn push_task_compiled(&mut self, table: &TaskTable, i: usize) {
        debug_assert!(
            !self.finished,
            "SimCursor::push_task_compiled after run_to_quiescence; snapshot \
             before finishing instead"
        );
        debug_assert!(
            !self.mid_finish,
            "SimCursor::push_task_compiled after an aborted bounded finish; \
             resume_from/reset the cursor first"
        );
        debug_assert!(
            table.params() == self.prof,
            "TaskTable compiled for a different device profile"
        );
        let slot = self.task_end.len();
        let htd = table.htd_bytes(i);
        let dth = table.dth_bytes(i);
        for (j, &b) in htd.iter().enumerate() {
            self.q_htd.push((slot, j, b));
        }
        for (j, &b) in dth.iter().enumerate() {
            self.q_dth.push((slot, j, b));
        }
        self.htd_pending.push(htd.len() as u32);
        self.dth_pending.push(dth.len() as u32);
        self.k_done.push(false);
        self.kernel_secs.push(table.kernel_secs(i));
        // Same solo-rate arithmetic the table precomputed per row.
        self.busy_htd += table.htd_secs(i);
        self.busy_dth += table.dth_secs(i);
        self.busy_k += table.kernel_secs(i);
        self.task_end.push(0.0);
        self.drain(false);
    }

    /// Append a canonical encoding of the cursor's *dynamic* simulation
    /// state to `out` (clock, active commands, queue contents, dependency
    /// counters — everything that determines how any future push sequence
    /// evolves; `task_end`/timeline/record flags are outputs, not state).
    /// Two cursors with equal encodings produce identical makespans for
    /// identical future push sequences — the exactness invariant behind
    /// the prefix transposition memo in `sched::parallel`.
    pub(crate) fn write_state_sig(&self, out: &mut Vec<u64>) {
        out.push(self.now.to_bits());
        out.push(self.init.htd_free.to_bits());
        out.push(self.init.k_free.to_bits());
        out.push(self.init.dth_free.to_bits());
        out.push(self.h_next as u64);
        out.push(self.d_next as u64);
        out.push(self.k_next as u64);
        out.push(self.htd_cmds_done as u64);
        for act in [&self.act_h, &self.act_d, &self.act_k] {
            match act {
                Some(c) => {
                    out.push(1 | ((c.task as u64) << 1));
                    out.push(((c.kind as u64) << 32) | c.seq as u64);
                    out.push(c.lat_left.to_bits());
                    out.push(c.work_left.to_bits());
                }
                None => out.extend_from_slice(&[0, 0, 0, 0]),
            }
        }
        out.push(self.q_htd.len() as u64);
        for &(t, j, b) in &self.q_htd {
            out.push(((t as u64) << 32) | j as u64);
            out.push(b);
        }
        out.push(self.q_dth.len() as u64);
        for &(t, j, b) in &self.q_dth {
            out.push(((t as u64) << 32) | j as u64);
            out.push(b);
        }
        out.push(self.kernel_secs.len() as u64);
        for &k in &self.kernel_secs {
            out.push(k.to_bits());
        }
        for (i, &p) in self.htd_pending.iter().enumerate() {
            out.push(((p as u64) << 33)
                | ((self.dth_pending[i] as u64) << 1)
                | self.k_done[i] as u64);
        }
    }

    /// Run every remaining event; returns the makespan. The cursor stays
    /// readable (task_end / end_state / timeline) but accepts no further
    /// pushes.
    pub fn run_to_quiescence(&mut self) -> f64 {
        self.drain(true);
        self.finished = true;
        self.now
    }

    /// Bounded probe finish: run the remaining events only while the
    /// simulated clock stays at or below `cutoff`, aborting the instant it
    /// strictly exceeds it. The clock is monotone and the final makespan
    /// is at least the clock at every event, so `None` proves the finished
    /// makespan would strictly exceed `cutoff` — an *admissible* early
    /// exit for branch-and-bound candidate scoring (the schedulers prune
    /// only candidates this proves strictly worse than an already-admitted
    /// score, so returned orders are bit-identical to unbounded search).
    ///
    /// `Some(makespan)` is bit-identical to [`SimCursor::run_to_quiescence`]
    /// (a `cutoff` of `f64::INFINITY` never aborts). An aborted cursor is
    /// left mid-drain in a consistent state: calling this again (with a
    /// larger cutoff) continues the event loop bit-exactly, but pushing
    /// further tasks is forbidden (debug-asserted) — the finishing drain
    /// may already have released DtH commands a longer order would have
    /// held back. NaN cutoffs never abort (a degenerate profile must not
    /// turn the bound into a wrong-answer path).
    pub fn run_to_quiescence_bounded(&mut self, cutoff: f64) -> Option<f64> {
        if self.drain_bounded(true, cutoff) {
            self.finished = true;
            Some(self.now)
        } else {
            self.mid_finish = true;
            None
        }
    }

    /// Admissible lower bound on the final makespan of everything pushed
    /// so far: the maximum of the current clock and the per-engine
    /// envelopes `engine_free_at + total solo-rate busy work` (commands
    /// run serially per engine, can never start before the engine's
    /// initial free time, and solo rates are the fastest the model ever
    /// grants — duplex contention only slows transfers down). On 1-DMA
    /// devices the shared transfer engine additionally serializes both
    /// directions. Maintained incrementally by the push paths (O(1) per
    /// command), monotone under further pushes and event processing.
    ///
    /// The bound is *mathematically* admissible; accumulated float
    /// rounding may differ from the event loop's by ULPs (and the loop's
    /// EPS tolerances are absolute), so callers comparing it against
    /// exact scores must keep the relative + absolute safety margins of
    /// `sched::search_util::provably_worse`.
    pub fn lower_bound(&self) -> f64 {
        self.lower_bound_with_remaining(0.0, 0.0, 0.0)
    }

    /// [`SimCursor::lower_bound`] extended by *remaining* (not yet
    /// pushed) per-engine solo-rate work: a lower bound on the final
    /// makespan of any completion that will eventually push tasks
    /// totalling `rem_htd`/`rem_k`/`rem_dth` engine seconds on top of
    /// what this cursor already carries. The schedulers feed it the
    /// suffix-aggregate sums compiled per group (whole-group totals at
    /// the seed stage, mask scans per surviving prefix), giving each
    /// candidate an O(1) admissible floor before any simulation.
    pub fn lower_bound_with_remaining(
        &self,
        rem_htd: f64,
        rem_k: f64,
        rem_dth: f64,
    ) -> f64 {
        let htd = self.busy_htd + rem_htd;
        let dth = self.busy_dth + rem_dth;
        let mut lb = self.now;
        lb = lb.max(self.init.k_free + self.busy_k + rem_k);
        lb = lb.max(self.init.htd_free + htd);
        lb = lb.max(self.init.dth_free + dth);
        if self.prof.single_dma {
            let start = self.init.htd_free.min(self.init.dth_free);
            lb = lb.max(start + htd + dth);
        }
        lb
    }

    /// Pin every task pushed so far as **committed** — already submitted
    /// to the device and immovable. Later pushes form the *uncommitted
    /// suffix*, which [`SimCursor::replan_suffix`] can retract wholesale
    /// so the scheduler may reorder the not-yet-submitted tail against
    /// the same [`TaskTable`]. The snapshot is stored internally (lazily
    /// boxed once, reused forever after), so warm commit/replan cycles
    /// are allocation-free. Returns the committed task count.
    pub fn commit_frontier(&mut self) -> usize {
        debug_assert!(
            !self.finished,
            "SimCursor::commit_frontier after run_to_quiescence; \
             replan_suffix back to the previous frontier first"
        );
        let mut snap = self.commit_snap.take().unwrap_or_default();
        snap.clone_core_from(self);
        snap.commit_valid = false; // snapshots never nest
        let n = snap.task_end.len();
        self.commit_snap = Some(snap);
        self.commit_valid = true;
        n
    }

    /// Retract every push — and any [`SimCursor::run_to_quiescence`] —
    /// since the last [`SimCursor::commit_frontier`], restoring the
    /// paused committed-frontier state bit-for-bit (the cursor becomes
    /// pushable again even if it was finished). Returns the number of
    /// uncommitted tasks retracted.
    pub fn replan_suffix(&mut self) -> usize {
        assert!(
            self.commit_valid,
            "SimCursor::replan_suffix without a prior commit_frontier"
        );
        let snap = self.commit_snap.take().expect("valid commit implies snapshot");
        let retracted = self.task_end.len() - snap.task_end.len();
        self.clone_core_from(&snap);
        self.commit_snap = Some(snap);
        retracted
    }

    /// Number of committed tasks (0 until the first
    /// [`SimCursor::commit_frontier`]).
    pub fn committed_len(&self) -> usize {
        if self.commit_valid {
            self.commit_snap.as_ref().map_or(0, |s| s.task_end.len())
        } else {
            0
        }
    }

    /// Whether a committed frontier is currently pinned.
    pub fn has_commit(&self) -> bool {
        self.commit_valid
    }

    /// Owning snapshot (allocates; the hot path uses
    /// [`SimCursor::resume_from`] on a pooled cursor instead).
    pub fn snapshot(&self) -> SimCursor {
        self.clone()
    }

    /// Become a copy of `snap`'s *simulation* state, reusing this
    /// cursor's buffers — zero heap allocations once capacities have
    /// warmed up. The committed-frontier split is deliberately NOT
    /// resumed (the destination's commit is invalidated): resume targets
    /// are scoring probes and beam entries that only simulate forward,
    /// and copying the source's commit snapshot would double the cost of
    /// every candidate resume in the schedulers' hot loops. Use
    /// [`SimCursor::snapshot`] / `clone_from` for a full-fidelity copy
    /// including the frontier.
    pub fn resume_from(&mut self, snap: &SimCursor) {
        self.clone_core_from(snap);
        self.commit_valid = false;
    }

    /// Drive the event loop. With `finishing == false` the loop stops at
    /// the committed frontier: the moment the HtD engine would go idle
    /// with an empty HtD queue. Up to that instant the event sequence is
    /// invariant under future `push_task` calls (appended HtD commands
    /// would first run exactly at the frontier; DtH rates and 1-DMA
    /// engine sharing only depend on HtD activity, which is fully known
    /// until then), so pause/resume replays the from-scratch event
    /// sequence bit for bit.
    fn drain(&mut self, finishing: bool) {
        let done = self.drain_bounded(finishing, f64::INFINITY);
        debug_assert!(done, "unbounded drain can never abort");
    }

    /// [`SimCursor::drain`] with the early-exit cutoff of
    /// [`SimCursor::run_to_quiescence_bounded`]: returns `false` — leaving
    /// the loop state consistent and resumable — the moment the clock
    /// strictly exceeds `cutoff` (checked only at event boundaries, where
    /// in-flight work has been burned and completions processed). The
    /// plain `>` deliberately never fires on NaN/infinite cutoffs, and an
    /// infinite cutoff makes this bit-identical to the unbounded drain.
    fn drain_bounded(&mut self, finishing: bool, cutoff: f64) -> bool {
        if self.now > cutoff {
            return false;
        }
        loop {
            // ---- Activation phase: move ready queue heads into engines.
            // HtD engine.
            if self.act_h.is_none() && self.h_next < self.q_htd.len() {
                let (t, j, b) = self.q_htd[self.h_next];
                // Single-DMA: the transfer engine is shared; it must not
                // carry an active DtH (act_d) either.
                let engine_ok = !self.prof.single_dma || self.act_d.is_none();
                if engine_ok && self.now + EPS >= self.init.htd_free {
                    self.act_h = Some(Cmd {
                        task: t,
                        kind: CmdKind::HtD,
                        seq: j,
                        lat_left: self.prof.htd_latency,
                        work_left: b as f64,
                        start: self.now.max(self.init.htd_free),
                    });
                    self.h_next += 1;
                }
            }
            // DtH engine: head must satisfy (a) its kernel done, (b) on
            // 1-DMA devices all HtD commands done AND the shared engine
            // free. "All HtD commands" is only a known set once the caller
            // stops pushing, hence the `finishing` gate.
            if self.act_d.is_none() && self.d_next < self.q_dth.len() {
                let (t, j, b) = self.q_dth[self.d_next];
                let dep_ok = self.k_done[t]
                    && (!self.prof.single_dma
                        || (finishing
                            && self.htd_cmds_done == self.q_htd.len()
                            && self.act_h.is_none()));
                if dep_ok && self.now + EPS >= self.init.dth_free {
                    self.act_d = Some(Cmd {
                        task: t,
                        kind: CmdKind::DtH,
                        seq: j,
                        lat_left: self.prof.dth_latency,
                        work_left: b as f64,
                        start: self.now.max(self.init.dth_free),
                    });
                    self.d_next += 1;
                }
            }
            // Compute engine: strictly serial, K_t after all its HtD.
            if self.act_k.is_none()
                && self.k_next < self.k_done.len()
                && self.htd_pending[self.k_next] == 0
                && self.now + EPS >= self.init.k_free
            {
                self.act_k = Some(Cmd {
                    task: self.k_next,
                    kind: CmdKind::Kernel,
                    seq: 0,
                    lat_left: 0.0,
                    work_left: self.kernel_secs[self.k_next],
                    start: self.now.max(self.init.k_free),
                });
                self.k_next += 1;
            }

            // ---- Committed frontier: while pushes may still arrive, stop
            // the clock where a future task's first HtD would slot in.
            if !finishing && self.act_h.is_none() && self.h_next >= self.q_htd.len()
            {
                return true;
            }

            // ---- Termination: nothing active and nothing activatable.
            if self.act_h.is_none() && self.act_d.is_none() && self.act_k.is_none()
            {
                if self.h_next >= self.q_htd.len()
                    && self.d_next >= self.q_dth.len()
                    && self.k_next >= self.k_done.len()
                {
                    return true;
                }
                // Engines blocked purely by init free-times: jump forward.
                // Only consider queue heads whose *dependencies* are
                // already satisfied — others can never unblock while
                // nothing runs.
                let mut jump = f64::INFINITY;
                if self.h_next < self.q_htd.len() {
                    jump = jump.min(self.init.htd_free);
                }
                if self.d_next < self.q_dth.len() {
                    let (t, _, _) = self.q_dth[self.d_next];
                    if self.k_done[t]
                        && (!self.prof.single_dma
                            || self.htd_cmds_done == self.q_htd.len())
                    {
                        jump = jump.min(self.init.dth_free);
                    }
                }
                if self.k_next < self.k_done.len()
                    && self.htd_pending[self.k_next] == 0
                {
                    jump = jump.min(self.init.k_free);
                }
                assert!(
                    jump.is_finite() && jump > self.now,
                    "simulator deadlock at t={}",
                    self.now
                );
                self.now = jump;
                if self.now > cutoff {
                    return false;
                }
                continue;
            }

            // ---- Rate assignment (re-estimated every event, Fig. 5).
            let both_transfers = self.act_h.is_some() && self.act_d.is_some();
            let rate_h = self.prof.rate(true, both_transfers);
            let rate_d = self.prof.rate(false, both_transfers);

            // ---- Earliest completion among active commands.
            let eta = |c: &Cmd, rate: f64| c.lat_left + c.work_left / rate;
            let mut dt = f64::INFINITY;
            if let Some(c) = &self.act_h {
                dt = dt.min(eta(c, rate_h));
            }
            if let Some(c) = &self.act_d {
                dt = dt.min(eta(c, rate_d));
            }
            if let Some(c) = &self.act_k {
                dt = dt.min(eta(c, 1.0));
            }
            debug_assert!(dt.is_finite() && dt >= 0.0);
            self.now += dt;

            // ---- Advance in-flight work and collect completions.
            let done_h = advance_cmd(&mut self.act_h, rate_h, dt);
            let done_d = advance_cmd(&mut self.act_d, rate_d, dt);
            let done_k = advance_cmd(&mut self.act_k, 1.0, dt);
            for done in [done_h, done_d, done_k].into_iter().flatten() {
                self.complete(done);
            }
            if self.now > cutoff {
                return false;
            }
        }
    }

    fn complete(&mut self, done: Cmd) {
        match done.kind {
            CmdKind::HtD => {
                self.htd_pending[done.task] -= 1;
                self.htd_cmds_done += 1;
                self.end_state.htd_free = self.now;
            }
            CmdKind::Kernel => {
                self.k_done[done.task] = true;
                self.end_state.k_free = self.now;
                if self.dth_pending[done.task] == 0 {
                    self.task_end[done.task] = self.now;
                }
            }
            CmdKind::DtH => {
                self.dth_pending[done.task] -= 1;
                self.end_state.dth_free = self.now;
                if self.dth_pending[done.task] == 0 {
                    self.task_end[done.task] = self.now;
                }
            }
        }
        if self.record {
            self.timeline.push(CmdRecord {
                task: done.task,
                kind: done.kind,
                seq: done.seq,
                start: done.start,
                end: self.now,
            });
        }
    }

    fn into_result(self) -> SimResult {
        SimResult {
            makespan: self.now,
            task_end: self.task_end,
            end_state: self.end_state,
            timeline: self.timeline,
        }
    }
}

/// Burn `dt` seconds of an in-flight command at `rate`; returns the
/// command if it completed (same arithmetic as the original loop, so
/// cursor and from-scratch runs agree bit for bit).
#[inline]
fn advance_cmd(c: &mut Option<Cmd>, rate: f64, dt: f64) -> Option<Cmd> {
    if let Some(cmd) = c.as_mut() {
        let lat_used = dt.min(cmd.lat_left);
        cmd.lat_left -= lat_used;
        cmd.work_left -= (dt - lat_used).max(0.0) * rate;
        if cmd.lat_left <= EPS && cmd.work_left <= rate.max(1.0) * EPS {
            let done = *cmd;
            *c = None;
            return Some(done);
        }
    }
    None
}

impl SimCursor {
    /// Buffer-reusing copy of the *core* simulation state — everything
    /// except the committed-frontier bookkeeping. `Vec::clone_from`
    /// truncates and extends in place, so a warmed-up destination
    /// performs no heap allocation. Shared by `Clone::clone_from`, the
    /// internal commit snapshot, and `replan_suffix`'s restore.
    fn clone_core_from(&mut self, src: &SimCursor) {
        self.prof = src.prof;
        self.init = src.init;
        self.record = src.record;
        self.q_htd.clone_from(&src.q_htd);
        self.q_dth.clone_from(&src.q_dth);
        self.h_next = src.h_next;
        self.d_next = src.d_next;
        self.k_next = src.k_next;
        self.htd_pending.clone_from(&src.htd_pending);
        self.k_done.clone_from(&src.k_done);
        self.dth_pending.clone_from(&src.dth_pending);
        self.kernel_secs.clone_from(&src.kernel_secs);
        self.htd_cmds_done = src.htd_cmds_done;
        self.act_h = src.act_h;
        self.act_d = src.act_d;
        self.act_k = src.act_k;
        self.now = src.now;
        self.end_state = src.end_state;
        self.task_end.clone_from(&src.task_end);
        self.timeline.clone_from(&src.timeline);
        self.finished = src.finished;
        self.mid_finish = src.mid_finish;
        self.busy_htd = src.busy_htd;
        self.busy_k = src.busy_k;
        self.busy_dth = src.busy_dth;
    }
}

impl Clone for SimCursor {
    fn clone(&self) -> SimCursor {
        SimCursor {
            prof: self.prof,
            init: self.init,
            record: self.record,
            q_htd: self.q_htd.clone(),
            q_dth: self.q_dth.clone(),
            h_next: self.h_next,
            d_next: self.d_next,
            k_next: self.k_next,
            htd_pending: self.htd_pending.clone(),
            k_done: self.k_done.clone(),
            dth_pending: self.dth_pending.clone(),
            kernel_secs: self.kernel_secs.clone(),
            htd_cmds_done: self.htd_cmds_done,
            act_h: self.act_h,
            act_d: self.act_d,
            act_k: self.act_k,
            now: self.now,
            end_state: self.end_state,
            task_end: self.task_end.clone(),
            timeline: self.timeline.clone(),
            finished: self.finished,
            mid_finish: self.mid_finish,
            busy_htd: self.busy_htd,
            busy_k: self.busy_k,
            busy_dth: self.busy_dth,
            commit_snap: self.commit_snap.clone(),
            commit_valid: self.commit_valid,
        }
    }

    /// Buffer-reusing copy (core state plus the committed frontier), so a
    /// warmed-up destination performs no heap allocation.
    fn clone_from(&mut self, src: &SimCursor) {
        self.clone_core_from(src);
        self.commit_valid = src.commit_valid;
        if let Some(s) = &src.commit_snap {
            if let Some(dst) = &mut self.commit_snap {
                dst.clone_core_from(s);
                dst.commit_valid = false;
            } else {
                self.commit_snap = Some(s.clone());
            }
        }
        // When src carries no snapshot, keep our (possibly allocated) box
        // for reuse; `commit_valid` above already marks it dead.
    }
}

/// Predict the execution of `tasks` submitted in the given vector order on
/// `profile`, starting from `init` engine state.
pub fn simulate(
    tasks: &[TaskSpec],
    profile: &DeviceProfile,
    init: EngineState,
    opts: SimOptions,
) -> SimResult {
    let mut cursor = SimCursor::with_options(profile, init, opts);
    for task in tasks {
        cursor.push_task(task);
    }
    cursor.run_to_quiescence();
    cursor.into_result()
}

/// Zero-copy variant: predict `tasks` submitted in `order` (a permutation
/// of indices into `tasks`). Record/task_end indices are *slots*
/// (positions in `order`), matching `simulate`. Compiles a [`TaskTable`]
/// once and pushes from it; schedulers that score *many* orders of the
/// same group should compile the table themselves (or hold cursors
/// directly and pay for shared prefixes once).
pub fn simulate_order(
    all_tasks: &[TaskSpec],
    order: &[usize],
    profile: &DeviceProfile,
    init: EngineState,
    opts: SimOptions,
) -> SimResult {
    let table = TaskTable::compile(all_tasks, profile);
    simulate_order_compiled(&table, order, init, opts)
}

/// [`simulate_order`] over a pre-compiled [`TaskTable`] — the zero-
/// recompilation path for sweeps that score many orders of one group.
pub fn simulate_order_compiled(
    table: &TaskTable,
    order: &[usize],
    init: EngineState,
    opts: SimOptions,
) -> SimResult {
    let mut cursor = SimCursor { record: opts.record_timeline, ..SimCursor::default() };
    cursor.reset_params(table.params(), init);
    for &i in order {
        cursor.push_task_compiled(table, i);
    }
    cursor.run_to_quiescence();
    cursor.into_result()
}

/// Convenience: makespan of an order over a task group.
pub fn makespan_of_order(
    tasks: &[TaskSpec],
    order: &[usize],
    profile: &DeviceProfile,
) -> f64 {
    simulate_order(tasks, order, profile, EngineState::default(), SimOptions::default())
        .makespan
}

/// The pre-refactor single-shot event loop, kept verbatim as an
/// independently-coded reference implementation: the incremental-cursor
/// property tests pin [`SimCursor`] to it (<= 1e-12), and
/// `benches/table6_overhead.rs` uses it (via
/// `sched::heuristic::batch_reorder_beam_replay`) as the from-scratch
/// baseline the resumable path is measured against. Allocates ~6 Vecs per
/// call by construction — do not use on hot paths.
pub fn simulate_order_fromscratch(
    all_tasks: &[TaskSpec],
    order: &[usize],
    profile: &DeviceProfile,
    init: EngineState,
    opts: SimOptions,
) -> SimResult {
    struct IndexView<'a> {
        all: &'a [TaskSpec],
        order: &'a [usize],
    }
    impl<'a> IndexView<'a> {
        #[inline]
        fn get(&self, slot: usize) -> &TaskSpec {
            &self.all[self.order[slot]]
        }
    }
    let tasks = IndexView { all: all_tasks, order };
    let n = order.len();
    let mut result = SimResult {
        makespan: 0.0,
        task_end: vec![0.0; n],
        end_state: init,
        timeline: Vec::new(),
    };
    if n == 0 {
        return result;
    }

    // Flattened FIFO queues. Entries are (task, seq, bytes).
    let mut q_htd: Vec<(usize, usize, u64)> = Vec::new();
    let mut q_dth: Vec<(usize, usize, u64)> = Vec::new();
    for t in 0..n {
        let task = tasks.get(t);
        for (j, &b) in task.htd_bytes.iter().enumerate() {
            q_htd.push((t, j, b));
        }
        for (j, &b) in task.dth_bytes.iter().enumerate() {
            q_dth.push((t, j, b));
        }
    }
    // Queue cursors.
    let mut h_next = 0usize;
    let mut d_next = 0usize;
    let mut k_next = 0usize;

    // Dependency bookkeeping.
    let mut htd_pending: Vec<usize> =
        (0..n).map(|t| tasks.get(t).htd_bytes.len()).collect();
    let mut k_done: Vec<bool> = vec![false; n];
    let mut dth_pending: Vec<usize> =
        (0..n).map(|t| tasks.get(t).dth_bytes.len()).collect();
    let single_dma = profile.dma_engines < 2;
    let total_htd_cmds = q_htd.len();
    let mut htd_cmds_done = 0usize;

    // Active slots: at most one command per engine.
    let mut act_h: Option<Cmd> = None;
    let mut act_d: Option<Cmd> = None;
    let mut act_k: Option<Cmd> = None;

    let mut now = 0.0f64;
    let eps = EPS;

    loop {
        // ---- Activation phase: move ready queue heads into free engines.
        if act_h.is_none() && h_next < q_htd.len() {
            let (t, j, b) = q_htd[h_next];
            let free_at = init.htd_free;
            let engine_ok = !single_dma || act_d.is_none();
            if engine_ok && now + eps >= free_at {
                act_h = Some(Cmd {
                    task: t,
                    kind: CmdKind::HtD,
                    seq: j,
                    lat_left: profile.htd.latency,
                    work_left: b as f64,
                    start: now.max(free_at),
                });
                h_next += 1;
            }
        }
        if act_d.is_none() && d_next < q_dth.len() {
            let (t, j, b) = q_dth[d_next];
            let dep_ok = k_done[t]
                && (!single_dma
                    || (htd_cmds_done == total_htd_cmds && act_h.is_none()));
            if dep_ok && now + eps >= init.dth_free {
                act_d = Some(Cmd {
                    task: t,
                    kind: CmdKind::DtH,
                    seq: j,
                    lat_left: profile.dth.latency,
                    work_left: b as f64,
                    start: now.max(init.dth_free),
                });
                d_next += 1;
            }
        }
        if act_k.is_none()
            && k_next < n
            && htd_pending[k_next] == 0
            && now + eps >= init.k_free
        {
            let dur = tasks.get(k_next).kernel.est_secs()
                + profile.kernel_launch_overhead;
            act_k = Some(Cmd {
                task: k_next,
                kind: CmdKind::Kernel,
                seq: 0,
                lat_left: 0.0,
                work_left: dur,
                start: now.max(init.k_free),
            });
            k_next += 1;
        }

        // ---- Termination: nothing active and nothing activatable.
        if act_h.is_none() && act_d.is_none() && act_k.is_none() {
            if h_next >= q_htd.len() && d_next >= q_dth.len() && k_next >= n {
                break;
            }
            let mut jump = f64::INFINITY;
            if h_next < q_htd.len() {
                jump = jump.min(init.htd_free);
            }
            if d_next < q_dth.len() {
                let (t, _, _) = q_dth[d_next];
                if k_done[t] && (!single_dma || htd_cmds_done == total_htd_cmds)
                {
                    jump = jump.min(init.dth_free);
                }
            }
            if k_next < n && htd_pending[k_next] == 0 {
                jump = jump.min(init.k_free);
            }
            assert!(
                jump.is_finite() && jump > now,
                "simulator deadlock at t={now}"
            );
            now = jump;
            continue;
        }

        // ---- Rate assignment (re-estimated every event, Fig. 5).
        let both_transfers = act_h.is_some() && act_d.is_some();
        let rate_h = profile.rate(true, both_transfers);
        let rate_d = profile.rate(false, both_transfers);

        // ---- Earliest completion among active commands.
        let eta = |c: &Cmd, rate: f64| c.lat_left + c.work_left / rate;
        let mut dt = f64::INFINITY;
        if let Some(c) = &act_h {
            dt = dt.min(eta(c, rate_h));
        }
        if let Some(c) = &act_d {
            dt = dt.min(eta(c, rate_d));
        }
        if let Some(c) = &act_k {
            dt = dt.min(eta(c, 1.0));
        }
        debug_assert!(dt.is_finite() && dt >= 0.0);
        now += dt;

        let done_h = advance_cmd(&mut act_h, rate_h, dt);
        let done_d = advance_cmd(&mut act_d, rate_d, dt);
        let done_k = advance_cmd(&mut act_k, 1.0, dt);

        for done in [done_h, done_d, done_k].into_iter().flatten() {
            match done.kind {
                CmdKind::HtD => {
                    htd_pending[done.task] -= 1;
                    htd_cmds_done += 1;
                    result.end_state.htd_free = now;
                }
                CmdKind::Kernel => {
                    k_done[done.task] = true;
                    result.end_state.k_free = now;
                    if tasks.get(done.task).dth_bytes.is_empty() {
                        result.task_end[done.task] = now;
                    }
                }
                CmdKind::DtH => {
                    dth_pending[done.task] -= 1;
                    result.end_state.dth_free = now;
                    if dth_pending[done.task] == 0 {
                        result.task_end[done.task] = now;
                    }
                }
            }
            if opts.record_timeline {
                result.timeline.push(CmdRecord {
                    task: done.task,
                    kind: done.kind,
                    seq: done.seq,
                    start: done.start,
                    end: now,
                });
            }
        }
    }

    result.makespan = now;
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;
    use crate::task::synthetic::{synthetic_benchmark, synthetic_task};
    use crate::task::{KernelSpec, TaskSpec};

    fn timed(name: &str, htd: u64, k: f64, dth: u64) -> TaskSpec {
        TaskSpec::simple(name, htd, KernelSpec::Timed { secs: k }, dth)
    }

    fn opts() -> SimOptions {
        SimOptions { record_timeline: true }
    }

    #[test]
    fn single_task_is_sequential() {
        let p = profile_by_name("amd_r9").unwrap();
        let t = synthetic_task(0, &p, 1.0);
        let r = simulate(&[t.clone()], &p, EngineState::default(), opts());
        let want = t.sequential_secs(&p);
        assert!(
            (r.makespan - want).abs() < 1e-9,
            "{} vs {want}",
            r.makespan
        );
        assert_eq!(r.timeline.len(), 3);
    }

    #[test]
    fn pipeline_overlaps_on_two_dma() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK100", &p, 1.0).unwrap();
        let r = simulate(&g.tasks, &p, EngineState::default(), opts());
        let serial: f64 =
            g.tasks.iter().map(|t| t.sequential_secs(&p)).sum();
        // Dominant-kernel tasks pipeline almost perfectly: makespan must be
        // well below the serial floor but above the kernel-sum lower bound.
        let k_sum: f64 =
            g.tasks.iter().map(|t| t.stage_secs(&p).k).sum();
        assert!(r.makespan < 0.85 * serial, "{} vs {serial}", r.makespan);
        assert!(r.makespan >= k_sum - 1e-9);
    }

    #[test]
    fn kernels_never_overlap() {
        let p = profile_by_name("k20c").unwrap();
        let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
        let r = simulate(&g.tasks, &p, EngineState::default(), opts());
        let mut kernels: Vec<&CmdRecord> = r
            .timeline
            .iter()
            .filter(|c| c.kind == CmdKind::Kernel)
            .collect();
        kernels.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
        for w in kernels.windows(2) {
            assert!(
                w[1].start >= w[0].end - 1e-9,
                "CKE in model: {:?} then {:?}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn intra_task_dependencies_hold() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK25", &p, 1.0).unwrap();
        let r = simulate(&g.tasks, &p, EngineState::default(), opts());
        for t in 0..g.len() {
            let h_end = r
                .timeline
                .iter()
                .filter(|c| c.task == t && c.kind == CmdKind::HtD)
                .map(|c| c.end)
                .fold(0.0, f64::max);
            let k = r
                .timeline
                .iter()
                .find(|c| c.task == t && c.kind == CmdKind::Kernel)
                .unwrap();
            let d_start = r
                .timeline
                .iter()
                .filter(|c| c.task == t && c.kind == CmdKind::DtH)
                .map(|c| c.start)
                .fold(f64::INFINITY, f64::min);
            assert!(k.start >= h_end - 1e-9, "task {t}: K before HtD done");
            assert!(d_start >= k.end - 1e-9, "task {t}: DtH before K done");
        }
    }

    #[test]
    fn one_dma_serializes_all_transfers() {
        let p = profile_by_name("xeon_phi").unwrap();
        let g = synthetic_benchmark("BK0", &p, 1.0).unwrap();
        let r = simulate(&g.tasks, &p, EngineState::default(), opts());
        let mut xfers: Vec<&CmdRecord> = r
            .timeline
            .iter()
            .filter(|c| c.kind != CmdKind::Kernel)
            .collect();
        xfers.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
        for w in xfers.windows(2) {
            assert!(
                w[1].start >= w[0].end - 1e-9,
                "transfers overlap on 1-DMA device: {:?} / {:?}",
                w[0],
                w[1]
            );
        }
        // And all HtD precede all DtH (grouped-by-type submission).
        let last_htd = r
            .timeline
            .iter()
            .filter(|c| c.kind == CmdKind::HtD)
            .map(|c| c.end)
            .fold(0.0, f64::max);
        let first_dth = r
            .timeline
            .iter()
            .filter(|c| c.kind == CmdKind::DtH)
            .map(|c| c.start)
            .fold(f64::INFINITY, f64::min);
        assert!(first_dth >= last_htd - 1e-9);
    }

    #[test]
    fn duplex_contention_stretches_transfers() {
        let p = profile_by_name("amd_r9").unwrap();
        // Task 0: long HtD; task 1's DtH will overlap task 0's... build a
        // pair where overlap is forced: t0 tiny kernel + big DtH, t1 big HtD.
        let t0 = timed("t0", 1_000, 0.1e-3, 40_000_000);
        let t1 = timed("t1", 40_000_000, 0.1e-3, 1_000);
        let r = simulate(
            &[t0.clone(), t1.clone()],
            &p,
            EngineState::default(),
            opts(),
        );
        // DtH of t0 and HtD of t1 overlap -> both stretched vs solo.
        let dth0 = r
            .timeline
            .iter()
            .find(|c| c.task == 0 && c.kind == CmdKind::DtH)
            .unwrap();
        assert!(dth0.dur() > p.dth.transfer_secs(40_000_000) + 0.2e-3);
    }

    #[test]
    fn order_changes_makespan() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK25", &p, 1.0).unwrap();
        let forward = makespan_of_order(&g.tasks, &[0, 1, 2, 3], &p);
        let mut best = f64::INFINITY;
        let mut worst: f64 = 0.0;
        let perms = crate::sched::bruteforce::permutations(4);
        for perm in &perms {
            let m = makespan_of_order(&g.tasks, perm, &p);
            best = best.min(m);
            worst = worst.max(m);
        }
        assert!(worst > best * 1.02, "ordering should matter: {best}..{worst}");
        assert!(forward >= best - 1e-12 && forward <= worst + 1e-12);
    }

    #[test]
    fn engine_state_carryover_delays_start() {
        let p = profile_by_name("amd_r9").unwrap();
        let t = synthetic_task(0, &p, 1.0);
        let delayed = simulate(
            &[t.clone()],
            &p,
            EngineState { htd_free: 5e-3, k_free: 0.0, dth_free: 0.0 },
            opts(),
        );
        let fresh =
            simulate(&[t], &p, EngineState::default(), opts());
        assert!(
            (delayed.makespan - (fresh.makespan + 5e-3)).abs() < 1e-9,
            "{} vs {}",
            delayed.makespan,
            fresh.makespan
        );
    }

    #[test]
    fn null_transfer_stages() {
        let p = profile_by_name("k20c").unwrap();
        let t = timed("konly", 0, 2e-3, 0);
        let r = simulate(&[t], &p, EngineState::default(), opts());
        assert_eq!(r.timeline.len(), 1);
        assert!((r.makespan - (2e-3 + p.kernel_launch_overhead)).abs() < 1e-9);
    }

    #[test]
    fn empty_group() {
        let p = profile_by_name("amd_r9").unwrap();
        let r = simulate(&[], &p, EngineState::default(), opts());
        assert_eq!(r.makespan, 0.0);
    }

    #[test]
    fn cursor_matches_fromscratch_on_catalogs() {
        for dev in ["amd_r9", "k20c", "xeon_phi"] {
            let p = profile_by_name(dev).unwrap();
            for label in ["BK0", "BK25", "BK50", "BK75", "BK100"] {
                let g = synthetic_benchmark(label, &p, 1.0).unwrap();
                for perm in crate::sched::bruteforce::permutations(4) {
                    let a = simulate_order(
                        &g.tasks,
                        &perm,
                        &p,
                        EngineState::default(),
                        opts(),
                    );
                    let b = simulate_order_fromscratch(
                        &g.tasks,
                        &perm,
                        &p,
                        EngineState::default(),
                        opts(),
                    );
                    assert!(
                        (a.makespan - b.makespan).abs() <= 1e-12,
                        "{dev}/{label}/{perm:?}: {} vs {}",
                        a.makespan,
                        b.makespan
                    );
                    assert_eq!(a.timeline.len(), b.timeline.len());
                    assert_eq!(a.task_end, b.task_end);
                    assert_eq!(a.end_state, b.end_state);
                }
            }
        }
    }

    #[test]
    fn snapshot_resume_scores_extensions_exactly() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
        // Simulate prefix [2, 0] once, snapshot, then score extensions 1
        // and 3 by resuming — must equal the from-scratch runs.
        let mut prefix = SimCursor::new(&p, EngineState::default());
        prefix.push_task(&g.tasks[2]);
        prefix.push_task(&g.tasks[0]);
        let mut probe = SimCursor::new(&p, EngineState::default());
        for ext in [1usize, 3] {
            probe.resume_from(&prefix);
            probe.push_task(&g.tasks[ext]);
            let m = probe.run_to_quiescence();
            let want = simulate_order_fromscratch(
                &g.tasks,
                &[2, 0, ext],
                &p,
                EngineState::default(),
                SimOptions::default(),
            )
            .makespan;
            assert!((m - want).abs() <= 1e-12, "ext {ext}: {m} vs {want}");
        }
        // The snapshot source is still resumable afterwards.
        prefix.push_task(&g.tasks[1]);
        prefix.push_task(&g.tasks[3]);
        let m = prefix.run_to_quiescence();
        let want = makespan_of_order(&g.tasks, &[2, 0, 1, 3], &p);
        assert!((m - want).abs() <= 1e-12);
    }

    #[test]
    fn commit_then_replan_retracts_uncommitted_suffix() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
        let mut cur = SimCursor::new(&p, EngineState::default());
        cur.push_task(&g.tasks[1]);
        cur.push_task(&g.tasks[0]);
        assert_eq!(cur.commit_frontier(), 2);
        assert!(cur.has_commit());
        // Explore one suffix to quiescence, then retract it entirely.
        cur.push_task(&g.tasks[2]);
        cur.push_task(&g.tasks[3]);
        let explored = cur.run_to_quiescence();
        assert!(cur.is_finished());
        assert_eq!(cur.replan_suffix(), 2);
        assert!(!cur.is_finished());
        assert_eq!(cur.n_tasks(), 2);
        assert_eq!(cur.committed_len(), 2);
        // The retracted cursor accepts a different suffix and reproduces
        // the from-scratch simulation of committed prefix + new suffix.
        cur.push_task(&g.tasks[3]);
        cur.push_task(&g.tasks[2]);
        let m = cur.run_to_quiescence();
        let want = makespan_of_order_local(&g.tasks, &[1, 0, 3, 2], &p);
        assert!((m - want).abs() <= 1e-12, "{m} vs {want}");
        // And the explored order matches its own reference.
        let want_explored = makespan_of_order_local(&g.tasks, &[1, 0, 2, 3], &p);
        assert!((explored - want_explored).abs() <= 1e-12);
    }

    fn makespan_of_order_local(
        tasks: &[TaskSpec],
        order: &[usize],
        p: &crate::config::DeviceProfile,
    ) -> f64 {
        simulate_order_fromscratch(
            tasks,
            order,
            p,
            EngineState::default(),
            SimOptions::default(),
        )
        .makespan
    }

    #[test]
    fn commit_replan_cycles_are_repeatable() {
        let p = profile_by_name("xeon_phi").unwrap();
        let g = synthetic_benchmark("BK25", &p, 1.0).unwrap();
        let mut cur = SimCursor::new(&p, EngineState::default());
        cur.push_task(&g.tasks[0]);
        cur.commit_frontier();
        // Several explore/retract cycles must all agree with from-scratch.
        for suffix in [[1usize, 2, 3], [3, 2, 1], [2, 1, 3]] {
            for &i in &suffix {
                cur.push_task(&g.tasks[i]);
            }
            let m = cur.run_to_quiescence();
            let mut order = vec![0usize];
            order.extend_from_slice(&suffix);
            let want = makespan_of_order_local(&g.tasks, &order, &p);
            assert!((m - want).abs() <= 1e-12, "{suffix:?}: {m} vs {want}");
            cur.replan_suffix();
        }
        // Committing again moves the frontier forward.
        cur.push_task(&g.tasks[2]);
        assert_eq!(cur.commit_frontier(), 2);
        assert_eq!(cur.replan_suffix(), 0);
    }

    #[test]
    fn bounded_run_aborts_resumes_and_matches_unbounded() {
        for dev in ["amd_r9", "xeon_phi"] {
            let p = profile_by_name(dev).unwrap();
            let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
            let mut full = SimCursor::new(&p, EngineState::default());
            for t in &g.tasks {
                full.push_task(t);
            }
            let want = full.clone().run_to_quiescence();

            // Infinite cutoff: bit-identical to the unbounded run.
            let mut inf = full.clone();
            assert_eq!(inf.run_to_quiescence_bounded(f64::INFINITY), Some(want));
            assert!(inf.is_finished());

            // A cutoff below the makespan aborts; the aborted cursor can
            // be finished later and still lands on the exact same bits.
            let mut bounded = full.clone();
            assert_eq!(bounded.run_to_quiescence_bounded(want * 0.5), None, "{dev}");
            assert!(!bounded.is_finished());
            assert!(bounded.clock() <= want);
            assert_eq!(bounded.run_to_quiescence_bounded(want * 0.75), None);
            assert_eq!(bounded.run_to_quiescence_bounded(f64::INFINITY), Some(want));

            // A cutoff at (or above) the makespan never aborts.
            let mut at = full.clone();
            assert_eq!(at.run_to_quiescence_bounded(want), Some(want), "{dev}");
        }
    }

    #[test]
    fn lower_bound_is_admissible_and_monotone() {
        for dev in ["amd_r9", "k20c", "xeon_phi"] {
            let p = profile_by_name(dev).unwrap();
            let g = synthetic_benchmark("BK25", &p, 1.0).unwrap();
            let init = EngineState { htd_free: 1e-3, k_free: 2e-3, dth_free: 0.5e-3 };
            let mut cur = SimCursor::new(&p, init);
            let mut prev_lb = 0.0f64;
            for t in &g.tasks {
                cur.push_task(t);
                let lb = cur.lower_bound();
                assert!(lb >= prev_lb, "{dev}: envelope must be monotone");
                prev_lb = lb;
            }
            let lb = cur.lower_bound();
            let m = cur.run_to_quiescence();
            // Admissible modulo float accumulation (margins mirror the
            // schedulers' provably_worse guard: relative + absolute).
            assert!(
                lb * (1.0 - 1e-9) - 1e-9 <= m,
                "{dev}: lower_bound {lb} vs makespan {m}"
            );
            assert!(lb > 0.0);
            // The finished clock is itself part of the envelope.
            assert!(cur.lower_bound() >= m);
        }
    }

    #[test]
    fn cursor_reset_reuses_buffers() {
        let p = profile_by_name("k20c").unwrap();
        let g = synthetic_benchmark("BK25", &p, 1.0).unwrap();
        let mut cur = SimCursor::new(&p, EngineState::default());
        for t in &g.tasks {
            cur.push_task(t);
        }
        let first = cur.run_to_quiescence();
        cur.reset(&p, EngineState::default());
        assert_eq!(cur.n_tasks(), 0);
        assert!(!cur.is_finished());
        for t in &g.tasks {
            cur.push_task(t);
        }
        let second = cur.run_to_quiescence();
        assert_eq!(first, second);
    }
}
