//! Compiled structure-of-arrays task tables — the scheduler's cache-
//! friendly view of a task group.
//!
//! The simulator's hot path ([`SimCursor::push_task`]) used to walk a
//! [`TaskSpec`] per push: two `Vec<u64>` field loads, a `KernelSpec` enum
//! match and a profile field read, all behind a `&TaskSpec` that points at
//! a heap-scattered struct (the group is cloned out of `Submission`s, so
//! consecutive tasks are rarely adjacent in memory). A [`TaskTable`] is
//! the same information *compiled once per (group, device)*:
//!
//! * all HtD / DtH command sizes live in two flat `Vec<u64>` arenas with
//!   per-task offset ranges (classic SoA / CSR layout), so pushing task
//!   `i` is two contiguous slice walks;
//! * kernel durations are pre-resolved to `est_secs + launch_overhead`
//!   (the exact value the cursor would compute), one `f64` load per push;
//! * the per-stage solo seconds, the `K - HtD` ranking key, the sequential
//!   floor and the dominance class are precomputed, so scheduler ranking
//!   passes ([`sched::heuristic`]'s first-task sort, LPT keys in
//!   [`sched::multidevice`]) read contiguous `f64` slices instead of
//!   recomputing `stage_secs` per comparison;
//! * spec-twin equivalence classes (`twin_class`, full-key proven) and
//!   group-aggregate stage sums / minimum kernel+DtH tail are compiled
//!   once, feeding the searches' bound-gated pruning layer: twin
//!   candidates collapse to one simulated representative per prefix, and
//!   the seed-stage admissible floors read the aggregates directly
//!   (surviving prefixes re-scan only their unplaced rows, O(T) per
//!   parent per depth instead of per candidate).
//!
//! Compilation is `O(commands)` and reuses buffers via
//! [`TaskTable::compile_into`], so a warm table performs no heap
//! allocation — the lane coordinator compiles each drained group into a
//! per-lane table, and the beam search (serial and parallel) scores every
//! candidate through [`SimCursor::push_task_compiled`].
//!
//! Every derived quantity is computed with the *same float expressions*
//! as the `TaskSpec` path (`stage_secs`, `sequential_secs`,
//! `kernel.est_secs() + overhead`), so table-driven simulation is
//! bit-identical to spec-driven simulation — property-tested in
//! `rust/tests/prop_parallel.rs`.
//!
//! [`TaskTable::compile_calibrated_into`] compiles the same group against
//! a *calibrated* planning model (`model::calibrate`): corrected link
//! rates arrive via the effective profile and kernel durations are scaled
//! at compile time, so every derived row value — stage secs, dominance,
//! twin classes, the group-aggregate floors — is re-derived from the
//! corrected model in one recompile.
//!
//! [`SimCursor::push_task`]: crate::model::SimCursor::push_task
//! [`SimCursor::push_task_compiled`]: crate::model::SimCursor::push_task_compiled
//! [`sched::heuristic`]: crate::sched::heuristic
//! [`sched::multidevice`]: crate::sched::multidevice

use crate::config::DeviceProfile;
use crate::model::simulator::ProfileParams;
use crate::task::{Dominance, TaskSpec};

/// A task group compiled against one device profile (see module docs).
#[derive(Clone, Debug, Default)]
pub struct TaskTable {
    pub(crate) prof: ProfileParams,
    /// Flat HtD command sizes; task `i` owns `htd_raw[htd_off[i]..htd_off[i+1]]`.
    htd_raw: Vec<u64>,
    htd_off: Vec<u32>,
    /// Flat DtH command sizes, same layout.
    dth_raw: Vec<u64>,
    dth_off: Vec<u32>,
    /// Kernel command duration incl. launch overhead (what the cursor runs).
    kernel: Vec<f64>,
    /// Solo per-stage seconds (identical arithmetic to `TaskSpec::stage_secs`).
    htd_secs: Vec<f64>,
    dth_secs: Vec<f64>,
    /// `k - htd`, the select-first ranking key of Algorithm 1.
    k_minus_htd: Vec<f64>,
    /// `htd + k + dth`, the NoConcurrency floor / LPT key.
    seq_secs: Vec<f64>,
    /// Same predicate as `TaskSpec::dominance` (`htd + dth > k`), so the
    /// classes agree even when a degenerate profile yields NaN stage
    /// times (the comparison then defaults to `DominantKernel` on both
    /// paths).
    dominant_transfer: Vec<bool>,
    /// FNV of each row's `write_row_sig` encoding (prefilter for the
    /// full-key compares below).
    row_hash: Vec<u64>,
    /// All row signatures concatenated (`sig_off` delimits row `i` as
    /// `sig_buf[sig_off[i]..sig_off[i+1]]`), so twin classification does
    /// full-key compares without re-encoding.
    sig_buf: Vec<u64>,
    sig_off: Vec<u32>,
    /// Spec-twin equivalence classes: `twin_class[i]` is the lowest row
    /// index whose simulation-relevant encoding equals row `i`'s (proven
    /// by full-key compare — the hash is only a prefilter). A row is its
    /// own class representative iff `twin_class[i] == i`. Twin rows are
    /// interchangeable for the simulator, which the searches exploit to
    /// collapse candidates (serial twin collapse, parallel memo).
    twin_class: Vec<u32>,
    has_twins: bool,
    /// Group-aggregate solo stage sums and the smallest kernel+DtH tail,
    /// feeding the searches' seed-stage admissible floors without any
    /// per-call scan (partial prefixes re-scan their unplaced rows).
    total_htd: f64,
    total_k: f64,
    total_dth: f64,
    min_tail: f64,
}

impl TaskTable {
    /// Empty, detached table; [`TaskTable::compile_into`] before use.
    pub fn new() -> TaskTable {
        TaskTable::default()
    }

    /// Compile `tasks` against `profile` (allocating constructor).
    pub fn compile(tasks: &[TaskSpec], profile: &DeviceProfile) -> TaskTable {
        let mut t = TaskTable::new();
        t.compile_into(tasks, profile);
        t
    }

    /// Recompile in place, retaining every buffer's capacity: a warm table
    /// recompiled for a same-or-smaller group performs no heap allocation.
    pub fn compile_into(&mut self, tasks: &[TaskSpec], profile: &DeviceProfile) {
        self.compile_impl(tasks, profile, 1.0);
    }

    /// [`TaskTable::compile_into`] against a calibrated planning model
    /// (`model::calibrate`): link corrections are already baked into the
    /// effective profile, and kernel durations are additionally scaled by
    /// [`CalibratedProfile::kernel_scale`] (kernel estimates live per
    /// task, not in the profile, so the scale rides with the compile).
    /// With an identity calibration this is bit-identical to
    /// `compile_into(tasks, base)` — scaling by 1.0 is exact — which is
    /// what pins the recalibration-off pipeline to today's orders
    /// (rust/tests/prop_calibrate.rs). Calibrated tables must be
    /// simulated through [`SimCursor::push_task_compiled`] only: the
    /// `TaskSpec` push path knows nothing of the kernel scale.
    ///
    /// [`CalibratedProfile::kernel_scale`]: crate::model::calibrate::CalibratedProfile::kernel_scale
    /// [`SimCursor::push_task_compiled`]: crate::model::SimCursor::push_task_compiled
    pub fn compile_calibrated_into(
        &mut self,
        tasks: &[TaskSpec],
        cal: &crate::model::calibrate::CalibratedProfile,
    ) {
        self.compile_impl(tasks, cal.effective(), cal.kernel_scale());
    }

    fn compile_impl(
        &mut self,
        tasks: &[TaskSpec],
        profile: &DeviceProfile,
        kernel_scale: f64,
    ) {
        self.prof = ProfileParams::of(profile);
        self.htd_raw.clear();
        self.htd_off.clear();
        self.dth_raw.clear();
        self.dth_off.clear();
        self.kernel.clear();
        self.htd_secs.clear();
        self.dth_secs.clear();
        self.k_minus_htd.clear();
        self.seq_secs.clear();
        self.dominant_transfer.clear();
        self.htd_off.push(0);
        self.dth_off.push(0);
        self.total_htd = 0.0;
        self.total_k = 0.0;
        self.total_dth = 0.0;
        self.min_tail = 0.0;
        for task in tasks {
            self.htd_raw.extend_from_slice(&task.htd_bytes);
            self.htd_off.push(self.htd_raw.len() as u32);
            self.dth_raw.extend_from_slice(&task.dth_bytes);
            self.dth_off.push(self.dth_raw.len() as u32);
            // Same expressions as TaskSpec::{stage_secs, sequential_secs}
            // and SimCursor::push_task, so derived values are bit-equal.
            let htd: f64 =
                task.htd_bytes.iter().map(|&b| profile.htd.transfer_secs(b)).sum();
            let dth: f64 =
                task.dth_bytes.iter().map(|&b| profile.dth.transfer_secs(b)).sum();
            // kernel_scale is 1.0 on the uncalibrated path, and x * 1.0
            // is bitwise x — the calibrated compile shares this body
            // without perturbing the plain one.
            let k = (task.kernel.est_secs() + profile.kernel_launch_overhead)
                * kernel_scale;
            self.kernel.push(k);
            self.htd_secs.push(htd);
            self.dth_secs.push(dth);
            self.k_minus_htd.push(k - htd);
            self.seq_secs.push(htd + k + dth);
            self.dominant_transfer.push(htd + dth > k);
            self.total_htd += htd;
            self.total_k += k;
            self.total_dth += dth;
            let tail = k + dth;
            if self.kernel.len() == 1 || tail < self.min_tail {
                self.min_tail = tail;
            }
        }
        self.classify_rows();
    }

    /// Gather rows of `src` (in `rows` order) into `self`, producing a
    /// sub-table bit-identical to compiling the corresponding `TaskSpec`
    /// subset against the same profile: per-row derived values are copied
    /// bitwise (they were computed row-independently at `src`'s compile),
    /// the group aggregates re-accumulate in row order with the exact
    /// `compile_into` expressions, and twin classes are re-derived for
    /// the sub-group (class representatives are *local* row indices).
    /// Buffers are reused, so a warm gather allocates nothing — this is
    /// how `sched::fleet` reorders each device's placement list without
    /// re-resolving specs the per-device tables already hold.
    pub fn gather_into(&mut self, src: &TaskTable, rows: &[usize]) {
        self.prof = src.prof;
        self.htd_raw.clear();
        self.htd_off.clear();
        self.dth_raw.clear();
        self.dth_off.clear();
        self.kernel.clear();
        self.htd_secs.clear();
        self.dth_secs.clear();
        self.k_minus_htd.clear();
        self.seq_secs.clear();
        self.dominant_transfer.clear();
        self.htd_off.push(0);
        self.dth_off.push(0);
        self.total_htd = 0.0;
        self.total_k = 0.0;
        self.total_dth = 0.0;
        self.min_tail = 0.0;
        for &r in rows {
            self.htd_raw.extend_from_slice(src.htd_bytes(r));
            self.htd_off.push(self.htd_raw.len() as u32);
            self.dth_raw.extend_from_slice(src.dth_bytes(r));
            self.dth_off.push(self.dth_raw.len() as u32);
            let htd = src.htd_secs[r];
            let dth = src.dth_secs[r];
            let k = src.kernel[r];
            self.kernel.push(k);
            self.htd_secs.push(htd);
            self.dth_secs.push(dth);
            self.k_minus_htd.push(src.k_minus_htd[r]);
            self.seq_secs.push(src.seq_secs[r]);
            self.dominant_transfer.push(src.dominant_transfer[r]);
            self.total_htd += htd;
            self.total_k += k;
            self.total_dth += dth;
            let tail = k + dth;
            if self.kernel.len() == 1 || tail < self.min_tail {
                self.min_tail = tail;
            }
        }
        self.classify_rows();
    }

    /// Spec-twin classification pass shared by [`TaskTable::compile_into`]
    /// and [`TaskTable::gather_into`]: rows whose simulation-relevant
    /// encodings are byte-identical are interchangeable for the
    /// simulator; the searches collapse such candidates (one simulated
    /// representative per class per prefix) and the parallel
    /// transposition memo can only ever hit when a class has more than
    /// one member, so all-distinct groups skip key building entirely.
    /// Every class assignment is proven by full-key comparison — the
    /// FNV hash is only a prefilter.
    fn classify_rows(&mut self) {
        self.row_hash.clear();
        self.twin_class.clear();
        self.sig_off.clear();
        self.sig_off.push(0);
        self.has_twins = false;
        let mut buf = std::mem::take(&mut self.sig_buf);
        buf.clear();
        for i in 0..self.kernel.len() {
            let start = buf.len();
            self.write_row_sig(i, &mut buf);
            let len = buf.len() - start;
            let h = fnv64(&buf[start..]);
            let mut class = i as u32;
            for j in 0..i {
                if self.row_hash[j] != h {
                    continue;
                }
                let (js, je) =
                    (self.sig_off[j] as usize, self.sig_off[j + 1] as usize);
                if je - js == len && buf[js..je] == buf[start..start + len] {
                    class = self.twin_class[j];
                    self.has_twins = true;
                    break;
                }
            }
            self.row_hash.push(h);
            self.twin_class.push(class);
            self.sig_off.push(buf.len() as u32);
        }
        self.sig_buf = buf;
    }

    /// Whether any two rows share a simulation-relevant encoding (spec
    /// twins), i.e. any [`TaskTable::twin_class`] has more than one
    /// member. Gates the transposition memo in `sched::parallel`: with
    /// all-distinct rows no memo key can ever repeat, so building keys
    /// would be pure serialized overhead.
    pub(crate) fn has_spec_twins(&self) -> bool {
        self.has_twins
    }

    /// Spec-twin equivalence class of row `i`: the lowest row index whose
    /// simulation-relevant encoding is byte-identical to row `i`'s
    /// (full-key proven). Rows in one class are interchangeable for the
    /// simulator — pushing either produces bit-identical state.
    #[inline]
    pub(crate) fn twin_class(&self, i: usize) -> u32 {
        self.twin_class[i]
    }

    /// Group-aggregate solo HtD seconds (Σ [`TaskTable::htd_secs`]).
    #[inline]
    pub(crate) fn total_htd_secs(&self) -> f64 {
        self.total_htd
    }

    /// Group-aggregate kernel seconds (Σ [`TaskTable::kernel_secs`]).
    #[inline]
    pub(crate) fn total_kernel_secs(&self) -> f64 {
        self.total_k
    }

    /// Group-aggregate solo DtH seconds (Σ [`TaskTable::dth_secs`]).
    #[inline]
    pub(crate) fn total_dth_secs(&self) -> f64 {
        self.total_dth
    }

    /// Smallest kernel+DtH tail over all rows (0.0 for an empty table):
    /// whatever task ends up last in an order still owes at least this
    /// after its final HtD — the seed-stage chain floor's tail term.
    #[inline]
    pub(crate) fn min_kd_tail(&self) -> f64 {
        self.min_tail
    }

    /// Number of compiled tasks.
    pub fn len(&self) -> usize {
        self.kernel.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kernel.is_empty()
    }

    /// HtD command sizes of task `i` (contiguous slice).
    #[inline]
    pub fn htd_bytes(&self, i: usize) -> &[u64] {
        &self.htd_raw[self.htd_off[i] as usize..self.htd_off[i + 1] as usize]
    }

    /// DtH command sizes of task `i` (contiguous slice).
    #[inline]
    pub fn dth_bytes(&self, i: usize) -> &[u64] {
        &self.dth_raw[self.dth_off[i] as usize..self.dth_off[i + 1] as usize]
    }

    /// Kernel duration incl. launch overhead — exactly what the cursor runs.
    #[inline]
    pub fn kernel_secs(&self, i: usize) -> f64 {
        self.kernel[i]
    }

    /// Solo HtD stage seconds (== `stage_secs().htd`).
    #[inline]
    pub fn htd_secs(&self, i: usize) -> f64 {
        self.htd_secs[i]
    }

    /// Solo DtH stage seconds (== `stage_secs().dth`).
    #[inline]
    pub fn dth_secs(&self, i: usize) -> f64 {
        self.dth_secs[i]
    }

    /// Algorithm 1's select-first key: `k - htd`, precomputed.
    #[inline]
    pub fn k_minus_htd(&self, i: usize) -> f64 {
        self.k_minus_htd[i]
    }

    /// Sequential (zero-overlap) seconds (== `sequential_secs`).
    #[inline]
    pub fn sequential_secs(&self, i: usize) -> f64 {
        self.seq_secs[i]
    }

    /// Dominance class on the compiled device.
    #[inline]
    pub fn dominance(&self, i: usize) -> Dominance {
        if self.dominant_transfer[i] {
            Dominance::DominantTransfer
        } else {
            Dominance::DominantKernel
        }
    }

    /// Total commands across all tasks (HtD + K + DtH).
    pub fn total_commands(&self) -> usize {
        self.htd_raw.len() + self.dth_raw.len() + self.kernel.len()
    }

    /// Device constants this table was compiled against.
    pub(crate) fn params(&self) -> ProfileParams {
        self.prof
    }

    /// Append a canonical encoding of task `i`'s *simulation-relevant*
    /// content (command sizes + kernel duration; names excluded) to `out`.
    /// Two tasks with equal row signatures are interchangeable for the
    /// simulator — the transposition memo in `sched::parallel` keys
    /// rollout sequences on this.
    pub(crate) fn write_row_sig(&self, i: usize, out: &mut Vec<u64>) {
        let htd = self.htd_bytes(i);
        let dth = self.dth_bytes(i);
        out.push(((htd.len() as u64) << 32) | dth.len() as u64);
        out.extend_from_slice(htd);
        out.push(self.kernel[i].to_bits());
        out.extend_from_slice(dth);
    }
}

/// FNV-1a over u64 words — the prefilter hash for row/state signatures
/// (shared with the transposition memo in `sched::parallel`).
pub(crate) fn fnv64(words: &[u64]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &w in words {
        h ^= w;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;
    use crate::task::synthetic::synthetic_benchmark;
    use crate::task::KernelSpec;

    #[test]
    fn compiled_rows_match_spec_arithmetic() {
        for dev in ["amd_r9", "k20c", "xeon_phi"] {
            let p = profile_by_name(dev).unwrap();
            let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
            let t = TaskTable::compile(&g.tasks, &p);
            assert_eq!(t.len(), g.tasks.len());
            for (i, task) in g.tasks.iter().enumerate() {
                let s = task.stage_secs(&p);
                assert_eq!(t.htd_bytes(i), &task.htd_bytes[..]);
                assert_eq!(t.dth_bytes(i), &task.dth_bytes[..]);
                assert_eq!(t.kernel_secs(i), s.k);
                assert_eq!(t.htd_secs(i), s.htd);
                assert_eq!(t.dth_secs(i), s.dth);
                assert_eq!(t.k_minus_htd(i), s.k - s.htd);
                assert_eq!(t.sequential_secs(i), task.sequential_secs(&p));
                assert_eq!(t.dominance(i), task.dominance(&p));
            }
        }
    }

    #[test]
    fn recompile_reuses_and_resizes() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK25", &p, 1.0).unwrap();
        let mut t = TaskTable::compile(&g.tasks, &p);
        t.compile_into(&g.tasks[..2], &p);
        assert_eq!(t.len(), 2);
        assert_eq!(t.htd_bytes(1), &g.tasks[1].htd_bytes[..]);
        t.compile_into(&g.tasks, &p);
        assert_eq!(t.len(), 4);
        assert_eq!(t.dth_bytes(3), &g.tasks[3].dth_bytes[..]);
    }

    #[test]
    fn row_sig_distinguishes_specs_and_matches_duplicates() {
        let p = profile_by_name("k20c").unwrap();
        let a = TaskSpec::simple("a", 1000, KernelSpec::Timed { secs: 1e-3 }, 500);
        let b = TaskSpec::simple("b", 1000, KernelSpec::Timed { secs: 1e-3 }, 500);
        let c = TaskSpec::simple("c", 2000, KernelSpec::Timed { secs: 1e-3 }, 500);
        let t = TaskTable::compile(&[a, b, c], &p);
        let sig = |i: usize| {
            let mut v = Vec::new();
            t.write_row_sig(i, &mut v);
            v
        };
        assert_eq!(sig(0), sig(1), "identical specs, different names");
        assert_ne!(sig(0), sig(2));
        assert!(t.has_spec_twins());
        assert_eq!(t.twin_class(0), 0);
        assert_eq!(t.twin_class(1), 0, "twin maps to lowest class member");
        assert_eq!(t.twin_class(2), 2);
        let distinct = TaskTable::compile(
            &[
                TaskSpec::simple("a", 1000, KernelSpec::Timed { secs: 1e-3 }, 500),
                TaskSpec::simple("c", 2000, KernelSpec::Timed { secs: 1e-3 }, 500),
            ],
            &p,
        );
        assert!(!distinct.has_spec_twins());
        assert_eq!(distinct.twin_class(0), 0);
        assert_eq!(distinct.twin_class(1), 1);
    }

    #[test]
    fn twin_classes_chain_to_lowest_representative() {
        let p = profile_by_name("amd_r9").unwrap();
        let mk = |n| TaskSpec::simple(n, 1000, KernelSpec::Timed { secs: 1e-3 }, 500);
        let other =
            TaskSpec::simple("x", 7000, KernelSpec::Timed { secs: 2e-3 }, 100);
        let t = TaskTable::compile(&[mk("a"), other, mk("b"), mk("c")], &p);
        assert_eq!(t.twin_class(0), 0);
        assert_eq!(t.twin_class(1), 1);
        assert_eq!(t.twin_class(2), 0);
        assert_eq!(t.twin_class(3), 0, "chained twin resolves to the root");
    }

    #[test]
    fn aggregate_totals_sum_rows() {
        let p = profile_by_name("k20c").unwrap();
        let g = synthetic_benchmark("BK75", &p, 1.0).unwrap();
        let t = TaskTable::compile(&g.tasks, &p);
        let (mut htd, mut k, mut dth) = (0.0f64, 0.0f64, 0.0f64);
        let mut tail = f64::INFINITY;
        for i in 0..t.len() {
            htd += t.htd_secs(i);
            k += t.kernel_secs(i);
            dth += t.dth_secs(i);
            tail = tail.min(t.kernel_secs(i) + t.dth_secs(i));
        }
        assert_eq!(t.total_htd_secs(), htd);
        assert_eq!(t.total_kernel_secs(), k);
        assert_eq!(t.total_dth_secs(), dth);
        assert_eq!(t.min_kd_tail(), tail);
        assert_eq!(TaskTable::compile(&[], &p).min_kd_tail(), 0.0);
    }

    #[test]
    fn calibrated_compile_rescales_rows_identity_stays_bitwise() {
        use crate::model::calibrate::{CalibratedProfile, Corrections};
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
        let plain = TaskTable::compile(&g.tasks, &p);
        // Identity calibration: every derived row value is bitwise equal.
        let mut id = TaskTable::new();
        id.compile_calibrated_into(&g.tasks, &CalibratedProfile::identity(&p));
        for i in 0..plain.len() {
            assert_eq!(id.kernel_secs(i).to_bits(), plain.kernel_secs(i).to_bits());
            assert_eq!(id.htd_secs(i).to_bits(), plain.htd_secs(i).to_bits());
            assert_eq!(id.dth_secs(i).to_bits(), plain.dth_secs(i).to_bits());
            assert_eq!(id.k_minus_htd(i).to_bits(), plain.k_minus_htd(i).to_bits());
            assert_eq!(
                id.sequential_secs(i).to_bits(),
                plain.sequential_secs(i).to_bits()
            );
            assert_eq!(id.dominance(i), plain.dominance(i));
            assert_eq!(id.twin_class(i), plain.twin_class(i));
        }
        assert_eq!(id.min_kd_tail().to_bits(), plain.min_kd_tail().to_bits());
        // Skewed calibration: scaled engines re-derive, untouched ones
        // stay bitwise (dth scale 1.0).
        let cal =
            CalibratedProfile::new(&p, Corrections { htd: 2.0, k: 1.5, dth: 1.0 });
        let mut t = TaskTable::new();
        t.compile_calibrated_into(&g.tasks, &cal);
        for i in 0..plain.len() {
            let k = plain.kernel_secs(i);
            let h = plain.htd_secs(i);
            assert!((t.kernel_secs(i) - 1.5 * k).abs() <= 1e-12 * k.abs());
            assert!((t.htd_secs(i) - 2.0 * h).abs() <= 1e-12 * h.abs());
            assert_eq!(t.dth_secs(i).to_bits(), plain.dth_secs(i).to_bits());
        }
    }

    #[test]
    fn gather_matches_subset_compile_bitwise() {
        let p = profile_by_name("xeon_phi").unwrap();
        let g = synthetic_benchmark("BK75", &p, 1.0).unwrap();
        let full = TaskTable::compile(&g.tasks, &p);
        // A duplicated row so the sub-group has twins the full table's
        // classes can't express with local indices.
        let rows = [3usize, 1, 4, 1, 0];
        let subset: Vec<TaskSpec> =
            rows.iter().map(|&r| g.tasks[r].clone()).collect();
        let reference = TaskTable::compile(&subset, &p);
        let mut gathered = TaskTable::new();
        gathered.gather_into(&full, &rows);
        assert_eq!(gathered.len(), reference.len());
        for i in 0..reference.len() {
            assert_eq!(gathered.htd_bytes(i), reference.htd_bytes(i));
            assert_eq!(gathered.dth_bytes(i), reference.dth_bytes(i));
            assert_eq!(
                gathered.kernel_secs(i).to_bits(),
                reference.kernel_secs(i).to_bits()
            );
            assert_eq!(
                gathered.htd_secs(i).to_bits(),
                reference.htd_secs(i).to_bits()
            );
            assert_eq!(
                gathered.dth_secs(i).to_bits(),
                reference.dth_secs(i).to_bits()
            );
            assert_eq!(
                gathered.k_minus_htd(i).to_bits(),
                reference.k_minus_htd(i).to_bits()
            );
            assert_eq!(
                gathered.sequential_secs(i).to_bits(),
                reference.sequential_secs(i).to_bits()
            );
            assert_eq!(gathered.dominance(i), reference.dominance(i));
            assert_eq!(gathered.twin_class(i), reference.twin_class(i));
        }
        assert_eq!(gathered.has_spec_twins(), reference.has_spec_twins());
        assert!(gathered.has_spec_twins(), "row 1 was gathered twice");
        assert_eq!(
            gathered.total_htd_secs().to_bits(),
            reference.total_htd_secs().to_bits()
        );
        assert_eq!(
            gathered.total_kernel_secs().to_bits(),
            reference.total_kernel_secs().to_bits()
        );
        assert_eq!(
            gathered.total_dth_secs().to_bits(),
            reference.total_dth_secs().to_bits()
        );
        assert_eq!(
            gathered.min_kd_tail().to_bits(),
            reference.min_kd_tail().to_bits()
        );
        // Empty gather leaves a valid empty table.
        gathered.gather_into(&full, &[]);
        assert!(gathered.is_empty());
        assert_eq!(gathered.min_kd_tail(), 0.0);
    }

    #[test]
    fn empty_table() {
        let p = profile_by_name("amd_r9").unwrap();
        let t = TaskTable::compile(&[], &p);
        assert!(t.is_empty());
        assert_eq!(t.total_commands(), 0);
    }
}
