//! Per-command execution records (the paper's "time counter structures",
//! Fig. 5), ASCII Gantt rendering, and overlap/idleness metrics.

use std::fmt;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CmdKind {
    HtD,
    Kernel,
    DtH,
}

impl fmt::Display for CmdKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CmdKind::HtD => write!(f, "HtD"),
            CmdKind::Kernel => write!(f, "K"),
            CmdKind::DtH => write!(f, "DtH"),
        }
    }
}

/// One executed (or simulated) command occurrence.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CmdRecord {
    /// Index of the task within the submitted group (submission order).
    pub task: usize,
    pub kind: CmdKind,
    /// Command index within its stage (multi-command stages).
    pub seq: usize,
    pub start: f64,
    pub end: f64,
}

impl CmdRecord {
    pub fn dur(&self) -> f64 {
        self.end - self.start
    }
}

/// Aggregate view of a command timeline.
pub struct Timeline<'a>(pub &'a [CmdRecord]);

impl<'a> Timeline<'a> {
    pub fn makespan(&self) -> f64 {
        self.0.iter().map(|r| r.end).fold(0.0, f64::max)
    }

    /// Sum of command durations: the zero-overlap serial floor.
    pub fn busy_sum(&self) -> f64 {
        self.0.iter().map(CmdRecord::dur).sum()
    }

    /// Overlap win: serial floor minus makespan (>= 0 when any commands
    /// ran concurrently).
    pub fn overlap_gain(&self) -> f64 {
        self.busy_sum() - self.makespan()
    }

    /// Busy time of one command kind (per-engine utilization numerator).
    pub fn busy_of(&self, kind: CmdKind) -> f64 {
        self.0.iter().filter(|r| r.kind == kind).map(CmdRecord::dur).sum()
    }

    /// Render an ASCII Gantt: one row per (task, kind), `width` chars wide.
    pub fn gantt(&self, width: usize) -> String {
        let span = self.makespan();
        if span <= 0.0 || self.0.is_empty() {
            return String::from("(empty timeline)\n");
        }
        let ntasks = self.0.iter().map(|r| r.task).max().unwrap_or(0) + 1;
        let mut out = String::new();
        for task in 0..ntasks {
            for kind in [CmdKind::HtD, CmdKind::Kernel, CmdKind::DtH] {
                let recs: Vec<&CmdRecord> = self
                    .0
                    .iter()
                    .filter(|r| r.task == task && r.kind == kind)
                    .collect();
                if recs.is_empty() {
                    continue;
                }
                let mut row = vec![b' '; width];
                for r in &recs {
                    let a = ((r.start / span) * width as f64) as usize;
                    let b = (((r.end / span) * width as f64).ceil() as usize)
                        .min(width);
                    let ch = match kind {
                        CmdKind::HtD => b'>',
                        CmdKind::Kernel => b'#',
                        CmdKind::DtH => b'<',
                    };
                    for c in row.iter_mut().take(b).skip(a) {
                        *c = ch;
                    }
                }
                out.push_str(&format!(
                    "T{task:<2} {kind:<3} |{}|\n",
                    String::from_utf8(row).unwrap()
                ));
            }
        }
        out.push_str(&format!("makespan = {:.3} ms\n", span * 1e3));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(task: usize, kind: CmdKind, start: f64, end: f64) -> CmdRecord {
        CmdRecord { task, kind, seq: 0, start, end }
    }

    #[test]
    fn metrics() {
        let recs = vec![
            rec(0, CmdKind::HtD, 0.0, 1.0),
            rec(0, CmdKind::Kernel, 1.0, 3.0),
            rec(1, CmdKind::HtD, 1.0, 2.0), // overlaps task 0's kernel
            rec(0, CmdKind::DtH, 3.0, 4.0),
        ];
        let t = Timeline(&recs);
        assert_eq!(t.makespan(), 4.0);
        assert_eq!(t.busy_sum(), 5.0);
        assert_eq!(t.overlap_gain(), 1.0);
        assert_eq!(t.busy_of(CmdKind::HtD), 2.0);
    }

    #[test]
    fn gantt_renders_rows() {
        let recs = vec![
            rec(0, CmdKind::HtD, 0.0, 0.5),
            rec(0, CmdKind::Kernel, 0.5, 1.0),
        ];
        let g = Timeline(&recs).gantt(40);
        assert!(g.contains("T0  HtD"), "{g}");
        assert!(g.contains('#') && g.contains('>'), "{g}");
        assert!(g.contains("makespan"), "{g}");
    }

    #[test]
    fn empty_timeline() {
        assert!(Timeline(&[]).gantt(10).contains("empty"));
    }
}
