//! Bidirectional PCIe transfer-time models (paper §4.2.1, Fig. 6).
//!
//! Solo transfers follow the reduced LogGP form `t = latency + bytes/bw`
//! (van Werkhoven et al. [21]). For two transfers in *opposite* directions
//! whose executions overlap, three predictors are compared:
//!
//! * **NonOverlapped** — pretends the engines serialize: the second
//!   transfer only starts when the first ends. Accurate at 0% overlap,
//!   pessimistic elsewhere.
//! * **FullOverlap** — pretends both directions run at full bandwidth.
//!   Accurate at 0% and optimistic at high overlap on real buses, where
//!   duplex traffic contends for protocol/host-memory bandwidth.
//! * **PartialOverlap** (the paper's model) — while both directions are
//!   active each link runs at `bw / sigma` with a measured slowdown
//!   `sigma >= 1`; rates integrate piecewise. Accurate at any degree.

use crate::config::DeviceProfile;

/// Which bidirectional predictor to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverlapModel {
    NonOverlapped,
    FullOverlap,
    PartialOverlap,
}

/// Prediction for a HtD/DtH pair: completion times of both transfers,
/// measured from the start of the first (HtD) transfer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PairPrediction {
    /// HtD completion time (s).
    pub t_htd: f64,
    /// DtH completion time (s), absolute (includes its start offset).
    pub t_dth: f64,
}

impl PairPrediction {
    pub fn makespan(&self) -> f64 {
        self.t_htd.max(self.t_dth)
    }
}

/// Predict an HtD transfer of `htd_bytes` starting at t=0 and a DtH
/// transfer of `dth_bytes` starting at `dth_start >= 0`, on `profile`.
pub fn predict_pair(
    model: OverlapModel,
    profile: &DeviceProfile,
    htd_bytes: u64,
    dth_bytes: u64,
    dth_start: f64,
) -> PairPrediction {
    let solo_h = profile.htd.transfer_secs(htd_bytes);
    let solo_d = profile.dth.transfer_secs(dth_bytes);
    // One DMA engine cannot overlap at all: every model degenerates to
    // serialization on such devices.
    if profile.dma_engines < 2 {
        let dth_begin = dth_start.max(solo_h);
        return PairPrediction { t_htd: solo_h, t_dth: dth_begin + solo_d };
    }
    match model {
        OverlapModel::NonOverlapped => {
            let dth_begin = dth_start.max(solo_h);
            PairPrediction { t_htd: solo_h, t_dth: dth_begin + solo_d }
        }
        OverlapModel::FullOverlap => {
            PairPrediction { t_htd: solo_h, t_dth: dth_start + solo_d }
        }
        OverlapModel::PartialOverlap => {
            predict_partial(profile, htd_bytes, dth_bytes, dth_start)
        }
    }
}

/// Piecewise-rate integration of the partially overlapped pair.
fn predict_partial(
    profile: &DeviceProfile,
    htd_bytes: u64,
    dth_bytes: u64,
    dth_start: f64,
) -> PairPrediction {
    let sigma = profile.duplex_slowdown;
    let bw_h = profile.htd.bytes_per_sec;
    let bw_d = profile.dth.bytes_per_sec;

    // Phase 0: HtD alone until dth_start (latency first, then bytes).
    let mut h_lat = profile.htd.latency;
    let mut h_bytes = htd_bytes as f64;
    let mut t = 0.0;
    let solo_end_h;

    // Advance HtD alone to dth_start.
    let alone = dth_start - t;
    let (lat_used, bytes_time) = advance(h_lat, h_bytes, bw_h, alone);
    h_lat -= lat_used;
    h_bytes -= bytes_time * bw_h;
    t = dth_start;
    if h_lat <= 1e-15 && h_bytes <= 1e-9 {
        // HtD finished before DtH began: no overlap at all.
        solo_end_h = profile.htd.transfer_secs(htd_bytes);
        return PairPrediction {
            t_htd: solo_end_h,
            t_dth: dth_start + profile.dth.transfer_secs(dth_bytes),
        };
    }

    // Phase 1: both active; each at bw/sigma (latency burns in real time).
    let mut d_lat = profile.dth.latency;
    let mut d_bytes = dth_bytes as f64;
    let rem_h = h_lat + h_bytes / (bw_h / sigma);
    let rem_d = d_lat + d_bytes / (bw_d / sigma);
    if rem_h <= rem_d {
        // HtD ends first; DtH continues at full rate.
        let t_htd = t + rem_h;
        let (lu, bt) = advance(d_lat, d_bytes, bw_d / sigma, rem_h);
        d_lat -= lu;
        d_bytes -= bt * (bw_d / sigma);
        let t_dth = t_htd + d_lat + d_bytes / bw_d;
        PairPrediction { t_htd, t_dth }
    } else {
        let t_dth = t + rem_d;
        let (lu, bt) = advance(h_lat, h_bytes, bw_h / sigma, rem_d);
        h_lat -= lu;
        h_bytes -= bt * (bw_h / sigma);
        let t_htd = t_dth + h_lat + h_bytes / bw_h;
        PairPrediction { t_htd, t_dth }
    }
}

/// Burn `dt` seconds of a (latency, bytes@rate) transfer; returns
/// (latency consumed, seconds spent moving bytes).
fn advance(lat: f64, bytes: f64, rate: f64, dt: f64) -> (f64, f64) {
    if dt <= 0.0 {
        return (0.0, 0.0);
    }
    if dt <= lat {
        return (dt, 0.0);
    }
    let bytes_time = (dt - lat).min(bytes / rate);
    (lat, bytes_time)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;

    fn r9() -> DeviceProfile {
        profile_by_name("amd_r9").unwrap()
    }

    #[test]
    fn all_models_agree_at_zero_overlap() {
        let p = r9();
        let b = 32 * 1024 * 1024;
        let solo_h = p.htd.transfer_secs(b);
        for m in [
            OverlapModel::NonOverlapped,
            OverlapModel::FullOverlap,
            OverlapModel::PartialOverlap,
        ] {
            // DtH starts exactly when HtD finishes: no overlap.
            let pred = predict_pair(m, &p, b, b, solo_h);
            assert!((pred.t_htd - solo_h).abs() < 1e-9, "{m:?}");
            assert!(
                (pred.t_dth - (solo_h + p.dth.transfer_secs(b))).abs() < 1e-9,
                "{m:?}"
            );
        }
    }

    #[test]
    fn partial_sits_between_extremes() {
        let p = r9();
        let b = 64 * 1024 * 1024;
        for frac in [0.0, 0.25, 0.5, 0.75] {
            let start = frac * p.htd.transfer_secs(b);
            let non = predict_pair(OverlapModel::NonOverlapped, &p, b, b, start);
            let full = predict_pair(OverlapModel::FullOverlap, &p, b, b, start);
            let ours = predict_pair(OverlapModel::PartialOverlap, &p, b, b, start);
            assert!(
                ours.makespan() <= non.makespan() + 1e-9,
                "frac={frac}: ours {} vs non {}",
                ours.makespan(),
                non.makespan()
            );
            assert!(
                ours.makespan() >= full.makespan() - 1e-9,
                "frac={frac}: ours {} vs full {}",
                ours.makespan(),
                full.makespan()
            );
        }
    }

    #[test]
    fn partial_full_overlap_slowdown() {
        // Simultaneous start, equal sizes, near-symmetric links: both see
        // ~sigma slowdown while overlapped.
        let mut p = r9();
        p.htd.bytes_per_sec = 6e9;
        p.dth.bytes_per_sec = 6e9;
        p.htd.latency = 0.0;
        p.dth.latency = 0.0;
        let b = 60_000_000; // 10 ms solo
        let ours = predict_pair(OverlapModel::PartialOverlap, &p, b, b, 0.0);
        let solo = 0.01;
        assert!((ours.t_htd - solo * p.duplex_slowdown).abs() < 1e-4);
        assert!((ours.t_dth - solo * p.duplex_slowdown).abs() < 1e-4);
    }

    #[test]
    fn single_dma_always_serializes() {
        let p = profile_by_name("xeon_phi").unwrap();
        let b = 16 * 1024 * 1024;
        let pred = predict_pair(OverlapModel::FullOverlap, &p, b, b, 0.0);
        let solo_h = p.htd.transfer_secs(b);
        assert!((pred.t_dth - (solo_h + p.dth.transfer_secs(b))).abs() < 1e-9);
    }

    #[test]
    fn first_finisher_frees_bandwidth() {
        let p = r9();
        // Small DtH overlapping a large HtD: after DtH ends, HtD should run
        // at full speed again -> total < fully-contended estimate.
        let big = 128 * 1024 * 1024;
        let small = 8 * 1024 * 1024;
        let ours = predict_pair(OverlapModel::PartialOverlap, &p, big, small, 0.0);
        let fully_contended =
            p.htd.latency + big as f64 / (p.htd.bytes_per_sec / p.duplex_slowdown);
        assert!(ours.t_htd < fully_contended);
        assert!(ours.t_htd > p.htd.transfer_secs(big));
    }
}
