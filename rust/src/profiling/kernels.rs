//! Eq. 1 calibration on the live PJRT runtime: time each artifact variant,
//! fit `T = eta * m + gamma` per kernel family, and hand back per-variant
//! duration estimates for the scheduler's model (the paper keeps exactly
//! these two parameters per kernel from an offline run).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::model::kernel::LinearKernelModel;
use crate::runtime::engine::PjrtRuntime;
use crate::util::stats;

/// Calibration output.
#[derive(Clone, Debug, Default)]
pub struct KernelCalibration {
    /// Per-family linear model over htd_bytes as the size proxy.
    pub models: BTreeMap<String, LinearKernelModel>,
    /// Median measured seconds per variant.
    pub variant_secs: BTreeMap<String, f64>,
}

impl KernelCalibration {
    /// Model-estimated seconds for a variant (fall back to measurement).
    pub fn estimate(&self, runtime: &PjrtRuntime, variant: &str) -> Option<f64> {
        if let Some(&t) = self.variant_secs.get(variant) {
            return Some(t);
        }
        let meta = runtime.manifest().get(variant).ok()?;
        self.models.get(&meta.kernel).map(|m| m.predict(meta.htd_bytes as f64))
    }
}

/// Time every variant `reps` times (after one warmup) and fit per-family
/// linear models.
pub fn calibrate_kernels(runtime: &PjrtRuntime, reps: usize) -> Result<KernelCalibration> {
    let mut cal = KernelCalibration::default();
    let names: Vec<String> =
        runtime.manifest().variants.keys().cloned().collect();
    for name in &names {
        runtime.warmup(name)?;
        let mut samples = Vec::with_capacity(reps);
        for _ in 0..reps.max(1) {
            samples.push(runtime.execute(name)?.exec_secs);
        }
        cal.variant_secs.insert(name.clone(), stats::median(&samples));
    }
    // Per-family fits over (htd_bytes, time).
    let families: std::collections::BTreeSet<String> = runtime
        .manifest()
        .variants
        .values()
        .map(|v| v.kernel.clone())
        .collect();
    for fam in families {
        let pts: Vec<(f64, f64)> = runtime
            .manifest()
            .family(&fam)
            .iter()
            .map(|v| (v.htd_bytes as f64, cal.variant_secs[&v.name]))
            .collect();
        if pts.len() >= 2 {
            let xs: Vec<f64> = pts.iter().map(|p| p.0).collect();
            let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
            cal.models.insert(fam, LinearKernelModel::fit(&xs, &ys));
        }
    }
    Ok(cal)
}

#[cfg(test)]
mod tests {
    // PJRT-dependent tests live in rust/tests/integration_runtime.rs.
    use super::*;

    #[test]
    fn estimate_prefers_measurement() {
        let mut cal = KernelCalibration::default();
        cal.variant_secs.insert("mm_256".into(), 1.5e-3);
        cal.models.insert(
            "matmul".into(),
            LinearKernelModel::new(1e-9, 1e-4),
        );
        // No runtime needed when the variant was measured directly.
        assert_eq!(cal.variant_secs.get("mm_256"), Some(&1.5e-3));
    }
}
