//! Link calibration: replays the paper's PCIe micro-benchmark against the
//! virtual device bus and fits the reduced LogGP parameters. In a real
//! deployment this would run against actual hardware once; here it closes
//! the loop model -> device -> measured constants -> model.

use std::sync::Arc;

use crate::config::{DeviceProfile, LinkParams};
use crate::device::bus::Bus;
use crate::util::stats;

/// Measured link constants.
#[derive(Clone, Copy, Debug)]
pub struct LinkCalibration {
    pub htd: LinkParams,
    pub dth: LinkParams,
    /// Measured duplex slowdown sigma (1.0 on single-DMA devices).
    pub duplex_slowdown: f64,
}

/// Calibrate by timing solo transfers over `sizes` bytes in each
/// direction, then a fully overlapped pair to extract sigma.
pub fn calibrate_link(profile: &DeviceProfile, sizes: &[u64]) -> LinkCalibration {
    assert!(sizes.len() >= 2, "need >= 2 sizes to fit a line");
    let bus = Bus::new(Arc::new(profile.clone()));

    let fit_dir = |htd: bool| -> LinkParams {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for &bytes in sizes {
            let t0 = std::time::Instant::now();
            let _g = bus.begin_transfer(htd);
            bus.pace(htd, bytes);
            drop(_g);
            xs.push(bytes as f64);
            ys.push(t0.elapsed().as_secs_f64());
        }
        let (g_slope, latency) = stats::linfit(&xs, &ys);
        LinkParams {
            latency: latency.max(0.0),
            bytes_per_sec: 1.0 / g_slope.max(1e-18),
        }
    };
    let htd = fit_dir(true);
    let dth = fit_dir(false);

    // Duplex: run equal-size transfers in both directions simultaneously.
    let duplex_slowdown = if profile.dma_engines < 2 {
        1.0
    } else {
        let bytes = *sizes.last().unwrap();
        let solo = htd.transfer_secs(bytes);
        let bus2 = bus.clone();
        let other = std::thread::spawn(move || {
            let _g = bus2.begin_transfer(false);
            bus2.pace(false, bytes);
        });
        let t0 = std::time::Instant::now();
        let _g = bus.begin_transfer(true);
        bus.pace(true, bytes);
        drop(_g);
        let overlapped = t0.elapsed().as_secs_f64();
        other.join().unwrap();
        (overlapped / solo).max(1.0)
    };

    LinkCalibration { htd, dth, duplex_slowdown }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;

    #[test]
    fn recovers_profile_constants() {
        let _t = crate::util::timing::timing_test_lock();
        let p = profile_by_name("cpu_live").unwrap();
        // Sizes chosen so transfers are 0.5-2 ms: fast test, good fit.
        let sizes: Vec<u64> =
            vec![4_000_000, 8_000_000, 12_000_000, 16_000_000];
        let cal = calibrate_link(&p, &sizes);
        let bw_err = (cal.htd.bytes_per_sec - p.htd.bytes_per_sec).abs()
            / p.htd.bytes_per_sec;
        assert!(bw_err < 0.10, "bw err {bw_err}");
        assert!(cal.htd.latency < 200e-6, "latency {}", cal.htd.latency);
    }

    #[test]
    fn duplex_sigma_close_to_profile() {
        let _t = crate::util::timing::timing_test_lock();
        let p = profile_by_name("amd_r9").unwrap();
        let sizes: Vec<u64> = vec![6_000_000, 12_000_000];
        let cal = calibrate_link(&p, &sizes);
        assert!(
            (cal.duplex_slowdown - p.duplex_slowdown).abs() < 0.15,
            "sigma {} vs {}",
            cal.duplex_slowdown,
            p.duplex_slowdown
        );
    }

    #[test]
    fn single_dma_sigma_is_one() {
        let p = profile_by_name("xeon_phi").unwrap();
        let cal = calibrate_link(&p, &[2_000_000, 4_000_000]);
        assert_eq!(cal.duplex_slowdown, 1.0);
    }
}
