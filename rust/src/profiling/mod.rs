//! Offline calibration (paper §4.2): measures the constants the temporal
//! model consumes, the way the paper runs micro-benchmarks on each device.
//!
//! * [`loggp`] — transfer-link calibration: solo latency/bandwidth per
//!   direction (LogGP reduced form) and the duplex slowdown sigma.
//! * [`kernels`] — Eq. 1 calibration: measures artifact execution times on
//!   the PJRT runtime across each family's size variants and fits
//!   `T = eta * m + gamma`.

pub mod kernels;
pub mod loggp;

pub use kernels::{calibrate_kernels, KernelCalibration};
pub use loggp::{calibrate_link, LinkCalibration};
