//! The command vocabulary the host proxy submits to the virtual device.

use crate::queue::event::Event;
use crate::task::KernelSpec;

/// Which software command queue a command is enqueued on (paper §3.2:
/// OpenCL associates even/odd CQs with different DMA engines; we keep the
/// same three-queue layout for 2-DMA devices and two queues for 1-DMA).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QueueId {
    /// Transfers HtD (2-DMA) or *all* transfers (1-DMA).
    Transfer0,
    /// Transfers DtH (2-DMA only).
    Transfer1,
    /// Kernel execution queue.
    Compute,
}

#[derive(Clone, Debug)]
pub enum CommandKind {
    HtD { bytes: u64 },
    Kernel { spec: KernelSpec },
    DtH { bytes: u64 },
}

impl CommandKind {
    pub fn is_transfer(&self) -> bool {
        !matches!(self, CommandKind::Kernel { .. })
    }
}

/// One submitted command: payload + dependency events + completion event.
#[derive(Clone, Debug)]
pub struct Command {
    /// Task index within the submitted group (for records/metrics).
    pub task: usize,
    /// Command index within its stage.
    pub seq: usize,
    pub kind: CommandKind,
    /// Events that must be complete before this command may start
    /// (intra-task green arrows; the 1-DMA red arrow is enforced by queue
    /// ordering, not an event, exactly as in Fig. 2).
    pub waits: Vec<Event>,
    /// Event this command completes when it finishes.
    pub completion: Event,
}

impl Command {
    pub fn new(task: usize, seq: usize, kind: CommandKind, waits: Vec<Event>) -> Self {
        Command { task, seq, kind, waits, completion: Event::new() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_classification() {
        assert!(CommandKind::HtD { bytes: 4 }.is_transfer());
        assert!(CommandKind::DtH { bytes: 4 }.is_transfer());
        assert!(!CommandKind::Kernel {
            spec: KernelSpec::Timed { secs: 1e-3 }
        }
        .is_transfer());
    }

    #[test]
    fn command_carries_events() {
        let dep = Event::new();
        let c = Command::new(
            2,
            0,
            CommandKind::HtD { bytes: 128 },
            vec![dep.clone()],
        );
        assert_eq!(c.task, 2);
        assert!(!c.completion.is_complete());
        dep.complete(0.0);
        assert!(c.waits[0].is_complete());
    }
}
