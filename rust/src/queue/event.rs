//! OpenCL-style events: one-shot completion flags with blocking waiters.
//!
//! The host proxy associates an event with each submitted command; later
//! commands in *other* queues list events as wait conditions, reproducing
//! the red/green dependency arrows of Figs. 2-4.
//!
//! Every lock below recovers from poisoning (`PoisonError::into_inner`):
//! the guarded `Option<f64>` is written in one assignment, so a holder
//! that panics for unrelated reasons never leaves it mid-mutation, and a
//! worker parked in `wait` must still be woken by whichever thread
//! completes the event during panic unwinding — the recovery layer's
//! liveness guarantee.

use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

#[derive(Clone, Debug, Default)]
pub struct Event {
    inner: Arc<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    done: Mutex<Option<f64>>, // completion timestamp (secs since epoch t0)
    cv: Condvar,
}

impl Event {
    pub fn new() -> Self {
        Event::default()
    }

    /// Signal completion at `timestamp` (seconds on the device clock).
    /// Signalling twice is a bug in the caller.
    pub fn complete(&self, timestamp: f64) {
        let mut g =
            self.inner.done.lock().unwrap_or_else(PoisonError::into_inner);
        assert!(g.is_none(), "event completed twice");
        *g = Some(timestamp);
        self.inner.cv.notify_all();
    }

    pub fn is_complete(&self) -> bool {
        self.inner
            .done
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .is_some()
    }

    /// Completion timestamp if signalled.
    pub fn timestamp(&self) -> Option<f64> {
        *self.inner.done.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Block until completion; returns the completion timestamp.
    pub fn wait(&self) -> f64 {
        let mut g =
            self.inner.done.lock().unwrap_or_else(PoisonError::into_inner);
        while g.is_none() {
            g = self
                .inner
                .cv
                .wait(g)
                .unwrap_or_else(PoisonError::into_inner);
        }
        g.unwrap()
    }

    /// Block with a timeout; None on timeout.
    pub fn wait_timeout(&self, d: Duration) -> Option<f64> {
        let deadline = Instant::now() + d;
        let mut g =
            self.inner.done.lock().unwrap_or_else(PoisonError::into_inner);
        while g.is_none() {
            let left = deadline.checked_duration_since(Instant::now())?;
            let (ng, res) = self
                .inner
                .cv
                .wait_timeout(g, left)
                .unwrap_or_else(PoisonError::into_inner);
            g = ng;
            if res.timed_out() && g.is_none() {
                return None;
            }
        }
        *g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn signal_and_wait() {
        let e = Event::new();
        assert!(!e.is_complete());
        let e2 = e.clone();
        let h = thread::spawn(move || e2.wait());
        thread::sleep(Duration::from_millis(5));
        e.complete(1.25);
        assert_eq!(h.join().unwrap(), 1.25);
        assert_eq!(e.timestamp(), Some(1.25));
    }

    #[test]
    fn wait_timeout_expires() {
        let e = Event::new();
        assert_eq!(e.wait_timeout(Duration::from_millis(10)), None);
        e.complete(0.5);
        assert_eq!(e.wait_timeout(Duration::from_millis(10)), Some(0.5));
    }

    #[test]
    #[should_panic(expected = "completed twice")]
    fn double_complete_panics() {
        let e = Event::new();
        e.complete(0.0);
        e.complete(1.0);
    }

    #[test]
    fn poisoned_event_stays_live() {
        // A thread panics while holding the event mutex (the Option is
        // never mid-mutation, so poisoning carries no information). A
        // waiter blocked across the poisoning must still complete — this
        // is the liveness regression test for the poison-recovery sweep.
        let e = Event::new();
        let e2 = e.clone();
        let poisoner = thread::spawn(move || {
            let _g = e2.inner.done.lock().unwrap();
            panic!("poison the event lock");
        })
        .join();
        assert!(poisoner.is_err(), "the poisoning thread must have panicked");
        assert!(!e.is_complete(), "recovered read of the untouched state");
        let e3 = e.clone();
        let waiter = thread::spawn(move || e3.wait());
        e.complete(2.5);
        assert_eq!(waiter.join().unwrap(), 2.5);
        assert_eq!(e.timestamp(), Some(2.5));
        assert_eq!(e.wait_timeout(Duration::from_millis(1)), Some(2.5));
    }
}
