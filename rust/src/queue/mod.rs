//! OpenCL-style software command queues and events (paper §3).
//!
//! * [`event::Event`] — one-shot completion objects commands signal and
//!   other commands wait on (the paper's intra-task dependencies).
//! * [`command`] — the command vocabulary submitted to the device.
//! * [`submit`] — the two §3.2 submission schemes mapping a task group
//!   onto command queues: grouped-by-type (1 DMA engine, Fig. 2) and
//!   grouped-by-task (2 DMA engines, Fig. 3).

pub mod command;
pub mod event;
pub mod submit;

pub use command::{Command, CommandKind, QueueId};
pub use event::Event;
pub use submit::{submission_plan, SubmissionPlan};
