//! The §3.2 submission schemes: mapping an *ordered* task group onto
//! command queues with the right dependency events.
//!
//! * **Grouped-by-type** (devices with 1 DMA engine, Fig. 2): two queues.
//!   All HtD commands (task order) then all DtH commands go to the single
//!   transfer queue — the HtD-before-DtH "red arrow" is queue order, not
//!   an event. Kernels go to the compute queue with events enforcing
//!   K_i-after-HtD_i; DtH_i waits on K_i.
//! * **Grouped-by-task** (2 DMA engines, Fig. 3): three queues. HtD on
//!   Transfer0, DtH on Transfer1, kernels on Compute; commands submitted
//!   task by task, maximizing the window where both engines run.

use crate::config::DeviceProfile;
use crate::queue::command::{Command, CommandKind, QueueId};
use crate::queue::event::Event;
use crate::task::TaskSpec;

/// Commands per queue, in submission order.
#[derive(Debug, Default)]
pub struct SubmissionPlan {
    pub transfer0: Vec<Command>,
    pub transfer1: Vec<Command>,
    pub compute: Vec<Command>,
}

impl SubmissionPlan {
    pub fn queue(&self, id: QueueId) -> &[Command] {
        match id {
            QueueId::Transfer0 => &self.transfer0,
            QueueId::Transfer1 => &self.transfer1,
            QueueId::Compute => &self.compute,
        }
    }

    pub fn total_commands(&self) -> usize {
        self.transfer0.len() + self.transfer1.len() + self.compute.len()
    }

    /// Completion events of the last command of each task (task-done).
    pub fn task_done_events(&self, n_tasks: usize) -> Vec<Event> {
        let mut out: Vec<Option<(usize, Event)>> = vec![None; n_tasks];
        // The last command of a task is its final DtH, or its kernel when
        // the DtH stage is empty. Scan all queues; keep the "largest" rank.
        let rank = |c: &Command| match c.kind {
            CommandKind::HtD { .. } => 0usize,
            CommandKind::Kernel { .. } => 1,
            CommandKind::DtH { .. } => 2,
        };
        for q in [&self.transfer0, &self.transfer1, &self.compute] {
            for c in q.iter() {
                let r = rank(c) * 1000 + c.seq;
                match &out[c.task] {
                    Some((prev, _)) if *prev >= r => {}
                    _ => out[c.task] = Some((r, c.completion.clone())),
                }
            }
        }
        out.into_iter().map(|o| o.expect("task with no commands").1).collect()
    }
}

/// Build the submission plan for `tasks` (already in the desired order)
/// on `profile`, including all dependency events.
pub fn submission_plan(tasks: &[TaskSpec], profile: &DeviceProfile) -> SubmissionPlan {
    if profile.dma_engines < 2 {
        grouped_by_type(tasks)
    } else {
        grouped_by_task(tasks)
    }
}

/// Fig. 2: 1-DMA scheme (two queues, commands grouped by type).
fn grouped_by_type(tasks: &[TaskSpec]) -> SubmissionPlan {
    let mut plan = SubmissionPlan::default();
    let mut last_htd: Vec<Vec<Event>> = vec![Vec::new(); tasks.len()];
    // 1) All HtD commands, task order.
    for (t, task) in tasks.iter().enumerate() {
        for (j, &bytes) in task.htd_bytes.iter().enumerate() {
            let c = Command::new(t, j, CommandKind::HtD { bytes }, vec![]);
            last_htd[t].push(c.completion.clone());
            plan.transfer0.push(c);
        }
    }
    // 2) Kernels, task order, each waiting on its own HtD completions.
    let mut k_events: Vec<Event> = Vec::with_capacity(tasks.len());
    for (t, task) in tasks.iter().enumerate() {
        let c = Command::new(
            t,
            0,
            CommandKind::Kernel { spec: task.kernel.clone() },
            last_htd[t].clone(),
        );
        k_events.push(c.completion.clone());
        plan.compute.push(c);
    }
    // 3) All DtH commands, task order, after every HtD (queue order) and
    //    each after its kernel (event).
    for (t, task) in tasks.iter().enumerate() {
        for (j, &bytes) in task.dth_bytes.iter().enumerate() {
            let c = Command::new(
                t,
                j,
                CommandKind::DtH { bytes },
                vec![k_events[t].clone()],
            );
            plan.transfer0.push(c);
        }
    }
    plan
}

/// Fig. 3: 2-DMA scheme (three queues, commands grouped by task).
fn grouped_by_task(tasks: &[TaskSpec]) -> SubmissionPlan {
    let mut plan = SubmissionPlan::default();
    for (t, task) in tasks.iter().enumerate() {
        let mut htd_events = Vec::new();
        for (j, &bytes) in task.htd_bytes.iter().enumerate() {
            let c = Command::new(t, j, CommandKind::HtD { bytes }, vec![]);
            htd_events.push(c.completion.clone());
            plan.transfer0.push(c);
        }
        let k = Command::new(
            t,
            0,
            CommandKind::Kernel { spec: task.kernel.clone() },
            htd_events,
        );
        let k_event = k.completion.clone();
        plan.compute.push(k);
        for (j, &bytes) in task.dth_bytes.iter().enumerate() {
            let c = Command::new(
                t,
                j,
                CommandKind::DtH { bytes },
                vec![k_event.clone()],
            );
            plan.transfer1.push(c);
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;
    use crate::task::synthetic::synthetic_benchmark;

    #[test]
    fn one_dma_uses_two_queues_grouped_by_type() {
        let p = profile_by_name("xeon_phi").unwrap();
        let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
        let plan = submission_plan(&g.tasks, &p);
        assert!(plan.transfer1.is_empty());
        assert_eq!(plan.compute.len(), 4);
        assert_eq!(plan.transfer0.len(), 8); // 4 HtD + 4 DtH
        // First 4 are HtD in task order, last 4 DtH in task order.
        for (i, c) in plan.transfer0.iter().take(4).enumerate() {
            assert!(matches!(c.kind, CommandKind::HtD { .. }));
            assert_eq!(c.task, i);
        }
        for (i, c) in plan.transfer0.iter().skip(4).enumerate() {
            assert!(matches!(c.kind, CommandKind::DtH { .. }));
            assert_eq!(c.task, i);
            assert_eq!(c.waits.len(), 1); // waits on its kernel
        }
    }

    #[test]
    fn two_dma_uses_three_queues_grouped_by_task() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
        let plan = submission_plan(&g.tasks, &p);
        assert_eq!(plan.transfer0.len(), 4);
        assert_eq!(plan.transfer1.len(), 4);
        assert_eq!(plan.compute.len(), 4);
        // DtH_i waits on K_i: completing K_0's event readies DtH_0 only.
        let k0 = &plan.compute[0];
        k0.completion.complete(0.0);
        assert!(plan.transfer1[0].waits.iter().all(|e| e.is_complete()));
        assert!(!plan.transfer1[1].waits.iter().all(|e| e.is_complete()));
    }

    #[test]
    fn kernel_waits_on_all_its_htd_commands() {
        let p = profile_by_name("amd_r9").unwrap();
        let mut g = synthetic_benchmark("BK25", &p, 1.0).unwrap();
        // Split task 0's HtD into two commands.
        let half = g.tasks[0].htd_bytes[0] / 2;
        g.tasks[0].htd_bytes = vec![half, half];
        let plan = submission_plan(&g.tasks, &p);
        assert_eq!(plan.compute[0].waits.len(), 2);
    }

    #[test]
    fn task_done_events_map_to_last_command() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK0", &p, 1.0).unwrap();
        let plan = submission_plan(&g.tasks, &p);
        let done = plan.task_done_events(4);
        // Completing task 2's DtH completes exactly done[2].
        plan.transfer1[2].completion.complete(7.0);
        assert_eq!(done[2].timestamp(), Some(7.0));
        assert!(done[0].timestamp().is_none());
    }
}
