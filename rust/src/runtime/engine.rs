//! Compile-once / execute-many PJRT registry.
//!
//! HLO *text* is the interchange format: `HloModuleProto::from_text_file`
//! re-parses and re-assigns instruction ids, sidestepping the 64-bit-id
//! protos jax >= 0.5 emits that xla_extension 0.5.1 rejects (see
//! /opt/xla-example/README.md and python/compile/aot.py).
//!
//! The live implementation needs the `xla` crate, which is not vendored
//! in this offline workspace; it is gated behind the `pjrt` cargo feature
//! (see rust/Cargo.toml). The default build ships a stub whose
//! constructor fails with an actionable message, so every other layer
//! (model, scheduler, virtual device, coordinator) builds and runs
//! without PJRT.

use anyhow::Result;

/// One timed execution.
#[derive(Clone, Copy, Debug)]
pub struct ExecStats {
    /// Wall time of the on-device execution (excludes input build).
    pub exec_secs: f64,
    /// Number of output buffers produced.
    pub n_outputs: usize,
}

#[cfg(feature = "pjrt")]
mod live {
    use std::collections::HashMap;
    use std::path::Path;
    use std::sync::Mutex;
    use std::time::Instant;

    use anyhow::{Context, Result};

    use super::ExecStats;
    use crate::runtime::manifest::{Manifest, VariantMeta};
    use crate::util::rng::Pcg64;

    struct Compiled {
        exe: xla::PjRtLoadedExecutable,
        /// Deterministic input literals, built once (host-side "pinned
        /// buffers"; input creation is the HtD analogue which the virtual
        /// device paces separately).
        inputs: Vec<xla::Literal>,
    }

    /// Thread-safe artifact registry bound to one PJRT CPU client.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        manifest: Manifest,
        cache: Mutex<HashMap<String, std::sync::Arc<Compiled>>>,
    }

    impl PjrtRuntime {
        /// Create a CPU-client runtime over an artifact directory.
        pub fn new(artifact_dir: &Path) -> Result<PjrtRuntime> {
            let manifest = Manifest::load(artifact_dir)?;
            let client =
                xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(PjrtRuntime { client, manifest, cache: Mutex::new(HashMap::new()) })
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        fn compiled(&self, variant: &str) -> Result<std::sync::Arc<Compiled>> {
            if let Some(c) = self.cache.lock().unwrap().get(variant) {
                return Ok(c.clone());
            }
            let meta = self.manifest.get(variant)?.clone();
            let path = self.manifest.hlo_path(variant)?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {variant}"))?;
            let inputs = build_inputs(&meta)?;
            let arc = std::sync::Arc::new(Compiled { exe, inputs });
            self.cache.lock().unwrap().insert(variant.to_string(), arc.clone());
            Ok(arc)
        }

        /// Pre-compile a variant (hot-path warmup).
        pub fn warmup(&self, variant: &str) -> Result<()> {
            self.compiled(variant).map(|_| ())
        }

        /// Execute a variant with its cached deterministic inputs; returns
        /// wall time and output count. The outputs are fetched to host
        /// literals to close the full execute-and-read path.
        pub fn execute(&self, variant: &str) -> Result<ExecStats> {
            let c = self.compiled(variant)?;
            let t0 = Instant::now();
            let result = c.exe.execute::<xla::Literal>(&c.inputs)?[0][0]
                .to_literal_sync()?;
            let outs = result.to_tuple()?;
            let exec_secs = t0.elapsed().as_secs_f64();
            anyhow::ensure!(
                outs.len() == self.manifest.get(variant)?.outputs.len(),
                "variant {variant}: expected {} outputs, got {}",
                self.manifest.get(variant)?.outputs.len(),
                outs.len()
            );
            Ok(ExecStats { exec_secs, n_outputs: outs.len() })
        }

        /// Execute and return the first output as f32s (tests/examples).
        pub fn execute_collect(&self, variant: &str) -> Result<Vec<f32>> {
            let c = self.compiled(variant)?;
            let result = c.exe.execute::<xla::Literal>(&c.inputs)?[0][0]
                .to_literal_sync()?;
            let outs = result.to_tuple()?;
            anyhow::ensure!(!outs.is_empty(), "no outputs");
            Ok(outs[0].to_vec::<f32>()?)
        }
    }

    /// Deterministic, numerically safe inputs matching the manifest shapes
    /// (uniform in [0.5, 1.5], seeded per buffer — the same distribution
    /// the Python tests use).
    pub(super) fn build_inputs(meta: &VariantMeta) -> Result<Vec<xla::Literal>> {
        let mut out = Vec::with_capacity(meta.inputs.len());
        for (i, buf) in meta.inputs.iter().enumerate() {
            let mut rng = Pcg64::new(0xA07 ^ i as u64, 17);
            let data: Vec<f32> =
                (0..buf.numel()).map(|_| rng.uniform(0.5, 1.5) as f32).collect();
            let lit = xla::Literal::vec1(&data);
            let dims: Vec<i64> = buf.shape.iter().map(|&d| d as i64).collect();
            out.push(lit.reshape(&dims)?);
        }
        Ok(out)
    }
}

#[cfg(feature = "pjrt")]
pub use live::PjrtRuntime;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::path::Path;

    use anyhow::Result;

    use super::ExecStats;
    use crate::runtime::manifest::Manifest;

    const UNAVAILABLE: &str = "oclcc was built without the `pjrt` feature: \
         PJRT kernel execution is unavailable (enable the feature and add \
         the xla dependency in rust/Cargo.toml)";

    /// Stub registry: keeps the `cpu_live` code paths compiling; the
    /// constructor fails fast so callers (PjrtService::start, `oclcc
    /// profile --kernels`) degrade with a clear message.
    pub struct PjrtRuntime {
        manifest: Manifest,
    }

    impl PjrtRuntime {
        pub fn new(_artifact_dir: &Path) -> Result<PjrtRuntime> {
            anyhow::bail!("{UNAVAILABLE}")
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn warmup(&self, _variant: &str) -> Result<()> {
            anyhow::bail!("{UNAVAILABLE}")
        }

        pub fn execute(&self, _variant: &str) -> Result<ExecStats> {
            anyhow::bail!("{UNAVAILABLE}")
        }

        pub fn execute_collect(&self, _variant: &str) -> Result<Vec<f32>> {
            anyhow::bail!("{UNAVAILABLE}")
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::PjrtRuntime;

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    // PJRT-backed tests live in rust/tests/integration_runtime.rs, where
    // the artifact directory is guaranteed present; here we only cover the
    // input builder against synthetic metadata.
    use crate::runtime::manifest::{BufferMeta, VariantMeta};

    #[test]
    fn inputs_match_shapes_and_are_deterministic() {
        let meta = VariantMeta {
            name: "t".into(),
            kernel: "vecadd".into(),
            file: "t.hlo.txt".into(),
            dominance: "DT".into(),
            inputs: vec![
                BufferMeta { shape: vec![4, 8] },
                BufferMeta { shape: vec![32] },
            ],
            outputs: vec![BufferMeta { shape: vec![32] }],
            htd_bytes: 256,
            dth_bytes: 128,
        };
        let a = super::live::build_inputs(&meta).unwrap();
        let b = super::live::build_inputs(&meta).unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].element_count(), 32);
        assert_eq!(
            a[1].to_vec::<f32>().unwrap(),
            b[1].to_vec::<f32>().unwrap()
        );
        let vals = a[0].to_vec::<f32>().unwrap();
        assert!(vals.iter().all(|v| (0.5..1.5).contains(v)));
    }
}
