//! Live kernel backend: the virtual device's compute engine executes real
//! AOT artifacts through the PJRT service thread (the `cpu_live` profile).

use std::time::Duration;

use crate::device::executor::KernelExecutor;
use crate::runtime::service::PjrtService;
use crate::task::KernelSpec;
use crate::util::timing;

pub struct PjrtExecutor {
    service: PjrtService,
}

impl PjrtExecutor {
    pub fn new(service: PjrtService) -> Self {
        PjrtExecutor { service }
    }
}

impl KernelExecutor for PjrtExecutor {
    fn execute(&self, spec: &KernelSpec, launch_overhead: f64) -> anyhow::Result<()> {
        match spec {
            // Synthetic / replayed kernels still burn their duration so
            // mixed groups behave on the live device.
            KernelSpec::Timed { secs } => {
                timing::precise_wait(Duration::from_secs_f64(secs + launch_overhead));
                Ok(())
            }
            KernelSpec::Artifact { variant, .. } => {
                timing::precise_wait(Duration::from_secs_f64(launch_overhead));
                self.service.execute(variant).map(|_| ())
            }
        }
    }
}
