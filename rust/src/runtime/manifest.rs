//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. One entry per AOT-compiled (kernel x size) variant.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Shape of one f32 input/output buffer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BufferMeta {
    pub shape: Vec<usize>,
}

impl BufferMeta {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> u64 {
        4 * self.numel() as u64
    }
}

/// One AOT variant (e.g. `mm_256`).
#[derive(Clone, Debug)]
pub struct VariantMeta {
    pub name: String,
    /// Kernel family (`matmul`, `vecadd`, ...).
    pub kernel: String,
    /// HLO text file, relative to the artifact directory.
    pub file: String,
    pub inputs: Vec<BufferMeta>,
    pub outputs: Vec<BufferMeta>,
    pub htd_bytes: u64,
    pub dth_bytes: u64,
    /// 'DK' or 'DT' majority label from the Python side.
    pub dominance: String,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub variants: BTreeMap<String, VariantMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        let obj = json.as_obj().ok_or_else(|| anyhow!("manifest root not an object"))?;
        let mut variants = BTreeMap::new();
        for (name, entry) in obj {
            variants.insert(name.clone(), parse_variant(name, entry)?);
        }
        Ok(Manifest { dir: dir.to_path_buf(), variants })
    }

    pub fn get(&self, name: &str) -> Result<&VariantMeta> {
        self.variants
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact variant '{name}'"))
    }

    /// Absolute path of a variant's HLO text.
    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.get(name)?.file))
    }

    /// Variants of one kernel family, sorted by input size.
    pub fn family(&self, kernel: &str) -> Vec<&VariantMeta> {
        let mut v: Vec<&VariantMeta> =
            self.variants.values().filter(|m| m.kernel == kernel).collect();
        v.sort_by_key(|m| m.htd_bytes);
        v
    }
}

fn parse_variant(name: &str, j: &Json) -> Result<VariantMeta> {
    let str_field = |k: &str| -> Result<String> {
        Ok(j.get(k)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("variant {name}: missing {k}"))?
            .to_string())
    };
    let num_field = |k: &str| -> Result<u64> {
        j.get(k)
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("variant {name}: missing {k}"))
    };
    let buffers = |k: &str| -> Result<Vec<BufferMeta>> {
        j.get(k)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("variant {name}: missing {k}"))?
            .iter()
            .map(|b| {
                let shape = b
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("variant {name}: bad buffer"))?
                    .iter()
                    .map(|d| d.as_u64().map(|x| x as usize))
                    .collect::<Option<Vec<usize>>>()
                    .ok_or_else(|| anyhow!("variant {name}: bad shape"))?;
                let dtype = b.get("dtype").and_then(Json::as_str).unwrap_or("f32");
                anyhow::ensure!(dtype == "f32", "variant {name}: dtype {dtype} unsupported");
                Ok(BufferMeta { shape })
            })
            .collect()
    };
    Ok(VariantMeta {
        name: name.to_string(),
        kernel: str_field("kernel")?,
        file: str_field("file")?,
        dominance: str_field("dominance")?,
        inputs: buffers("inputs")?,
        outputs: buffers("outputs")?,
        htd_bytes: num_field("htd_bytes")?,
        dth_bytes: num_field("dth_bytes")?,
    })
}

/// Default artifact directory: `$OCLCC_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("OCLCC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("oclcc_manifest_test");
        write_manifest(
            &dir,
            r#"{"mm_8": {"kernel": "matmul", "file": "mm_8.hlo.txt",
                "dominance": "DK",
                "inputs": [{"shape": [8, 8], "dtype": "f32"},
                           {"shape": [8, 8], "dtype": "f32"}],
                "outputs": [{"shape": [8, 8], "dtype": "f32"}],
                "htd_bytes": 512, "dth_bytes": 256}}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        let v = m.get("mm_8").unwrap();
        assert_eq!(v.inputs.len(), 2);
        assert_eq!(v.inputs[0].numel(), 64);
        assert_eq!(v.inputs[0].bytes(), 256);
        assert_eq!(v.htd_bytes, 512);
        assert!(m.get("nope").is_err());
        assert!(m.hlo_path("mm_8").unwrap().ends_with("mm_8.hlo.txt"));
    }

    #[test]
    fn family_sorted_by_size() {
        let dir = std::env::temp_dir().join("oclcc_manifest_family");
        write_manifest(
            &dir,
            r#"{"va_big": {"kernel": "vecadd", "file": "b.hlo.txt",
                 "dominance": "DT",
                 "inputs": [{"shape": [1024], "dtype": "f32"}],
                 "outputs": [{"shape": [1024], "dtype": "f32"}],
                 "htd_bytes": 4096, "dth_bytes": 4096},
                "va_small": {"kernel": "vecadd", "file": "s.hlo.txt",
                 "dominance": "DT",
                 "inputs": [{"shape": [16], "dtype": "f32"}],
                 "outputs": [{"shape": [16], "dtype": "f32"}],
                 "htd_bytes": 64, "dth_bytes": 64}}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        let fam = m.family("vecadd");
        assert_eq!(fam.len(), 2);
        assert_eq!(fam[0].name, "va_small");
    }

    #[test]
    fn missing_manifest_is_actionable() {
        let err = Manifest::load(Path::new("/definitely/not/here"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
