//! PJRT runtime — loads the AOT artifacts produced by `make artifacts`
//! and executes them on the `xla` crate's CPU client.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (variant -> HLO file,
//!   input/output shapes, transfer byte counts).
//! * [`engine`] — compile-once/execute-many registry over
//!   `PjRtClient::cpu()`; interchange is HLO *text* (xla_extension 0.5.1
//!   rejects jax >= 0.5 serialized protos — see python/compile/aot.py).
//! * [`executor`] — `PjrtExecutor`, the live kernel backend for the
//!   virtual device's compute engine (`cpu_live` profile).

pub mod engine;
pub mod executor;
pub mod manifest;
pub mod service;

pub use engine::PjrtRuntime;
pub use executor::PjrtExecutor;
pub use service::PjrtService;
pub use manifest::{Manifest, VariantMeta};
