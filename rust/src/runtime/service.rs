//! PJRT service thread: the `xla` crate's client/executable/literal types
//! are `!Send` (Rc + raw pointers), so a single dedicated thread owns the
//! `PjrtRuntime` and serves execution requests over a channel. The
//! cloneable [`PjrtService`] handle is `Send + Sync` and safe to share
//! with the virtual device's engine threads.
//!
//! This also faithfully models real accelerators: one in-order compute
//! queue consuming kernel commands (the model's no-CKE assumption).

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::runtime::engine::{ExecStats, PjrtRuntime};

enum Request {
    Warmup(String, mpsc::Sender<Result<()>>),
    Execute(String, mpsc::Sender<Result<ExecStats>>),
    Platform(mpsc::Sender<String>),
    Shutdown,
}

/// Cloneable, thread-safe handle to the PJRT service thread.
#[derive(Clone)]
pub struct PjrtService {
    tx: Arc<Mutex<mpsc::Sender<Request>>>,
}

impl PjrtService {
    /// Start the service over an artifact directory. Fails fast if the
    /// manifest is missing or the PJRT client cannot be created.
    pub fn start(artifact_dir: PathBuf) -> Result<PjrtService> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || {
                let runtime = match PjrtRuntime::new(&artifact_dir) {
                    Ok(r) => {
                        let _ = ready_tx.send(Ok(()));
                        r
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for req in rx {
                    match req {
                        Request::Warmup(v, reply) => {
                            let _ = reply.send(runtime.warmup(&v));
                        }
                        Request::Execute(v, reply) => {
                            let _ = reply.send(runtime.execute(&v));
                        }
                        Request::Platform(reply) => {
                            let _ = reply.send(runtime.platform());
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .expect("spawn pjrt service");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("pjrt service died during startup"))??;
        Ok(PjrtService { tx: Arc::new(Mutex::new(tx)) })
    }

    fn send(&self, req: Request) -> Result<()> {
        self.tx
            .lock()
            .unwrap()
            .send(req)
            .map_err(|_| anyhow!("pjrt service is gone"))
    }

    pub fn warmup(&self, variant: &str) -> Result<()> {
        let (tx, rx) = mpsc::channel();
        self.send(Request::Warmup(variant.to_string(), tx))?;
        rx.recv().map_err(|_| anyhow!("pjrt service dropped request"))?
    }

    pub fn execute(&self, variant: &str) -> Result<ExecStats> {
        let (tx, rx) = mpsc::channel();
        self.send(Request::Execute(variant.to_string(), tx))?;
        rx.recv().map_err(|_| anyhow!("pjrt service dropped request"))?
    }

    pub fn platform(&self) -> Result<String> {
        let (tx, rx) = mpsc::channel();
        self.send(Request::Platform(tx))?;
        rx.recv().map_err(|_| anyhow!("pjrt service dropped request"))
    }

    pub fn shutdown(&self) {
        let _ = self.send(Request::Shutdown);
    }
}
