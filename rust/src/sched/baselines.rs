//! Baseline ordering policies, used as ablation comparators in the benches
//! (`oclcc bench ablation`) and as sanity anchors in tests.

use crate::config::DeviceProfile;
use crate::model::simulator::SimCursor;
use crate::model::{EngineState, TaskTable};
use crate::task::{Dominance, TaskSpec};
use crate::util::rng::Pcg64;

/// Submission order exactly as received (the NoReorder identity).
pub fn fifo(tasks: &[TaskSpec]) -> Vec<usize> {
    (0..tasks.len()).collect()
}

/// Uniformly random order.
pub fn random(tasks: &[TaskSpec], rng: &mut Pcg64) -> Vec<usize> {
    let mut order: Vec<usize> = (0..tasks.len()).collect();
    rng.shuffle(&mut order);
    order
}

/// Shortest-job-first by solo sequential time (`total_cmp`: a NaN from a
/// degenerate profile sorts last instead of panicking the proxy thread).
pub fn sjf(tasks: &[TaskSpec], profile: &DeviceProfile) -> Vec<usize> {
    let mut order: Vec<usize> = (0..tasks.len()).collect();
    order.sort_by(|&a, &b| {
        tasks[a]
            .sequential_secs(profile)
            .total_cmp(&tasks[b].sequential_secs(profile))
    });
    order
}

/// Longest-kernel-first: greedy proxy for "hide the biggest K behind
/// transfers of everything that follows".
pub fn longest_kernel_first(tasks: &[TaskSpec], profile: &DeviceProfile) -> Vec<usize> {
    let mut order: Vec<usize> = (0..tasks.len()).collect();
    order.sort_by(|&a, &b| {
        tasks[b]
            .stage_secs(profile)
            .k
            .total_cmp(&tasks[a].stage_secs(profile).k)
    });
    order
}

/// Alternate dominant-kernel and dominant-transfer tasks (DK first), the
/// folk heuristic the paper's Algorithm 1 refines.
pub fn alternate_dominance(tasks: &[TaskSpec], profile: &DeviceProfile) -> Vec<usize> {
    let mut dk: Vec<usize> = Vec::new();
    let mut dt: Vec<usize> = Vec::new();
    for (i, t) in tasks.iter().enumerate() {
        match t.dominance(profile) {
            Dominance::DominantKernel => dk.push(i),
            Dominance::DominantTransfer => dt.push(i),
        }
    }
    let mut order = Vec::with_capacity(tasks.len());
    let (mut i, mut j) = (0, 0);
    while i < dk.len() || j < dt.len() {
        if i < dk.len() {
            order.push(dk[i]);
            i += 1;
        }
        if j < dt.len() {
            order.push(dt[j]);
            j += 1;
        }
    }
    order
}

/// Simulated makespan of every baseline policy on one group: the group is
/// compiled once into a [`TaskTable`] and every order is replayed through
/// a single reused [`SimCursor`] (the ablation bench calls this per group
/// x device; table + shared cursor keep the sweep allocation-light the
/// same way the heuristic's `BeamScratch` does).
pub fn baseline_makespans(
    tasks: &[TaskSpec],
    profile: &DeviceProfile,
    rng: &mut Pcg64,
) -> Vec<(&'static str, f64)> {
    let orders: Vec<(&'static str, Vec<usize>)> = vec![
        ("fifo", fifo(tasks)),
        ("random", random(tasks, rng)),
        ("sjf", sjf(tasks, profile)),
        ("lkf", longest_kernel_first(tasks, profile)),
        ("alternate", alternate_dominance(tasks, profile)),
    ];
    let table = TaskTable::compile(tasks, profile);
    let mut cursor = SimCursor::new(profile, EngineState::default());
    orders
        .into_iter()
        .map(|(name, order)| {
            cursor.reset(profile, EngineState::default());
            for &i in &order {
                cursor.push_task_compiled(&table, i);
            }
            (name, cursor.run_to_quiescence())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;
    use crate::task::synthetic::synthetic_benchmark;

    fn is_perm(order: &[usize], n: usize) -> bool {
        let mut v = order.to_vec();
        v.sort_unstable();
        v == (0..n).collect::<Vec<_>>()
    }

    #[test]
    fn all_baselines_are_permutations() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
        let mut rng = Pcg64::seeded(4);
        for order in [
            fifo(&g.tasks),
            random(&g.tasks, &mut rng),
            sjf(&g.tasks, &p),
            longest_kernel_first(&g.tasks, &p),
            alternate_dominance(&g.tasks, &p),
        ] {
            assert!(is_perm(&order, 4), "{order:?}");
        }
    }

    #[test]
    fn sjf_sorts_by_sequential_time() {
        let p = profile_by_name("k20c").unwrap();
        let g = synthetic_benchmark("BK25", &p, 1.0).unwrap();
        let order = sjf(&g.tasks, &p);
        for w in order.windows(2) {
            assert!(
                g.tasks[w[0]].sequential_secs(&p)
                    <= g.tasks[w[1]].sequential_secs(&p) + 1e-12
            );
        }
    }

    #[test]
    fn baseline_makespans_match_direct_simulation() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK25", &p, 1.0).unwrap();
        let mut rng_a = Pcg64::seeded(11);
        let mut rng_b = Pcg64::seeded(11);
        let got = baseline_makespans(&g.tasks, &p, &mut rng_a);
        assert_eq!(got.len(), 5);
        let want: Vec<(&str, f64)> = vec![
            ("fifo", fifo(&g.tasks)),
            ("random", random(&g.tasks, &mut rng_b)),
            ("sjf", sjf(&g.tasks, &p)),
            ("lkf", longest_kernel_first(&g.tasks, &p)),
            ("alternate", alternate_dominance(&g.tasks, &p)),
        ]
        .into_iter()
        .map(|(n, o)| {
            (n, crate::model::simulator::makespan_of_order(&g.tasks, &o, &p))
        })
        .collect();
        for ((na, ma), (nb, mb)) in got.iter().zip(&want) {
            assert_eq!(na, nb);
            assert!((ma - mb).abs() <= 1e-12, "{na}: {ma} vs {mb}");
        }
    }

    #[test]
    fn alternate_interleaves() {
        let p = profile_by_name("amd_r9").unwrap();
        // BK50 = T0, T1 (DK), T4, T5 (DT).
        let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
        let order = alternate_dominance(&g.tasks, &p);
        assert_eq!(order.len(), 4);
        assert_eq!(
            g.tasks[order[0]].dominance(&p),
            Dominance::DominantKernel
        );
        assert_eq!(
            g.tasks[order[1]].dominance(&p),
            Dominance::DominantTransfer
        );
    }
}
