//! Exhaustive and sampled permutation evaluation — the NoReorder setup of
//! §6.2: the baseline distribution (worst / median / best over orderings)
//! that Figs. 9-10 plot speedups against.

use crate::config::DeviceProfile;
use crate::model::simulator::SimCursor;
use crate::model::{EngineState, TaskTable};
use crate::task::TaskSpec;
use crate::util::rng::Pcg64;
use crate::util::stats;

/// All permutations of 0..n in lexicographic order (n! of them; n <= 10
/// guarded — the paper itself stops exhaustive evaluation at T = 8).
pub fn permutations(n: usize) -> Vec<Vec<usize>> {
    assert!(n <= 10, "n! explosion: refusing n = {n} > 10");
    let mut cur: Vec<usize> = (0..n).collect();
    let mut out = Vec::new();
    loop {
        out.push(cur.clone());
        if !next_permutation(&mut cur) {
            break;
        }
    }
    out
}

/// In-place lexicographic successor; false when wrapped.
pub fn next_permutation(xs: &mut [usize]) -> bool {
    let n = xs.len();
    if n < 2 {
        return false;
    }
    let mut i = n - 1;
    while i > 0 && xs[i - 1] >= xs[i] {
        i -= 1;
    }
    if i == 0 {
        xs.reverse();
        return false;
    }
    let mut j = n - 1;
    while xs[j] <= xs[i - 1] {
        j -= 1;
    }
    xs.swap(i - 1, j);
    xs[i..].reverse();
    true
}

/// Sample up to `cap` distinct-ish permutations; when n! <= cap, this is
/// the exhaustive set (mirrors the paper: all permutations at T=4, a 5%
/// random subset at T=6/N=2, N=1 only at T=8).
pub fn permutation_sample(n: usize, cap: usize, rng: &mut Pcg64) -> Vec<Vec<usize>> {
    let total: usize = (1..=n).product();
    if total <= cap {
        return permutations(n);
    }
    let mut out = Vec::with_capacity(cap);
    for _ in 0..cap {
        let mut p: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut p);
        out.push(p);
    }
    out
}

/// Distribution of simulated makespans over a set of orderings.
#[derive(Clone, Debug)]
pub struct OrderStats {
    pub n_orders: usize,
    pub best: f64,
    pub worst: f64,
    pub mean: f64,
    pub median: f64,
    pub best_order: Vec<usize>,
    pub worst_order: Vec<usize>,
}

impl OrderStats {
    /// Evaluate every ordering in `orders` with the temporal model. The
    /// group is compiled once into a [`TaskTable`] and a single
    /// [`SimCursor`] is reset per order, so the sweep walks contiguous
    /// SoA rows and reuses its queue/counter buffers instead of
    /// re-reading `TaskSpec`s and allocating ~6 Vecs per ordering (this
    /// path evaluates up to T! orders per experiment cell).
    pub fn evaluate(
        tasks: &[TaskSpec],
        orders: &[Vec<usize>],
        profile: &DeviceProfile,
    ) -> OrderStats {
        assert!(!orders.is_empty());
        let table = TaskTable::compile(tasks, profile);
        let mut times = Vec::with_capacity(orders.len());
        let mut best = f64::INFINITY;
        let mut worst = f64::NEG_INFINITY;
        let mut best_order = orders[0].clone();
        let mut worst_order = orders[0].clone();
        let mut cursor = SimCursor::new(profile, EngineState::default());
        for order in orders {
            cursor.reset(profile, EngineState::default());
            for &i in order {
                cursor.push_task_compiled(&table, i);
            }
            let t = cursor.run_to_quiescence();
            // total_cmp instead of `<`/`>`: with raw comparisons a NaN
            // makespan makes both false and silently vanishes from the
            // recorded extremes; under the total order it loses `best`
            // and surfaces as `worst`, where a degenerate profile is
            // actually visible.
            if t.total_cmp(&best).is_lt() {
                best = t;
                best_order = order.clone();
            }
            if t.total_cmp(&worst).is_gt() {
                worst = t;
                worst_order = order.clone();
            }
            times.push(t);
        }
        OrderStats {
            n_orders: orders.len(),
            best,
            worst,
            mean: stats::mean(&times),
            median: stats::median(&times),
            best_order,
            worst_order,
        }
    }

    /// Exhaustive (or capped) evaluation of a task group.
    pub fn exhaustive(
        tasks: &[TaskSpec],
        profile: &DeviceProfile,
        cap: usize,
        rng: &mut Pcg64,
    ) -> OrderStats {
        let orders = permutation_sample(tasks.len(), cap, rng);
        Self::evaluate(tasks, &orders, profile)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;
    use crate::model::simulator::makespan_of_order;
    use crate::task::synthetic::synthetic_benchmark;

    #[test]
    fn permutation_count_and_uniqueness() {
        let perms = permutations(4);
        assert_eq!(perms.len(), 24);
        let mut sorted = perms.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), 24);
    }

    #[test]
    fn next_permutation_order() {
        let mut p = vec![0, 1, 2];
        assert!(next_permutation(&mut p));
        assert_eq!(p, vec![0, 2, 1]);
        let mut last = vec![2, 1, 0];
        assert!(!next_permutation(&mut last));
        assert_eq!(last, vec![0, 1, 2]); // wrapped
    }

    #[test]
    fn sample_caps() {
        let mut rng = Pcg64::seeded(1);
        assert_eq!(permutation_sample(3, 100, &mut rng).len(), 6);
        assert_eq!(permutation_sample(6, 50, &mut rng).len(), 50);
    }

    #[test]
    fn stats_bounds_are_consistent() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK25", &p, 1.0).unwrap();
        let mut rng = Pcg64::seeded(2);
        let st = OrderStats::exhaustive(&g.tasks, &p, 1000, &mut rng);
        assert_eq!(st.n_orders, 24);
        assert!(st.best <= st.median && st.median <= st.worst);
        assert!(st.best <= st.mean && st.mean <= st.worst);
        // Recorded extreme orders reproduce their times.
        assert!(
            (makespan_of_order(&g.tasks, &st.best_order, &p) - st.best).abs()
                < 1e-12
        );
        assert!(
            (makespan_of_order(&g.tasks, &st.worst_order, &p) - st.worst).abs()
                < 1e-12
        );
    }

    #[test]
    #[should_panic(expected = "explosion")]
    fn permutations_guard() {
        permutations(11);
    }
}
