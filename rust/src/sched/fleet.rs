//! Heterogeneous fleet scheduling: calibrated earliest-completion-time
//! placement over per-device [`TaskTable`]s, scored through the bound-
//! gated machinery of `sched::search_util` instead of a full
//! `run_to_quiescence` probe per (task × device).
//!
//! This is the promotion of `sched::multidevice` to a first-class fleet
//! scheduler (the old `schedule_multi` is now a thin wrapper over
//! [`schedule_fleet`]). Two phases, as before:
//!
//! 1. **Placement** — tasks in descending max-solo-duration order (LPT);
//!    each goes to the device whose simulated completion time grows the
//!    least. Three prune mechanisms make the D-way scoring cheap while
//!    provably never changing a decision (all markers carry a proof of
//!    *strict* exclusion, and ties break first-device exactly as the
//!    exact scan would):
//!    * **floors** — `SimCursor::lower_bound_with_remaining` over the
//!      candidate row's solo seconds, rejected via `provably_worse`
//!      against the best exact completion seen so far this step;
//!    * **bounded probes** — surviving candidates simulate under the
//!      running best as an admissible early-exit cutoff;
//!    * **twin collapse** — a device's exact score for row `i` is reused
//!      for any later row of the same `TaskTable::twin_class` while
//!      that device's prefix is unchanged (twin rows push byte-identical
//!      command sequences, so the completion is bit-equal). Only *exact*
//!      scores are memoised — `INFINITY` exclusion markers are
//!      cutoff-dependent and never cached.
//! 2. **Ordering** — each device's sublist is gathered into a sub-table
//!    ([`TaskTable::gather_into`], no spec re-resolution) and reordered
//!    by the bound-gated beam via `batch_reorder_table_into`.
//!
//! Per-device tables mean per-device twin classes, floors and — on the
//! calibrated path ([`schedule_fleet_calibrated`]) — per-device
//! `Calibrator` corrections: a task can be transfer-dominant on one
//! device and kernel-dominant on another (the paper's Table 4 DCT/FWT
//! flips), and measured drift is per *device*, not per fleet.
//!
//! [`steal_predicts_win`] is the cross-device work-stealing predicate
//! used by `coordinator::fleet`: a thief accepts stolen work only when
//! its own (calibrated) model proves a strict win over leaving the work
//! where it is. Transfer cost needs no separate term — the stolen rows
//! are compiled against the *thief's* profile, so the thief-side HtD/DtH
//! seconds (its own links, its own calibrated rates) are already in the
//! completion time being compared.

use crate::config::DeviceProfile;
use crate::model::calibrate::CalibratedProfile;
use crate::model::simulator::{simulate_order_compiled, SimCursor};
use crate::model::{EngineState, SimOptions, TaskTable};
use crate::sched::heuristic::{batch_reorder_table_into, BeamScratch, DEFAULT_BEAM_WIDTH};
use crate::sched::search_util::{bounded_append_score, provably_worse, PruneCounters};
use crate::task::TaskSpec;

/// Knobs for [`schedule_fleet`].
#[derive(Clone, Copy, Debug)]
pub struct FleetOptions {
    /// Beam width for the per-device ordering phase.
    pub width: usize,
    /// Bound-gated placement (floors, bounded probes, twin collapse).
    /// Decisions are bit-identical either way (prop_fleet.rs); off keeps
    /// the exact full-probe scan for reference and debugging.
    pub prune: bool,
}

impl Default for FleetOptions {
    fn default() -> FleetOptions {
        FleetOptions { width: DEFAULT_BEAM_WIDTH, prune: true }
    }
}

/// A complete fleet schedule.
#[derive(Clone, Debug)]
pub struct FleetSchedule {
    /// `assignment[i]` = device index for task `i`.
    pub assignment: Vec<usize>,
    /// Per-device submission order (indices into the original task slice).
    pub orders: Vec<Vec<usize>>,
    /// Predicted makespan per device.
    pub device_makespans: Vec<f64>,
    /// Placement + per-device beam pruning counters (placement floor
    /// rejections and early-exited probes land in `n_cands_pruned` /
    /// `n_rollouts_early_exit`; cross-device twin reuse in
    /// `n_twin_collapsed`).
    pub prune: PruneCounters,
}

impl FleetSchedule {
    /// Predicted group makespan (max over devices).
    pub fn makespan(&self) -> f64 {
        self.device_makespans.iter().cloned().fold(0.0, f64::max)
    }
}

/// Schedule `tasks` across `profiles` (one entry per device), each
/// device planning with its plain (uncalibrated) profile.
///
/// Panics if `profiles` is empty — same contract as
/// `sched::multidevice::schedule_multi` / `round_robin`.
pub fn schedule_fleet(
    tasks: &[TaskSpec],
    profiles: &[DeviceProfile],
    opts: &FleetOptions,
) -> FleetSchedule {
    assert!(!profiles.is_empty(), "need at least one device");
    let tables: Vec<TaskTable> =
        profiles.iter().map(|p| TaskTable::compile(tasks, p)).collect();
    let inits = vec![EngineState::default(); profiles.len()];
    schedule_fleet_tables(tasks.len(), &tables, &inits, opts)
}

/// [`schedule_fleet`] with per-device *calibrated* planning models: each
/// device's table compiles through its own `CalibratedProfile`, so
/// placement compares corrected completion times across the fleet.
pub fn schedule_fleet_calibrated(
    tasks: &[TaskSpec],
    cals: &[CalibratedProfile],
    opts: &FleetOptions,
) -> FleetSchedule {
    assert!(!cals.is_empty(), "need at least one device");
    let tables: Vec<TaskTable> = cals
        .iter()
        .map(|c| {
            let mut t = TaskTable::new();
            t.compile_calibrated_into(tasks, c);
            t
        })
        .collect();
    let inits = vec![EngineState::default(); cals.len()];
    schedule_fleet_tables(tasks.len(), &tables, &inits, opts)
}

/// Core fleet scheduler over pre-compiled per-device tables and initial
/// engine states (one per device — a device may already be busy). All
/// `n` tasks must be rows `0..n` of every table. Public so property
/// tests can drive it with randomized busy-device states.
pub fn schedule_fleet_tables(
    n: usize,
    tables: &[TaskTable],
    inits: &[EngineState],
    opts: &FleetOptions,
) -> FleetSchedule {
    assert!(!tables.is_empty(), "need at least one device");
    assert_eq!(tables.len(), inits.len(), "one init state per device");
    let d = tables.len();

    // Phase 1: LPT-style greedy placement by simulated completion time
    // (max solo duration across devices as the LPT key; total_cmp so a
    // NaN cannot panic).
    let mut by_size: Vec<usize> = (0..n).collect();
    by_size.sort_by(|&a, &b| {
        let dur = |i: usize| -> f64 {
            tables.iter().map(|t| t.sequential_secs(i)).fold(0.0, f64::max)
        };
        dur(b).total_cmp(&dur(a))
    });

    let mut counters = PruneCounters::default();
    let mut lists: Vec<Vec<usize>> = vec![Vec::new(); d];
    let mut device_cursors: Vec<SimCursor> = tables
        .iter()
        .zip(inits)
        .map(|(t, &init)| {
            let mut c = SimCursor::detached();
            c.reset_for_table(t, init);
            c
        })
        .collect();
    let mut probe = SimCursor::detached();
    // Per-device twin memo: (twin class, tasks placed on the device when
    // the score was computed, exact completion). Valid only while the
    // device's prefix is unchanged; never holds an exclusion marker.
    let mut memo: Vec<Option<(u32, usize, f64)>> = vec![None; d];
    for &i in &by_size {
        let mut best_dev = 0;
        let mut best_time = f64::INFINITY;
        for dev in 0..d {
            let t = if opts.prune {
                let class = tables[dev].twin_class(i);
                match memo[dev] {
                    Some((c, placed, s))
                        if c == class && placed == lists[dev].len() =>
                    {
                        counters.n_twin_collapsed += 1;
                        s
                    }
                    _ => {
                        let s = bounded_append_score(
                            &mut probe,
                            &device_cursors[dev],
                            &tables[dev],
                            i,
                            best_time,
                            true,
                            &mut counters,
                        );
                        if s.is_finite() {
                            memo[dev] = Some((class, lists[dev].len(), s));
                        }
                        s
                    }
                }
            } else {
                bounded_append_score(
                    &mut probe,
                    &device_cursors[dev],
                    &tables[dev],
                    i,
                    f64::INFINITY,
                    false,
                    &mut counters,
                )
            };
            // total_cmp, not `<`: a NaN completion time from a degenerate
            // profile must lose the placement race, never win it (and the
            // INFINITY exclusion markers sort after every exact score).
            if t.total_cmp(&best_time).is_lt() {
                best_time = t;
                best_dev = dev;
            }
        }
        device_cursors[best_dev].push_task_compiled(&tables[best_dev], i);
        lists[best_dev].push(i);
        memo[best_dev] = None;
    }

    // Phase 2: per-device bound-gated beam reordering over gathered
    // sub-tables — no TaskSpec re-resolution, one scratch for the fleet.
    let mut orders = Vec::with_capacity(d);
    let mut device_makespans = Vec::with_capacity(d);
    let mut assignment = vec![0usize; n];
    let mut sub = TaskTable::new();
    let mut scratch = BeamScratch::with_pruning(opts.prune);
    let mut local: Vec<usize> = Vec::new();
    for (dev, list) in lists.iter().enumerate() {
        for &i in list {
            assignment[i] = dev;
        }
        sub.gather_into(&tables[dev], list);
        local.clear();
        batch_reorder_table_into(&sub, inits[dev], opts.width, &mut scratch, &mut local);
        let order: Vec<usize> = local.iter().map(|&j| list[j]).collect();
        let m = simulate_order_compiled(&sub, &local, inits[dev], SimOptions::default())
            .makespan;
        orders.push(order);
        device_makespans.push(m);
    }
    counters.merge(&scratch.prune_counters());
    FleetSchedule { assignment, orders, device_makespans, prune: counters }
}

/// Cross-device steal predicate: would moving `rows` of `thief_table`
/// (the stolen tasks compiled against the *thief's* calibrated profile)
/// onto the thief's frontier finish strictly before `victim_remaining`
/// (the victim's predicted remaining seconds for that work, on the
/// thief's clock)?
///
/// One-sided soundness — pinned in prop_fleet.rs: `true` implies the
/// thief's *exact* completion of the stolen rows is strictly below
/// `victim_remaining`. `false` makes no claim (the floor rejection and
/// the bounded probe may be conservative), which is the right polarity
/// for stealing: a rejected steal only costs idle time, a wrongly
/// accepted one costs makespan. A NaN on either side rejects the steal:
/// `provably_worse` never fires on NaN, and the final comparison is a
/// plain `<` — false on NaN — rather than `total_cmp` (which would sort
/// a NaN budget *above* every exact score and wrongly accept).
///
/// Transfer cost enters through `thief_table` itself: the rows carry the
/// thief's own HtD/DtH link seconds (calibrated), so the comparison is
/// net of moving the task's bytes over the thief's links.
pub fn steal_predicts_win(
    probe: &mut SimCursor,
    thief_frontier: &SimCursor,
    thief_table: &TaskTable,
    rows: &[usize],
    victim_remaining: f64,
    counters: &mut PruneCounters,
) -> bool {
    let (mut rem_htd, mut rem_k, mut rem_dth) = (0.0f64, 0.0f64, 0.0f64);
    for &r in rows {
        rem_htd += thief_table.htd_secs(r);
        rem_k += thief_table.kernel_secs(r);
        rem_dth += thief_table.dth_secs(r);
    }
    let bound = thief_frontier.lower_bound_with_remaining(rem_htd, rem_k, rem_dth);
    if provably_worse(bound, victim_remaining) {
        counters.n_cands_pruned += 1;
        return false;
    }
    probe.resume_from(thief_frontier);
    for &r in rows {
        probe.push_task_compiled(thief_table, r);
        if probe.clock() > victim_remaining {
            counters.n_rollouts_early_exit += 1;
            return false;
        }
    }
    match probe.run_to_quiescence_bounded(victim_remaining) {
        // Plain `<`: strict win required, and false on a NaN budget.
        Some(t) => t < victim_remaining,
        None => {
            counters.n_rollouts_early_exit += 1;
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;
    use crate::task::real::real_benchmark;
    use crate::task::synthetic::synthetic_benchmark;
    use crate::util::rng::Pcg64;

    fn het3() -> Vec<DeviceProfile> {
        vec![
            profile_by_name("amd_r9").unwrap(),
            profile_by_name("xeon_phi").unwrap(),
            profile_by_name("k20c").unwrap(),
        ]
    }

    #[test]
    fn covers_every_task_exactly_once() {
        let p = profile_by_name("amd_r9").unwrap();
        let mut rng = Pcg64::seeded(11);
        let g = real_benchmark("BK50", "amd_r9", &p, 12, &mut rng, 1.0).unwrap();
        let s = schedule_fleet(&g.tasks, &het3(), &FleetOptions::default());
        let mut seen: Vec<usize> = s.orders.concat();
        seen.sort_unstable();
        assert_eq!(seen, (0..12).collect::<Vec<_>>());
        for (dev, order) in s.orders.iter().enumerate() {
            for &i in order {
                assert_eq!(s.assignment[i], dev);
            }
        }
    }

    #[test]
    fn prune_counters_fire_on_heterogeneous_fleet() {
        let p = profile_by_name("amd_r9").unwrap();
        let mut rng = Pcg64::seeded(3);
        let g = real_benchmark("BK50", "amd_r9", &p, 16, &mut rng, 1.0).unwrap();
        let s = schedule_fleet(&g.tasks, &het3(), &FleetOptions::default());
        assert!(
            s.prune.total_saved() > 0,
            "16 tasks × 3 devices must prune or collapse something: {:?}",
            s.prune
        );
    }

    #[test]
    fn pruning_never_changes_the_schedule() {
        let p = profile_by_name("amd_r9").unwrap();
        for seed in [1u64, 7, 42] {
            let mut rng = Pcg64::seeded(seed);
            let g = real_benchmark("BK50", "amd_r9", &p, 10, &mut rng, 1.0).unwrap();
            let on = schedule_fleet(
                &g.tasks,
                &het3(),
                &FleetOptions { prune: true, ..FleetOptions::default() },
            );
            let off = schedule_fleet(
                &g.tasks,
                &het3(),
                &FleetOptions { prune: false, ..FleetOptions::default() },
            );
            assert_eq!(on.assignment, off.assignment, "seed {seed}");
            assert_eq!(on.orders, off.orders, "seed {seed}");
            for (a, b) in on.device_makespans.iter().zip(&off.device_makespans) {
                assert_eq!(a.to_bits(), b.to_bits(), "seed {seed}");
            }
        }
    }

    #[test]
    fn calibrated_placement_reacts_to_corrections() {
        use crate::model::calibrate::Corrections;
        // Two identical devices; calibration says device 1's links are
        // actually 4x slower. Placement must shift load to device 0.
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
        let mut tasks = g.tasks.clone();
        tasks.extend(g.tasks.clone());
        let cals = vec![
            CalibratedProfile::identity(&p),
            CalibratedProfile::new(&p, Corrections { htd: 4.0, k: 4.0, dth: 4.0 }),
        ];
        let s = schedule_fleet_calibrated(&tasks, &cals, &FleetOptions::default());
        assert!(
            s.orders[0].len() > s.orders[1].len(),
            "calibration must shift load off the slow device: {:?}",
            s.orders.iter().map(Vec::len).collect::<Vec<_>>()
        );
    }

    #[test]
    fn steal_predicate_is_one_sided() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
        let table = TaskTable::compile(&g.tasks, &p);
        let mut frontier = SimCursor::detached();
        frontier.reset_for_table(&table, EngineState::default());
        let mut probe = SimCursor::detached();
        let mut exact = SimCursor::detached();
        let mut counters = PruneCounters::default();
        for rows in [&[0usize][..], &[0, 1][..], &[2, 3, 1][..]] {
            // Exact thief completion for these rows.
            exact.resume_from(&frontier);
            for &r in rows {
                exact.push_task_compiled(&table, r);
            }
            let t_exact = exact.run_to_quiescence();
            // Nothing wins against zero remaining work.
            assert!(!steal_predicts_win(
                &mut probe, &frontier, &table, rows, 0.0, &mut counters
            ));
            // A generous budget is accepted, and acceptance implies the
            // exact completion beats it.
            let generous = t_exact * 2.0;
            assert!(steal_predicts_win(
                &mut probe, &frontier, &table, rows, generous, &mut counters
            ));
            assert!(t_exact < generous);
            // Just below the exact completion must reject.
            assert!(!steal_predicts_win(
                &mut probe,
                &frontier,
                &table,
                rows,
                t_exact * (1.0 - 1e-6),
                &mut counters
            ));
            // NaN budget rejects.
            assert!(!steal_predicts_win(
                &mut probe,
                &frontier,
                &table,
                rows,
                f64::NAN,
                &mut counters
            ));
        }
        assert!(counters.n_cands_pruned + counters.n_rollouts_early_exit > 0);
    }

    #[test]
    #[should_panic(expected = "need at least one device")]
    fn empty_fleet_panics() {
        schedule_fleet(&[], &[], &FleetOptions::default());
    }
}
