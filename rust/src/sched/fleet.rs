//! Heterogeneous fleet scheduling: calibrated earliest-completion-time
//! placement over per-device [`TaskTable`]s, scored through the bound-
//! gated machinery of `sched::search_util` instead of a full
//! `run_to_quiescence` probe per (task × device).
//!
//! This is the promotion of `sched::multidevice` to a first-class fleet
//! scheduler (the old `schedule_multi` is now a thin wrapper over
//! [`schedule_fleet`]). Two phases, as before:
//!
//! 1. **Placement** — tasks in descending max-solo-duration order (LPT);
//!    each goes to the device whose simulated completion time grows the
//!    least. Three prune mechanisms make the D-way scoring cheap while
//!    provably never changing a decision (all markers carry a proof of
//!    *strict* exclusion, and ties break first-device exactly as the
//!    exact scan would):
//!    * **floors** — `SimCursor::lower_bound_with_remaining` over the
//!      candidate row's solo seconds, rejected via `provably_worse`
//!      against the best exact completion seen so far this step;
//!    * **bounded probes** — surviving candidates simulate under the
//!      running best as an admissible early-exit cutoff;
//!    * **twin collapse** — a device's exact score for row `i` is reused
//!      for any later row of the same `TaskTable::twin_class` while
//!      that device's prefix is unchanged (twin rows push byte-identical
//!      command sequences, so the completion is bit-equal). Only *exact*
//!      scores are memoised — `INFINITY` exclusion markers are
//!      cutoff-dependent and never cached.
//! 2. **Ordering** — each device's sublist is gathered into a sub-table
//!    ([`TaskTable::gather_into`], no spec re-resolution) and reordered
//!    by the bound-gated beam via `batch_reorder_table_into`.
//!
//! Per-device tables mean per-device twin classes, floors and — on the
//! calibrated path ([`schedule_fleet_calibrated`]) — per-device
//! `Calibrator` corrections: a task can be transfer-dominant on one
//! device and kernel-dominant on another (the paper's Table 4 DCT/FWT
//! flips), and measured drift is per *device*, not per fleet.
//!
//! [`steal_predicts_win`] is the cross-device work-stealing predicate
//! used by `coordinator::fleet`: a thief accepts stolen work only when
//! its own (calibrated) model proves a strict win over leaving the work
//! where it is. Transfer cost needs no separate term — the stolen rows
//! are compiled against the *thief's* profile, so the thief-side HtD/DtH
//! seconds (its own links, its own calibrated rates) are already in the
//! completion time being compared.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, PoisonError};

use crate::config::DeviceProfile;
use crate::model::calibrate::CalibratedProfile;
use crate::model::simulator::{simulate_order_compiled, SimCursor};
use crate::model::{EngineState, SimOptions, TaskTable};
use crate::sched::heuristic::{batch_reorder_table_into, BeamScratch, DEFAULT_BEAM_WIDTH};
use crate::sched::parallel::ScoringPool;
use crate::sched::search_util::{bounded_append_score, provably_worse, PruneCounters};
use crate::task::TaskSpec;

/// Knobs for [`schedule_fleet`].
#[derive(Clone, Copy, Debug)]
pub struct FleetOptions {
    /// Beam width for the per-device ordering phase.
    pub width: usize,
    /// Bound-gated placement (floors, bounded probes, twin collapse).
    /// Decisions are bit-identical either way (prop_fleet.rs); off keeps
    /// the exact full-probe scan for reference and debugging.
    pub prune: bool,
}

impl Default for FleetOptions {
    fn default() -> FleetOptions {
        FleetOptions { width: DEFAULT_BEAM_WIDTH, prune: true }
    }
}

/// A complete fleet schedule.
#[derive(Clone, Debug)]
pub struct FleetSchedule {
    /// `assignment[i]` = device index for task `i`.
    pub assignment: Vec<usize>,
    /// Per-device submission order (indices into the original task slice).
    pub orders: Vec<Vec<usize>>,
    /// Predicted makespan per device.
    pub device_makespans: Vec<f64>,
    /// Placement + per-device beam pruning counters (placement floor
    /// rejections and early-exited probes land in `n_cands_pruned` /
    /// `n_rollouts_early_exit`; cross-device twin reuse in
    /// `n_twin_collapsed`).
    pub prune: PruneCounters,
}

impl FleetSchedule {
    /// Predicted group makespan (max over devices).
    pub fn makespan(&self) -> f64 {
        self.device_makespans.iter().cloned().fold(0.0, f64::max)
    }
}

/// Schedule `tasks` across `profiles` (one entry per device), each
/// device planning with its plain (uncalibrated) profile.
///
/// Panics if `profiles` is empty — same contract as
/// `sched::multidevice::schedule_multi` / `round_robin`.
pub fn schedule_fleet(
    tasks: &[TaskSpec],
    profiles: &[DeviceProfile],
    opts: &FleetOptions,
) -> FleetSchedule {
    assert!(!profiles.is_empty(), "need at least one device");
    let tables: Vec<TaskTable> =
        profiles.iter().map(|p| TaskTable::compile(tasks, p)).collect();
    let inits = vec![EngineState::default(); profiles.len()];
    schedule_fleet_tables(tasks.len(), &tables, &inits, opts)
}

/// [`schedule_fleet`] with per-device *calibrated* planning models: each
/// device's table compiles through its own `CalibratedProfile`, so
/// placement compares corrected completion times across the fleet.
pub fn schedule_fleet_calibrated(
    tasks: &[TaskSpec],
    cals: &[CalibratedProfile],
    opts: &FleetOptions,
) -> FleetSchedule {
    assert!(!cals.is_empty(), "need at least one device");
    let tables: Vec<TaskTable> = cals
        .iter()
        .map(|c| {
            let mut t = TaskTable::new();
            t.compile_calibrated_into(tasks, c);
            t
        })
        .collect();
    let inits = vec![EngineState::default(); cals.len()];
    schedule_fleet_tables(tasks.len(), &tables, &inits, opts)
}

/// Core fleet scheduler over pre-compiled per-device tables and initial
/// engine states (one per device — a device may already be busy). All
/// `n` tasks must be rows `0..n` of every table. Public so property
/// tests can drive it with randomized busy-device states.
pub fn schedule_fleet_tables(
    n: usize,
    tables: &[TaskTable],
    inits: &[EngineState],
    opts: &FleetOptions,
) -> FleetSchedule {
    assert!(!tables.is_empty(), "need at least one device");
    assert_eq!(tables.len(), inits.len(), "one init state per device");
    let d = tables.len();

    // Phase 1: LPT-style greedy placement by simulated completion time
    // (max solo duration across devices as the LPT key; total_cmp so a
    // NaN cannot panic).
    let mut by_size: Vec<usize> = (0..n).collect();
    by_size.sort_by(|&a, &b| {
        let dur = |i: usize| -> f64 {
            tables.iter().map(|t| t.sequential_secs(i)).fold(0.0, f64::max)
        };
        dur(b).total_cmp(&dur(a))
    });

    let mut counters = PruneCounters::default();
    let mut lists: Vec<Vec<usize>> = vec![Vec::new(); d];
    let mut device_cursors: Vec<SimCursor> = tables
        .iter()
        .zip(inits)
        .map(|(t, &init)| {
            let mut c = SimCursor::detached();
            c.reset_for_table(t, init);
            c
        })
        .collect();
    let mut probe = SimCursor::detached();
    // Per-device twin memo: (twin class, tasks placed on the device when
    // the score was computed, exact completion). Valid only while the
    // device's prefix is unchanged; never holds an exclusion marker.
    let mut memo: Vec<Option<(u32, usize, f64)>> = vec![None; d];
    for &i in &by_size {
        let mut best_dev = 0;
        let mut best_time = f64::INFINITY;
        for dev in 0..d {
            let t = if opts.prune {
                let class = tables[dev].twin_class(i);
                match memo[dev] {
                    Some((c, placed, s))
                        if c == class && placed == lists[dev].len() =>
                    {
                        counters.n_twin_collapsed += 1;
                        s
                    }
                    _ => {
                        let s = bounded_append_score(
                            &mut probe,
                            &device_cursors[dev],
                            &tables[dev],
                            i,
                            best_time,
                            true,
                            &mut counters,
                        );
                        if s.is_finite() {
                            memo[dev] = Some((class, lists[dev].len(), s));
                        }
                        s
                    }
                }
            } else {
                bounded_append_score(
                    &mut probe,
                    &device_cursors[dev],
                    &tables[dev],
                    i,
                    f64::INFINITY,
                    false,
                    &mut counters,
                )
            };
            // total_cmp, not `<`: a NaN completion time from a degenerate
            // profile must lose the placement race, never win it (and the
            // INFINITY exclusion markers sort after every exact score).
            if t.total_cmp(&best_time).is_lt() {
                best_time = t;
                best_dev = dev;
            }
        }
        device_cursors[best_dev].push_task_compiled(&tables[best_dev], i);
        lists[best_dev].push(i);
        memo[best_dev] = None;
    }

    // Phase 2: per-device bound-gated beam reordering over gathered
    // sub-tables — no TaskSpec re-resolution, one scratch for the fleet.
    let mut orders = Vec::with_capacity(d);
    let mut device_makespans = Vec::with_capacity(d);
    let mut assignment = vec![0usize; n];
    let mut sub = TaskTable::new();
    let mut scratch = BeamScratch::with_pruning(opts.prune);
    let mut local: Vec<usize> = Vec::new();
    for (dev, list) in lists.iter().enumerate() {
        for &i in list {
            assignment[i] = dev;
        }
        sub.gather_into(&tables[dev], list);
        local.clear();
        batch_reorder_table_into(&sub, inits[dev], opts.width, &mut scratch, &mut local);
        let order: Vec<usize> = local.iter().map(|&j| list[j]).collect();
        let m = simulate_order_compiled(&sub, &local, inits[dev], SimOptions::default())
            .makespan;
        orders.push(order);
        device_makespans.push(m);
    }
    counters.merge(&scratch.prune_counters());
    FleetSchedule { assignment, orders, device_makespans, prune: counters }
}

/// Cross-device steal predicate: would moving `rows` of `thief_table`
/// (the stolen tasks compiled against the *thief's* calibrated profile)
/// onto the thief's frontier finish strictly before `victim_remaining`
/// (the victim's predicted remaining seconds for that work, on the
/// thief's clock)?
///
/// One-sided soundness — pinned in prop_fleet.rs: `true` implies the
/// thief's *exact* completion of the stolen rows is strictly below
/// `victim_remaining`. `false` makes no claim (the floor rejection and
/// the bounded probe may be conservative), which is the right polarity
/// for stealing: a rejected steal only costs idle time, a wrongly
/// accepted one costs makespan. A NaN on either side rejects the steal:
/// `provably_worse` never fires on NaN, and the final comparison is a
/// plain `<` — false on NaN — rather than `total_cmp` (which would sort
/// a NaN budget *above* every exact score and wrongly accept).
///
/// Transfer cost enters through `thief_table` itself: the rows carry the
/// thief's own HtD/DtH link seconds (calibrated), so the comparison is
/// net of moving the task's bytes over the thief's links.
pub fn steal_predicts_win(
    probe: &mut SimCursor,
    thief_frontier: &SimCursor,
    thief_table: &TaskTable,
    rows: &[usize],
    victim_remaining: f64,
    counters: &mut PruneCounters,
) -> bool {
    let (mut rem_htd, mut rem_k, mut rem_dth) = (0.0f64, 0.0f64, 0.0f64);
    for &r in rows {
        rem_htd += thief_table.htd_secs(r);
        rem_k += thief_table.kernel_secs(r);
        rem_dth += thief_table.dth_secs(r);
    }
    let bound = thief_frontier.lower_bound_with_remaining(rem_htd, rem_k, rem_dth);
    if provably_worse(bound, victim_remaining) {
        counters.n_cands_pruned += 1;
        return false;
    }
    probe.resume_from(thief_frontier);
    for &r in rows {
        probe.push_task_compiled(thief_table, r);
        if probe.clock() > victim_remaining {
            counters.n_rollouts_early_exit += 1;
            return false;
        }
    }
    match probe.run_to_quiescence_bounded(victim_remaining) {
        // Plain `<`: strict win required, and false on a NaN budget.
        Some(t) => t < victim_remaining,
        None => {
            counters.n_rollouts_early_exit += 1;
            false
        }
    }
}

/// Result of a [`BatchPlacer::place_batch`] round.
#[derive(Clone, Copy, Debug)]
pub struct BatchPlaceOutcome {
    /// Model-clock objective of the chosen assignment: max over available
    /// devices of (replayed completion − device elapsed), i.e. the worst
    /// remaining work across the fleet after the batch lands.
    pub objective: f64,
    /// Objective of the per-arrival frozen-frontier greedy baseline (the
    /// exact decisions the pre-batching coordinator would have made for
    /// this batch). `objective <= greedy_objective` always holds — the
    /// greedy assignment is one of the candidates.
    pub greedy_objective: f64,
}

/// Joint placement of a drained ingress batch over per-device frontiers.
///
/// Reusable scratch for the fleet coordinator's hot path: one persistent
/// [`ScoringPool`] plus per-stripe probe cursors, an atomic score grid,
/// and trial frontiers. A placement round runs in two phases:
///
/// 1. **Parallel grid scan** — every (batch task × device) pair is scored
///    by resuming the device's *cached* batch-start frontier (resumed once
///    per probe, not re-derived per candidate) and bound-gating the append
///    through `search_util`. Tasks are striped over the pool
///    (`i % stripes`), and each stripe performs the same serial per-task
///    device scan the per-arrival path used — task-local running cutoff,
///    first-device ties — so every slot holds either the *exact* bit-equal
///    completion clock or an `INFINITY` marker carrying a proof of strict
///    exclusion relative to that task's own scan. Slots are written by
///    exactly one stripe each, which makes the grid (and everything
///    derived from it) bit-identical for any stripe count, pruned or not.
/// 2. **Serial assignment trials** — three candidate assignments are
///    built from the grid and compared on a replayed model clock:
///    * *frozen greedy*: per-task argmin over the frozen-frontier grid in
///      arrival order — exactly the old per-arrival decisions;
///    * *extending greedy, arrival order*: each placement extends the
///      winner's trial frontier, so later tasks see the batch's own load;
///    * *extending greedy, LPT order*: same, visiting tasks in descending
///      max-solo-seconds order (the static fleet scheduler's key).
///    Each trial's objective is evaluated by one uniform replay per
///    device — frontier resume + pushes in **arrival order** (the order
///    the lane will actually enqueue) — and the minimum wins, ties
///    preferring the earlier trial. A batch of one makes all three trials
///    identical, so the frozen greedy wins the tie and the placement is
///    bit-identical to the per-arrival path (pinned in prop_fleet.rs).
///
/// Grid exclusion markers are *cutoff-dependent* proofs: they are only
/// reused where the frozen-frontier context still holds (a device with no
/// trial placements and a finite slot). An extending trial re-scores
/// anything else against its own frontiers and running cutoff — so
/// pruned-on and pruned-off rounds still make bit-identical decisions.
pub struct BatchPlacer {
    pool: ScoringPool,
    /// One probe cursor per stripe: holds the resumed frontier across the
    /// stripe's whole scan of a device (the placement-cursor cache).
    probes: Vec<Mutex<SimCursor>>,
    /// Per-stripe cumulative prune counters (merged on demand).
    stripe_counters: Vec<Mutex<PruneCounters>>,
    /// Coordinator-side counters: serial trials + objective replays.
    counters: PruneCounters,
    /// `(task × device)` completion clocks from the grid scan, stored as
    /// `f64::to_bits` so stripes can publish without locking.
    scores: Vec<AtomicU64>,
    /// Coordinator-side probe for the serial trials and replays.
    probe: SimCursor,
    /// Per-device trial frontiers (frozen frontier + trial placements).
    ext: Vec<SimCursor>,
    placed: Vec<usize>,
    memo: Vec<Option<(u32, usize, f64)>>,
    lpt: Vec<usize>,
    assign_frozen: Vec<usize>,
    assign_trial: Vec<usize>,
}

impl BatchPlacer {
    /// `threads` is the total stripe count including the calling thread,
    /// same contract as [`ScoringPool::new`] (`new(1)` is fully serial).
    pub fn new(threads: usize) -> BatchPlacer {
        let pool = ScoringPool::new(threads);
        let stripes = pool.stripes();
        BatchPlacer {
            pool,
            probes: (0..stripes).map(|_| Mutex::new(SimCursor::detached())).collect(),
            stripe_counters: (0..stripes)
                .map(|_| Mutex::new(PruneCounters::default()))
                .collect(),
            counters: PruneCounters::default(),
            scores: Vec::new(),
            probe: SimCursor::detached(),
            ext: Vec::new(),
            placed: Vec::new(),
            memo: Vec::new(),
            lpt: Vec::new(),
            assign_frozen: Vec::new(),
            assign_trial: Vec::new(),
        }
    }

    /// Total parallel stripes (worker threads + the calling thread).
    pub fn stripes(&self) -> usize {
        self.pool.stripes()
    }

    /// Cumulative pruning counters across all placement rounds so far
    /// (coordinator-side trials plus every stripe's grid-scan share).
    pub fn prune_counters(&self) -> PruneCounters {
        let mut total = self.counters;
        for c in &self.stripe_counters {
            total.merge(&c.lock().unwrap_or_else(PoisonError::into_inner));
        }
        total
    }

    /// Jointly place batch rows `0..n` (rows of every device's table)
    /// onto `d` devices. `frontiers[dev]` is the device's batch-start
    /// frontier (committed prefix + incumbent plan already pushed);
    /// `elapsed[dev]` is how much of that frontier's clock has already
    /// passed in wall time, so devices are compared on *remaining* work;
    /// `available[dev] == false` excludes a device (quarantined).
    ///
    /// On success fills `assignment[k]` = device for batch task `k` and
    /// returns the chosen + baseline objectives. Returns `None` (and an
    /// empty `assignment`) when `n == 0` or no device is available — the
    /// caller falls back to its round-robin path.
    #[allow(clippy::too_many_arguments)]
    pub fn place_batch(
        &mut self,
        n: usize,
        tables: &[&TaskTable],
        frontiers: &[SimCursor],
        elapsed: &[f64],
        available: &[bool],
        prune: bool,
        assignment: &mut Vec<usize>,
    ) -> Option<BatchPlaceOutcome> {
        let d = tables.len();
        assert_eq!(d, frontiers.len(), "one frontier per device");
        assert_eq!(d, elapsed.len(), "one elapsed clock per device");
        assert_eq!(d, available.len(), "one availability flag per device");
        assignment.clear();
        if n == 0 || !available.iter().any(|&a| a) {
            return None;
        }
        let BatchPlacer {
            pool,
            probes,
            stripe_counters,
            counters,
            scores,
            probe,
            ext,
            placed,
            memo,
            lpt,
            assign_frozen,
            assign_trial,
        } = self;

        // Phase 1: parallel grid scan against the cached frozen frontiers.
        if scores.len() < n * d {
            scores.resize_with(n * d, || AtomicU64::new(0));
        }
        {
            let scores: &[AtomicU64] = scores;
            let probes: &[Mutex<SimCursor>] = probes;
            let stripe_counters: &[Mutex<PruneCounters>] = stripe_counters;
            let stripes = pool.stripes();
            let job = move |stripe: usize| {
                let mut probe =
                    probes[stripe].lock().unwrap_or_else(PoisonError::into_inner);
                let mut ctr = stripe_counters[stripe]
                    .lock()
                    .unwrap_or_else(PoisonError::into_inner);
                // Per-device twin memo, valid for the stripe's whole scan
                // because the frozen frontiers never move during phase 1.
                // Exact scores only — exclusion markers are never cached.
                let mut twin: Vec<Option<(u32, f64)>> = vec![None; d];
                let mut i = stripe;
                while i < n {
                    let mut best_rem = f64::INFINITY;
                    for dev in 0..d {
                        let slot = &scores[i * d + dev];
                        if !available[dev] {
                            slot.store(f64::INFINITY.to_bits(), Ordering::Relaxed);
                            continue;
                        }
                        let t = if prune {
                            let class = tables[dev].twin_class(i);
                            match twin[dev] {
                                Some((c, s)) if c == class => {
                                    ctr.n_twin_collapsed += 1;
                                    s
                                }
                                _ => {
                                    let s = bounded_append_score(
                                        &mut probe,
                                        &frontiers[dev],
                                        tables[dev],
                                        i,
                                        best_rem + elapsed[dev],
                                        true,
                                        &mut ctr,
                                    );
                                    if s.is_finite() {
                                        twin[dev] = Some((class, s));
                                    }
                                    s
                                }
                            }
                        } else {
                            bounded_append_score(
                                &mut probe,
                                &frontiers[dev],
                                tables[dev],
                                i,
                                f64::INFINITY,
                                false,
                                &mut ctr,
                            )
                        };
                        slot.store(t.to_bits(), Ordering::Relaxed);
                        let rem = t - elapsed[dev];
                        if rem.total_cmp(&best_rem).is_lt() {
                            best_rem = rem;
                        }
                    }
                    i += stripes;
                }
            };
            pool.run(&job);
        }

        // Phase 2a: frozen-frontier greedy in arrival order — bit-identical
        // to the per-arrival decisions the batching replaced.
        assign_frozen.clear();
        for i in 0..n {
            assign_frozen.push(grid_argmin(&scores[i * d..(i + 1) * d], elapsed, available));
        }
        let o_frozen =
            replay_objective(n, tables, frontiers, elapsed, available, assign_frozen, probe);
        assignment.clone_from(assign_frozen);
        let mut best_obj = o_frozen;

        // Phase 2b/2c: extending-greedy trials (arrival order, then LPT).
        lpt.clear();
        lpt.extend(0..n);
        lpt.sort_by(|&a, &b| {
            let solo = |i: usize| -> f64 {
                tables
                    .iter()
                    .zip(available)
                    .filter(|&(_, &av)| av)
                    .map(|(t, _)| t.sequential_secs(i))
                    .fold(0.0, f64::max)
            };
            solo(b).total_cmp(&solo(a))
        });
        for trial in 0..2 {
            let order: Option<&[usize]> = if trial == 0 { None } else { Some(lpt) };
            ext_greedy_trial(
                n, tables, frontiers, elapsed, available, prune, order, scores, ext,
                placed, memo, probe, counters, assign_trial,
            );
            let o = replay_objective(
                n, tables, frontiers, elapsed, available, assign_trial, probe,
            );
            // Strict improvement required: ties keep the earlier trial, so
            // a batch of one always resolves to the frozen greedy.
            if o.total_cmp(&best_obj).is_lt() {
                assignment.clone_from(assign_trial);
                best_obj = o;
            }
        }
        Some(BatchPlaceOutcome { objective: best_obj, greedy_objective: o_frozen })
    }
}

/// Argmin over one grid row: the available device minimizing
/// (completion − elapsed) under `total_cmp`, first device winning ties —
/// the exact tie/NaN semantics of the per-arrival scan. Falls back to the
/// first available device if every slot is non-finite (degenerate
/// profiles); callers guarantee at least one device is available.
fn grid_argmin(row: &[AtomicU64], elapsed: &[f64], available: &[bool]) -> usize {
    let mut best_dev = usize::MAX;
    let mut best_rem = f64::INFINITY;
    for (dev, slot) in row.iter().enumerate() {
        if !available[dev] {
            continue;
        }
        if best_dev == usize::MAX {
            best_dev = dev;
        }
        let rem = f64::from_bits(slot.load(Ordering::Relaxed)) - elapsed[dev];
        if rem.total_cmp(&best_rem).is_lt() {
            best_rem = rem;
            best_dev = dev;
        }
    }
    best_dev
}

/// One extending-greedy trial: visit the batch in `order` (arrival order
/// when `None`), scoring each task against per-device *trial* frontiers
/// that accumulate this trial's own placements. Grid scores are reused
/// only where their frozen-frontier context still holds — a device with
/// no trial placements and a finite (exact) slot; anything else, in
/// particular every cutoff-dependent `INFINITY` exclusion marker, is
/// re-scored against the trial frontier under the trial's own running
/// cutoff. Fills `assign[i]` = device, indexed by original batch index.
#[allow(clippy::too_many_arguments)]
fn ext_greedy_trial(
    n: usize,
    tables: &[&TaskTable],
    frontiers: &[SimCursor],
    elapsed: &[f64],
    available: &[bool],
    prune: bool,
    order: Option<&[usize]>,
    grid: &[AtomicU64],
    ext: &mut Vec<SimCursor>,
    placed: &mut Vec<usize>,
    memo: &mut Vec<Option<(u32, usize, f64)>>,
    probe: &mut SimCursor,
    counters: &mut PruneCounters,
    assign: &mut Vec<usize>,
) {
    let d = tables.len();
    if ext.len() < d {
        ext.resize_with(d, SimCursor::detached);
    }
    for dev in 0..d {
        if available[dev] {
            ext[dev].resume_from(&frontiers[dev]);
        }
    }
    placed.clear();
    placed.resize(d, 0);
    memo.clear();
    memo.resize(d, None);
    assign.clear();
    assign.resize(n, usize::MAX);
    for k in 0..n {
        let i = order.map_or(k, |o| o[k]);
        let mut best_dev = usize::MAX;
        let mut best_rem = f64::INFINITY;
        for dev in 0..d {
            if !available[dev] {
                continue;
            }
            if best_dev == usize::MAX {
                best_dev = dev;
            }
            let cached = if placed[dev] == 0 {
                let g = f64::from_bits(grid[i * d + dev].load(Ordering::Relaxed));
                g.is_finite().then_some(g)
            } else {
                None
            };
            let t = match cached {
                Some(g) => g,
                None => {
                    let class = tables[dev].twin_class(i);
                    match memo[dev] {
                        Some((c, p, s)) if prune && c == class && p == placed[dev] => {
                            counters.n_twin_collapsed += 1;
                            s
                        }
                        _ => {
                            let cutoff =
                                if prune { best_rem + elapsed[dev] } else { f64::INFINITY };
                            let s = bounded_append_score(
                                probe, &ext[dev], tables[dev], i, cutoff, prune, counters,
                            );
                            if s.is_finite() {
                                memo[dev] = Some((class, placed[dev], s));
                            }
                            s
                        }
                    }
                }
            };
            let rem = t - elapsed[dev];
            if rem.total_cmp(&best_rem).is_lt() {
                best_rem = rem;
                best_dev = dev;
            }
        }
        ext[best_dev].push_task_compiled(tables[best_dev], i);
        placed[best_dev] += 1;
        memo[best_dev] = None;
        assign[i] = best_dev;
    }
}

/// Uniform objective for one candidate assignment: per available device,
/// resume the frozen frontier, push that device's batch rows **in arrival
/// order** (the order the lane will actually enqueue them), run to
/// quiescence, and take the worst (completion − elapsed) across the
/// fleet. Every trial is judged by this same replay, so the comparison
/// between trials is exact regardless of how their scans were pruned.
fn replay_objective(
    n: usize,
    tables: &[&TaskTable],
    frontiers: &[SimCursor],
    elapsed: &[f64],
    available: &[bool],
    assign: &[usize],
    probe: &mut SimCursor,
) -> f64 {
    let d = tables.len();
    let mut obj = f64::NEG_INFINITY;
    for dev in 0..d {
        if !available[dev] {
            continue;
        }
        probe.resume_from(&frontiers[dev]);
        for i in 0..n {
            if assign[i] == dev {
                probe.push_task_compiled(tables[dev], i);
            }
        }
        let rem = probe.run_to_quiescence() - elapsed[dev];
        if rem.total_cmp(&obj).is_gt() {
            obj = rem;
        }
    }
    obj
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;
    use crate::task::real::real_benchmark;
    use crate::task::synthetic::synthetic_benchmark;
    use crate::util::rng::Pcg64;

    fn het3() -> Vec<DeviceProfile> {
        vec![
            profile_by_name("amd_r9").unwrap(),
            profile_by_name("xeon_phi").unwrap(),
            profile_by_name("k20c").unwrap(),
        ]
    }

    #[test]
    fn covers_every_task_exactly_once() {
        let p = profile_by_name("amd_r9").unwrap();
        let mut rng = Pcg64::seeded(11);
        let g = real_benchmark("BK50", "amd_r9", &p, 12, &mut rng, 1.0).unwrap();
        let s = schedule_fleet(&g.tasks, &het3(), &FleetOptions::default());
        let mut seen: Vec<usize> = s.orders.concat();
        seen.sort_unstable();
        assert_eq!(seen, (0..12).collect::<Vec<_>>());
        for (dev, order) in s.orders.iter().enumerate() {
            for &i in order {
                assert_eq!(s.assignment[i], dev);
            }
        }
    }

    #[test]
    fn prune_counters_fire_on_heterogeneous_fleet() {
        let p = profile_by_name("amd_r9").unwrap();
        let mut rng = Pcg64::seeded(3);
        let g = real_benchmark("BK50", "amd_r9", &p, 16, &mut rng, 1.0).unwrap();
        let s = schedule_fleet(&g.tasks, &het3(), &FleetOptions::default());
        assert!(
            s.prune.total_saved() > 0,
            "16 tasks × 3 devices must prune or collapse something: {:?}",
            s.prune
        );
    }

    #[test]
    fn pruning_never_changes_the_schedule() {
        let p = profile_by_name("amd_r9").unwrap();
        for seed in [1u64, 7, 42] {
            let mut rng = Pcg64::seeded(seed);
            let g = real_benchmark("BK50", "amd_r9", &p, 10, &mut rng, 1.0).unwrap();
            let on = schedule_fleet(
                &g.tasks,
                &het3(),
                &FleetOptions { prune: true, ..FleetOptions::default() },
            );
            let off = schedule_fleet(
                &g.tasks,
                &het3(),
                &FleetOptions { prune: false, ..FleetOptions::default() },
            );
            assert_eq!(on.assignment, off.assignment, "seed {seed}");
            assert_eq!(on.orders, off.orders, "seed {seed}");
            for (a, b) in on.device_makespans.iter().zip(&off.device_makespans) {
                assert_eq!(a.to_bits(), b.to_bits(), "seed {seed}");
            }
        }
    }

    #[test]
    fn calibrated_placement_reacts_to_corrections() {
        use crate::model::calibrate::Corrections;
        // Two identical devices; calibration says device 1's links are
        // actually 4x slower. Placement must shift load to device 0.
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
        let mut tasks = g.tasks.clone();
        tasks.extend(g.tasks.clone());
        let cals = vec![
            CalibratedProfile::identity(&p),
            CalibratedProfile::new(&p, Corrections { htd: 4.0, k: 4.0, dth: 4.0 }),
        ];
        let s = schedule_fleet_calibrated(&tasks, &cals, &FleetOptions::default());
        assert!(
            s.orders[0].len() > s.orders[1].len(),
            "calibration must shift load off the slow device: {:?}",
            s.orders.iter().map(Vec::len).collect::<Vec<_>>()
        );
    }

    #[test]
    fn steal_predicate_is_one_sided() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
        let table = TaskTable::compile(&g.tasks, &p);
        let mut frontier = SimCursor::detached();
        frontier.reset_for_table(&table, EngineState::default());
        let mut probe = SimCursor::detached();
        let mut exact = SimCursor::detached();
        let mut counters = PruneCounters::default();
        for rows in [&[0usize][..], &[0, 1][..], &[2, 3, 1][..]] {
            // Exact thief completion for these rows.
            exact.resume_from(&frontier);
            for &r in rows {
                exact.push_task_compiled(&table, r);
            }
            let t_exact = exact.run_to_quiescence();
            // Nothing wins against zero remaining work.
            assert!(!steal_predicts_win(
                &mut probe, &frontier, &table, rows, 0.0, &mut counters
            ));
            // A generous budget is accepted, and acceptance implies the
            // exact completion beats it.
            let generous = t_exact * 2.0;
            assert!(steal_predicts_win(
                &mut probe, &frontier, &table, rows, generous, &mut counters
            ));
            assert!(t_exact < generous);
            // Just below the exact completion must reject.
            assert!(!steal_predicts_win(
                &mut probe,
                &frontier,
                &table,
                rows,
                t_exact * (1.0 - 1e-6),
                &mut counters
            ));
            // NaN budget rejects.
            assert!(!steal_predicts_win(
                &mut probe,
                &frontier,
                &table,
                rows,
                f64::NAN,
                &mut counters
            ));
        }
        assert!(counters.n_cands_pruned + counters.n_rollouts_early_exit > 0);
    }

    #[test]
    #[should_panic(expected = "need at least one device")]
    fn empty_fleet_panics() {
        schedule_fleet(&[], &[], &FleetOptions::default());
    }

    fn fresh_frontiers(tables: &[TaskTable]) -> Vec<SimCursor> {
        tables
            .iter()
            .map(|t| {
                let mut c = SimCursor::detached();
                c.reset_for_table(t, EngineState::default());
                c
            })
            .collect()
    }

    #[test]
    fn batch_of_one_matches_exact_serial_scan() {
        let p = profile_by_name("amd_r9").unwrap();
        let mut rng = Pcg64::seeded(21);
        let g = real_benchmark("BK50", "amd_r9", &p, 8, &mut rng, 1.0).unwrap();
        let tables: Vec<TaskTable> =
            het3().iter().map(|pr| TaskTable::compile(&g.tasks, pr)).collect();
        let mut frontiers = fresh_frontiers(&tables);
        let elapsed = [0.0; 3];
        let available = [true; 3];
        let mut placer = BatchPlacer::new(3);
        let mut probe = SimCursor::detached();
        let mut assignment = Vec::new();
        for i in 0..8 {
            // Per-device one-row sub-tables whose row 0 is task `i`, like
            // a coordinator batch of one.
            let subs: Vec<TaskTable> = tables
                .iter()
                .map(|t| {
                    let mut s = TaskTable::new();
                    s.gather_into(t, &[i]);
                    s
                })
                .collect();
            // Reference: the exact per-arrival scan (full probe, no
            // pruning), first-device ties under total_cmp.
            let mut best_dev = 0;
            let mut best_rem = f64::INFINITY;
            for dev in 0..3 {
                probe.resume_from(&frontiers[dev]);
                probe.push_task_compiled(&subs[dev], 0);
                let rem = probe.run_to_quiescence() - elapsed[dev];
                if rem.total_cmp(&best_rem).is_lt() {
                    best_rem = rem;
                    best_dev = dev;
                }
            }
            let refs: Vec<&TaskTable> = subs.iter().collect();
            let out = placer
                .place_batch(1, &refs, &frontiers, &elapsed, &available, true, &mut assignment)
                .unwrap();
            assert_eq!(assignment, vec![best_dev], "task {i}");
            // A batch of one has nothing to improve jointly.
            assert_eq!(out.objective.to_bits(), out.greedy_objective.to_bits());
            frontiers[best_dev].push_task_compiled(&subs[best_dev], 0);
        }
    }

    #[test]
    fn batched_placement_joint_not_worse_and_deterministic() {
        let p = profile_by_name("amd_r9").unwrap();
        for seed in [5u64, 9, 33] {
            let mut rng = Pcg64::seeded(seed);
            let g = real_benchmark("BK50", "amd_r9", &p, 10, &mut rng, 1.0).unwrap();
            let tables: Vec<TaskTable> =
                het3().iter().map(|pr| TaskTable::compile(&g.tasks, pr)).collect();
            let frontiers = fresh_frontiers(&tables);
            let refs: Vec<&TaskTable> = tables.iter().collect();
            let elapsed = [0.0; 3];
            let available = [true; 3];
            let mut base: Option<(Vec<usize>, u64, u64)> = None;
            for stripes in [1usize, 2, 4, 8] {
                for prune in [true, false] {
                    let mut placer = BatchPlacer::new(stripes);
                    let mut assignment = Vec::new();
                    let out = placer
                        .place_batch(
                            10, &refs, &frontiers, &elapsed, &available, prune,
                            &mut assignment,
                        )
                        .unwrap();
                    assert!(
                        out.objective.total_cmp(&out.greedy_objective).is_le(),
                        "seed {seed}: joint {} > greedy {}",
                        out.objective,
                        out.greedy_objective
                    );
                    let key = (
                        assignment.clone(),
                        out.objective.to_bits(),
                        out.greedy_objective.to_bits(),
                    );
                    match &base {
                        None => base = Some(key),
                        Some(b) => assert_eq!(
                            &key, b,
                            "seed {seed} stripes {stripes} prune {prune}"
                        ),
                    }
                }
            }
        }
    }

    #[test]
    fn batch_placer_counters_fire() {
        let p = profile_by_name("amd_r9").unwrap();
        let mut rng = Pcg64::seeded(3);
        let g = real_benchmark("BK50", "amd_r9", &p, 16, &mut rng, 1.0).unwrap();
        let tables: Vec<TaskTable> =
            het3().iter().map(|pr| TaskTable::compile(&g.tasks, pr)).collect();
        let frontiers = fresh_frontiers(&tables);
        let refs: Vec<&TaskTable> = tables.iter().collect();
        let mut placer = BatchPlacer::new(2);
        let mut assignment = Vec::new();
        placer
            .place_batch(
                16, &refs, &frontiers, &[0.0; 3], &[true; 3], true, &mut assignment,
            )
            .unwrap();
        assert!(
            placer.prune_counters().total_saved() > 0,
            "16 tasks × 3 devices must prune or collapse something: {:?}",
            placer.prune_counters()
        );
    }

    #[test]
    fn batch_placer_declines_empty_and_unavailable() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
        let tables = vec![TaskTable::compile(&g.tasks, &p)];
        let frontiers = fresh_frontiers(&tables);
        let refs: Vec<&TaskTable> = tables.iter().collect();
        let mut placer = BatchPlacer::new(1);
        let mut assignment = vec![7usize];
        assert!(placer
            .place_batch(0, &refs, &frontiers, &[0.0], &[true], true, &mut assignment)
            .is_none());
        assert!(assignment.is_empty());
        assert!(placer
            .place_batch(2, &refs, &frontiers, &[0.0], &[false], true, &mut assignment)
            .is_none());
        assert!(assignment.is_empty());
    }
}
