//! The Batch Reordering Algorithm (paper §5.1, Algorithm 1).
//!
//! Greedy construction of a near-optimal submission order:
//!
//! 1. `select_first_task` — pick the task with a *short HtD* and *long K*
//!    relative to the rest (maximize K - HtD); ties broken by the longer
//!    DtH. This hides the most kernel time behind subsequent transfers and
//!    minimizes the initial engine idle gap.
//! 2. `select_next_task` — while more than two tasks remain, append the
//!    candidate whose addition minimizes the *simulated* completion time
//!    of the ordered prefix (the temporal model is the fitness function;
//!    this is exactly "maximize the overlap degree" since command sums are
//!    fixed). Ties again prefer longer DtH to feed the return link.
//! 3. `select_last_tasks` — for the final two slots, evaluate both
//!    remaining orders with a *trailing-exposure penalty*: the DtH tail of
//!    the last task runs with nothing left to overlap it, so the order
//!    that minimizes simulated makespan (which includes that exposed tail)
//!    wins.
//!
//! The returned order is a permutation of `0..tasks.len()` over the input
//! slice. Cost: O(T^2) simulator calls, each O(C) — Table 6 measures
//! 0.06-0.22 ms for T = 4-8 on the paper's Core 2 Quad.

use crate::config::DeviceProfile;
use crate::model::simulator::simulate_order;
use crate::model::{EngineState, SimOptions};
use crate::task::TaskSpec;

/// Beam width of the generalized greedy. Width 1 is Algorithm 1's pure
/// greedy; the default 3 recovers near-optimal orders the pure greedy
/// misses on tie-dense groups while keeping the O(w * T^2) simulation
/// budget far below the Table-6 overhead envelope.
pub const DEFAULT_BEAM_WIDTH: usize = 3;

/// Compute a near-optimal submission order for `tasks` on `profile`,
/// starting from engine state `init` (Algorithm 1's t_HTD/t_K/t_DTH).
pub fn batch_reorder(
    tasks: &[TaskSpec],
    profile: &DeviceProfile,
    init: EngineState,
) -> Vec<usize> {
    batch_reorder_beam(tasks, profile, init, DEFAULT_BEAM_WIDTH)
}

/// Beam-parameterized variant (width 1 = the paper's exact greedy loop;
/// exposed for the ablation bench).
pub fn batch_reorder_beam(
    tasks: &[TaskSpec],
    profile: &DeviceProfile,
    init: EngineState,
    width: usize,
) -> Vec<usize> {
    let n = tasks.len();
    let width = width.max(1);
    if n <= 1 {
        return (0..n).collect();
    }

    // ---- select_first_task: seed the beam with the best starters by the
    // short-HtD / long-K rule (long-DtH tie-break).
    let mut firsts: Vec<usize> = (0..n).collect();
    firsts.sort_by(|&a, &b| {
        let (sa, sb) = (tasks[a].stage_secs(profile), tasks[b].stage_secs(profile));
        let (ka, kb) = (sa.k - sa.htd, sb.k - sb.htd);
        kb.partial_cmp(&ka)
            .unwrap()
            .then(sb.dth.partial_cmp(&sa.dth).unwrap())
    });
    // Width 1 reproduces Algorithm 1 exactly: the first task comes from
    // the short-HtD/long-K rule. Wider beams consider every starter and
    // let the completion lower bound prune, which strictly dominates the
    // hand rule when more than one prefix survives.
    let seeds: Vec<usize> = if width == 1 {
        vec![firsts[0]]
    } else {
        (0..n).collect()
    };
    // Memoized rollout order (stage_secs sorts are invariant per call).
    let firsts_sorted = firsts;
    let mut beam: Vec<(Vec<usize>, f64)> = seeds
        .into_iter()
        .map(|i| {
            let score = prefix_score(tasks, &[i], &firsts_sorted, profile, init);
            (vec![i], score)
        })
        .collect();
    beam.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    beam.truncate(width);

    // ---- greedy expansion: append each remaining candidate, keep the
    // `width` prefixes with the smallest *completion lower bound* — the
    // simulated prefix end-state plus the remaining per-engine work (the
    // "best fit" of select_next_task, made pruning-safe).
    for _depth in 1..n {
        let mut next: Vec<(Vec<usize>, f64)> = Vec::new();
        for (prefix, _) in &beam {
            for cand in 0..n {
                if prefix.contains(&cand) {
                    continue;
                }
                let mut order = prefix.clone();
                order.push(cand);
                let score =
                    prefix_score(tasks, &order, &firsts_sorted, profile, init);
                next.push((order, score));
            }
        }
        next.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        next.dedup_by(|a, b| a.0 == b.0);
        next.truncate(width);
        beam = next;
    }
    // Final orders are complete, so their score IS the simulated makespan;
    // pick the best. A width-1 run is the pure Algorithm-1 greedy and acts
    // as the floor for wider beams.
    let best_beam = beam
        .into_iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .map(|(order, _)| order)
        .unwrap();
    if width == 1 {
        return best_beam;
    }
    let greedy = batch_reorder_beam(tasks, profile, init, 1);
    let m_beam = prefix_makespan(tasks, &best_beam, &[], profile, init);
    let m_greedy = prefix_makespan(tasks, &greedy, &[], profile, init);
    if m_greedy < m_beam {
        greedy
    } else {
        best_beam
    }
}

/// Pruning score of a partial order: the simulated makespan of the prefix
/// *completed by a cheap deterministic rollout* of the remaining tasks
/// (sorted by descending K - HtD, the select_first rule applied
/// repeatedly). A pure prefix-makespan or lower-bound score is loose
/// exactly on the branches that later turn bad, which mis-prunes the
/// beam; a rollout scores every prefix by a *realizable* full completion,
/// so the kept prefixes are the ones that can actually finish early. For
/// a complete order the rollout is empty and the score is the exact
/// simulated makespan.
fn prefix_score(
    tasks: &[TaskSpec],
    order: &[usize],
    rollout_rank: &[usize],
    profile: &DeviceProfile,
    init: EngineState,
) -> f64 {
    let mut full = Vec::with_capacity(tasks.len());
    full.extend_from_slice(order);
    full.extend(rollout_rank.iter().filter(|i| !order.contains(i)));
    simulate_order(tasks, &full, profile, init, SimOptions::default()).makespan
}

/// Simulated makespan of ordered prefix + suffix candidates.
fn prefix_makespan(
    tasks: &[TaskSpec],
    ordered: &[usize],
    suffix: &[usize],
    profile: &DeviceProfile,
    init: EngineState,
) -> f64 {
    let mut order = Vec::with_capacity(ordered.len() + suffix.len());
    order.extend_from_slice(ordered);
    order.extend_from_slice(suffix);
    simulate_order(tasks, &order, profile, init, SimOptions::default()).makespan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;
    use crate::model::simulator::makespan_of_order;
    use crate::sched::bruteforce::permutations;
    use crate::task::real::real_benchmark;
    use crate::task::synthetic::{benchmark_labels, synthetic_benchmark};
    use crate::util::rng::Pcg64;
    use crate::util::stats;

    #[test]
    fn returns_valid_permutation() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
        let mut order = batch_reorder(&g.tasks, &p, EngineState::default());
        order.sort_unstable();
        assert_eq!(order, vec![0, 1, 2, 3]);
    }

    #[test]
    fn trivial_sizes() {
        let p = profile_by_name("k20c").unwrap();
        let g = synthetic_benchmark("BK0", &p, 1.0).unwrap();
        assert!(batch_reorder(&[], &p, EngineState::default()).is_empty());
        assert_eq!(
            batch_reorder(&g.tasks[..1], &p, EngineState::default()),
            vec![0]
        );
        let two = batch_reorder(&g.tasks[..2], &p, EngineState::default());
        assert_eq!(two.len(), 2);
    }

    #[test]
    fn first_task_prefers_short_htd_long_k() {
        let p = profile_by_name("amd_r9").unwrap();
        // BK25 = [T0, T4, T6, T7]; T0 (0.1/0.8/0.1) maximizes K - HtD, so
        // the width-1 (pure Algorithm-1 greedy) run must start with it.
        let g = synthetic_benchmark("BK25", &p, 1.0).unwrap();
        let order =
            batch_reorder_beam(&g.tasks, &p, EngineState::default(), 1);
        assert_eq!(g.tasks[order[0]].name, "T0");
    }

    #[test]
    fn wider_beam_never_worse() {
        let p = profile_by_name("amd_r9").unwrap();
        for label in benchmark_labels() {
            let g = synthetic_benchmark(label, &p, 1.0).unwrap();
            let m1 = makespan_of_order(
                &g.tasks,
                &batch_reorder_beam(&g.tasks, &p, EngineState::default(), 1),
                &p,
            );
            let m3 = makespan_of_order(
                &g.tasks,
                &batch_reorder_beam(&g.tasks, &p, EngineState::default(), 3),
                &p,
            );
            assert!(m3 <= m1 + 1e-9, "{label}: beam3 {m3} vs beam1 {m1}");
        }
    }

    #[test]
    fn beats_mean_of_all_permutations_synthetic() {
        // The paper's core claim: the heuristic is always better than the
        // permutation average, and close to the best.
        for dev in ["amd_r9", "k20c", "xeon_phi"] {
            let p = profile_by_name(dev).unwrap();
            for label in benchmark_labels() {
                let g = synthetic_benchmark(label, &p, 1.0).unwrap();
                let all: Vec<f64> = permutations(4)
                    .iter()
                    .map(|perm| makespan_of_order(&g.tasks, perm, &p))
                    .collect();
                let order = batch_reorder(&g.tasks, &p, EngineState::default());
                let h = makespan_of_order(&g.tasks, &order, &p);
                let mean = stats::mean(&all);
                let best = stats::min(&all);
                assert!(
                    h <= mean + 1e-9,
                    "{dev}/{label}: heuristic {h} vs mean {mean}"
                );
                assert!(
                    h <= best * 1.10 + 1e-9,
                    "{dev}/{label}: heuristic {h} vs best {best}"
                );
            }
        }
    }

    #[test]
    fn beats_mean_on_random_real_groups() {
        let mut rng = Pcg64::seeded(31);
        for dev in ["amd_r9", "k20c"] {
            let p = profile_by_name(dev).unwrap();
            for trial in 0..5 {
                let g = real_benchmark("BK50", dev, &p, 5, &mut rng, 1.0)
                    .unwrap();
                let all: Vec<f64> = permutations(5)
                    .iter()
                    .map(|perm| makespan_of_order(&g.tasks, perm, &p))
                    .collect();
                let order = batch_reorder(&g.tasks, &p, EngineState::default());
                let h = makespan_of_order(&g.tasks, &order, &p);
                assert!(
                    h <= stats::mean(&all) + 1e-9,
                    "{dev} trial {trial}: {h} vs mean {}",
                    stats::mean(&all)
                );
            }
        }
    }

    #[test]
    fn respects_initial_engine_state() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
        // Busy HtD engine should not crash or produce an invalid order.
        let st = EngineState { htd_free: 3e-3, k_free: 1e-3, dth_free: 0.0 };
        let mut order = batch_reorder(&g.tasks, &p, st);
        order.sort_unstable();
        assert_eq!(order, vec![0, 1, 2, 3]);
    }
}
