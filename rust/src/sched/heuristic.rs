//! The Batch Reordering Algorithm (paper §5.1, Algorithm 1).
//!
//! Greedy construction of a near-optimal submission order:
//!
//! 1. `select_first_task` — pick the task with a *short HtD* and *long K*
//!    relative to the rest (maximize K - HtD); ties broken by the longer
//!    DtH. This hides the most kernel time behind subsequent transfers and
//!    minimizes the initial engine idle gap.
//! 2. `select_next_task` — while more than two tasks remain, append the
//!    candidate whose addition minimizes the *simulated* completion time
//!    of the ordered prefix (the temporal model is the fitness function;
//!    this is exactly "maximize the overlap degree" since command sums are
//!    fixed). Ties again prefer longer DtH to feed the return link.
//! 3. `select_last_tasks` — for the final two slots, evaluate both
//!    remaining orders with a *trailing-exposure penalty*: the DtH tail of
//!    the last task runs with nothing left to overlap it, so the order
//!    that minimizes simulated makespan (which includes that exposed tail)
//!    wins.
//!
//! The returned order is a permutation of `0..tasks.len()` over the input
//! slice.
//!
//! # Cost (post-refactor, bound-gated)
//!
//! The search runs on [`SimCursor`]s: every surviving beam prefix is
//! simulated **once** up to its committed frontier and kept paused inside
//! its [`BeamScratch`] entry; each candidate extension is scored by
//! `resume_from` + `push_task_compiled` + a **bounded** finish on a pooled
//! probe cursor instead of replaying the prefix from scratch. On top of
//! the amortized O(w·T²·C) resume structure sits a branch-and-bound layer
//! (see `sched::search_util`): each expansion round carries a running
//! admission cutoff — the w-th best score seen, seeded from the sorted
//! parent beam's w-th admitted score — and a candidate is simulated only
//! when (a) its static admissible floor (paused prefix clock + remaining
//! solo HtD work + smallest remaining kernel+DtH tail, and its own
//! sequential floor) cannot prove it strictly worse, and (b) no spec-twin
//! representative of it was already scored for the same prefix
//! (`TaskTable::twin_class` collapse). Survivors run under the cutoff and
//! abort the instant the simulated clock — a monotone lower bound on the
//! final makespan — strictly exceeds it. Pruning fires only on *strict*
//! dominance (margin-guarded for analytic floors, exact for the clock),
//! so the returned permutation is bit-identical to the unpruned search
//! for every width, profile and thread count — `rust/tests/prop_bounds.rs`
//! pins this; worst-case cost is unchanged, but on twin-rich groups most
//! provable losers now cost O(1) instead of a full O(T·C) rollout.
//!
//! Membership tests are bitmask words, the group is compiled once per
//! call into a [`TaskTable`], and the whole inner loop performs **zero
//! heap allocations** after warm-up: beam entries, masks, candidate
//! lists, cutoff buffers, the table and the cursors all live in the
//! reusable [`BeamScratch`] arena (thread-local for the convenience
//! wrappers, caller-owned via [`batch_reorder_beam_into`]). For larger
//! groups, `sched::parallel` fans candidate scoring out over a persistent
//! thread pool while returning bit-identical orders. The pre-refactor
//! implementation is preserved as [`batch_reorder_beam_replay`] for
//! equivalence tests and as the overhead baseline in
//! `benches/table6_overhead.rs`.
//!
//! All f64 score comparisons use `f64::total_cmp`: a NaN from a
//! degenerate profile must not panic the coordinator's proxy thread
//! mid-drain (it sorts last instead, and never admits a prune).

use std::cell::RefCell;

use crate::config::DeviceProfile;
use crate::model::simulator::{simulate_order_fromscratch, SimCursor};
use crate::model::{EngineState, SimOptions, TaskTable};
use crate::sched::search_util::{
    cand_cmp, debug_assert_mask_sized, entry_at, gated_score, mask_contains,
    mask_set, mask_words, remaining_floor, rollout_score_bounded,
    score_candidate_bounded, set_mask_len, BeamEntry, Cand, PruneCounters,
    RunningCutoff,
};
use crate::task::TaskSpec;

/// Beam width of the generalized greedy. Width 1 is Algorithm 1's pure
/// greedy; the default 3 recovers near-optimal orders the pure greedy
/// misses on tie-dense groups while keeping the simulation budget far
/// below the Table-6 overhead envelope.
pub const DEFAULT_BEAM_WIDTH: usize = 3;

/// Reusable arena for the beam search: compiled task table, cursors, beam
/// entry pools, candidate list, rollout ranking and the pruning layer's
/// cutoff buffer. After the first call at a given (T, command-count)
/// size, subsequent calls through the same scratch perform no heap
/// allocations.
pub struct BeamScratch {
    table: TaskTable,
    base: SimCursor,
    probe: SimCursor,
    beam: Vec<BeamEntry>,
    next: Vec<BeamEntry>,
    beam_len: usize,
    cands: Vec<Cand>,
    firsts: Vec<usize>,
    greedy: Vec<usize>,
    pruning: bool,
    cutoff: RunningCutoff,
    counters: PruneCounters,
}

impl BeamScratch {
    pub fn new() -> BeamScratch {
        Self::with_pruning(true)
    }

    /// `pruning: false` disables the whole bound-gated layer (static
    /// floors, twin collapse, bounded rollouts) — every candidate is
    /// simulated to quiescence exactly as before the layer existed. The
    /// results are bit-identical either way (property-tested); the switch
    /// exists for that test and for the pruned-vs-unpruned overhead rows
    /// in `benches/table6_overhead.rs`.
    pub fn with_pruning(pruning: bool) -> BeamScratch {
        BeamScratch {
            table: TaskTable::new(),
            base: SimCursor::detached(),
            probe: SimCursor::detached(),
            beam: Vec::new(),
            next: Vec::new(),
            beam_len: 0,
            cands: Vec::new(),
            firsts: Vec::new(),
            greedy: Vec::new(),
            pruning,
            cutoff: RunningCutoff::default(),
            counters: PruneCounters::default(),
        }
    }

    pub fn set_pruning(&mut self, pruning: bool) {
        self.pruning = pruning;
    }

    /// Pruning efficacy counters accumulated since construction (or the
    /// last [`BeamScratch::reset_prune_counters`]).
    pub fn prune_counters(&self) -> PruneCounters {
        self.counters
    }

    pub fn reset_prune_counters(&mut self) {
        self.counters = PruneCounters::default();
    }
}

impl Default for BeamScratch {
    fn default() -> Self {
        Self::new()
    }
}

thread_local! {
    /// Per-thread arena backing the convenience wrappers, so repeated
    /// calls (coordinator rounds, benches, multi-device placement) reuse
    /// warm buffers without threading a scratch through every signature.
    static TLS_SCRATCH: RefCell<BeamScratch> = RefCell::new(BeamScratch::new());
}

/// Compute a near-optimal submission order for `tasks` on `profile`,
/// starting from engine state `init` (Algorithm 1's t_HTD/t_K/t_DTH).
pub fn batch_reorder(
    tasks: &[TaskSpec],
    profile: &DeviceProfile,
    init: EngineState,
) -> Vec<usize> {
    batch_reorder_beam(tasks, profile, init, DEFAULT_BEAM_WIDTH)
}

/// Beam-parameterized variant (width 1 = the paper's exact greedy loop;
/// exposed for the ablation bench).
pub fn batch_reorder_beam(
    tasks: &[TaskSpec],
    profile: &DeviceProfile,
    init: EngineState,
    width: usize,
) -> Vec<usize> {
    TLS_SCRATCH.with(|s| {
        let mut scratch = s.borrow_mut();
        let mut out = Vec::with_capacity(tasks.len());
        batch_reorder_beam_into(tasks, profile, init, width, &mut scratch, &mut out);
        out
    })
}

/// Allocation-free core: writes the order into `out` using only buffers
/// from `scratch` (both are reused across calls; after warm-up the whole
/// search performs zero heap allocations — see `rust/tests/alloc_free.rs`).
/// Compiles the group into the scratch's [`TaskTable`] once and runs the
/// search entirely over the compiled SoA rows.
pub fn batch_reorder_beam_into(
    tasks: &[TaskSpec],
    profile: &DeviceProfile,
    init: EngineState,
    width: usize,
    scratch: &mut BeamScratch,
    out: &mut Vec<usize>,
) {
    let mut table = std::mem::take(&mut scratch.table);
    table.compile_into(tasks, profile);
    beam_over_table(&table, init, width, scratch, out);
    scratch.table = table;
}

/// [`batch_reorder_beam_into`] over a caller-compiled [`TaskTable`] — the
/// serial counterpart of
/// `sched::parallel::batch_reorder_table_parallel_into`, for callers that
/// already hold the group compiled (a lane sharing one table between
/// search and prediction, or a table compiled against a *calibrated*
/// planning model via `model::calibrate` — the search is model-parametric
/// and runs bit-exactly over whatever rates the table carries).
pub fn batch_reorder_table_into(
    table: &TaskTable,
    init: EngineState,
    width: usize,
    scratch: &mut BeamScratch,
    out: &mut Vec<usize>,
) {
    beam_over_table(table, init, width, scratch, out);
}

/// The search proper, over a pre-compiled table. Split out so the width-1
/// greedy floor (and the parallel search's serial fallback) recurse
/// without recompiling the table.
pub(crate) fn beam_over_table(
    table: &TaskTable,
    init: EngineState,
    width: usize,
    scratch: &mut BeamScratch,
    out: &mut Vec<usize>,
) {
    let n = table.len();
    let width = width.max(1);
    out.clear();
    if n <= 1 {
        out.extend(0..n);
        return;
    }
    let words = mask_words(n);

    {
        let BeamScratch {
            base,
            probe,
            beam,
            next,
            beam_len,
            cands,
            firsts,
            pruning,
            cutoff,
            counters,
            ..
        } = scratch;
        let prune = *pruning;

        rank_firsts(table, firsts);
        base.reset_params(table.params(), init);

        // ---- seed the beam. Width 1 reproduces Algorithm 1 exactly: the
        // first task comes from the short-HtD/long-K rule. Wider beams
        // consider every starter — walked in rollout-rank order so
        // spec-twin seeds collapse onto one simulated representative —
        // and let the rollout score prune, which strictly dominates the
        // hand rule when more than one prefix survives.
        *beam_len = 0;
        if width == 1 {
            let seed = firsts[0];
            let e = entry_at(beam, 0);
            e.order.clear();
            e.order.push(seed);
            set_mask_len(&mut e.mask, words);
            mask_set(&mut e.mask, seed);
            e.cursor.resume_from(base);
            e.cursor.push_task_compiled(table, seed);
            e.score = rollout_score_bounded(
                probe,
                &e.cursor,
                &e.mask,
                firsts,
                table,
                |p| p,
                f64::INFINITY,
            )
            .expect("unbounded rollout always completes");
            *beam_len = 1;
        } else {
            cutoff.reset(width, f64::INFINITY);
            // Static floor shared by every seed: nothing is placed yet,
            // so the remaining work is exactly the table's compiled
            // group aggregates — no scan needed.
            let common = base
                .lower_bound_with_remaining(
                    table.total_htd_secs(),
                    table.total_kernel_secs(),
                    table.total_dth_secs(),
                )
                .max(base.clock() + table.total_htd_secs() + table.min_kd_tail());
            let mut prev: Option<(u32, f64)> = None;
            for &seed in firsts.iter() {
                let e = entry_at(beam, *beam_len);
                e.order.clear();
                e.order.push(seed);
                set_mask_len(&mut e.mask, words);
                mask_set(&mut e.mask, seed);
                e.cursor.resume_from(base);
                e.cursor.push_task_compiled(table, seed);
                e.score = gated_score(
                    prune,
                    cutoff,
                    counters,
                    &mut prev,
                    table.twin_class(seed),
                    common.max(base.clock() + table.sequential_secs(seed)),
                    |thr| {
                        rollout_score_bounded(
                            probe, &e.cursor, &e.mask, firsts, table, |p| p, thr,
                        )
                    },
                );
                *beam_len += 1;
            }
        }
        beam[..*beam_len].sort_unstable_by(|a, b| {
            a.score.total_cmp(&b.score).then(a.order[0].cmp(&b.order[0]))
        });
        *beam_len = (*beam_len).min(width);

        // ---- greedy expansion: extend each surviving prefix by every
        // absent candidate (walked in rollout-rank order so spec twins
        // collapse), score survivors by resuming the prefix cursor under
        // the round's admission cutoff, keep the `width` best. The cutoff
        // seed is sound because each sorted parent's firsts-head
        // extension replays the parent's own rollout bit-exactly.
        for _depth in 1..n {
            cands.clear();
            let seed_thr = if prune && *beam_len >= width {
                beam[width - 1].score
            } else {
                f64::INFINITY
            };
            cutoff.reset(width, seed_thr);
            for p in 0..*beam_len {
                let parent = &beam[p];
                debug_assert_mask_sized(&parent.mask, n);
                let p_bound = if prune {
                    let (rem_htd, rem_k, rem_dth, min_tail) = remaining_floor(
                        n,
                        table,
                        |pos| pos,
                        |pos| mask_contains(&parent.mask, pos),
                    );
                    parent
                        .cursor
                        .lower_bound_with_remaining(rem_htd, rem_k, rem_dth)
                        .max(parent.cursor.clock() + rem_htd + min_tail)
                } else {
                    0.0
                };
                let mut prev: Option<(u32, f64)> = None;
                for &cand in firsts.iter() {
                    if mask_contains(&parent.mask, cand) {
                        continue;
                    }
                    let score = gated_score(
                        prune,
                        cutoff,
                        counters,
                        &mut prev,
                        table.twin_class(cand),
                        p_bound.max(
                            parent.cursor.clock() + table.sequential_secs(cand),
                        ),
                        |thr| {
                            score_candidate_bounded(
                                probe,
                                &parent.cursor,
                                &parent.mask,
                                cand,
                                firsts,
                                table,
                                |p| p,
                                thr,
                            )
                        },
                    );
                    cands.push(Cand {
                        parent: p as u32,
                        cand: cand as u32,
                        score,
                    });
                }
            }
            cands.sort_unstable_by(cand_cmp);
            let keep = width.min(cands.len());
            for (k, c) in cands[..keep].iter().enumerate() {
                let parent = &beam[c.parent as usize];
                let e = entry_at(next, k);
                e.order.clone_from(&parent.order);
                e.order.push(c.cand as usize);
                e.mask.clone_from(&parent.mask);
                mask_set(&mut e.mask, c.cand as usize);
                e.cursor.resume_from(&parent.cursor);
                e.cursor.push_task_compiled(table, c.cand as usize);
                e.score = c.score;
            }
            std::mem::swap(beam, next);
            *beam_len = keep;
        }

        // ---- final orders are complete, so their score IS the simulated
        // makespan (pruned candidates can never be kept: every prune is a
        // proof of strict exclusion from the top-w); the beam is sorted
        // ascending with the generation-order tie-break, so beam[0] is
        // exactly what the replay path's `min_by` (first of equal minima)
        // selects.
        out.clone_from(&beam[0].order);
        if width == 1 {
            return;
        }
    }

    // ---- width-1 floor: a pure Algorithm-1 greedy run acts as the floor
    // for wider beams (scratch is reused; `out` holds the beam result).
    // total_cmp: under `<` a NaN beam score kept the beam order; under
    // the total order the greedy floor wins against it — mirrored
    // exactly in the parallel and replay paths so all three stay
    // bit-identical.
    let m_beam = order_makespan(&mut scratch.probe, table, out, init);
    let mut greedy = std::mem::take(&mut scratch.greedy);
    beam_over_table(table, init, 1, scratch, &mut greedy);
    let m_greedy = order_makespan(&mut scratch.probe, table, &greedy, init);
    if m_greedy.total_cmp(&m_beam).is_lt() {
        out.clone_from(&greedy);
    }
    scratch.greedy = greedy;
}

/// The select_first_task ranking (descending `K - HtD`, ties by longer
/// DtH, then index — reproducing the stable sort of the replay path),
/// reused as the rollout order of prefix scores. Reads the table's
/// precomputed keys; `total_cmp` keeps a NaN from panicking the caller.
pub(crate) fn rank_firsts(table: &TaskTable, firsts: &mut Vec<usize>) {
    firsts.clear();
    firsts.extend(0..table.len());
    firsts.sort_unstable_by(|&a, &b| {
        table
            .k_minus_htd(b)
            .total_cmp(&table.k_minus_htd(a))
            .then(table.dth_secs(b).total_cmp(&table.dth_secs(a)))
            .then(a.cmp(&b))
    });
}

/// Exact simulated makespan of a complete order, on a pooled cursor.
pub(crate) fn order_makespan(
    probe: &mut SimCursor,
    table: &TaskTable,
    order: &[usize],
    init: EngineState,
) -> f64 {
    probe.reset_params(table.params(), init);
    for &i in order {
        probe.push_task_compiled(table, i);
    }
    probe.run_to_quiescence()
}

// ---------------------------------------------------------------------------
// Pre-refactor reference implementation
// ---------------------------------------------------------------------------

/// The pre-refactor beam search, verbatim: every candidate prefix is
/// re-simulated from scratch with [`simulate_order_fromscratch`] and
/// membership is an O(T) `contains` scan. Kept as (a) the reference the
/// equivalence property tests pin the fast path to (identical orders on
/// random groups), and (b) the baseline `benches/table6_overhead.rs`
/// measures the >= 3x reorder-overhead win against.
pub fn batch_reorder_beam_replay(
    tasks: &[TaskSpec],
    profile: &DeviceProfile,
    init: EngineState,
    width: usize,
) -> Vec<usize> {
    let n = tasks.len();
    let width = width.max(1);
    if n <= 1 {
        return (0..n).collect();
    }

    let mut firsts: Vec<usize> = (0..n).collect();
    firsts.sort_by(|&a, &b| {
        let (sa, sb) = (tasks[a].stage_secs(profile), tasks[b].stage_secs(profile));
        let (ka, kb) = (sa.k - sa.htd, sb.k - sb.htd);
        kb.total_cmp(&ka).then(sb.dth.total_cmp(&sa.dth))
    });
    let seeds: Vec<usize> = if width == 1 {
        vec![firsts[0]]
    } else {
        (0..n).collect()
    };
    let firsts_sorted = firsts;
    let mut beam: Vec<(Vec<usize>, f64)> = seeds
        .into_iter()
        .map(|i| {
            let score =
                prefix_score_replay(tasks, &[i], &firsts_sorted, profile, init);
            (vec![i], score)
        })
        .collect();
    beam.sort_by(|a, b| a.1.total_cmp(&b.1));
    beam.truncate(width);

    for _depth in 1..n {
        let mut next: Vec<(Vec<usize>, f64)> = Vec::new();
        for (prefix, _) in &beam {
            for cand in 0..n {
                if prefix.contains(&cand) {
                    continue;
                }
                let mut order = prefix.clone();
                order.push(cand);
                let score = prefix_score_replay(
                    tasks,
                    &order,
                    &firsts_sorted,
                    profile,
                    init,
                );
                next.push((order, score));
            }
        }
        next.sort_by(|a, b| a.1.total_cmp(&b.1));
        next.dedup_by(|a, b| a.0 == b.0);
        next.truncate(width);
        beam = next;
    }
    let best_beam = beam
        .into_iter()
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .map(|(order, _)| order)
        .unwrap();
    if width == 1 {
        return best_beam;
    }
    let greedy = batch_reorder_beam_replay(tasks, profile, init, 1);
    let m_beam = prefix_makespan_replay(tasks, &best_beam, &[], profile, init);
    let m_greedy = prefix_makespan_replay(tasks, &greedy, &[], profile, init);
    // total_cmp, matching the resumable path's floor comparison (the
    // equivalence tests pin the two implementations to each other).
    if m_greedy.total_cmp(&m_beam).is_lt() {
        greedy
    } else {
        best_beam
    }
}

/// Replay counterpart of the rollout pruning score (from-scratch
/// simulation + O(n^2) membership scan, as before the refactor).
fn prefix_score_replay(
    tasks: &[TaskSpec],
    order: &[usize],
    rollout_rank: &[usize],
    profile: &DeviceProfile,
    init: EngineState,
) -> f64 {
    let mut full = Vec::with_capacity(tasks.len());
    full.extend_from_slice(order);
    full.extend(rollout_rank.iter().filter(|i| !order.contains(i)));
    simulate_order_fromscratch(tasks, &full, profile, init, SimOptions::default())
        .makespan
}

/// Simulated makespan of ordered prefix + suffix candidates (replay path).
fn prefix_makespan_replay(
    tasks: &[TaskSpec],
    ordered: &[usize],
    suffix: &[usize],
    profile: &DeviceProfile,
    init: EngineState,
) -> f64 {
    let mut order = Vec::with_capacity(ordered.len() + suffix.len());
    order.extend_from_slice(ordered);
    order.extend_from_slice(suffix);
    simulate_order_fromscratch(tasks, &order, profile, init, SimOptions::default())
        .makespan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;
    use crate::model::simulator::makespan_of_order;
    use crate::sched::bruteforce::permutations;
    use crate::task::real::real_benchmark;
    use crate::task::synthetic::{benchmark_labels, synthetic_benchmark};
    use crate::util::rng::Pcg64;
    use crate::util::stats;

    #[test]
    fn returns_valid_permutation() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
        let mut order = batch_reorder(&g.tasks, &p, EngineState::default());
        order.sort_unstable();
        assert_eq!(order, vec![0, 1, 2, 3]);
    }

    #[test]
    fn trivial_sizes() {
        let p = profile_by_name("k20c").unwrap();
        let g = synthetic_benchmark("BK0", &p, 1.0).unwrap();
        assert!(batch_reorder(&[], &p, EngineState::default()).is_empty());
        assert_eq!(
            batch_reorder(&g.tasks[..1], &p, EngineState::default()),
            vec![0]
        );
        let two = batch_reorder(&g.tasks[..2], &p, EngineState::default());
        assert_eq!(two.len(), 2);
    }

    #[test]
    fn first_task_prefers_short_htd_long_k() {
        let p = profile_by_name("amd_r9").unwrap();
        // BK25 = [T0, T4, T6, T7]; T0 (0.1/0.8/0.1) maximizes K - HtD, so
        // the width-1 (pure Algorithm-1 greedy) run must start with it.
        let g = synthetic_benchmark("BK25", &p, 1.0).unwrap();
        let order =
            batch_reorder_beam(&g.tasks, &p, EngineState::default(), 1);
        assert_eq!(g.tasks[order[0]].name, "T0");
    }

    #[test]
    fn wider_beam_never_worse() {
        let p = profile_by_name("amd_r9").unwrap();
        for label in benchmark_labels() {
            let g = synthetic_benchmark(label, &p, 1.0).unwrap();
            let m1 = makespan_of_order(
                &g.tasks,
                &batch_reorder_beam(&g.tasks, &p, EngineState::default(), 1),
                &p,
            );
            let m3 = makespan_of_order(
                &g.tasks,
                &batch_reorder_beam(&g.tasks, &p, EngineState::default(), 3),
                &p,
            );
            assert!(m3 <= m1 + 1e-9, "{label}: beam3 {m3} vs beam1 {m1}");
        }
    }

    #[test]
    fn beats_mean_of_all_permutations_synthetic() {
        // The paper's core claim: the heuristic is always better than the
        // permutation average, and close to the best.
        for dev in ["amd_r9", "k20c", "xeon_phi"] {
            let p = profile_by_name(dev).unwrap();
            for label in benchmark_labels() {
                let g = synthetic_benchmark(label, &p, 1.0).unwrap();
                let all: Vec<f64> = permutations(4)
                    .iter()
                    .map(|perm| makespan_of_order(&g.tasks, perm, &p))
                    .collect();
                let order = batch_reorder(&g.tasks, &p, EngineState::default());
                let h = makespan_of_order(&g.tasks, &order, &p);
                let mean = stats::mean(&all);
                let best = stats::min(&all);
                assert!(
                    h <= mean + 1e-9,
                    "{dev}/{label}: heuristic {h} vs mean {mean}"
                );
                assert!(
                    h <= best * 1.10 + 1e-9,
                    "{dev}/{label}: heuristic {h} vs best {best}"
                );
            }
        }
    }

    #[test]
    fn beats_mean_on_random_real_groups() {
        let mut rng = Pcg64::seeded(31);
        for dev in ["amd_r9", "k20c"] {
            let p = profile_by_name(dev).unwrap();
            for trial in 0..5 {
                let g = real_benchmark("BK50", dev, &p, 5, &mut rng, 1.0)
                    .unwrap();
                let all: Vec<f64> = permutations(5)
                    .iter()
                    .map(|perm| makespan_of_order(&g.tasks, perm, &p))
                    .collect();
                let order = batch_reorder(&g.tasks, &p, EngineState::default());
                let h = makespan_of_order(&g.tasks, &order, &p);
                assert!(
                    h <= stats::mean(&all) + 1e-9,
                    "{dev} trial {trial}: {h} vs mean {}",
                    stats::mean(&all)
                );
            }
        }
    }

    #[test]
    fn respects_initial_engine_state() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
        // Busy HtD engine should not crash or produce an invalid order.
        let st = EngineState { htd_free: 3e-3, k_free: 1e-3, dth_free: 0.0 };
        let mut order = batch_reorder(&g.tasks, &p, st);
        order.sort_unstable();
        assert_eq!(order, vec![0, 1, 2, 3]);
    }

    #[test]
    fn matches_replay_on_catalogs() {
        // The resumable (and pruned) search must return exactly the order
        // the pre-refactor implementation returned.
        for dev in ["amd_r9", "k20c", "xeon_phi"] {
            let p = profile_by_name(dev).unwrap();
            for label in benchmark_labels() {
                let g = synthetic_benchmark(label, &p, 1.0).unwrap();
                for width in [1usize, 2, 3, 6] {
                    let fast = batch_reorder_beam(
                        &g.tasks,
                        &p,
                        EngineState::default(),
                        width,
                    );
                    let slow = batch_reorder_beam_replay(
                        &g.tasks,
                        &p,
                        EngineState::default(),
                        width,
                    );
                    assert_eq!(fast, slow, "{dev}/{label} width {width}");
                }
            }
        }
    }

    #[test]
    fn pruned_matches_unpruned_and_counters_fire_on_twins() {
        // Twin-rich group: the 4-spec BK50 catalog repeated to T=12.
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
        let tasks: Vec<crate::task::TaskSpec> =
            (0..12).map(|i| g.tasks[i % 4].clone()).collect();
        let mut pruned = BeamScratch::new();
        let mut plain = BeamScratch::with_pruning(false);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        for width in [1usize, 3] {
            batch_reorder_beam_into(
                &tasks,
                &p,
                EngineState::default(),
                width,
                &mut pruned,
                &mut a,
            );
            batch_reorder_beam_into(
                &tasks,
                &p,
                EngineState::default(),
                width,
                &mut plain,
                &mut b,
            );
            assert_eq!(a, b, "width {width}");
        }
        let c = pruned.prune_counters();
        assert!(c.n_twin_collapsed > 0, "twin-rich group never collapsed: {c:?}");
        assert!(
            c.n_cands_pruned + c.n_rollouts_early_exit > 0,
            "bound layer never fired: {c:?}"
        );
        let c0 = plain.prune_counters();
        assert_eq!(c0.total_saved(), 0, "pruning-off scratch must not count");
    }

    #[test]
    fn explicit_scratch_matches_wrapper() {
        let p = profile_by_name("k20c").unwrap();
        let mut rng = Pcg64::seeded(77);
        let g = real_benchmark("BK50", "k20c", &p, 6, &mut rng, 1.0).unwrap();
        let via_tls = batch_reorder(&g.tasks, &p, EngineState::default());
        let mut scratch = BeamScratch::new();
        let mut out = Vec::new();
        for _ in 0..3 {
            batch_reorder_beam_into(
                &g.tasks,
                &p,
                EngineState::default(),
                DEFAULT_BEAM_WIDTH,
                &mut scratch,
                &mut out,
            );
            assert_eq!(out, via_tls);
        }
    }
}
