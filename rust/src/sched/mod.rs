//! Task-ordering schedulers (paper §5).
//!
//! * `heuristic` — the paper's Batch Reordering Algorithm (Algorithm 1):
//!   a greedy, model-guided beam search over resumable `SimCursor`
//!   snapshots (each prefix simulated once, candidates scored by resume),
//!   allocation-free after warm-up via its `BeamScratch` arena.
//! * `parallel` — the same beam search with candidate scoring fanned out
//!   over a persistent thread pool (per-stripe probe arenas + an exact
//!   prefix transposition memo), returning bit-identical orders.
//! * `online` — incremental mid-group re-planning for an open submission
//!   stream: the uncommitted suffix is re-scored against a committed
//!   prefix's paused cursor state, admission-controlled by a
//!   predicted-vs-measured drift gate.
//! * `search_util` — plumbing shared by the three beam searches (pooled
//!   entries, membership masks, the deterministic candidate ordering) and
//!   the bound-gated pruning layer (admission cutoffs, admissible floors,
//!   bounded rollouts, spec-twin collapse) they all consult — provably
//!   result-invariant, so every search stays bit-identical with pruning
//!   on or off.
//! * `fleet` — heterogeneous multi-device scheduling: calibrated
//!   earliest-completion-time placement over per-device `TaskTable`s,
//!   scored through the bound-gated layer (floors, bounded probes,
//!   cross-device twin collapse — bit-identical with pruning on or off),
//!   plus the calibrated cross-device steal predicate.
//! * `multidevice` — the stable `MultiSchedule` surface (now a wrapper
//!   over `fleet`) and the `round_robin` baseline.
//! * `bruteforce` — exhaustive / sampled permutation evaluation (the
//!   NoReorder experimental setup of §6.2).
//! * `baselines` — classic orderings (FIFO, random, SJF, LPT-kernel,
//!   alternate-dominance) used as ablation comparators.

pub mod baselines;
pub mod bruteforce;
pub mod fleet;
pub mod heuristic;
pub mod multidevice;
pub mod online;
pub mod parallel;
pub mod search_util;

pub use bruteforce::{permutations, OrderStats};
pub use fleet::{
    schedule_fleet, schedule_fleet_calibrated, schedule_fleet_tables,
    steal_predicts_win, FleetOptions, FleetSchedule,
};
pub use heuristic::{
    batch_reorder, batch_reorder_beam_into, batch_reorder_table_into, BeamScratch,
};
pub use multidevice::{round_robin, schedule_multi, MultiSchedule};
pub use online::{replan_into, DriftGate, OnlineOptions, OnlineScratch, Replan};
pub use parallel::{
    batch_reorder_beam_parallel_into, batch_reorder_table_parallel_into,
    ParBeamScratch, ScoringPool,
};
pub use search_util::PruneCounters;
