//! Task-ordering schedulers (paper §5).
//!
//! * `heuristic` — the paper's Batch Reordering Algorithm (Algorithm 1):
//!   a greedy, model-guided search that runs in O(T^2) simulations.
//! * `bruteforce` — exhaustive / sampled permutation evaluation (the
//!   NoReorder experimental setup of §6.2).
//! * `baselines` — classic orderings (FIFO, random, SJF, LPT-kernel,
//!   alternate-dominance) used as ablation comparators.

pub mod baselines;
pub mod bruteforce;
pub mod heuristic;
pub mod multidevice;

pub use bruteforce::{permutations, OrderStats};
pub use heuristic::batch_reorder;
pub use multidevice::{schedule_multi, MultiSchedule};
