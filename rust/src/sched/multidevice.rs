//! Multi-accelerator scheduling — the paper's stated future work ("we
//! plan to integrate our heuristic and execution model in a multi-GPU
//! architecture"), built on the same temporal model.
//!
//! [`schedule_multi`] is now a thin wrapper over the fleet scheduler
//! ([`crate::sched::fleet::schedule_fleet`]): same two-phase shape
//! (earliest-completion-time LPT placement, then per-device Batch
//! Reordering), with placement scored through the bound-gated pruning
//! layer instead of a full probe per (task × device) — decisions are
//! bit-identical (see `sched::fleet` and rust/tests/prop_fleet.rs).
//! This module keeps the stable `MultiSchedule` surface and the
//! [`round_robin`] baseline.
//!
//! The group makespan is the max over devices.

use crate::config::DeviceProfile;
use crate::model::simulator::simulate_order;
use crate::model::{EngineState, SimOptions};
use crate::sched::fleet::{schedule_fleet, FleetOptions};
use crate::task::TaskSpec;

/// A complete multi-device schedule.
#[derive(Clone, Debug)]
pub struct MultiSchedule {
    /// assignment[i] = device index for task i.
    pub assignment: Vec<usize>,
    /// Per-device submission order (indices into the original task slice).
    pub orders: Vec<Vec<usize>>,
    /// Predicted makespan per device.
    pub device_makespans: Vec<f64>,
}

impl MultiSchedule {
    /// Predicted group makespan (max over devices).
    pub fn makespan(&self) -> f64 {
        self.device_makespans.iter().cloned().fold(0.0, f64::max)
    }
}

/// Schedule `tasks` across `profiles` (one entry per device).
///
/// Panics if `profiles` is empty ("need at least one device") — the same
/// documented contract as [`round_robin`].
pub fn schedule_multi(tasks: &[TaskSpec], profiles: &[DeviceProfile]) -> MultiSchedule {
    let f = schedule_fleet(tasks, profiles, &FleetOptions::default());
    MultiSchedule {
        assignment: f.assignment,
        orders: f.orders,
        device_makespans: f.device_makespans,
    }
}

/// Baseline: round-robin placement, arrival order per device.
///
/// Panics if `profiles` is empty ("need at least one device") — the
/// modulo routing would otherwise divide by zero; this is the same
/// contract as [`schedule_multi`], asserted instead of left to the
/// arithmetic panic.
pub fn round_robin(tasks: &[TaskSpec], profiles: &[DeviceProfile]) -> MultiSchedule {
    assert!(!profiles.is_empty(), "need at least one device");
    let d = profiles.len();
    let mut orders: Vec<Vec<usize>> = vec![Vec::new(); d];
    let mut assignment = vec![0usize; tasks.len()];
    for i in 0..tasks.len() {
        orders[i % d].push(i);
        assignment[i] = i % d;
    }
    let device_makespans = orders
        .iter()
        .zip(profiles)
        .map(|(order, p)| {
            simulate_order(tasks, order, p, EngineState::default(), SimOptions::default())
                .makespan
        })
        .collect();
    MultiSchedule { assignment, orders, device_makespans }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;
    use crate::task::real::real_benchmark;
    use crate::task::synthetic::synthetic_benchmark;
    use crate::util::rng::Pcg64;

    fn two_r9() -> Vec<DeviceProfile> {
        vec![
            profile_by_name("amd_r9").unwrap(),
            profile_by_name("amd_r9").unwrap(),
        ]
    }

    #[test]
    fn schedule_covers_every_task_exactly_once() {
        let p = profile_by_name("amd_r9").unwrap();
        let mut rng = Pcg64::seeded(1);
        let g = real_benchmark("BK50", "amd_r9", &p, 8, &mut rng, 1.0).unwrap();
        let s = schedule_multi(&g.tasks, &two_r9());
        let mut seen: Vec<usize> = s.orders.concat();
        seen.sort_unstable();
        assert_eq!(seen, (0..8).collect::<Vec<_>>());
        assert_eq!(s.assignment.len(), 8);
        for (dev, order) in s.orders.iter().enumerate() {
            for &i in order {
                assert_eq!(s.assignment[i], dev);
            }
        }
    }

    #[test]
    fn two_devices_roughly_halve_makespan() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
        // 8 tasks: duplicate the benchmark.
        let mut tasks = g.tasks.clone();
        tasks.extend(g.tasks.clone());
        let single = schedule_multi(&tasks, &[p.clone()]);
        let dual = schedule_multi(&tasks, &two_r9());
        assert!(
            dual.makespan() < 0.7 * single.makespan(),
            "dual {} vs single {}",
            dual.makespan(),
            single.makespan()
        );
    }

    #[test]
    fn beats_round_robin_on_heterogeneous_devices() {
        // R9 + Phi: placement should exploit the per-device dominance
        // flips instead of alternating blindly.
        let profiles = vec![
            profile_by_name("amd_r9").unwrap(),
            profile_by_name("xeon_phi").unwrap(),
        ];
        let p = profile_by_name("amd_r9").unwrap();
        let mut rng = Pcg64::seeded(5);
        let g = real_benchmark("BK50", "amd_r9", &p, 10, &mut rng, 1.0).unwrap();
        let smart = schedule_multi(&g.tasks, &profiles);
        let rr = round_robin(&g.tasks, &profiles);
        assert!(
            smart.makespan() <= rr.makespan() + 1e-9,
            "smart {} vs rr {}",
            smart.makespan(),
            rr.makespan()
        );
    }

    #[test]
    fn single_device_reduces_to_batch_reorder() {
        let p = profile_by_name("k20c").unwrap();
        let g = synthetic_benchmark("BK25", &p, 1.0).unwrap();
        let s = schedule_multi(&g.tasks, std::slice::from_ref(&p));
        assert_eq!(s.orders.len(), 1);
        let direct = crate::sched::heuristic::batch_reorder(
            &g.tasks,
            &p,
            EngineState::default(),
        );
        let m_direct = crate::model::simulator::makespan_of_order(&g.tasks, &direct, &p);
        assert!((s.makespan() - m_direct).abs() < 1e-2 * m_direct);
    }

    #[test]
    fn empty_group() {
        let s = schedule_multi(&[], &two_r9());
        assert_eq!(s.makespan(), 0.0);
        assert!(s.orders.iter().all(|o| o.is_empty()));
    }

    #[test]
    #[should_panic(expected = "need at least one device")]
    fn round_robin_empty_profiles_panics() {
        // Regression: used to reach `i % 0` on a non-empty task list.
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK25", &p, 1.0).unwrap();
        round_robin(&g.tasks, &[]);
    }

    #[test]
    #[should_panic(expected = "need at least one device")]
    fn schedule_multi_empty_profiles_panics() {
        schedule_multi(&[], &[]);
    }

    #[test]
    fn wrapper_matches_fleet_bitwise() {
        let p = profile_by_name("amd_r9").unwrap();
        let mut rng = Pcg64::seeded(9);
        let g = real_benchmark("BK50", "amd_r9", &p, 9, &mut rng, 1.0).unwrap();
        let profiles = vec![
            profile_by_name("amd_r9").unwrap(),
            profile_by_name("xeon_phi").unwrap(),
        ];
        let m = schedule_multi(&g.tasks, &profiles);
        let f = schedule_fleet(&g.tasks, &profiles, &FleetOptions::default());
        assert_eq!(m.assignment, f.assignment);
        assert_eq!(m.orders, f.orders);
        for (a, b) in m.device_makespans.iter().zip(&f.device_makespans) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
