//! Multi-accelerator scheduling — the paper's stated future work ("we
//! plan to integrate our heuristic and execution model in a multi-GPU
//! architecture"), built on the same temporal model.
//!
//! Two-phase schedule for a task group over D (possibly heterogeneous)
//! devices:
//!
//! 1. **Placement** — greedy earliest-completion-time: tasks are taken in
//!    descending solo-duration order (LPT, the classic makespan
//!    guarantee) and each goes to the device whose *simulated* completion
//!    time grows the least, using each device's own profile (a task can
//!    be transfer-dominant on one device and kernel-dominant on another —
//!    Table 4's DCT/FWT flips — so placement must be model-driven).
//! 2. **Ordering** — each device's sublist is reordered with the Batch
//!    Reordering heuristic.
//!
//! The group makespan is the max over devices.

use crate::config::DeviceProfile;
use crate::model::simulator::{simulate_order, simulate_order_compiled, SimCursor};
use crate::model::{EngineState, SimOptions, TaskTable};
use crate::sched::heuristic::batch_reorder;
use crate::task::TaskSpec;

/// A complete multi-device schedule.
#[derive(Clone, Debug)]
pub struct MultiSchedule {
    /// assignment[i] = device index for task i.
    pub assignment: Vec<usize>,
    /// Per-device submission order (indices into the original task slice).
    pub orders: Vec<Vec<usize>>,
    /// Predicted makespan per device.
    pub device_makespans: Vec<f64>,
}

impl MultiSchedule {
    /// Predicted group makespan (max over devices).
    pub fn makespan(&self) -> f64 {
        self.device_makespans.iter().cloned().fold(0.0, f64::max)
    }
}

/// Schedule `tasks` across `profiles` (one entry per device).
pub fn schedule_multi(tasks: &[TaskSpec], profiles: &[DeviceProfile]) -> MultiSchedule {
    assert!(!profiles.is_empty(), "need at least one device");
    let n = tasks.len();
    let d = profiles.len();

    // Compile the whole group once per device: placement scoring and the
    // final makespan checks all run over SoA rows (a task's bytes/kernel
    // row is read D times per placement step — the table makes those
    // reads contiguous and profile-resolved).
    let tables: Vec<TaskTable> =
        profiles.iter().map(|p| TaskTable::compile(tasks, p)).collect();

    // Phase 1: LPT-style greedy placement by simulated completion time.
    let mut by_size: Vec<usize> = (0..n).collect();
    by_size.sort_by(|&a, &b| {
        // Use the max solo duration across devices as the LPT key
        // (precomputed per table; total_cmp so a NaN cannot panic).
        let dur = |i: usize| -> f64 {
            tables
                .iter()
                .map(|t| t.sequential_secs(i))
                .fold(0.0, f64::max)
        };
        dur(b).total_cmp(&dur(a))
    });
    // Each device keeps a paused SimCursor over its assigned sublist;
    // scoring "append task i to device dev" is resume + push + finish on
    // a probe cursor instead of re-simulating the whole sublist from
    // scratch — O(n) incremental placement work per device instead of the
    // old O(n^2) full replays, and no allocation once probes are warm.
    let mut lists: Vec<Vec<usize>> = vec![Vec::new(); d];
    let mut device_cursors: Vec<SimCursor> = profiles
        .iter()
        .map(|p| SimCursor::new(p, EngineState::default()))
        .collect();
    let mut probe = SimCursor::detached();
    for &i in &by_size {
        let mut best_dev = 0;
        let mut best_time = f64::INFINITY;
        for dev in 0..d {
            probe.resume_from(&device_cursors[dev]);
            probe.push_task_compiled(&tables[dev], i);
            let t = probe.run_to_quiescence();
            // total_cmp, not `<`: a NaN completion time from a degenerate
            // profile must lose the placement race, never win it by
            // making every comparison false.
            if t.total_cmp(&best_time).is_lt() {
                best_time = t;
                best_dev = dev;
            }
        }
        device_cursors[best_dev].push_task_compiled(&tables[best_dev], i);
        lists[best_dev].push(i);
    }

    // Phase 2: per-device Batch Reordering.
    let mut orders = Vec::with_capacity(d);
    let mut device_makespans = Vec::with_capacity(d);
    let mut assignment = vec![0usize; n];
    for (dev, list) in lists.iter().enumerate() {
        for &i in list {
            assignment[i] = dev;
        }
        let sub: Vec<TaskSpec> = list.iter().map(|&i| tasks[i].clone()).collect();
        let local = batch_reorder(&sub, &profiles[dev], EngineState::default());
        let order: Vec<usize> = local.iter().map(|&j| list[j]).collect();
        let m = simulate_order_compiled(
            &tables[dev],
            &order,
            EngineState::default(),
            SimOptions::default(),
        )
        .makespan;
        orders.push(order);
        device_makespans.push(m);
    }
    MultiSchedule { assignment, orders, device_makespans }
}

/// Baseline: round-robin placement, arrival order per device.
pub fn round_robin(tasks: &[TaskSpec], profiles: &[DeviceProfile]) -> MultiSchedule {
    let d = profiles.len();
    let mut orders: Vec<Vec<usize>> = vec![Vec::new(); d];
    let mut assignment = vec![0usize; tasks.len()];
    for i in 0..tasks.len() {
        orders[i % d].push(i);
        assignment[i] = i % d;
    }
    let device_makespans = orders
        .iter()
        .zip(profiles)
        .map(|(order, p)| {
            simulate_order(tasks, order, p, EngineState::default(), SimOptions::default())
                .makespan
        })
        .collect();
    MultiSchedule { assignment, orders, device_makespans }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profile_by_name;
    use crate::task::real::real_benchmark;
    use crate::task::synthetic::synthetic_benchmark;
    use crate::util::rng::Pcg64;

    fn two_r9() -> Vec<DeviceProfile> {
        vec![
            profile_by_name("amd_r9").unwrap(),
            profile_by_name("amd_r9").unwrap(),
        ]
    }

    #[test]
    fn schedule_covers_every_task_exactly_once() {
        let p = profile_by_name("amd_r9").unwrap();
        let mut rng = Pcg64::seeded(1);
        let g = real_benchmark("BK50", "amd_r9", &p, 8, &mut rng, 1.0).unwrap();
        let s = schedule_multi(&g.tasks, &two_r9());
        let mut seen: Vec<usize> = s.orders.concat();
        seen.sort_unstable();
        assert_eq!(seen, (0..8).collect::<Vec<_>>());
        assert_eq!(s.assignment.len(), 8);
        for (dev, order) in s.orders.iter().enumerate() {
            for &i in order {
                assert_eq!(s.assignment[i], dev);
            }
        }
    }

    #[test]
    fn two_devices_roughly_halve_makespan() {
        let p = profile_by_name("amd_r9").unwrap();
        let g = synthetic_benchmark("BK50", &p, 1.0).unwrap();
        // 8 tasks: duplicate the benchmark.
        let mut tasks = g.tasks.clone();
        tasks.extend(g.tasks.clone());
        let single = schedule_multi(&tasks, &[p.clone()]);
        let dual = schedule_multi(&tasks, &two_r9());
        assert!(
            dual.makespan() < 0.7 * single.makespan(),
            "dual {} vs single {}",
            dual.makespan(),
            single.makespan()
        );
    }

    #[test]
    fn beats_round_robin_on_heterogeneous_devices() {
        // R9 + Phi: placement should exploit the per-device dominance
        // flips instead of alternating blindly.
        let profiles = vec![
            profile_by_name("amd_r9").unwrap(),
            profile_by_name("xeon_phi").unwrap(),
        ];
        let p = profile_by_name("amd_r9").unwrap();
        let mut rng = Pcg64::seeded(5);
        let g = real_benchmark("BK50", "amd_r9", &p, 10, &mut rng, 1.0).unwrap();
        let smart = schedule_multi(&g.tasks, &profiles);
        let rr = round_robin(&g.tasks, &profiles);
        assert!(
            smart.makespan() <= rr.makespan() + 1e-9,
            "smart {} vs rr {}",
            smart.makespan(),
            rr.makespan()
        );
    }

    #[test]
    fn single_device_reduces_to_batch_reorder() {
        let p = profile_by_name("k20c").unwrap();
        let g = synthetic_benchmark("BK25", &p, 1.0).unwrap();
        let s = schedule_multi(&g.tasks, std::slice::from_ref(&p));
        assert_eq!(s.orders.len(), 1);
        let direct = crate::sched::heuristic::batch_reorder(
            &g.tasks,
            &p,
            EngineState::default(),
        );
        let m_direct = crate::model::simulator::makespan_of_order(&g.tasks, &direct, &p);
        assert!((s.makespan() - m_direct).abs() < 1e-2 * m_direct);
    }

    #[test]
    fn empty_group() {
        let s = schedule_multi(&[], &two_r9());
        assert_eq!(s.makespan(), 0.0);
        assert!(s.orders.iter().all(|o| o.is_empty()));
    }
}
